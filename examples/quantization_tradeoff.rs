//! Quantization trade-off study on both paths:
//!   1. analytic — the paper's Table I models under every catalog quant,
//!      simulated throughput vs accuracy-admission (Fig. 6 in miniature);
//!   2. real — the tiny model's measured ΔPPL (artifacts/ppl.json) merged
//!      into the same catalog, plus live generation divergence between
//!      fp16 and W4A16 weights through the PJRT engine.
//!
//!   cargo run --release --example quantization_tradeoff

use edgellm::coordinator::Dftsp;
use edgellm::model::LlmSpec;
use edgellm::quant;
use edgellm::runtime::{artifacts_available, Engine};
use edgellm::sim::{self, SimConfig};
use edgellm::util::fmt::Table;
use edgellm::util::json::Json;
use std::path::PathBuf;

fn main() {
    // ---- analytic sweep (paper models) --------------------------------
    let mut table = Table::new(&[
        "model",
        "quant",
        "dPPL",
        "throughput (req/s)",
        "dropped %",
    ]);
    for model in [LlmSpec::bloom_3b(), LlmSpec::bloom_7b()] {
        for q in quant::catalog() {
            let cfg = SimConfig {
                model: model.clone(),
                quant: q.clone(),
                epochs: 15,
                seed: 99,
                ..SimConfig::paper_default()
            };
            let m = sim::run(&cfg, &mut Dftsp::new());
            table.row(&[
                model.name.clone(),
                q.label(),
                format!("{:.2}", q.dppl_for(&model.name)),
                format!("{:.2}", m.throughput()),
                format!("{:.1}", 100.0 * m.dropped as f64 / m.offered.max(1) as f64),
            ]);
        }
    }
    println!("analytic sweep (λ=50 req/s, accuracy req ~ U[0,1]):");
    print!("{}", table.render());

    // ---- measured dPPL for the tiny real model ------------------------
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let ppl_path = dir.join("ppl.json");
    if let Ok(src) = std::fs::read_to_string(&ppl_path) {
        let j = Json::parse(&src).expect("ppl.json parses");
        println!(
            "\nmeasured PPL of {} (base {:.2}):",
            j.req_str("model").unwrap_or("?"),
            j.req_f64("base_ppl").unwrap_or(f64::NAN)
        );
        let mut t = Table::new(&["variant", "PPL", "dPPL", "admits a<=f(dPPL)"]);
        if let Some(entries) = j.get("entries").and_then(|e| e.as_arr()) {
            for e in entries {
                let dppl = e.req_f64("dppl").unwrap_or(f64::NAN);
                t.row(&[
                    e.req_str("label").unwrap_or("?").to_string(),
                    format!("{:.3}", e.req_f64("ppl").unwrap_or(f64::NAN)),
                    format!("{:.4}", dppl),
                    format!("a <= {:.2}", quant::f_accuracy(dppl)),
                ]);
            }
        }
        print!("{}", t.render());
    } else {
        println!("\n(ppl.json not built — run `make artifacts` for measured dPPL)");
    }

    // ---- live divergence through PJRT ---------------------------------
    if artifacts_available(&dir) {
        let fp = Engine::load_with_variants(&dir, "W16A16", &[1]).expect("fp engine");
        let w4 = Engine::load_with_variants(&dir, "W4A16/ZQ-Local", &[1]).expect("w4 engine");
        let prompt = vec![(0..24).map(|i| (i * 13) % 512).collect::<Vec<i32>>()];
        let (lf, _) = fp.prefill(&prompt).unwrap();
        let (lq, _) = w4.prefill(&prompt).unwrap();
        let max_diff = lf[0]
            .iter()
            .zip(lq[0].iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        let gf = fp.generate_greedy(&prompt, 10, None).unwrap();
        let gq = w4.generate_greedy(&prompt, 10, None).unwrap();
        println!("\nlive PJRT check: max |logit(fp16) − logit(W4A16)| = {max_diff:.4}");
        println!("  fp16 tokens:  {:?}", gf[0]);
        println!("  W4A16 tokens: {:?}", gq[0]);
    } else {
        println!("\n(artifacts not built — skipping live PJRT check)");
    }
}
