//! Quickstart: schedule one epoch of synthetic requests with DFTSP and the
//! two baselines, printing who got scheduled and why.
//!
//!   cargo run --release --example quickstart

use edgellm::cluster::ClusterSpec;
use edgellm::coordinator::{
    Dftsp, EpochParams, NoBatching, ProblemInstance, Scheduler, StaticBatching,
};
use edgellm::model::{CostModel, LlmSpec};
use edgellm::quant;
use edgellm::request::{EpochRequest, RequestBuilder};
use edgellm::util::fmt::Table;
use edgellm::util::rng::Rng;
use edgellm::wireless::{ChannelParams, RadioParams};

fn main() {
    // The paper's default deployment: BLOOM-3B, W8A16, 20 Jetson TX2s.
    let inst = ProblemInstance::new(
        CostModel::new(LlmSpec::bloom_3b()),
        quant::default_quant(),
        ClusterSpec::paper_default(),
        EpochParams::default(),
        512,
        0.0,
    );

    // 32 synthetic requests in the paper's §IV distributions.
    let mut rng = Rng::new(42);
    let mut builder = RequestBuilder::new();
    let radio = RadioParams::default();
    let channel = ChannelParams::default();
    let levels = [128u32, 256, 512];
    let requests: Vec<EpochRequest> = (0..32)
        .map(|_| {
            let req = builder.build(
                -rng.uniform(0.0, 2.0), // arrived during the previous epoch
                *rng.choice(&levels),
                *rng.choice(&levels),
                rng.uniform(0.5, 2.0),
                rng.uniform(0.0, 1.0),
            );
            let h = channel.draw_h(&mut rng);
            EpochRequest::annotate(req, h, &radio, inst.epoch.t_u, inst.epoch.t_d)
        })
        .collect();

    println!(
        "epoch 0: {} candidate requests, model {}, quant {} (alpha {:.2}, beta {:.2})\n",
        requests.len(),
        inst.cost.spec.name,
        inst.quant.label(),
        inst.quant.alpha,
        inst.quant.beta,
    );

    let mut table = Table::new(&[
        "scheduler",
        "batch",
        "compute time (s)",
        "uplink used",
        "downlink used",
        "nodes visited",
    ]);
    let mut schedulers: Vec<Box<dyn Scheduler>> = vec![
        Box::new(Dftsp::new()),
        Box::new(StaticBatching::new()),
        Box::new(NoBatching::new()),
    ];
    for s in schedulers.iter_mut() {
        let sched = s.schedule(&inst, &requests);
        table.row(&[
            s.name().to_string(),
            sched.batch_size().to_string(),
            format!("{:.3}", sched.compute_time),
            format!("{:.3}", sched.rho_u_total),
            format!("{:.3}", sched.rho_d_total),
            sched.stats.nodes_visited.to_string(),
        ]);
    }
    print!("{}", table.render());

    // Show DFTSP's chosen set in detail.
    let sched = Dftsp::new().schedule(&inst, &requests);
    println!("\nDFTSP selected {} requests:", sched.batch_size());
    for r in &requests {
        if sched.scheduled.contains(&r.id()) {
            println!(
                "  req {:>2}: s={:>3} n={:>3} tau={:.2}s a={:.2} rho_u={:.5}",
                r.id(),
                r.req.prompt_tokens,
                r.req.output_tokens,
                r.req.latency_req,
                r.req.accuracy_req,
                r.rho_min_u
            );
        }
    }
}
