//! Trace record + deterministic replay: generate a Poisson workload trace,
//! persist it to JSONL, replay it through two simulator runs and diff the
//! schedules — byte-identical metrics prove the whole stack is reproducible.
//!
//!   cargo run --release --example trace_replay

use edgellm::coordinator::Dftsp;
use edgellm::sim::{self, SimConfig};
use edgellm::workload::{trace, WorkloadGenerator, WorkloadParams};

fn main() {
    // 1. Record a trace.
    let params = WorkloadParams {
        arrival_rate: 60.0,
        ..Default::default()
    };
    let mut gen = WorkloadGenerator::new(params.clone(), 2024);
    let requests = gen.arrivals_between(0.0, 30.0);
    let path = std::env::temp_dir().join("edgellm_trace.jsonl");
    trace::save(&path, &requests).expect("save trace");
    println!("recorded {} requests to {:?}", requests.len(), path);

    // 2. Replay it twice through the simulator (same seed => same channel
    //    draws) and compare.
    let cfg = SimConfig {
        workload: params,
        epochs: 15,
        seed: 2024,
        ..SimConfig::paper_default()
    };
    let run1 = sim::run(&cfg, &mut Dftsp::new());
    let run2 = sim::run(&cfg, &mut Dftsp::new());

    println!("\nrun 1:\n{}", run1.report("DFTSP replay #1"));
    println!("run 2:\n{}", run2.report("DFTSP replay #2"));

    assert_eq!(run1.offered, run2.offered);
    assert_eq!(run1.completed_in_deadline, run2.completed_in_deadline);
    assert_eq!(run1.scheduled, run2.scheduled);
    assert_eq!(run1.search.nodes_visited, run2.search.nodes_visited);
    println!("replays identical: OK");

    // 3. Reload the trace from disk and verify integrity.
    let loaded = trace::load(&path).expect("load trace");
    assert_eq!(loaded.len(), requests.len());
    for (a, b) in requests.iter().zip(loaded.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.prompt_tokens, b.prompt_tokens);
    }
    println!("trace round-trip: OK ({} requests)", loaded.len());
    std::fs::remove_file(&path).ok();
}
