//! END-TO-END DRIVER: serve batched requests against the *real* tiny
//! transformer through the PJRT runtime, with DFTSP admission/batching, and
//! report latency/throughput. This is the whole stack composing:
//!
//!   clients → epoch server (L3, Rust) → DFTSP schedule → PJRT engine
//!     → AOT HLO (L2 JAX graphs) → Pallas attention (L1) → tokens back
//!
//! Requires `make artifacts`. Results are recorded in EXPERIMENTS.md.
//!
//!   cargo run --release --example edge_serving [-- --epochs 12 --rate 6]

use edgellm::coordinator::Dftsp;
use edgellm::runtime::{artifacts_available, Engine};
use edgellm::serving::{EpochServer, ServeOutcome, ServeRequest, ServerConfig};
use edgellm::util::cli::Args;
use edgellm::util::fmt;
use edgellm::util::rng::Rng;
use edgellm::util::stats::percentile;
use std::path::PathBuf;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let epochs = args.u64_or("epochs", 12);
    let rate = args.f64_or("rate", 6.0);
    let clients = args.u64_or("clients", 3);
    let quant = args.str_or("quant", "W8A16/RTN");

    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts_available(&dir) {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let engine = Engine::load(&dir, &quant).expect("engine load");
    println!(
        "loaded {} ({} params order entries) on {}, quant {}",
        engine.meta.model_name,
        engine.meta.param_order.len(),
        engine.platform(),
        quant
    );

    let cfg = ServerConfig::default();
    let epoch_s = cfg.epoch.duration;
    let mut server = EpochServer::new(engine, cfg, Box::new(Dftsp::new()));
    let handle = server.handle();

    let horizon = epochs as f64 * epoch_s;
    println!(
        "serving {epochs} epochs × {epoch_s}s with {clients} clients at ~{rate} req/s total\n"
    );

    let joins: Vec<_> = (0..clients)
        .map(|c| {
            let tx = handle.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(0xE2E ^ (c * 104729));
                let (rtx, rrx) = std::sync::mpsc::channel();
                let mut submitted = 0u64;
                let t0 = std::time::Instant::now();
                while t0.elapsed().as_secs_f64() < horizon - 2.0 * epoch_s {
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        rng.exponential(rate / clients as f64).min(1.0),
                    ));
                    let plen = rng.int_range(4, 48) as usize;
                    let prompt: Vec<i32> = (0..plen).map(|_| rng.below(512) as i32).collect();
                    tx.send(ServeRequest {
                        prompt,
                        output_tokens: rng.int_range(4, 24) as u32,
                        latency_req: rng.uniform(1.0, 4.0),
                        accuracy_req: rng.uniform(0.0, 0.6),
                        respond: rtx.clone(),
                    })
                    .ok();
                    submitted += 1;
                }
                drop(rtx);
                let responses: Vec<_> = rrx.iter().collect();
                (submitted, responses)
            })
        })
        .collect();

    server.run_for(epochs);
    println!("{}", server.metrics().report("edge_serving (DFTSP over the runtime engine)"));

    let mut latencies = Vec::new();
    let mut completed = 0u64;
    let mut late = 0u64;
    let mut rejected = 0u64;
    let mut submitted = 0u64;
    let mut sample_tokens: Option<Vec<i32>> = None;
    for j in joins {
        let (sent, responses) = j.join().expect("client join");
        submitted += sent;
        for r in responses {
            match r.outcome {
                ServeOutcome::Completed => {
                    completed += 1;
                    latencies.push(r.latency);
                    if sample_tokens.is_none() && !r.tokens.is_empty() {
                        sample_tokens = Some(r.tokens.clone());
                    }
                }
                ServeOutcome::CompletedLate => late += 1,
                ServeOutcome::Rejected => rejected += 1,
            }
        }
    }
    println!("client view: submitted {submitted}, completed {completed}, late {late}, rejected {rejected}");
    if !latencies.is_empty() {
        println!(
            "client latency: p50 {}  p95 {}  max {}",
            fmt::duration(percentile(&latencies, 50.0)),
            fmt::duration(percentile(&latencies, 95.0)),
            fmt::duration(percentile(&latencies, 100.0)),
        );
        println!(
            "throughput (client-observed): {:.2} req/s over {horizon:.1}s",
            completed as f64 / horizon
        );
    }
    if let Some(toks) = sample_tokens {
        println!("sample generated tokens: {:?}", &toks[..toks.len().min(12)]);
    }
    assert!(completed > 0, "end-to-end run must complete some requests");
    println!("\nEND-TO-END OK: all three layers composed on the request path.");
}
