//! Figure 6 reproduction — throughput under different quantization methods.
//!
//! Fig. 6(a): accuracy requirements ignored; throughput vs precision
//! (W16A16 / W8A16 / W4A16) for the three Table I models — lower precision
//! frees memory (α) and compute (β), raising throughput; larger models
//! serve fewer requests.
//! Fig. 6(b): accuracy constraint active; throughput vs the users' accuracy
//! requirement ceiling for GPTQ vs ZQ-Local at W4A16, with the W8A16
//! default as the paper's dotted reference line.
//!
//! Run: cargo bench --bench fig6_quantization

use edgellm::coordinator::Dftsp;
use edgellm::model::LlmSpec;
use edgellm::quant::{self, Precision, QuantAlgo, QuantSpec};
use edgellm::sim::{self, SimConfig};
use edgellm::util::fmt::Table;
use edgellm::workload::WorkloadParams;

fn epochs() -> usize {
    std::env::var("EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(15)
}

fn run_one(model: &LlmSpec, q: &QuantSpec, accuracy: (f64, f64)) -> f64 {
    let cfg = SimConfig {
        model: model.clone(),
        quant: q.clone(),
        workload: WorkloadParams {
            arrival_rate: 60.0,
            accuracy_range: accuracy,
            ..Default::default()
        },
        epochs: epochs(),
        seed: 77,
        ..SimConfig::paper_default()
    };
    sim::run(&cfg, &mut Dftsp::new()).throughput()
}

fn fig6a() {
    println!("== Fig. 6(a): throughput (req/s) vs precision, accuracy requirements ignored ==");
    let quants = [
        QuantSpec::fp16(),
        quant::by_label(Precision::W8A16, QuantAlgo::Gptq).unwrap(),
        quant::by_label(Precision::W4A16, QuantAlgo::Gptq).unwrap(),
    ];
    let mut t = Table::new(&["model", "W16A16", "W8A16", "W4A16"]);
    for model in LlmSpec::catalog() {
        let vals: Vec<String> = quants
            .iter()
            .map(|q| format!("{:.2}", run_one(&model, q, (0.0, 0.0))))
            .collect();
        t.row(&[model.name.clone(), vals[0].clone(), vals[1].clone(), vals[2].clone()]);
    }
    print!("{}", t.render());
}

fn fig6b() {
    println!("\n== Fig. 6(b): throughput (req/s) vs accuracy requirement ceiling (BLOOM-3B) ==");
    println!("   users draw a_i ~ U[0, ceiling]; larger ceiling = stricter population");
    let w8 = quant::by_label(Precision::W8A16, QuantAlgo::Gptq).unwrap();
    let gptq = quant::by_label(Precision::W4A16, QuantAlgo::Gptq).unwrap();
    let zq = quant::by_label(Precision::W4A16, QuantAlgo::ZqLocal).unwrap();
    let model = LlmSpec::bloom_3b();
    let mut t = Table::new(&[
        "accuracy ceiling",
        "W4A16/GPTQ",
        "W4A16/ZQ-Local",
        "W8A16 (dotted ref)",
    ]);
    for ceil in [0.05, 0.1, 0.25, 0.5, 0.75, 1.0] {
        t.row(&[
            format!("{ceil:.2}"),
            format!("{:.2}", run_one(&model, &gptq, (0.0, ceil))),
            format!("{:.2}", run_one(&model, &zq, (0.0, ceil))),
            format!("{:.2}", run_one(&model, &w8, (0.0, ceil))),
        ]);
    }
    print!("{}", t.render());
    println!(
        "(f(dPPL): GPTQ admits a <= {:.2}, ZQ-Local a <= {:.2}, W8A16 a <= {:.2} on BLOOM-3B)",
        quant::f_accuracy(gptq.dppl_for("BLOOM-3B")),
        quant::f_accuracy(zq.dppl_for("BLOOM-3B")),
        quant::f_accuracy(w8.dppl_for("BLOOM-3B")),
    );
}

fn main() {
    let t0 = std::time::Instant::now();
    fig6a();
    fig6b();
    println!(
        "\nfig6 bench completed in {:.1}s ({} epochs per point)",
        t0.elapsed().as_secs_f64(),
        epochs()
    );
}
