//! Table III reproduction — algorithm time reduction with tree-pruning.
//!
//! The paper reports the complexity reduction of DFTSP (pruned depth-first
//! tree search) vs brute-force tree search at arrival rates 10/50/100/200
//! req/s: 45.52% / 71.18% / 79.07% / 97.92%. We count *visited tree nodes*
//! across an identical simulated horizon for both searchers and report
//! 1 − nodes(DFTSP)/nodes(brute). When the brute-force search trips its node
//! budget the reduction is a lower bound (marked ">=").
//!
//! Run: cargo bench --bench table3_pruning

use edgellm::coordinator::{BruteForce, Dftsp};
use edgellm::sim::{self, SimConfig};
use edgellm::util::fmt::Table;
use edgellm::workload::WorkloadParams;

fn epochs() -> usize {
    std::env::var("EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(6)
}

fn main() {
    let t0 = std::time::Instant::now();
    println!("== Table III: node-visit reduction of DFTSP vs brute-force tree search ==");
    let rates = [10.0, 50.0, 100.0, 200.0];
    let mut table = Table::new(&[
        "arrival rate (req/s)",
        "brute-force nodes",
        "DFTSP nodes",
        "reduction",
        "paper",
    ]);
    let paper = ["45.52%", "71.18%", "79.07%", "97.92%"];
    for (i, &rate) in rates.iter().enumerate() {
        let cfg = SimConfig {
            workload: WorkloadParams {
                arrival_rate: rate,
                ..Default::default()
            },
            epochs: epochs(),
            seed: 77,
            ..SimConfig::paper_default()
        };
        let d = sim::run(&cfg, &mut Dftsp::new());
        let b = sim::run(&cfg, &mut BruteForce::with_budget(20_000_000));
        let dn = d.search.nodes_visited;
        let bn = b.search.nodes_visited;
        let reduction = 1.0 - dn as f64 / bn.max(1) as f64;
        table.row(&[
            format!("{rate:.0}"),
            format!(
                "{}{}",
                bn,
                if b.search.budget_exhausted { " (budget)" } else { "" }
            ),
            dn.to_string(),
            format!(
                "{}{:.2}%",
                if b.search.budget_exhausted { ">= " } else { "" },
                100.0 * reduction
            ),
            paper[i].to_string(),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\ntable3 bench completed in {:.1}s ({} epochs per point)",
        t0.elapsed().as_secs_f64(),
        epochs()
    );
}
