//! §Perf micro-benchmarks — the L3 scheduler hot path and (when artifacts
//! exist) the PJRT runtime request path. The before/after iteration log
//! lives in EXPERIMENTS.md §Perf.
//!
//! Run: cargo bench --bench perf_hotpath

use edgellm::cluster::ClusterSpec;
use edgellm::coordinator::{
    Dftsp, EpochParams, FeasibilityChecker, ProblemInstance, Scheduler,
};
use edgellm::coordinator::tree::{build_levels, suffix_capacity};
use edgellm::model::{CostModel, LlmSpec};
use edgellm::quant;
use edgellm::request::{EpochRequest, RequestBuilder};
use edgellm::runtime::{artifacts_available, Engine};
use edgellm::util::bench::{black_box, Bencher};
use edgellm::util::rng::Rng;
use edgellm::wireless::{ChannelParams, RadioParams};
use std::path::PathBuf;

fn paper_inst() -> ProblemInstance {
    ProblemInstance::new(
        CostModel::new(LlmSpec::bloom_3b()),
        quant::default_quant(),
        ClusterSpec::paper_default(),
        EpochParams::default(),
        512,
        0.0,
    )
}

fn random_requests(n: usize, seed: u64) -> Vec<EpochRequest> {
    let mut rng = Rng::new(seed);
    let mut b = RequestBuilder::new();
    let radio = RadioParams::default();
    let channel = ChannelParams::default();
    let levels = [128u32, 256, 512];
    (0..n)
        .map(|_| {
            let req = b.build(
                -rng.uniform(0.0, 2.0),
                *rng.choice(&levels),
                *rng.choice(&levels),
                rng.uniform(0.5, 2.0),
                rng.uniform(0.0, 1.0),
            );
            let h = channel.draw_h(&mut rng);
            EpochRequest::annotate(req, h, &radio, 0.25, 0.25)
        })
        .collect()
}

fn scheduler_benches(bench: &Bencher) {
    let inst = paper_inst();
    for n in [32usize, 128, 512] {
        let reqs = random_requests(n, 42);
        let r = bench.run(&format!("dftsp/schedule/n={n}"), || {
            let s = Dftsp::new().schedule(black_box(&inst), black_box(&reqs));
            black_box(s.batch_size());
        });
        println!("{}", r.report());
    }

    let reqs = random_requests(256, 43);
    let subset: Vec<&EpochRequest> = reqs.iter().take(64).collect();
    let checker = FeasibilityChecker::new(&inst);
    let r = bench.run("feasibility/check/64", || {
        black_box(checker.check(black_box(&subset)).is_ok());
    });
    println!("{}", r.report());

    let pool: Vec<&EpochRequest> = reqs.iter().collect();
    let r = bench.run("tree/build_levels/256", || {
        let levels = build_levels(black_box(&inst), black_box(&pool));
        black_box(suffix_capacity(&levels).len());
    });
    println!("{}", r.report());
}

fn runtime_benches(bench: &Bencher) {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts_available(&dir) {
        println!("(artifacts/ not built — skipping runtime benches)");
        return;
    }
    let engine = Engine::load_with_variants(&dir, "W16A16", &[1, 4]).expect("engine");
    let prompts4: Vec<Vec<i32>> = (0..4)
        .map(|i| (0..32).map(|t| (t * 7 + i * 13) % 512).collect())
        .collect();
    let r = bench.run("runtime/prefill/b4/s32", || {
        let (l, c) = engine.prefill(black_box(&prompts4)).unwrap();
        black_box((l.len(), c.active));
    });
    println!("{}", r.report());

    let (logits, mut cache) = engine.prefill(&prompts4).unwrap();
    let tokens: Vec<i32> = logits.iter().map(|l| edgellm::runtime::argmax(l)).collect();
    let r = bench.run("runtime/decode_step/b4", || {
        // NOTE decode mutates cache position; rebuild when the cache fills.
        if cache.pos.iter().any(|&p| p as usize >= engine.meta.max_seq) {
            let (_, c) = engine.prefill(&prompts4).unwrap();
            cache = c;
        }
        let l = engine.decode(black_box(&tokens), &mut cache).unwrap();
        black_box(l.len());
    });
    println!("{}", r.report());

    let one = vec![prompts4[0].clone()];
    let r = bench.run("runtime/generate_greedy/b1/8tok", || {
        let g = engine.generate_greedy(black_box(&one), 8, None).unwrap();
        black_box(g[0].len());
    });
    println!("{}", r.report());
}

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let bench = if quick { Bencher::quick() } else { Bencher::default() };
    println!("== L3 scheduler hot path ==");
    scheduler_benches(&bench);
    println!("\n== PJRT runtime request path ==");
    runtime_benches(&bench);
}
