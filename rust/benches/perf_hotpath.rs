//! §Perf micro-benchmarks — the L3 scheduler hot path and (when artifacts
//! exist) the PJRT runtime request path. The before/after iteration log
//! lives in EXPERIMENTS.md §Perf.
//!
//! Run: cargo bench --bench perf_hotpath [-- --quick] [-- --json]
//!
//! `--json` (or JSON=1) additionally writes the tracked baseline
//! `BENCH_dftsp.json` at the repository root: the {256, 1024, 4096} ×
//! {epoch, continuous} DFTSP scenario matrix with schedule latency and the
//! deterministic search-effort counters (nodes visited, leaves checked,
//! leaf-check work, prunes). CI's bench-smoke job runs exactly this and
//! uploads the file as an artifact, so the bench trajectory is tracked
//! commit-over-commit. `--quick` (or QUICK=1) shortens warmup/samples.

use edgellm::cluster::ClusterSpec;
use edgellm::coordinator::{
    Dftsp, EpochParams, FeasibilityChecker, ProblemInstance, Scheduler,
};
use edgellm::coordinator::tree::{build_levels, suffix_capacity};
use edgellm::model::{CostModel, LlmSpec};
use edgellm::quant;
use edgellm::request::{EpochRequest, RequestBuilder};
use edgellm::runtime::{artifacts_available, Engine};
use edgellm::util::bench::{black_box, BenchSuite, Bencher};
use edgellm::util::json::Json;
use edgellm::util::rng::Rng;
use edgellm::wireless::{ChannelParams, RadioParams};
use std::path::PathBuf;

/// Paper Table I instance at an epoch boundary (`now = 0`).
fn paper_inst() -> ProblemInstance {
    inst_at(0.0)
}

fn inst_at(now: f64) -> ProblemInstance {
    ProblemInstance::new(
        CostModel::new(LlmSpec::bloom_3b()),
        quant::default_quant(),
        ClusterSpec::paper_default(),
        EpochParams::default(),
        512,
        now,
    )
}

fn random_requests(n: usize, seed: u64) -> Vec<EpochRequest> {
    let mut rng = Rng::new(seed);
    let mut b = RequestBuilder::new();
    let radio = RadioParams::default();
    let channel = ChannelParams::default();
    let levels = [128u32, 256, 512];
    (0..n)
        .map(|_| {
            let req = b.build(
                -rng.uniform(0.0, 2.0),
                *rng.choice(&levels),
                *rng.choice(&levels),
                rng.uniform(0.5, 2.0),
                rng.uniform(0.0, 1.0),
            );
            let h = channel.draw_h(&mut rng);
            EpochRequest::annotate(req, h, &radio, 0.25, 0.25)
        })
        .collect()
}

/// The tracked scenario matrix: candidate-pool sizes × invocation contexts.
/// "epoch" schedules at the boundary (`now = 0`, the paper's protocol);
/// "continuous" schedules mid-epoch (`now = 0.6`, a decode-step boundary —
/// since PR 2 the continuous backend invokes the scheduler at that
/// granularity, with 0.6 s less slack across the same queue).
fn scheduler_scenarios(bench: &Bencher, suite: &mut BenchSuite) {
    for (mode, now) in [("epoch", 0.0), ("continuous", 0.6)] {
        for n in [256usize, 1024, 4096] {
            let inst = inst_at(now);
            let reqs = random_requests(n, 42);
            let name = format!("dftsp/{mode}/n={n}");
            let r = bench.run(&name, || {
                let s = Dftsp::new().schedule(black_box(&inst), black_box(&reqs));
                black_box(s.batch_size());
            });
            println!("{}", r.report());
            // One counted run for the deterministic search-effort columns.
            let sched = Dftsp::new().schedule(&inst, &reqs);
            let st = &sched.stats;
            suite.push(Json::obj(vec![
                ("scenario", Json::Str(name)),
                ("mode", Json::Str(mode.to_string())),
                ("candidates", Json::Num(n as f64)),
                ("admissible", Json::Num(inst.admissible(&reqs).len() as f64)),
                ("batch_size", Json::Num(sched.batch_size() as f64)),
                ("nodes_visited", Json::Num(st.nodes_visited as f64)),
                ("leaves_checked", Json::Num(st.solutions_checked as f64)),
                ("leaf_check_work", Json::Num(st.leaf_check_work as f64)),
                ("pruned_capacity", Json::Num(st.pruned_capacity as f64)),
                ("pruned_constraint", Json::Num(st.pruned_constraint as f64)),
                ("pruned_reuse", Json::Num(st.pruned_reuse as f64)),
                ("z_levels_skipped", Json::Num(st.z_levels_skipped as f64)),
                ("subproblems", Json::Num(st.subproblems as f64)),
                ("wall_mean_s", Json::Num(r.mean)),
                ("wall_median_s", Json::Num(r.median)),
                ("wall_p95_s", Json::Num(r.p95)),
                ("iters", Json::Num(r.iters as f64)),
            ]));
        }
    }
}

fn scheduler_microbenches(bench: &Bencher) {
    let inst = paper_inst();
    let reqs = random_requests(256, 43);
    let subset: Vec<&EpochRequest> = reqs.iter().take(64).collect();
    let checker = FeasibilityChecker::new(&inst);
    let r = bench.run("feasibility/check/64", || {
        black_box(checker.check(black_box(&subset)).is_ok());
    });
    println!("{}", r.report());

    let pool: Vec<&EpochRequest> = reqs.iter().collect();
    let r = bench.run("tree/build_levels/256", || {
        let levels = build_levels(black_box(&inst), black_box(&pool));
        black_box(suffix_capacity(&levels).len());
    });
    println!("{}", r.report());
}

fn runtime_benches(bench: &Bencher) {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts_available(&dir) {
        println!("(artifacts/ not built — skipping runtime benches)");
        return;
    }
    let engine = Engine::load_with_variants(&dir, "W16A16", &[1, 4]).expect("engine");
    let prompts4: Vec<Vec<i32>> = (0..4)
        .map(|i| (0..32).map(|t| (t * 7 + i * 13) % 512).collect())
        .collect();
    let r = bench.run("runtime/prefill/b4/s32", || {
        let (l, c) = engine.prefill(black_box(&prompts4)).unwrap();
        black_box((l.len(), c.active));
    });
    println!("{}", r.report());

    let (logits, mut cache) = engine.prefill(&prompts4).unwrap();
    let tokens: Vec<i32> = logits.iter().map(|l| edgellm::runtime::argmax(l)).collect();
    let r = bench.run("runtime/decode_step/b4", || {
        // NOTE decode mutates cache position; rebuild when the cache fills.
        if cache.pos.iter().any(|&p| p as usize >= engine.meta.max_seq) {
            let (_, c) = engine.prefill(&prompts4).unwrap();
            cache = c;
        }
        let l = engine.decode(black_box(&tokens), &mut cache).unwrap();
        black_box(l.len());
    });
    println!("{}", r.report());

    let one = vec![prompts4[0].clone()];
    let r = bench.run("runtime/generate_greedy/b1/8tok", || {
        let g = engine.generate_greedy(black_box(&one), 8, None).unwrap();
        black_box(g[0].len());
    });
    println!("{}", r.report());
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = std::env::var("QUICK").is_ok() || args.iter().any(|a| a == "--quick");
    let json = std::env::var("JSON").is_ok() || args.iter().any(|a| a == "--json");
    let bench = if quick { Bencher::quick() } else { Bencher::default() };

    println!("== L3 scheduler hot path ==");
    let mut suite = BenchSuite::new();
    scheduler_scenarios(&bench, &mut suite);
    scheduler_microbenches(&bench);

    if json {
        // CARGO_MANIFEST_DIR = rust/; the tracked baseline lives at the
        // repository root next to EXPERIMENTS.md.
        let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_dftsp.json");
        suite
            .write(
                &path,
                "cargo bench --bench perf_hotpath -- --json (QUICK=1 / --quick for the smoke profile)",
            )
            .expect("write BENCH_dftsp.json");
        println!("wrote {} scenario rows to {}", suite.len(), path.display());
    }

    println!("\n== PJRT runtime request path ==");
    runtime_benches(&bench);
}
