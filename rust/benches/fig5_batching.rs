//! Figure 5 reproduction — throughput under different batching schemes.
//!
//! Fig. 5(a): throughput vs arrival rate (paper: 5–250 req/s), DFTSP vs
//! StB vs NoB, for BLOOM-3B and BLOOM-7.1B at the default W8A16.
//! Fig. 5(b): throughput vs user latency requirement window.
//!
//! Absolute values differ from the paper (the testbed is an analytic
//! simulator, and the paper's own epoch/deadline settings bound goodput);
//! the *shape* — DFTSP on top, saturation with rate, 3B above 7.1B, more
//! lenient deadlines helping — is the reproduction target.
//!
//! Run: cargo bench --bench fig5_batching  (optionally EPOCHS=30)

use edgellm::coordinator::{Dftsp, NoBatching, Scheduler, StaticBatching};
use edgellm::model::LlmSpec;
use edgellm::sim::{self, SimConfig};
use edgellm::util::fmt::Table;
use edgellm::workload::WorkloadParams;

fn epochs() -> usize {
    std::env::var("EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(15)
}

fn run_one(model: &LlmSpec, rate: f64, latency: (f64, f64), sched: &mut dyn Scheduler) -> f64 {
    let cfg = SimConfig {
        model: model.clone(),
        workload: WorkloadParams {
            arrival_rate: rate,
            latency_range: latency,
            ..Default::default()
        },
        epochs: epochs(),
        seed: 77,
        ..SimConfig::paper_default()
    };
    sim::run(&cfg, sched).throughput()
}

fn fig5a() {
    println!("== Fig. 5(a): throughput (req/s) vs arrival rate, tau ~ U[0.5, 2] s ==");
    let rates = [5.0, 10.0, 25.0, 50.0, 100.0, 150.0, 200.0, 250.0];
    for model in [LlmSpec::bloom_3b(), LlmSpec::bloom_7b()] {
        let mut t = Table::new(&["arrival rate", "DFTSP", "StB", "NoB"]);
        for &r in &rates {
            t.row(&[
                format!("{r:.0}"),
                format!("{:.2}", run_one(&model, r, (0.5, 2.0), &mut Dftsp::new())),
                format!(
                    "{:.2}",
                    run_one(&model, r, (0.5, 2.0), &mut StaticBatching::new())
                ),
                format!("{:.2}", run_one(&model, r, (0.5, 2.0), &mut NoBatching::new())),
            ]);
        }
        println!("\n[{}]", model.name);
        print!("{}", t.render());
    }
}

fn fig5b() {
    println!("\n== Fig. 5(b): throughput (req/s) vs latency requirement, rate = 60 req/s ==");
    // The paper sweeps the users' latency requirement; we sweep the upper
    // edge of the U[tau/2, tau] window.
    let taus = [0.5, 1.0, 1.5, 2.0, 3.0, 4.0];
    for model in [LlmSpec::bloom_3b(), LlmSpec::bloom_7b()] {
        let mut t = Table::new(&["tau_hi (s)", "DFTSP", "StB", "NoB"]);
        for &tau in &taus {
            let window = (0.5 * tau, tau);
            t.row(&[
                format!("{tau:.1}"),
                format!("{:.2}", run_one(&model, 60.0, window, &mut Dftsp::new())),
                format!(
                    "{:.2}",
                    run_one(&model, 60.0, window, &mut StaticBatching::new())
                ),
                format!("{:.2}", run_one(&model, 60.0, window, &mut NoBatching::new())),
            ]);
        }
        println!("\n[{}]", model.name);
        print!("{}", t.render());
    }
}

fn main() {
    let t0 = std::time::Instant::now();
    fig5a();
    fig5b();
    println!(
        "\nfig5 bench completed in {:.1}s ({} epochs per point)",
        t0.elapsed().as_secs_f64(),
        epochs()
    );
}
