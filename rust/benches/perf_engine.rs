//! §Perf micro-benchmarks — the host engine request path: the
//! {B=1,8,32} × {f32, W8A16, W8A8, W8A8KV8} × {prefill, decode} scenario
//! matrix, the retained per-sequence reference decode as the before/after
//! baseline, and the tiled-vs-reference kernel matrix
//! (kernel/{f32,w8a16,w8a8}/{tiled,ref}) that isolates the cache-blocked
//! matmul rework from the rest of the engine.
//! The iteration log lives in EXPERIMENTS.md §Engine.
//!
//! Run: cargo bench --bench perf_engine [-- --quick] [-- --json]
//!
//! `--json` (or JSON=1) additionally writes the tracked baseline
//! `BENCH_engine.json` at the repository root: per scenario the wall/
//! throughput columns plus the deterministic columns — nominal FLOPs per
//! call (closed form below, mirrored by python/engine_mirror.py) and the
//! tracked allocations per decode step (scratch growth + KV-arena growth
//! events; 0 in steady state by construction). CI's bench-smoke job runs
//! exactly this and uploads the file, so the engine trajectory is tracked
//! commit-over-commit. `--quick` (or QUICK=1) shortens warmup/samples.

// The synthetic-engine scenario matrix exercises the host engine's batched
// decode and quantized kernels; the pjrt engine has neither, so this bench
// is a no-op stub under `--features pjrt`.
#[cfg(not(feature = "pjrt"))]
mod host_bench {
    use edgellm::quant::Precision;
    use edgellm::runtime::kernels::{
        matmul_f32_into, matmul_f32_tiled_into, matmul_w8a16_into, matmul_w8a16_tiled_into,
        matmul_w8a8_into, matmul_w8a8_tiled_into, pack_codes_col_blocked, quantize_per_tensor_i8,
    };
    use edgellm::runtime::{argmax, Engine, SyntheticSpec};
    use edgellm::util::bench::{black_box, BenchSuite, Bencher};
    use edgellm::util::json::Json;
    use std::path::PathBuf;

    const BATCHES: [usize; 3] = [1, 8, 32];
    const PROMPT_LEN: usize = 48;

    /// Kernel-matrix shape: a decode-sized GEMM (rows = batch, k×n = one
    /// projection of the bench spec). Mirrored by python/engine_mirror.py.
    const KERNEL_M: usize = 32;
    const KERNEL_K: usize = 256;
    const KERNEL_N: usize = 256;

    fn precision_tag(p: Precision) -> &'static str {
        match (p.w_bits, p.a_bits, p.kv_bits) {
            (16, 16, _) => "f32",
            (8, 16, _) => "w8a16",
            (8, 8, 8) => "w8a8kv8",
            _ => "w8a8",
        }
    }

    fn prompts(b: usize, vocab: usize) -> Vec<Vec<i32>> {
        (0..b)
            .map(|i| {
                (0..PROMPT_LEN)
                    .map(|t| ((t * 7 + i * 13) % vocab) as i32)
                    .collect()
            })
            .collect()
    }

    /// Nominal FLOPs of one batched decode step at position `pos`
    /// (multiply-add = 2 FLOPs; identical formula in python/engine_mirror.py).
    fn decode_step_flops(spec: &SyntheticSpec, b: usize, pos: usize) -> u64 {
        let (dm, df) = (spec.d_model as u64, spec.d_ff as u64);
        let mm = |m: u64, k: u64, n: u64| 2 * m * k * n;
        let per_layer = 4 * mm(1, dm, dm) + mm(1, dm, df) + mm(1, df, dm) + 4 * dm * (pos as u64 + 1);
        b as u64 * (spec.layers as u64 * per_layer + 2 * spec.vocab as u64 * dm)
    }

    /// Nominal FLOPs of one prefill call over `b` prompts of length `s`.
    fn prefill_flops(spec: &SyntheticSpec, b: usize, s: usize) -> u64 {
        let (dm, df, s64) = (spec.d_model as u64, spec.d_ff as u64, s as u64);
        let mm = |m: u64, k: u64, n: u64| 2 * m * k * n;
        let attn = 2 * dm * s64 * (s64 + 1); // sum over causal score+mix rows
        let per_layer = 4 * mm(s64, dm, dm) + mm(s64, dm, df) + mm(s64, df, dm) + attn;
        b as u64 * (spec.layers as u64 * per_layer + 2 * spec.vocab as u64 * dm)
    }

    fn push_row(
        suite: &mut BenchSuite,
        scenario: String,
        precision: &str,
        phase: &str,
        batch: usize,
        flops: u64,
        allocs_per_step: Option<f64>,
        tokens_per_s: Option<f64>,
        r: &edgellm::util::bench::BenchResult,
    ) {
        suite.push(Json::obj(vec![
            ("scenario", Json::Str(scenario)),
            ("precision", Json::Str(precision.to_string())),
            ("phase", Json::Str(phase.to_string())),
            ("batch", Json::Num(batch as f64)),
            ("prompt_len", Json::Num(PROMPT_LEN as f64)),
            ("flops_per_call", Json::Num(flops as f64)),
            (
                "allocs_per_step",
                allocs_per_step.map(Json::Num).unwrap_or(Json::Null),
            ),
            (
                "tokens_per_s",
                tokens_per_s.map(Json::Num).unwrap_or(Json::Null),
            ),
            ("wall_mean_s", Json::Num(r.mean)),
            ("wall_median_s", Json::Num(r.median)),
            ("wall_p95_s", Json::Num(r.p95)),
            ("iters", Json::Num(r.iters as f64)),
        ]));
    }

    /// The tiled cache-blocked kernels against their k-ascending reference
    /// implementations on one decode-sized GEMM. Deterministic columns:
    /// flops_per_call = 2·m·k·n, allocs_per_step = 0 (all buffers, including
    /// the packed weight layout and the W8A8 activation-row scratch, are
    /// built outside the timed region).
    fn kernel_scenarios(bench: &Bencher, suite: &mut BenchSuite) {
        let (m, k, n) = (KERNEL_M, KERNEL_K, KERNEL_N);
        let x: Vec<f32> = (0..m * k)
            .map(|i| ((i * 37 % 101) as f32 - 50.0) / 25.0)
            .collect();
        let w: Vec<f32> = (0..k * n)
            .map(|i| ((i * 53 % 97) as f32 - 48.0) / 32.0)
            .collect();
        let (codes, w_scale) = quantize_per_tensor_i8(&w);
        let packed = pack_codes_col_blocked(&codes, k, n);
        let mut out = vec![0f32; m * n];
        let mut qrow = vec![0i8; k];
        let flops = (2 * m * k * n) as u64;

        let mut row = |suite: &mut BenchSuite,
                       tag: &str,
                       variant: &str,
                       r: &edgellm::util::bench::BenchResult| {
            suite.push(Json::obj(vec![
                (
                    "scenario",
                    Json::Str(format!("kernel/{tag}/{variant}/m{m}")),
                ),
                ("precision", Json::Str(tag.to_string())),
                ("phase", Json::Str(variant.to_string())),
                ("batch", Json::Num(m as f64)),
                ("prompt_len", Json::Num(k as f64)),
                ("flops_per_call", Json::Num(flops as f64)),
                ("allocs_per_step", Json::Num(0.0)),
                ("tokens_per_s", Json::Null),
                ("wall_mean_s", Json::Num(r.mean)),
                ("wall_median_s", Json::Num(r.median)),
                ("wall_p95_s", Json::Num(r.p95)),
                ("iters", Json::Num(r.iters as f64)),
            ]));
        };

        let r = bench.run("kernel/f32/ref/m32", || {
            matmul_f32_into(black_box(&x), m, k, black_box(&w), n, &mut out);
            black_box(out[0]);
        });
        println!("{}", r.report());
        row(suite, "f32", "ref", &r);
        let r = bench.run("kernel/f32/tiled/m32", || {
            matmul_f32_tiled_into(black_box(&x), m, k, black_box(&w), n, &mut out);
            black_box(out[0]);
        });
        println!("{}", r.report());
        row(suite, "f32", "tiled", &r);

        let r = bench.run("kernel/w8a16/ref/m32", || {
            matmul_w8a16_into(black_box(&x), m, k, black_box(&codes), w_scale, n, &mut out);
            black_box(out[0]);
        });
        println!("{}", r.report());
        row(suite, "w8a16", "ref", &r);
        let r = bench.run("kernel/w8a16/tiled/m32", || {
            matmul_w8a16_tiled_into(black_box(&x), m, k, black_box(&packed), w_scale, n, &mut out);
            black_box(out[0]);
        });
        println!("{}", r.report());
        row(suite, "w8a16", "tiled", &r);

        let r = bench.run("kernel/w8a8/ref/m32", || {
            matmul_w8a8_into(
                black_box(&x),
                m,
                k,
                black_box(&codes),
                w_scale,
                n,
                &mut qrow,
                &mut out,
            );
            black_box(out[0]);
        });
        println!("{}", r.report());
        row(suite, "w8a8", "ref", &r);
        let r = bench.run("kernel/w8a8/tiled/m32", || {
            matmul_w8a8_tiled_into(
                black_box(&x),
                m,
                k,
                black_box(&packed),
                w_scale,
                n,
                &mut qrow,
                &mut out,
            );
            black_box(out[0]);
        });
        println!("{}", r.report());
        row(suite, "w8a8", "tiled", &r);
    }

    fn engine_scenarios(bench: &Bencher, suite: &mut BenchSuite) {
        let spec = SyntheticSpec::bench();
        for precision in [
            Precision::W16A16,
            Precision::W8A16,
            Precision::W8A8,
            Precision::W8A8KV8,
        ] {
            let tag = precision_tag(precision);
            let engine = Engine::synthetic(&spec, precision);
            for b in BATCHES {
                let ps = prompts(b, spec.vocab);

                // --- prefill ---
                let name = format!("engine/{tag}/prefill/b{b}");
                let r = bench.run(&name, || {
                    let (l, c) = engine.prefill(black_box(&ps)).unwrap();
                    black_box((l.len(), c.active));
                });
                println!("{}", r.report());
                push_row(
                    suite,
                    name,
                    tag,
                    "prefill",
                    b,
                    prefill_flops(&spec, b, PROMPT_LEN),
                    None,
                    Some(b as f64 * PROMPT_LEN as f64 / r.median),
                    &r,
                );

                // --- batched decode (allocation-free steady state) ---
                let (logits, mut cache) = engine.prefill(&ps).unwrap();
                let tokens: Vec<i32> = logits.iter().map(|l| argmax(l)).collect();
                let mut flat = Vec::new();
                engine.decode_into(&tokens, &mut cache, &mut flat).unwrap(); // warm
                let scratch0 = engine.scratch_allocs();
                let grown0 = cache.grow_events();
                let mut steps = 0u64;
                let name = format!("engine/{tag}/decode/b{b}");
                let r = bench.run(&name, || {
                    // Pin every timed step at the nominal position the
                    // flops_per_call column describes (a mid-loop re-prefill
                    // would cost ~50 decode steps and skew the sample;
                    // resetting pos is b integer writes).
                    for p in cache.pos.iter_mut() {
                        *p = PROMPT_LEN as i32;
                    }
                    let n = engine
                        .decode_into(black_box(&tokens), &mut cache, &mut flat)
                        .unwrap();
                    steps += 1;
                    black_box(n);
                });
                println!("{}", r.report());
                let tracked = (engine.scratch_allocs() - scratch0) + (cache.grow_events() - grown0);
                let allocs_per_step = tracked as f64 / steps.max(1) as f64;
                push_row(
                    suite,
                    name,
                    tag,
                    "decode",
                    b,
                    decode_step_flops(&spec, b, PROMPT_LEN),
                    Some(allocs_per_step),
                    Some(b as f64 / r.median),
                    &r,
                );

                // --- per-sequence reference decode (the pre-batching shape) ---
                let (logits, mut cache) = engine.prefill(&ps).unwrap();
                let tokens: Vec<i32> = logits.iter().map(|l| argmax(l)).collect();
                let name = format!("engine/{tag}/decode_ref/b{b}");
                let r = bench.run(&name, || {
                    // Same position pinning as the batched scenario above.
                    for p in cache.pos.iter_mut() {
                        *p = PROMPT_LEN as i32;
                    }
                    let l = engine
                        .decode_reference(black_box(&tokens), &mut cache)
                        .unwrap();
                    black_box(l.len());
                });
                println!("{}", r.report());
                push_row(
                    suite,
                    name,
                    tag,
                    "decode_ref",
                    b,
                    decode_step_flops(&spec, b, PROMPT_LEN),
                    None,
                    Some(b as f64 / r.median),
                    &r,
                );
            }
        }
    }

    pub fn run() {
        let args: Vec<String> = std::env::args().collect();
        let quick = std::env::var("QUICK").is_ok() || args.iter().any(|a| a == "--quick");
        let json = std::env::var("JSON").is_ok() || args.iter().any(|a| a == "--json");
        let bench = if quick { Bencher::quick() } else { Bencher::default() };

        println!("== tiled vs reference kernels ==");
        let mut suite = BenchSuite::new();
        kernel_scenarios(&bench, &mut suite);

        println!("== host engine request path ==");
        engine_scenarios(&bench, &mut suite);

        if json {
            // CARGO_MANIFEST_DIR = rust/; the tracked baseline lives at the
            // repository root next to BENCH_dftsp.json.
            let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_engine.json");
            let provenance =
                "cargo bench --bench perf_engine -- --json (QUICK=1 / --quick for the smoke profile)";
            suite
                .write(&path, provenance)
                .expect("write BENCH_engine.json");
            println!("wrote {} scenario rows to {}", suite.len(), path.display());
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    host_bench::run();
}

#[cfg(feature = "pjrt")]
fn main() {
    eprintln!("perf_engine benches the host engine's kernels; rebuild without --features pjrt");
}
