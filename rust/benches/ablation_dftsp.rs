//! Ablation studies for the design choices DESIGN.md calls out:
//!
//! A1. Constraint-based subtree pruning (DFTSP's second pruning rule, on top
//!     of the paper's capacity rule): node-count impact.
//! A2. Search vs greedy insertion: what the tree search buys over a single
//!     feasibility-preserving pass, per insertion order.
//! A3. Surplus-bandwidth allocation policy: effective upload times under
//!     MinOnly / Proportional / MaxMin (the "joint allocation" knob).
//! A4. Multi-LLM GPU partitioning: Equal vs LoadProportional under skewed
//!     demand.
//!
//! Run: cargo bench --bench ablation_dftsp

use edgellm::cluster::ClusterSpec;
use edgellm::coordinator::{
    Deployment, Dftsp, EpochParams, Greedy, GreedyOrder, MultiLlm, PartitionPolicy,
    ProblemInstance, Scheduler,
};
use edgellm::model::{CostModel, LlmSpec};
use edgellm::quant;
use edgellm::request::{EpochRequest, RequestBuilder};
use edgellm::sim::{self, SimConfig};
use edgellm::util::fmt::Table;
use edgellm::util::rng::Rng;
use edgellm::wireless::{allocate, AllocationPolicy, ChannelParams, RadioParams};
use edgellm::workload::WorkloadParams;

fn random_requests(n: usize, seed: u64) -> Vec<EpochRequest> {
    let mut rng = Rng::new(seed);
    let mut b = RequestBuilder::new();
    let radio = RadioParams::default();
    let channel = ChannelParams::default();
    let levels = [128u32, 256, 512];
    (0..n)
        .map(|_| {
            let req = b.build(
                -rng.uniform(0.0, 2.0),
                *rng.choice(&levels),
                *rng.choice(&levels),
                rng.uniform(0.5, 2.0),
                rng.uniform(0.0, 1.0),
            );
            let h = channel.draw_h(&mut rng);
            EpochRequest::annotate(req, h, &radio, 0.25, 0.25)
        })
        .collect()
}

fn inst() -> ProblemInstance {
    ProblemInstance::new(
        CostModel::new(LlmSpec::bloom_3b()),
        quant::default_quant(),
        ClusterSpec::paper_default(),
        EpochParams::default(),
        512,
        0.0,
    )
}

fn a1_constraint_pruning() {
    println!("== A1: constraint-based subtree pruning (batch sizes identical by construction) ==");
    let mut t = Table::new(&[
        "candidates",
        "nodes (full pruning)",
        "nodes (capacity rule only)",
        "extra reduction",
    ]);
    for n in [32usize, 128, 512] {
        let reqs = random_requests(n, 7);
        let i = inst();
        let full = Dftsp::new().schedule(&i, &reqs);
        let mut no_cp = Dftsp {
            disable_constraint_pruning: true,
            ..Dftsp::default()
        };
        let cap_only = no_cp.schedule(&i, &reqs);
        assert_eq!(full.batch_size(), cap_only.batch_size());
        t.row(&[
            n.to_string(),
            full.stats.nodes_visited.to_string(),
            cap_only.stats.nodes_visited.to_string(),
            format!(
                "{:.1}%",
                100.0
                    * (1.0
                        - full.stats.nodes_visited as f64
                            / cap_only.stats.nodes_visited.max(1) as f64)
            ),
        ]);
    }
    print!("{}", t.render());
}

fn a2_search_vs_greedy() {
    println!("\n== A2: DFTSP vs greedy insertion (simulated throughput, req/s) ==");
    let mut t = Table::new(&[
        "arrival rate",
        "DFTSP",
        "Greedy-slack",
        "Greedy-output",
        "Greedy-fcfs",
    ]);
    for rate in [25.0, 60.0, 120.0] {
        let cfg = SimConfig {
            workload: WorkloadParams {
                arrival_rate: rate,
                ..Default::default()
            },
            epochs: 12,
            seed: 77,
            ..SimConfig::paper_default()
        };
        let run = |s: &mut dyn Scheduler| sim::run(&cfg, s).throughput();
        t.row(&[
            format!("{rate:.0}"),
            format!("{:.2}", run(&mut Dftsp::new())),
            format!("{:.2}", run(&mut Greedy::new(GreedyOrder::SlackDescending))),
            format!("{:.2}", run(&mut Greedy::new(GreedyOrder::OutputAscending))),
            format!("{:.2}", run(&mut Greedy::new(GreedyOrder::Fcfs))),
        ]);
    }
    print!("{}", t.render());
}

fn a3_allocation_policies() {
    println!("\n== A3: surplus bandwidth allocation (scheduled batch of 12, mean upload time) ==");
    let i = inst();
    let reqs = random_requests(64, 11);
    let sched = Dftsp::new().schedule(&i, &reqs);
    let batch: Vec<&EpochRequest> = reqs
        .iter()
        .filter(|r| sched.scheduled.contains(&r.id()))
        .collect();
    let radio = RadioParams::default();
    let mut t = Table::new(&["policy", "Σρ_u", "mean upload", "max upload"]);
    for (name, policy) in [
        ("MinOnly", AllocationPolicy::MinOnly),
        ("Proportional", AllocationPolicy::Proportional),
        ("MaxMin", AllocationPolicy::MaxMin),
    ] {
        let allocs = allocate(&batch, &radio, 0.25, 0.25, policy);
        let mean_up =
            allocs.iter().map(|a| a.upload_time).sum::<f64>() / allocs.len().max(1) as f64;
        let max_up = allocs.iter().map(|a| a.upload_time).fold(0.0, f64::max);
        t.row(&[
            name.to_string(),
            format!("{:.4}", allocs.iter().map(|a| a.rho_u).sum::<f64>()),
            format!("{:.2} ms", mean_up * 1e3),
            format!("{:.2} ms", max_up * 1e3),
        ]);
    }
    print!("{}", t.render());
    println!("(batch size {}; MinOnly pins uploads at T_U = 250 ms)", batch.len());
}

fn a4_multi_llm_partitioning() {
    println!("\n== A4: multi-LLM GPU partitioning under skewed demand ==");
    let deps = vec![
        Deployment {
            model: LlmSpec::bloom_3b(),
            quant: quant::default_quant(),
        },
        Deployment {
            model: LlmSpec::bloom_7b(),
            quant: quant::default_quant(),
        },
    ];
    let cluster = ClusterSpec::paper_default();
    let mut t = Table::new(&[
        "demand (3B/7.1B)",
        "policy",
        "GPUs",
        "scheduled total",
    ]);
    for (d3, d7) in [(30usize, 2usize), (16, 16), (2, 30)] {
        let demand = vec![random_requests(d3, 3), random_requests(d7, 4)];
        for policy in [PartitionPolicy::Equal, PartitionPolicy::LoadProportional] {
            let mut m = MultiLlm::with_dftsp(deps.clone(), policy);
            let (schedules, gpus) = m
                .schedule_epoch(&cluster, &EpochParams::default(), 512, 0.0, &demand)
                .expect("cluster covers both deployments");
            t.row(&[
                format!("{d3}/{d7}"),
                format!("{policy:?}"),
                format!("{gpus:?}"),
                schedules
                    .iter()
                    .map(|s| s.batch_size())
                    .sum::<usize>()
                    .to_string(),
            ]);
        }
    }
    print!("{}", t.render());
}

fn main() {
    let t0 = std::time::Instant::now();
    a1_constraint_pruning();
    a2_search_vs_greedy();
    a3_allocation_policies();
    a4_multi_llm_partitioning();
    println!("\nablation bench completed in {:.1}s", t0.elapsed().as_secs_f64());
}
