//! Simulator-level shape tests: the qualitative claims of the paper's
//! Figures 5–6 and Table III must hold in this reproduction (absolute
//! numbers differ — the testbed is analytic — but who-wins and trends must
//! match; the bench harness regenerates the full curves).

use edgellm::coordinator::{BruteForce, Dftsp, NoBatching, StaticBatching};
use edgellm::model::LlmSpec;
use edgellm::quant::{self, Precision, QuantAlgo};
use edgellm::sim::{self, SimConfig};
use edgellm::workload::WorkloadParams;

fn cfg(rate: f64, epochs: usize) -> SimConfig {
    SimConfig {
        workload: WorkloadParams {
            arrival_rate: rate,
            ..Default::default()
        },
        epochs,
        seed: 77,
        ..SimConfig::paper_default()
    }
}

/// Fig. 5(a) shape: DFTSP >= StB >= NoB at every arrival rate tried, and
/// DFTSP throughput rises then saturates.
#[test]
fn fig5a_shape() {
    let rates = [5.0, 25.0, 75.0, 150.0];
    let mut dftsp = Vec::new();
    for rate in rates {
        let c = cfg(rate, 12);
        let d = sim::run(&c, &mut Dftsp::new()).throughput();
        let s = sim::run(&c, &mut StaticBatching::new()).throughput();
        let n = sim::run(&c, &mut NoBatching::new()).throughput();
        assert!(d + 1e-9 >= s, "rate {rate}: DFTSP {d} < StB {s}");
        assert!(d + 1e-9 >= n, "rate {rate}: DFTSP {d} < NoB {n}");
        dftsp.push(d);
    }
    // Saturation = strictly diminishing marginal throughput per unit rate.
    let marginal: Vec<f64> = dftsp
        .windows(2)
        .zip(rates.windows(2))
        .map(|(t, r)| (t[1] - t[0]) / (r[1] - r[0]))
        .collect();
    for w in marginal.windows(2) {
        assert!(
            w[1] < w[0],
            "marginal throughput must diminish: {marginal:?}"
        );
    }
}

/// Fig. 5(b) shape: relaxing latency requirements raises DFTSP throughput,
/// and BLOOM-3B beats BLOOM-7.1B throughout.
#[test]
fn fig5b_shape() {
    let mut last3 = 0.0;
    for tau_hi in [1.0, 2.0, 4.0] {
        let mut c3 = cfg(60.0, 12);
        c3.workload.latency_range = (0.5 * tau_hi, tau_hi);
        let mut c7 = c3.clone();
        c7.model = LlmSpec::bloom_7b();
        let t3 = sim::run(&c3, &mut Dftsp::new()).throughput();
        let t7 = sim::run(&c7, &mut Dftsp::new()).throughput();
        assert!(
            t3 + 1e-9 >= t7,
            "tau_hi {tau_hi}: BLOOM-3B {t3} < BLOOM-7.1B {t7}"
        );
        assert!(
            t3 + 1e-9 >= last3,
            "tau_hi {tau_hi}: throughput decreased ({t3} < {last3})"
        );
        last3 = t3;
    }
    assert!(last3 > 0.0);
}

/// Fig. 6(a) shape: with accuracy requirements disabled, lower precision
/// (smaller α, β) never hurts throughput; larger models serve less.
#[test]
fn fig6a_shape() {
    let run = |model: LlmSpec, q: quant::QuantSpec| {
        let mut c = cfg(60.0, 12);
        c.model = model;
        c.quant = q;
        c.workload.accuracy_range = (0.0, 0.0); // accuracy ignored
        sim::run(&c, &mut Dftsp::new()).throughput()
    };
    let w16 = run(LlmSpec::bloom_3b(), quant::QuantSpec::fp16());
    let w8 = run(
        LlmSpec::bloom_3b(),
        quant::by_label(Precision::W8A16, QuantAlgo::Gptq).unwrap(),
    );
    let w4 = run(
        LlmSpec::bloom_3b(),
        quant::by_label(Precision::W4A16, QuantAlgo::Gptq).unwrap(),
    );
    assert!(w8 + 1e-9 >= w16, "W8 {w8} < W16 {w16}");
    assert!(w4 + 1e-9 >= w8, "W4 {w4} < W8 {w8}");

    let b3 = run(
        LlmSpec::bloom_3b(),
        quant::by_label(Precision::W8A16, QuantAlgo::Gptq).unwrap(),
    );
    let o13 = run(
        LlmSpec::opt_13b(),
        quant::by_label(Precision::W8A16, QuantAlgo::Gptq).unwrap(),
    );
    assert!(b3 > o13, "BLOOM-3B {b3} <= OPT-13B {o13}");
}

/// Fig. 6(b) shape: with strict accuracy requirements, aggressive
/// quantization loses throughput (requests are inadmissible), and GPTQ
/// (lower ΔPPL) beats ZQ-Local at the same precision.
#[test]
fn fig6b_shape() {
    let run = |q: quant::QuantSpec, acc_hi: f64| {
        let mut c = cfg(60.0, 12);
        c.model = LlmSpec::bloom_3b();
        c.quant = q;
        c.workload.accuracy_range = (0.0, acc_hi);
        sim::run(&c, &mut Dftsp::new()).throughput()
    };
    let gptq = quant::by_label(Precision::W4A16, QuantAlgo::Gptq).unwrap();
    let zq = quant::by_label(Precision::W4A16, QuantAlgo::ZqLocal).unwrap();
    // strict accuracy population: GPTQ (dPPL .75 -> f=.25) admits a<=0.25;
    // ZQ (dPPL .92 -> f=.08) admits a<=0.08.
    let t_gptq = run(gptq.clone(), 1.0);
    let t_zq = run(zq.clone(), 1.0);
    assert!(
        t_gptq + 1e-9 >= t_zq,
        "GPTQ {t_gptq} < ZQ-Local {t_zq} under accuracy pressure"
    );
    // relaxing the accuracy population raises throughput for both
    let t_gptq_lax = run(gptq, 0.2);
    assert!(
        t_gptq_lax + 1e-9 >= t_gptq,
        "lax {t_gptq_lax} < strict {t_gptq}"
    );
}

/// Table III shape: DFTSP's pruning reduces visited nodes vs the unpruned
/// brute-force search, and the reduction grows with arrival rate.
#[test]
fn table3_shape() {
    let reduction = |rate: f64| {
        let c = cfg(rate, 6);
        let d = sim::run(&c, &mut Dftsp::new());
        let b = sim::run(&c, &mut BruteForce::with_budget(3_000_000));
        let dn = d.search.nodes_visited as f64;
        let bn = b.search.nodes_visited as f64;
        assert!(bn >= dn, "rate {rate}: brute {bn} < dftsp {dn}");
        1.0 - dn / bn.max(1.0)
    };
    let r10 = reduction(10.0);
    let r100 = reduction(100.0);
    assert!(r10 > 0.0, "pruning must reduce work at rate 10 (got {r10})");
    assert!(
        r100 >= r10,
        "reduction should grow with rate: {r100} < {r10}"
    );
}

/// Request conservation holds for every scheduler over a long horizon.
#[test]
fn conservation_all_schedulers() {
    let c = cfg(50.0, 15);
    let mut schedulers: Vec<Box<dyn edgellm::coordinator::Scheduler>> = vec![
        Box::new(Dftsp::new()),
        Box::new(StaticBatching::new()),
        Box::new(NoBatching::new()),
    ];
    for s in schedulers.iter_mut() {
        let m = sim::run(&c, s.as_mut());
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped,
            "{}",
            s.name()
        );
    }
}
