//! Property-based tests of coordinator invariants.
//!
//! No proptest crate is available offline, so this uses a seeded-case
//! harness: each property runs over many deterministic random instances and
//! failures report the offending seed for replay. `PROPTEST_CASES` bounds
//! the case count of the heavier properties (CI pins it to 64).

use edgellm::cluster::{ClusterSpec, GpuSpec};
use edgellm::coordinator::{
    BruteForce, Dftsp, EpochParams, FeasibilityChecker, PartialState, ProblemInstance,
    Scheduler, SchedulerConfig, Violation,
};
use edgellm::model::{CostModel, LlmSpec};
use edgellm::quant;
use edgellm::request::{EpochRequest, RequestBuilder};
use edgellm::util::rng::Rng;
use edgellm::wireless::RadioParams;

fn cases(default: u64) -> u64 {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Random problem instance: model, quant, cluster size, epoch all vary.
fn random_instance(rng: &mut Rng) -> ProblemInstance {
    let model = match rng.below(3) {
        0 => LlmSpec::bloom_3b(),
        1 => LlmSpec::bloom_7b(),
        _ => LlmSpec::opt_13b(),
    };
    let quants = quant::catalog();
    let q = quants[rng.below(quants.len() as u64) as usize].clone();
    let cluster = ClusterSpec::new(GpuSpec::jetson_tx2(), rng.int_range(1, 24) as usize);
    let epoch = EpochParams {
        duration: rng.uniform(1.0, 4.0),
        t_u: 0.25,
        t_d: 0.25,
    };
    ProblemInstance::new(CostModel::new(model), q, cluster, epoch, 512, 0.0)
}

/// Random request batch; `uniform_h` pins the concentration assumption.
fn random_requests(rng: &mut Rng, n: usize, uniform_h: bool) -> Vec<EpochRequest> {
    let mut b = RequestBuilder::new();
    let radio = RadioParams::default();
    let levels = [128u32, 256, 512];
    let h_common = (1e-3f64).sqrt();
    (0..n)
        .map(|_| {
            let req = b.build(
                -rng.uniform(0.0, 2.0),
                *rng.choice(&levels),
                *rng.choice(&levels),
                rng.uniform(0.5, 2.5),
                rng.uniform(0.0, 1.0),
            );
            let h = if uniform_h {
                h_common
            } else {
                rng.rayleigh(std::f64::consts::FRAC_1_SQRT_2) * 1e-3f64.sqrt()
            };
            EpochRequest::annotate(req, h.max(1e-9), &radio, 0.25, 0.25)
        })
        .collect()
}

/// Exhaustive maximum-cardinality feasible subset (oracle, n <= ~14).
fn exhaustive_opt(inst: &ProblemInstance, reqs: &[EpochRequest]) -> usize {
    let checker = FeasibilityChecker::new(inst);
    let n = reqs.len();
    let mut best = 0;
    for mask in 0u32..(1 << n) {
        let size = mask.count_ones() as usize;
        if size <= best {
            continue;
        }
        let subset: Vec<&EpochRequest> = (0..n)
            .filter(|i| mask & (1 << i) != 0)
            .map(|i| &reqs[i])
            .collect();
        if checker.check(&subset).is_ok() {
            best = size;
        }
    }
    best
}

/// Greedy-by-slack lower bound: add latency-tolerant requests while the
/// whole prefix stays feasible.
fn greedy_lower_bound(inst: &ProblemInstance, reqs: &[EpochRequest]) -> usize {
    let mut adm = inst.admissible(reqs);
    adm.sort_by(|a, b| {
        inst.compute_slack(b)
            .partial_cmp(&inst.compute_slack(a))
            .unwrap()
    });
    let checker = FeasibilityChecker::new(inst);
    let mut chosen: Vec<&EpochRequest> = Vec::new();
    for r in adm {
        chosen.push(r);
        if checker.check(&chosen).is_err() {
            chosen.pop();
        }
    }
    chosen.len()
}

/// PROPERTY: every DFTSP schedule satisfies constraints (1a)–(1e), on any
/// instance, with arbitrary per-user fading.
#[test]
fn prop_dftsp_schedules_always_feasible() {
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed);
        let inst = random_instance(&mut rng);
        let n = rng.int_range(1, 30) as usize;
        let reqs = random_requests(&mut rng, n, false);
        let sched = Dftsp::new().schedule(&inst, &reqs);
        let subset: Vec<&EpochRequest> = reqs
            .iter()
            .filter(|r| sched.scheduled.contains(&r.id()))
            .collect();
        assert!(
            FeasibilityChecker::new(&inst).check(&subset).is_ok(),
            "seed {seed}: infeasible schedule of size {}",
            subset.len()
        );
        // bandwidth totals reported correctly
        let rho_u: f64 = subset.iter().map(|r| r.rho_min_u).sum();
        assert!((rho_u - sched.rho_u_total).abs() < 1e-9, "seed {seed}");
    }
}

/// PROPERTY: DFTSP matches the exhaustive optimum under the paper's P2
/// assumption (uniform h across users).
#[test]
fn prop_dftsp_optimal_uniform_h() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(1000 + seed);
        let inst = random_instance(&mut rng);
        let n = rng.int_range(4, 12) as usize;
        let reqs = random_requests(&mut rng, n, true);
        let opt = exhaustive_opt(&inst, &reqs);
        let got = Dftsp::new().schedule(&inst, &reqs).batch_size();
        assert_eq!(got, opt, "seed {seed}");
    }
}

/// PROPERTY: DFTSP never does worse than the greedy-by-slack heuristic.
#[test]
fn prop_dftsp_at_least_greedy() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(2000 + seed);
        let inst = random_instance(&mut rng);
        let n = rng.int_range(2, 26) as usize;
        let reqs = random_requests(&mut rng, n, false);
        let greedy = greedy_lower_bound(&inst, &reqs);
        let dftsp = Dftsp::new().schedule(&inst, &reqs).batch_size();
        assert!(
            dftsp >= greedy,
            "seed {seed}: DFTSP {dftsp} < greedy {greedy}"
        );
    }
}

/// PROPERTY: DFTSP and brute force agree on cardinality (both exact over the
/// same tree), and brute force never visits fewer nodes.
#[test]
fn prop_brute_force_agrees_and_costs_more() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(3000 + seed);
        let inst = random_instance(&mut rng);
        let n = rng.int_range(2, 14) as usize;
        let reqs = random_requests(&mut rng, n, true);
        let d = Dftsp::new().schedule(&inst, &reqs);
        let bf = BruteForce::default().schedule(&inst, &reqs);
        if bf.stats.budget_exhausted {
            continue;
        }
        assert_eq!(d.batch_size(), bf.batch_size(), "seed {seed}");
    }
}

/// PROPERTY (issue satellite): on randomized small instances (≤ 8 users,
/// uniform h per the P2 concentration assumption), DFTSP's selected batch
/// achieves the same per-epoch throughput (batch cardinality) as brute
/// force *and* the exhaustive-subset oracle, and the selected batch never
/// violates constraints (1b)–(1d) — checked explicitly, on top of the full
/// (1a)–(1e) feasibility check.
#[test]
fn prop_dftsp_throughput_equals_brute_force_small() {
    for seed in 0..cases(64) {
        let mut rng = Rng::new(7000 + seed);
        let inst = random_instance(&mut rng);
        let n = rng.int_range(1, 8) as usize;
        let reqs = random_requests(&mut rng, n, true);

        let d = Dftsp::new().schedule(&inst, &reqs);
        let bf = BruteForce::default().schedule(&inst, &reqs);
        assert!(!bf.stats.budget_exhausted, "seed {seed}: n <= 8 fits budget");
        assert_eq!(
            d.batch_size(),
            bf.batch_size(),
            "seed {seed}: DFTSP vs brute force"
        );
        assert_eq!(
            d.batch_size(),
            exhaustive_opt(&inst, &reqs),
            "seed {seed}: DFTSP vs exhaustive oracle"
        );

        let subset: Vec<&EpochRequest> = reqs
            .iter()
            .filter(|r| d.scheduled.contains(&r.id()))
            .collect();
        // (1b) downlink bandwidth
        let rho_d: f64 = subset.iter().map(|r| r.rho_min_d).sum();
        assert!(rho_d <= 1.0 + 1e-9, "seed {seed}: (1b) violated: {rho_d}");
        // (1c) memory
        let kv: Vec<u64> = subset
            .iter()
            .map(|r| inst.kv_bytes(r.req.output_tokens))
            .collect();
        assert!(
            inst.cluster.batch_fits_memory(&inst.cost, &inst.quant, &kv),
            "seed {seed}: (1c) violated"
        );
        // (1d) latency: the shared batch completion meets every member's
        // deadline and fits the computation slot.
        if !subset.is_empty() {
            let t = FeasibilityChecker::new(&inst)
                .check(&subset)
                .unwrap_or_else(|v| panic!("seed {seed}: violated {v:?}"));
            let min_slack = subset
                .iter()
                .map(|r| inst.compute_slack(r))
                .fold(f64::INFINITY, f64::min);
            assert!(t <= min_slack + 1e-12, "seed {seed}: (1d) violated");
            assert!(t <= inst.epoch.t_c() + 1e-12, "seed {seed}: (1d) slot");
        }
    }
}

/// PROPERTY (issue satellite): online tree-pruning never prunes the node
/// holding the optimum — disabling the constraint-pruning rule must never
/// find a *larger* feasible batch, while visiting at least as many nodes.
#[test]
fn prop_pruning_never_prunes_the_optimal_node() {
    for seed in 0..cases(64) {
        let mut rng = Rng::new(7500 + seed);
        let inst = random_instance(&mut rng);
        let n = rng.int_range(2, 10) as usize;
        let reqs = random_requests(&mut rng, n, true);
        let pruned = Dftsp::new().schedule(&inst, &reqs);
        let unpruned = Dftsp {
            disable_constraint_pruning: true,
            ..Dftsp::default()
        }
        .schedule(&inst, &reqs);
        assert_eq!(
            pruned.batch_size(),
            unpruned.batch_size(),
            "seed {seed}: pruning changed the optimum"
        );
        assert!(
            pruned.stats.nodes_visited <= unpruned.stats.nodes_visited,
            "seed {seed}: pruning must not enlarge the search"
        );
    }
}

/// PROPERTY (issue satellite): the incremental `PartialState` leaf test —
/// DFTSP's O(1) fast path — agrees with `FeasibilityChecker::check` on
/// arbitrary subsets, NaN-poisoned requests included. Building the partial
/// one request at a time reproduces the checker's flat summation order, so
/// agreement here is bit-exact, down to which constraint fires first.
#[test]
fn prop_incremental_leaf_matches_exact_checker() {
    for seed in 0..cases(64) {
        let mut rng = Rng::new(8000 + seed);
        let inst = random_instance(&mut rng);
        let mut reqs = random_requests(&mut rng, 10, false);
        // Poison a couple of requests with NaN channel gain / deadline: the
        // incremental and exact forms must still agree (both treat NaN
        // comparisons as "no violation"), and neither may panic.
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        reqs.push(EpochRequest::annotate(
            b.build(0.0, 128, 256, 2.0, 0.2),
            f64::NAN,
            &radio,
            0.25,
            0.25,
        ));
        reqs.push(EpochRequest::annotate(
            b.build(0.0, 256, 128, f64::NAN, 0.2),
            (1e-3f64).sqrt(),
            &radio,
            0.25,
            0.25,
        ));
        for _ in 0..8 {
            let size = rng.int_range(0, reqs.len() as u64 - 1) as usize;
            let mut subset: Vec<&EpochRequest> = Vec::new();
            let mut p = PartialState::empty();
            for _ in 0..size {
                let r = &reqs[rng.below(reqs.len() as u64) as usize];
                subset.push(r);
                p = p.add_block(
                    1,
                    r.rho_min_u,
                    r.rho_min_d,
                    inst.kv_bytes(r.req.output_tokens),
                    inst.cost.decode_flops_per_req(inst.s_pad, r.req.output_tokens),
                    inst.compute_slack(r),
                );
            }
            let exact = FeasibilityChecker::new(&inst).check(&subset);
            if subset.iter().any(|r| !inst.admits(r)) {
                // (1e) is the checker's concern alone — the DFS pool is
                // admission-filtered before any PartialState exists.
                assert_eq!(exact, Err(Violation::Accuracy), "seed {seed}");
                continue;
            }
            let incremental = p.violation(&inst);
            assert_eq!(
                incremental.is_none(),
                exact.is_ok(),
                "seed {seed}: incremental {incremental:?} vs exact {exact:?} on {} reqs",
                subset.len()
            );
            if let (Some(vi), Err(ve)) = (incremental, exact) {
                assert_eq!(vi, ve, "seed {seed}: first violated constraint differs");
            }
        }
    }
}

/// PROPERTY: the blockwise (level-prefix) `PartialState` construction the
/// DFS actually uses — whole-level `add_block`s, the summation order that
/// *can* drift an ulp against the checker's flat sums — agrees with the
/// exact checker on every leaf outside `near_boundary`'s arbitration band;
/// inside the band the DFS defers to the exact checker by construction, so
/// only no-panic is asserted there.
#[test]
fn prop_blockwise_leaf_matches_exact_checker_outside_boundary() {
    use edgellm::coordinator::tree::{build_levels, materialize};
    for seed in 0..cases(64) {
        let mut rng = Rng::new(8500 + seed);
        let inst = random_instance(&mut rng);
        let n = rng.int_range(4, 16) as usize;
        let reqs = random_requests(&mut rng, n, false);
        let adm = inst.admissible(&reqs);
        if adm.is_empty() {
            continue;
        }
        let levels = build_levels(&inst, &adm);
        for _ in 0..8 {
            let counts: Vec<usize> = levels
                .iter()
                .map(|g| rng.int_range(0, g.len() as u64) as usize)
                .collect();
            let mut p = PartialState::empty();
            for (g, &c) in levels.iter().zip(&counts) {
                p = p.add_block(
                    c,
                    g.prefix_rho_u[c],
                    g.prefix_rho_d[c],
                    g.kv_per_req,
                    g.decode_flops_per_req * c as f64,
                    g.prefix_min_slack[c],
                );
            }
            let subset = materialize(&levels, &counts);
            let exact = FeasibilityChecker::new(&inst).check(&subset).is_ok();
            if p.near_boundary(&inst) {
                continue;
            }
            assert_eq!(
                p.violation(&inst).is_none(),
                exact,
                "seed {seed}: blockwise partial diverged outside the boundary band \
                 (counts {counts:?})"
            );
        }
    }
}

/// PROPERTY (issue satellite): the opt-in parallel d-pool search returns the
/// same schedule as the sequential chained search — same request ids in the
/// same order, same compute times, same bandwidth totals. (Search-effort
/// counters legitimately differ: a parallel wave may search pools past the
/// winning d; they must still be deterministic run-to-run.)
#[test]
fn prop_parallel_search_matches_sequential() {
    for seed in 0..cases(64) {
        let mut rng = Rng::new(9000 + seed);
        let inst = random_instance(&mut rng);
        let n = rng.int_range(2, 24) as usize;
        let reqs = random_requests(&mut rng, n, seed % 2 == 0);
        let seq = Dftsp::new().schedule(&inst, &reqs);
        let workers = rng.int_range(2, 5) as usize;
        let par = Dftsp::with_config(SchedulerConfig { workers }).schedule(&inst, &reqs);
        assert_eq!(seq.scheduled, par.scheduled, "seed {seed} workers {workers}");
        assert_eq!(seq.compute_time, par.compute_time, "seed {seed}");
        assert_eq!(seq.per_request_compute, par.per_request_compute, "seed {seed}");
        assert_eq!(seq.rho_u_total, par.rho_u_total, "seed {seed}");
        assert_eq!(seq.rho_d_total, par.rho_d_total, "seed {seed}");
        let par2 = Dftsp::with_config(SchedulerConfig { workers }).schedule(&inst, &reqs);
        assert_eq!(par.scheduled, par2.scheduled, "seed {seed}: parallel determinism");
        assert_eq!(par.stats, par2.stats, "seed {seed}: parallel stats determinism");
    }
}

/// PROPERTY: scheduling is deterministic — identical inputs, identical
/// outputs (ids and node counts).
#[test]
fn prop_deterministic() {
    for seed in 0..20u64 {
        let mut rng1 = Rng::new(4000 + seed);
        let inst1 = random_instance(&mut rng1);
        let reqs1 = random_requests(&mut rng1, 18, false);
        let mut rng2 = Rng::new(4000 + seed);
        let inst2 = random_instance(&mut rng2);
        let reqs2 = random_requests(&mut rng2, 18, false);
        let a = Dftsp::new().schedule(&inst1, &reqs1);
        let b = Dftsp::new().schedule(&inst2, &reqs2);
        assert_eq!(a.scheduled, b.scheduled, "seed {seed}");
        assert_eq!(a.stats, b.stats, "seed {seed}");
    }
}

/// PROPERTY: growing the cluster never shrinks the DFTSP batch (uniform h:
/// relaxing compute/memory can only help a cardinality-exact search).
#[test]
fn prop_more_gpus_never_hurt() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(5000 + seed);
        let n = rng.int_range(4, 12) as usize;
        let reqs = random_requests(&mut rng, n, true);
        let mk = |gpus: usize| {
            ProblemInstance::new(
                CostModel::new(LlmSpec::bloom_3b()),
                quant::default_quant(),
                ClusterSpec::new(GpuSpec::jetson_tx2(), gpus),
                EpochParams::default(),
                512,
                0.0,
            )
        };
        let small = Dftsp::new().schedule(&mk(2), &reqs).batch_size();
        let big = Dftsp::new().schedule(&mk(20), &reqs).batch_size();
        assert!(big >= small, "seed {seed}: {big} < {small}");
    }
}

/// PROPERTY: admission is sound — no returned id may belong to a request
/// whose accuracy requirement the deployed quantization cannot meet.
#[test]
fn prop_accuracy_admission_sound() {
    for seed in 0..30u64 {
        let mut rng = Rng::new(6000 + seed);
        let inst = random_instance(&mut rng);
        let reqs = random_requests(&mut rng, 20, false);
        let sched = Dftsp::new().schedule(&inst, &reqs);
        for r in &reqs {
            if sched.scheduled.contains(&r.id()) {
                assert!(
                    inst.quant
                        .satisfies_accuracy(&inst.cost.spec.name, r.req.accuracy_req),
                    "seed {seed}: scheduled request violates (1e)"
                );
            }
        }
    }
}
