//! PR 1 refactor-safety net: `sim::run` is now a thin adapter over the
//! shared `EpochDriver` (SimClock + AnalyticBackend). These tests prove the
//! refactor changed *nothing observable*:
//!
//! 1. `reference_run` below is a **frozen verbatim copy of the pre-refactor
//!    `sim::run` loop** (the second, now-deleted implementation of the
//!    Fig. 2 protocol). The driver-based `sim::run` must reproduce its
//!    `Metrics` bit-for-bit — same counters, same latency histogram, same
//!    online-stat accumulators, same search effort — across scenarios and
//!    schedulers.
//! 2. The `SimClock` and `WallClock` must deliver identical schedule
//!    decisions for identical arrival sequences: wall-clock jitter shifts
//!    every request's slack uniformly and must never flip a decision.

use edgellm::coordinator::{
    BruteForce, Dftsp, NoBatching, ProblemInstance, Schedule, Scheduler, StaticBatching,
};
use edgellm::driver::{
    run_epochs, AnalyticBackend, DriverPolicy, EpochDriver, InstanceTemplate, SPadPolicy,
    SimClock, StalePolicy, WallClock,
};
use edgellm::metrics::{Metrics, Outcome};
use edgellm::model::{CostModel, LlmSpec};
use edgellm::request::{EpochRequest, Request, RequestBuilder, RequestId};
use edgellm::sim::SimConfig;
use edgellm::util::rng::Rng;
use edgellm::wireless::{AllocationPolicy, ChannelParams, RadioParams};
use edgellm::workload::{WorkloadGenerator, WorkloadParams};

/// The pre-refactor simulator loop, frozen at the state of the seed commit.
/// DO NOT "improve" this function — its whole value is staying byte-for-byte
/// equivalent to the behavior the paper evaluation was validated against.
fn reference_run(config: &SimConfig, scheduler: &mut dyn Scheduler) -> Metrics {
    let mut metrics = Metrics::new();
    let mut gen = WorkloadGenerator::new(config.workload.clone(), config.seed);
    let mut channel_rng = Rng::new(config.seed ^ 0xC0FFEE);
    let cost = CostModel::new(config.model.clone());
    let duration = config.epoch.duration;

    let mut queue: Vec<Request> = Vec::new();

    for e in 0..config.epochs {
        let now = e as f64 * duration;

        // 1. Drop queued requests that can no longer make their deadline.
        let mut survivors = Vec::with_capacity(queue.len());
        for r in queue.drain(..) {
            let best_case = config.epoch.t_u
                + config.quant.beta
                    * cost.total_flops_per_req(r.prompt_tokens, r.output_tokens)
                    / config.cluster.total_flops()
                + config.epoch.t_d;
            if r.waited(now) + best_case > r.latency_req {
                metrics.record_outcome(Outcome::Dropped, 0.0);
            } else {
                survivors.push(r);
            }
        }
        queue = survivors;
        metrics.queue_depth.push(queue.len() as f64);

        // 2. Annotate the queue with this epoch's channel state.
        let s_pad = config
            .s_pad
            .unwrap_or_else(|| queue.iter().map(|r| r.prompt_tokens).max().unwrap_or(512));
        let inst = ProblemInstance::new(
            cost.clone(),
            config.quant.clone(),
            config.cluster.clone(),
            config.epoch.clone(),
            s_pad,
            now,
        );
        let annotated: Vec<EpochRequest> = queue
            .iter()
            .map(|r| {
                let h = config.channel.draw_h(&mut channel_rng);
                EpochRequest::annotate(
                    r.clone(),
                    h,
                    &config.radio,
                    config.epoch.t_u,
                    config.epoch.t_d,
                )
            })
            .collect();

        // 3. Drop requests the deployed quantization can never satisfy.
        let inadmissible: Vec<u64> = annotated
            .iter()
            .filter(|r| !inst.admits(r))
            .map(|r| r.id())
            .collect();
        for _ in &inadmissible {
            metrics.record_outcome(Outcome::Dropped, 0.0);
        }
        queue.retain(|r| !inadmissible.contains(&r.id));
        let annotated: Vec<EpochRequest> = annotated
            .into_iter()
            .filter(|r| !inadmissible.contains(&r.id()))
            .collect();

        // 4. Schedule.
        let sched = scheduler.schedule(&inst, &annotated);
        metrics.record_schedule(sched.batch_size(), &sched.stats);

        // 5. Resolve completions.
        for &(id, t_compute) in &sched.per_request_compute {
            let req = annotated
                .iter()
                .find(|r| r.id() == id)
                .expect("scheduler returned unknown request id");
            let completion = now + config.epoch.t_u + t_compute + config.epoch.t_d;
            let latency = completion - req.req.arrival;
            let outcome = if latency <= req.req.latency_req + 1e-9 {
                Outcome::CompletedInDeadline
            } else {
                Outcome::CompletedLate
            };
            metrics.record_outcome(outcome, latency);
        }
        queue.retain(|r| !sched.scheduled.contains(&r.id));

        // 6. Admit the arrivals of this epoch.
        let arrivals = gen.arrivals_between(now, now + duration);
        metrics.record_offered(arrivals.len() as u64);
        queue.extend(arrivals);
    }

    for _ in &queue {
        metrics.record_outcome(Outcome::Dropped, 0.0);
    }
    metrics.horizon = config.epochs as f64 * duration;
    metrics
}

fn assert_bit_identical(label: &str, got: &Metrics, want: &Metrics) {
    // Field-by-field first for readable failures, then the full PartialEq
    // (which also covers every histogram bucket and accumulator moment).
    assert_eq!(got.offered, want.offered, "{label}: offered");
    assert_eq!(got.scheduled, want.scheduled, "{label}: scheduled");
    assert_eq!(
        got.completed_in_deadline, want.completed_in_deadline,
        "{label}: in-deadline"
    );
    assert_eq!(got.completed_late, want.completed_late, "{label}: late");
    assert_eq!(got.dropped, want.dropped, "{label}: dropped");
    assert_eq!(got.search, want.search, "{label}: search stats");
    assert_eq!(got.epoch_overruns, 0, "{label}: sim clock never overruns");
    assert!(
        got.horizon == want.horizon,
        "{label}: horizon {} vs {}",
        got.horizon,
        want.horizon
    );
    assert_eq!(got, want, "{label}: full Metrics (histograms/moments)");
}

fn cfg(rate: f64, epochs: usize, seed: u64) -> SimConfig {
    SimConfig {
        workload: WorkloadParams {
            arrival_rate: rate,
            ..Default::default()
        },
        epochs,
        seed,
        ..SimConfig::paper_default()
    }
}

#[test]
fn driver_reproduces_pre_refactor_sim_paper_default() {
    let config = SimConfig::paper_default();
    let want = reference_run(&config, &mut Dftsp::new());
    let got = edgellm::sim::run(&config, &mut Dftsp::new());
    assert!(want.offered > 0 && want.completed_in_deadline > 0);
    assert_bit_identical("paper-default/DFTSP", &got, &want);
}

#[test]
fn driver_reproduces_pre_refactor_sim_across_rates() {
    for (rate, seed) in [(20.0, 7u64), (75.0, 1234)] {
        let config = cfg(rate, 10, seed);
        let want = reference_run(&config, &mut Dftsp::new());
        let got = edgellm::sim::run(&config, &mut Dftsp::new());
        assert_bit_identical(&format!("rate {rate}/DFTSP"), &got, &want);
    }
}

#[test]
fn driver_reproduces_pre_refactor_sim_all_schedulers() {
    let config = cfg(50.0, 8, 77);
    let pairs: Vec<(&str, Box<dyn Scheduler>, Box<dyn Scheduler>)> = vec![
        ("StB", Box::new(StaticBatching::new()), Box::new(StaticBatching::new())),
        ("NoB", Box::new(NoBatching::new()), Box::new(NoBatching::new())),
        (
            "Brute",
            Box::new(BruteForce::with_budget(3_000_000)),
            Box::new(BruteForce::with_budget(3_000_000)),
        ),
    ];
    for (name, mut ref_sched, mut new_sched) in pairs {
        let want = reference_run(&config, ref_sched.as_mut());
        let got = edgellm::sim::run(&config, new_sched.as_mut());
        assert_bit_identical(name, &got, &want);
    }
}

#[test]
fn driver_reproduces_pre_refactor_sim_fixed_padding() {
    let mut config = cfg(40.0, 10, 99);
    config.s_pad = Some(256);
    let want = reference_run(&config, &mut Dftsp::new());
    let got = edgellm::sim::run(&config, &mut Dftsp::new());
    assert_bit_identical("s_pad=256/DFTSP", &got, &want);
}

// ---------------------------------------------------------------------------
// Clock equivalence
// ---------------------------------------------------------------------------

/// Wraps a scheduler and logs every decision.
struct Recording<S: Scheduler> {
    inner: S,
    log: Vec<Vec<RequestId>>,
}

impl<S: Scheduler> Recording<S> {
    fn new(inner: S) -> Self {
        Recording {
            inner,
            log: Vec::new(),
        }
    }
}

impl<S: Scheduler> Scheduler for Recording<S> {
    fn name(&self) -> &'static str {
        "recording"
    }
    fn schedule(&mut self, inst: &ProblemInstance, candidates: &[EpochRequest]) -> Schedule {
        let s = self.inner.schedule(inst, candidates);
        self.log.push(s.scheduled.clone());
        s
    }
}

/// Run the identical arrival sequence through the driver under a given
/// clock; returns (per-epoch schedule decisions, final metrics).
fn run_with_clock(use_wall: bool) -> (Vec<Vec<RequestId>>, Metrics) {
    const DURATION: f64 = 0.05;
    const EPOCHS: u64 = 6;
    let template = InstanceTemplate {
        // A deliberately tiny model so compute never threatens the generous
        // deadlines — jitter between the clocks must not flip feasibility.
        cost: CostModel::new(LlmSpec::new("tiny-clock-test", 2, 64, 2, 32)),
        quant: edgellm::quant::default_quant(),
        cluster: edgellm::cluster::ClusterSpec::paper_default(),
        epoch: edgellm::coordinator::EpochParams {
            duration: DURATION,
            t_u: 0.005,
            t_d: 0.005,
        },
    };
    let mut driver: EpochDriver<()> = EpochDriver::new(
        template,
        DriverPolicy {
            stale: StalePolicy::BestCaseInfeasible,
            s_pad: SPadPolicy::Fixed(8),
            allocation: AllocationPolicy::MinOnly,
        },
        RadioParams::default(),
        ChannelParams::default(),
        Rng::new(0xC10C),
    );
    let mut sched = Recording::new(Dftsp::new());
    let mut backend = AnalyticBackend;
    // Arrivals are a *fixed* sequence: arrival times are the nominal epoch
    // boundaries, independent of what the clock reports.
    let mut builder = RequestBuilder::new();
    let mut epoch = 0u64;
    let ingest = |d: &mut EpochDriver<()>, _b: &mut AnalyticBackend, _now: f64| {
        let arrival = epoch as f64 * DURATION;
        for _ in 0..2 {
            d.offer(builder.build(arrival, 8, 4, 50.0, 0.1), ());
        }
        epoch += 1;
    };
    if use_wall {
        let mut clock = WallClock::start();
        run_epochs(&mut driver, &mut sched, &mut backend, &mut clock, EPOCHS, ingest);
    } else {
        let mut clock = SimClock::new();
        run_epochs(&mut driver, &mut sched, &mut backend, &mut clock, EPOCHS, ingest);
    }
    driver.finish(&mut backend, EPOCHS as f64 * DURATION);
    (sched.log, driver.into_metrics())
}

#[test]
fn sim_and_wall_clocks_deliver_identical_schedules() {
    let (sim_log, sim_metrics) = run_with_clock(false);
    let (wall_log, wall_metrics) = run_with_clock(true);
    assert_eq!(
        sim_log, wall_log,
        "identical arrivals must produce identical schedule decisions"
    );
    assert!(sim_log.iter().any(|e| !e.is_empty()), "something scheduled");
    assert_eq!(sim_metrics.offered, wall_metrics.offered);
    assert_eq!(sim_metrics.scheduled, wall_metrics.scheduled);
    assert_eq!(
        sim_metrics.completed_in_deadline,
        wall_metrics.completed_in_deadline
    );
    assert_eq!(sim_metrics.dropped, wall_metrics.dropped);
    assert_eq!(
        sim_metrics.offered,
        sim_metrics.completed_in_deadline + sim_metrics.completed_late + sim_metrics.dropped
    );
}
