//! Serving-layer end-to-end tests: the threaded epoch server composing
//! DFTSP with the PJRT engine. Skips when `make artifacts` has not run.

use edgellm::coordinator::{Dftsp, EpochParams};
use edgellm::runtime::{artifacts_available, Engine};
use edgellm::serving::{EpochServer, ServeOutcome, ServeRequest, ServerConfig};
use std::path::PathBuf;
use std::sync::mpsc::channel;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn server(max_wait_epochs: u64) -> Option<EpochServer> {
    if !artifacts_available(&artifact_dir()) {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    let engine =
        Engine::load_with_variants(&artifact_dir(), "W8A16/RTN", &[1, 2, 4]).expect("engine");
    let cfg = ServerConfig {
        epoch: EpochParams {
            duration: 0.2,
            t_u: 0.02,
            t_d: 0.02,
        },
        max_wait_epochs,
        ..Default::default()
    };
    Some(EpochServer::new(engine, cfg, Box::new(Dftsp::new())))
}

#[test]
fn serves_and_returns_tokens() {
    let Some(mut server) = server(8) else { return };
    let handle = server.handle();
    let (rtx, rrx) = channel();
    for i in 0..3 {
        handle
            .send(ServeRequest {
                prompt: vec![1 + i, 2 + i, 3 + i, 4 + i],
                output_tokens: 5,
                latency_req: 10.0,
                accuracy_req: 0.3,
                respond: rtx.clone(),
                stream: None,
            })
            .unwrap();
    }
    drop(rtx);
    server.run_for(10);
    let responses: Vec<_> = rrx.iter().collect();
    assert_eq!(responses.len(), 3);
    let completed: Vec<_> = responses
        .iter()
        .filter(|r| r.outcome == ServeOutcome::Completed)
        .collect();
    assert!(!completed.is_empty(), "some requests must complete");
    for r in &completed {
        assert_eq!(r.tokens.len(), 5, "requested 5 tokens");
        assert!(r.tokens.iter().all(|&t| (0..512).contains(&t)));
        assert!(r.latency > 0.0);
        assert!(r.epoch.is_some());
    }
    let m = server.metrics();
    assert_eq!(
        m.offered,
        m.completed_in_deadline + m.completed_late + m.dropped
    );
}

#[test]
fn rejects_invalid_requests_immediately() {
    let Some(mut server) = server(8) else { return };
    let handle = server.handle();
    let (rtx, rrx) = channel();
    // empty prompt, oversized prompt, zero output, oversized output
    let bad = vec![
        (vec![], 4u32),
        (vec![1i32; 1000], 4),
        (vec![1, 2, 3], 0),
        (vec![1, 2, 3], 10_000),
    ];
    for (prompt, out) in bad {
        handle
            .send(ServeRequest {
                prompt,
                output_tokens: out,
                latency_req: 10.0,
                accuracy_req: 0.1,
                respond: rtx.clone(),
                stream: None,
            })
            .unwrap();
    }
    drop(rtx);
    server.run_for(2);
    let responses: Vec<_> = rrx.iter().collect();
    assert_eq!(responses.len(), 4);
    assert!(responses
        .iter()
        .all(|r| r.outcome == ServeOutcome::Rejected && r.tokens.is_empty()));
}

#[test]
fn unservable_accuracy_is_rejected_not_starved() {
    let Some(mut server) = server(2) else { return };
    let handle = server.handle();
    let (rtx, rrx) = channel();
    // a=1.0: even the measured near-lossless W8A16/RTN cannot guarantee
    // f(dPPL) >= 1 unless dPPL is exactly 0 — but the request with a huge
    // deadline must still terminate (reject) rather than wait forever.
    handle
        .send(ServeRequest {
            prompt: vec![5, 6, 7],
            output_tokens: 4,
            latency_req: 1000.0,
            accuracy_req: 1.0,
            respond: rtx.clone(),
            stream: None,
        })
        .unwrap();
    drop(rtx);
    server.run_for(6);
    let responses: Vec<_> = rrx.iter().collect();
    assert_eq!(responses.len(), 1, "request must terminate");
}

#[test]
fn tcp_front_end_serves_text_prompts() {
    let Some(mut server) = server(8) else { return };
    let bpe_path = artifact_dir().join("bpe.json");
    if !bpe_path.exists() {
        eprintln!("skipping: bpe.json not built");
        return;
    }
    let bpe = edgellm::tokenizer::Bpe::load(&bpe_path).unwrap();
    let router = edgellm::serving::Router::single(server.model_name(), server.handle(), 64);
    let listener = edgellm::serving::spawn_listener(
        "127.0.0.1:0",
        router,
        Some(bpe),
        edgellm::serving::NetConfig::default(),
    )
    .expect("bind");
    let addr = listener.addr();

    // Client thread speaking the JSON-line protocol over TCP.
    let client = std::thread::spawn(move || {
        use std::io::{BufRead, BufReader, Write};
        let mut stream = std::net::TcpStream::connect(addr).expect("connect");
        writeln!(
            stream,
            r#"{{"prompt": "the scheduler batches requests", "output_tokens": 4, "latency_req": 30.0, "accuracy_req": 0.1}}"#
        )
        .unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        line
    });

    server.run_for(8);
    let line = client.join().expect("client");
    listener.shutdown();
    let j = edgellm::util::json::Json::parse(line.trim()).expect("json reply");
    assert_eq!(j.req_str("outcome").unwrap(), "completed");
    assert_eq!(j.get("ids").unwrap().as_arr().unwrap().len(), 4);
    assert!(j.get("text").is_some(), "reply carries decoded text");
}

#[test]
fn generated_tokens_match_direct_engine_output() {
    // The served result must equal what the engine produces directly — the
    // serving layer adds batching, not nondeterminism.
    let Some(mut server) = server(8) else { return };
    let direct_engine =
        Engine::load_with_variants(&artifact_dir(), "W8A16/RTN", &[1]).expect("engine");
    let prompt = vec![10, 20, 30, 40, 50];
    let want = direct_engine
        .generate_greedy(&[prompt.clone()], 6, None)
        .unwrap();

    let handle = server.handle();
    let (rtx, rrx) = channel();
    handle
        .send(ServeRequest {
            prompt,
            output_tokens: 6,
            latency_req: 30.0,
            accuracy_req: 0.1,
            respond: rtx,
            stream: None,
        })
        .unwrap();
    server.run_for(6);
    let resp = rrx.recv().expect("response");
    assert_eq!(resp.outcome, ServeOutcome::Completed);
    assert_eq!(resp.tokens, want[0]);
}
