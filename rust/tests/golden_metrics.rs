//! Golden `Metrics` fixtures — freeze one epoch-mode and one
//! continuous-mode sim run (fixed seed, the paper's Table I scenario
//! template) as JSON under `tests/golden/`, compared field-by-field with a
//! tolerance, so future refactors can't silently shift `Metrics`.
//!
//! Blessing: the first run (or any run with `UPDATE_GOLDEN=1`) writes the
//! fixture and passes; commit the generated `tests/golden/*.json` files.
//! Subsequent runs compare against the committed fixtures.

use edgellm::coordinator::{Dftsp, SchedulerConfig};
use edgellm::driver::BatchingMode;
use edgellm::metrics::Metrics;
use edgellm::sim::{self, SimConfig};
use edgellm::util::json::Json;
use std::path::PathBuf;

/// The fixtures freeze search-*effort* counters, which legitimately differ
/// between the sequential and parallel d-pool searches (schedules don't).
/// Pin the sequential reference so the fixtures hold under CI's
/// `SCHED_WORKERS` matrix.
fn sequential_dftsp() -> Dftsp {
    Dftsp::with_config(SchedulerConfig { workers: 0 })
}

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// Relative tolerance for field comparison. The simulator is bit-
/// deterministic on one toolchain; the tolerance only absorbs cross-
/// platform float-formatting and libm differences.
const REL_TOL: f64 = 1e-6;

fn check_or_bless(name: &str, m: &Metrics) {
    let path = golden_dir().join(format!("{name}.json"));
    let current = m.to_json();
    if std::env::var("UPDATE_GOLDEN").is_ok() || !path.exists() {
        std::fs::create_dir_all(golden_dir()).expect("create tests/golden");
        std::fs::write(&path, format!("{current}\n")).expect("write fixture");
        eprintln!("blessed golden fixture {path:?} — commit it");
        return;
    }
    let src = std::fs::read_to_string(&path).expect("read fixture");
    let want = Json::parse(src.trim()).expect("fixture parses");
    let (Json::Obj(want_fields), Json::Obj(current_fields)) = (&want, &current) else {
        panic!("golden `{name}`: fixture and metrics must both be JSON objects");
    };
    // Every frozen field must still exist and match; fields *added* to
    // Metrics later are allowed (bless to pick them up). Wall-clock fields
    // are exported for observability but are not bit-deterministic — skip.
    const NON_DETERMINISTIC: &[&str] = &["schedule_wall_s"];
    for (key, want_v) in want_fields {
        if NON_DETERMINISTIC.contains(&key.as_str()) {
            continue;
        }
        let cur_v = current_fields
            .get(key)
            .unwrap_or_else(|| panic!("golden `{name}`: field `{key}` vanished from Metrics"));
        let w = want_v
            .as_f64()
            .unwrap_or_else(|| panic!("golden `{name}`: fixture field `{key}` not numeric"));
        let c = cur_v
            .as_f64()
            .unwrap_or_else(|| panic!("golden `{name}`: current field `{key}` not numeric"));
        let tol = REL_TOL * w.abs().max(1.0);
        assert!(
            (w - c).abs() <= tol,
            "golden `{name}` field `{key}` drifted: fixture {w} vs current {c}\n\
             (intentional change? re-bless with UPDATE_GOLDEN=1 and commit)"
        );
    }
}

/// Paper §IV / Table I scenario, trimmed to a CI-friendly horizon but
/// otherwise untouched: BLOOM-3B, W8A16/GPTQ, 20×TX2, 2 s epochs, λ=50.
fn table1_config() -> SimConfig {
    SimConfig {
        epochs: 15,
        seed: 42,
        ..SimConfig::paper_default()
    }
}

#[test]
fn golden_epoch_mode_dftsp() {
    let m = sim::run(&table1_config(), &mut sequential_dftsp());
    assert!(m.offered > 0 && m.completed_in_deadline > 0, "run not degenerate");
    check_or_bless("epoch_dftsp_table1", &m);
}

#[test]
fn golden_continuous_mode_dftsp() {
    let mut cfg = table1_config();
    cfg.batching = BatchingMode::Continuous;
    let m = sim::run(&cfg, &mut sequential_dftsp());
    assert!(m.offered > 0 && m.completed_in_deadline > 0, "run not degenerate");
    check_or_bless("continuous_dftsp_table1", &m);
}

/// The sharded dispatch layer must not drift either: freeze a 2-shard
/// epoch-mode run of the same scenario (merged metrics, fixed shard-index
/// merge order).
#[test]
fn golden_sharded_epoch_mode_dftsp() {
    let mut cfg = table1_config();
    cfg.shards = 2;
    let m = sim::run_sharded(&cfg, |_| Box::new(sequential_dftsp()));
    assert!(m.offered > 0 && m.completed_in_deadline > 0, "run not degenerate");
    check_or_bless("sharded2_epoch_dftsp_table1", &m);
}
