//! Property tests of the continuous-batching backend: request conservation
//! and the KV-ledger capacity invariant, over randomized small scenarios.
//!
//! Seeded-case harness (no proptest crate offline): `PROPTEST_CASES`
//! controls the case count (CI pins it to 64 for deterministic, bounded
//! runtime); failures report the offending seed for replay.

use edgellm::cluster::{ClusterSpec, GpuSpec};
use edgellm::coordinator::{Dftsp, EpochParams};
use edgellm::driver::{
    ContinuousBackend, DriverPolicy, EpochDriver, InstanceTemplate, SPadPolicy, StalePolicy,
};
use edgellm::model::{CostModel, LlmSpec};
use edgellm::quant;
use edgellm::request::RequestBuilder;
use edgellm::util::rng::Rng;
use edgellm::wireless::{AllocationPolicy, ChannelParams, RadioParams};

fn cases(default: u64) -> u64 {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Random scenario: cluster size, per-GPU memory (some tight enough that
/// the KV gate actually binds), quantization and epoch length all vary.
fn random_template(rng: &mut Rng) -> InstanceTemplate {
    let quants = quant::catalog();
    let quant = quants[rng.below(quants.len() as u64) as usize].clone();
    let mem_bytes = *rng.choice(&[7u64 * (1 << 30), 8 * (1 << 30), 32 * (1 << 30)]);
    InstanceTemplate {
        cost: CostModel::new(LlmSpec::bloom_3b()),
        quant,
        cluster: ClusterSpec::new(
            GpuSpec {
                name: "prop-gpu".into(),
                flops: 1.33e12,
                mem_bytes,
            },
            rng.int_range(1, 8) as usize,
        ),
        epoch: EpochParams {
            duration: rng.uniform(1.0, 3.0),
            t_u: 0.25,
            t_d: 0.25,
        },
    }
}

/// PROPERTY: through the continuous backend, every offered request resolves
/// to exactly one of {completed-in-deadline, completed-late, dropped}
/// (dropped = rejected or stale), and the KV ledger's high-water mark never
/// exceeds its capacity at any decode step.
#[test]
fn prop_continuous_conservation_and_kv_capacity() {
    for seed in 0..cases(64) {
        let mut rng = Rng::new(0xC0_0017 + seed);
        let template = random_template(&mut rng);
        let duration = template.epoch.duration;
        let mut driver: EpochDriver<()> = EpochDriver::new(
            template.clone(),
            DriverPolicy {
                stale: StalePolicy::BestCaseInfeasible,
                s_pad: SPadPolicy::LongestQueued { fallback: 512 },
                allocation: AllocationPolicy::MinOnly,
            },
            RadioParams::default(),
            ChannelParams::default(),
            Rng::new(seed),
        );
        let mut backend = ContinuousBackend::new(&template);
        let mut sched = Dftsp::new();
        let mut b = RequestBuilder::new();
        let epochs = rng.int_range(2, 6);
        let levels = [128u32, 256, 512];
        let mut offered = 0u64;
        for e in 0..epochs {
            let now = e as f64 * duration;
            // Arrivals scattered through the window (the regime the epoch
            // barrier cannot express).
            for _ in 0..rng.int_range(0, 9) {
                let arrival = now + rng.uniform(0.0, duration);
                driver.offer(
                    b.build(
                        arrival,
                        *rng.choice(&levels),
                        *rng.choice(&levels),
                        rng.uniform(0.5, 3.0),
                        rng.uniform(0.0, 1.0),
                    ),
                    (),
                );
                offered += 1;
            }
            driver.step_epoch(&mut sched, &mut backend, now);
            // Invariant holds at every step, so in particular between epochs.
            assert!(
                backend.ledger().peak() <= backend.ledger().capacity(),
                "seed {seed}: KV peak {} exceeds capacity {}",
                backend.ledger().peak(),
                backend.ledger().capacity()
            );
        }
        driver.finish(&mut backend, epochs as f64 * duration);

        assert_eq!(backend.in_flight(), 0, "seed {seed}: finish drains flights");
        assert_eq!(backend.pending(), 0, "seed {seed}: finish drains the gate");
        assert_eq!(
            backend.ledger().in_use(),
            0,
            "seed {seed}: all reservations returned"
        );
        assert!(
            backend.ledger().peak() <= backend.ledger().capacity(),
            "seed {seed}: KV in use exceeded capacity"
        );

        let m = driver.into_metrics();
        assert_eq!(m.offered, offered, "seed {seed}: offered count");
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped,
            "seed {seed}: every request must resolve exactly once"
        );
    }
}

/// PROPERTY: the continuous backend is deterministic — identical scenario
/// and seeds give bit-identical metrics.
#[test]
fn prop_continuous_deterministic() {
    for seed in 0..cases(64).min(16) {
        let run = || {
            let mut rng = Rng::new(0xD0_0017 + seed);
            let template = random_template(&mut rng);
            let duration = template.epoch.duration;
            let mut driver: EpochDriver<()> = EpochDriver::new(
                template.clone(),
                DriverPolicy {
                    stale: StalePolicy::BestCaseInfeasible,
                    s_pad: SPadPolicy::LongestQueued { fallback: 512 },
                    allocation: AllocationPolicy::MinOnly,
                },
                RadioParams::default(),
                ChannelParams::default(),
                Rng::new(seed),
            );
            let mut backend = ContinuousBackend::new(&template);
            let mut sched = Dftsp::new();
            let mut b = RequestBuilder::new();
            for e in 0..4u64 {
                let now = e as f64 * duration;
                for i in 0..5 {
                    driver.offer(
                        b.build(now + 0.17 * i as f64, 128, 256, 2.0, 0.2),
                        (),
                    );
                }
                driver.step_epoch(&mut sched, &mut backend, now);
            }
            driver.finish(&mut backend, 4.0 * duration);
            driver.into_metrics()
        };
        assert_eq!(run(), run(), "seed {seed}");
    }
}
