//! TCP front-end end-to-end tests on real loopback sockets: typed
//! rejections, connection reuse after errors, deterministic shed under a
//! full admission gate, model-name routing across shards, streaming, and
//! liveness timeouts. Synthetic host engines only — no artifacts needed.
//!
//! Every scenario runs against both io models (threaded and, on Linux,
//! evented): the threaded path is the behavioral oracle, and the evented
//! path must be byte-identical on the wire. Evented-specific regressions
//! (slowloris, slow stream readers, mid-flight disconnects) are at the
//! bottom.
#![cfg(not(feature = "pjrt"))]

use edgellm::coordinator::{Dftsp, EpochParams};
use edgellm::quant::Precision;
use edgellm::runtime::{Engine, SyntheticSpec};
use edgellm::serving::{
    serve_sharded, spawn_listener, EpochServer, IoModel, NetConfig, Router, ServerConfig,
};
use edgellm::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

/// The io models this platform can run: both on Linux, threaded elsewhere.
fn io_models() -> Vec<IoModel> {
    #[cfg(target_os = "linux")]
    {
        vec![IoModel::Threaded, IoModel::Evented]
    }
    #[cfg(not(target_os = "linux"))]
    {
        vec![IoModel::Threaded]
    }
}

fn net_cfg(io: IoModel) -> NetConfig {
    NetConfig {
        io_model: io,
        ..Default::default()
    }
}

fn tiny_server() -> EpochServer {
    let cfg = ServerConfig {
        epoch: EpochParams {
            duration: 0.05,
            t_u: 0.005,
            t_d: 0.005,
        },
        ..Default::default()
    };
    EpochServer::new(
        Engine::synthetic(&SyntheticSpec::tiny(), Precision::W16A16),
        cfg,
        Box::new(Dftsp::new()),
    )
}

fn connect(addr: std::net::SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s
}

fn send_line(s: &mut TcpStream, line: &str) {
    writeln!(s, "{line}").expect("write request");
}

fn read_reply(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    let n = reader.read_line(&mut line).expect("read reply");
    assert!(n > 0, "connection closed instead of replying");
    Json::parse(line.trim()).expect("reply is well-formed JSON")
}

#[test]
fn well_formed_ids_request_completes_and_matches_direct_engine() {
    for io in io_models() {
        let mut server = tiny_server();
        let router = Router::single(server.model_name(), server.handle(), 64);
        let listener = spawn_listener("127.0.0.1:0", router, None, net_cfg(io)).expect("bind");
        let addr = listener.addr();
        // The served tokens must equal the engine's direct greedy decode —
        // the wire adds transport, not nondeterminism. This also pins the
        // single shard `--listen` path to the unsharded reply content.
        let want = Engine::synthetic(&SyntheticSpec::tiny(), Precision::W16A16)
            .generate_greedy(&[vec![1, 2, 3]], 4, None)
            .unwrap()[0]
            .clone();

        let client = std::thread::spawn(move || {
            let mut s = connect(addr);
            send_line(
                &mut s,
                r#"{"ids": [1, 2, 3], "output_tokens": 4, "latency_req": 30.0}"#,
            );
            let mut reader = BufReader::new(s);
            read_reply(&mut reader)
        });
        server.run_for(20);
        let j = client.join().unwrap();
        assert_eq!(j.req_str("outcome").unwrap(), "completed", "{io}");
        let ids: Vec<i32> = j
            .get("ids")
            .and_then(|v| v.as_arr())
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as i32)
            .collect();
        assert_eq!(ids, want, "{io}");
        assert!(listener.wait_drained(Duration::from_secs(10)), "{io}");
        assert_eq!(listener.net_metrics().net_connections, 1, "{io}");
        listener.shutdown();
    }
}

#[test]
fn malformed_requests_get_typed_errors_and_connection_survives() {
    for io in io_models() {
        let mut server = tiny_server();
        let router = Router::single(server.model_name(), server.handle(), 64);
        let listener = spawn_listener("127.0.0.1:0", router, None, net_cfg(io)).expect("bind");
        let addr = listener.addr();

        let client = std::thread::spawn(move || {
            let mut s = connect(addr);
            let mut reader = BufReader::new(s.try_clone().unwrap());
            // Every malformed class gets a typed `bad_request` on the SAME
            // connection — a client bug must not kill the transport.
            let malformed = [
                "not json at all",
                r#"{"output_tokens": 4}"#,
                r#"{"ids": [], "output_tokens": 4}"#,
                r#"{"ids": [1.5], "output_tokens": 4}"#,
                r#"{"ids": [1], "output_tokens": 0}"#,
                r#"{"ids": [1], "output_tokens": -5}"#,
                r#"{"ids": [1], "output_tokens": 3.5}"#,
                r#"{"ids": [1], "output_tokens": 1e400}"#,
                r#"{"ids": [1], "output_tokens": 1e12}"#,
                r#"{"ids": [1], "output_tokens": 4, "latency_req": "2.0"}"#,
                r#"{"ids": [1], "output_tokens": 4, "accuracy_req": true}"#,
                r#"{"ids": [1], "output_tokens": 4, "model": 7}"#,
                r#"{"ids": [1], "output_tokens": 4, "stream": "yes"}"#,
                r#"{"ids": [1], "output_tokens": 4, "model": "no-such-deployment"}"#,
            ];
            for line in malformed {
                send_line(&mut s, line);
                let j = read_reply(&mut reader);
                assert_eq!(j.req_str("outcome").unwrap(), "rejected", "{line}");
                assert_eq!(j.req_str("reason").unwrap(), "bad_request", "{line}");
            }
            // The connection is still usable for a good request afterwards.
            send_line(
                &mut s,
                r#"{"ids": [1, 2], "output_tokens": 2, "latency_req": 30.0}"#,
            );
            read_reply(&mut reader)
        });
        server.run_for(20);
        let j = client.join().unwrap();
        assert_eq!(j.req_str("outcome").unwrap(), "completed", "{io}");
        let net = listener.net_metrics();
        assert_eq!(net.bad_requests, 14, "every malformed line counted ({io})");
        assert!(listener.wait_drained(Duration::from_secs(10)), "{io}");
        listener.shutdown();
    }
}

#[test]
fn full_gate_sheds_with_typed_overloaded_reply() {
    for io in io_models() {
        let mut server = tiny_server();
        // cap = 1: with the epoch loop not yet running, the first admitted
        // request parks on its reply and holds the only permit; the other
        // is shed immediately with a typed `overloaded`. Exactly one of
        // each, whatever the arrival order.
        let router = Router::single(server.model_name(), server.handle(), 1);
        let listener = spawn_listener("127.0.0.1:0", router, None, net_cfg(io)).expect("bind");
        let addr = listener.addr();

        let mut a = connect(addr);
        send_line(
            &mut a,
            r#"{"ids": [1, 2], "output_tokens": 2, "latency_req": 30.0}"#,
        );
        // Give A's request time to take the permit before B arrives (the
        // assertion below holds for either winner; this just makes the
        // common path deterministic).
        std::thread::sleep(Duration::from_millis(300));
        let mut b = connect(addr);
        send_line(
            &mut b,
            r#"{"ids": [3, 4], "output_tokens": 2, "latency_req": 30.0}"#,
        );
        std::thread::sleep(Duration::from_millis(300));

        // Only now does the server start serving: the shed happened under a
        // genuinely full gate, not a race with completions.
        server.run_for(20);
        let mut ra = BufReader::new(a);
        let mut rb = BufReader::new(b);
        let ja = read_reply(&mut ra);
        let jb = read_reply(&mut rb);
        let outcomes = [
            ja.req_str("outcome").unwrap().to_string(),
            jb.req_str("outcome").unwrap().to_string(),
        ];
        assert!(
            outcomes.contains(&"completed".to_string()),
            "the permit holder completes ({io}): {outcomes:?}"
        );
        assert!(
            outcomes.contains(&"rejected".to_string()),
            "the other is shed ({io}): {outcomes:?}"
        );
        let shed = if outcomes[0] == "rejected" { &ja } else { &jb };
        assert_eq!(shed.req_str("reason").unwrap(), "overloaded", "{io}");
        assert_eq!(listener.net_metrics().shed_overloaded, 1, "{io}");
        drop(ra);
        drop(rb);
        assert!(listener.wait_drained(Duration::from_secs(10)), "{io}");
        listener.shutdown();
    }
}

#[test]
fn model_name_routes_to_the_matching_shard() {
    for io in io_models() {
        let make = |shard: usize| {
            let mut engine = Engine::synthetic(&SyntheticSpec::tiny(), Precision::W16A16);
            engine.meta.model_name = format!("m{shard}");
            let cfg = ServerConfig {
                epoch: EpochParams {
                    duration: 0.05,
                    t_u: 0.005,
                    t_d: 0.005,
                },
                seed: 7 + shard as u64,
                ..Default::default()
            };
            EpochServer::new(engine, cfg, Box::new(Dftsp::new()))
        };
        let per_shard = serve_sharded(2, 40, make, |handles| {
            assert_eq!(handles[0].model, "m0");
            assert_eq!(handles[1].model, "m1");
            let router = Router::new(
                handles
                    .iter()
                    .map(|h| (h.model.clone(), h.handle.clone()))
                    .collect(),
                64,
            );
            let listener = spawn_listener("127.0.0.1:0", router, None, net_cfg(io)).expect("bind");
            let addr = listener.addr();
            // One request per model name, both over the same wire endpoint.
            for model in ["m0", "m1"] {
                let mut s = connect(addr);
                send_line(
                    &mut s,
                    &format!(
                        r#"{{"ids": [1, 2], "output_tokens": 2, "latency_req": 30.0, "model": "{model}"}}"#
                    ),
                );
                let j = read_reply(&mut BufReader::new(s));
                assert_eq!(j.req_str("outcome").unwrap(), "completed", "{model} ({io})");
            }
            assert!(listener.wait_drained(Duration::from_secs(10)), "{io}");
            listener.shutdown();
        });
        // Affinity, not load, decided the shard: one request landed on each.
        assert_eq!(per_shard[0].offered, 1, "m0 went to shard 0 ({io})");
        assert_eq!(per_shard[1].offered, 1, "m1 went to shard 1 ({io})");
    }
}

#[test]
fn streamed_tokens_arrive_before_and_match_the_final_reply() {
    for io in io_models() {
        let mut server = tiny_server();
        let router = Router::single(server.model_name(), server.handle(), 64);
        let listener = spawn_listener("127.0.0.1:0", router, None, net_cfg(io)).expect("bind");
        let addr = listener.addr();

        let client = std::thread::spawn(move || {
            let mut s = connect(addr);
            send_line(
                &mut s,
                r#"{"ids": [1, 2, 3], "output_tokens": 4, "latency_req": 30.0, "stream": true}"#,
            );
            let mut reader = BufReader::new(s);
            let mut streamed: Vec<i32> = Vec::new();
            loop {
                let j = read_reply(&mut reader);
                if let Some(tok) = j.get("token") {
                    streamed.push(tok.as_f64().unwrap() as i32);
                } else {
                    return (streamed, j);
                }
            }
        });
        server.run_for(20);
        let (streamed, fin) = client.join().unwrap();
        assert_eq!(fin.req_str("outcome").unwrap(), "completed", "{io}");
        let ids: Vec<i32> = fin
            .get("ids")
            .and_then(|v| v.as_arr())
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as i32)
            .collect();
        assert_eq!(streamed.len(), 4, "one event per generated token ({io})");
        assert_eq!(streamed, ids, "stream and final reply agree ({io})");
        listener.shutdown();
    }
}

#[test]
fn reply_timeout_is_typed_and_releases_the_connection() {
    for io in io_models() {
        let server = tiny_server(); // never run: every reply wait times out
        let cfg = NetConfig {
            reply_timeout: Duration::from_millis(200),
            ..net_cfg(io)
        };
        let router = Router::single(server.model_name(), server.handle(), 4);
        let listener = spawn_listener("127.0.0.1:0", router, None, cfg).expect("bind");
        let mut s = connect(listener.addr());
        send_line(
            &mut s,
            r#"{"ids": [1], "output_tokens": 1, "latency_req": 30.0}"#,
        );
        let mut reader = BufReader::new(s);
        let j = read_reply(&mut reader);
        assert_eq!(j.req_str("outcome").unwrap(), "rejected", "{io}");
        assert_eq!(j.req_str("reason").unwrap(), "timeout", "{io}");
        // The server closes after a timeout (a late reply would desync the
        // line protocol): the next read sees EOF, and the handler exits.
        let mut rest = String::new();
        assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "{io}");
        assert!(listener.wait_drained(Duration::from_secs(10)), "{io}");
        assert_eq!(listener.net_metrics().net_timeouts, 1, "{io}");
        listener.shutdown();
    }
}

#[test]
fn idle_connections_are_reaped_not_leaked() {
    for io in io_models() {
        let server = tiny_server(); // never run; nothing is ever submitted
        let cfg = NetConfig {
            idle_timeout: Duration::from_millis(200),
            ..net_cfg(io)
        };
        let router = Router::single(server.model_name(), server.handle(), 4);
        let listener = spawn_listener("127.0.0.1:0", router, None, cfg).expect("bind");
        let s = connect(listener.addr());
        // Send nothing: the server must hang up on us, not park a thread
        // forever on a silent connection.
        let mut reader = BufReader::new(s);
        let mut line = String::new();
        assert_eq!(
            reader.read_line(&mut line).unwrap(),
            0,
            "server hangs up ({io})"
        );
        assert!(listener.wait_drained(Duration::from_secs(10)), "{io}");
        assert_eq!(listener.open_connections(), 0, "{io}");
        listener.shutdown();
    }
}

#[test]
fn per_peer_cap_rejects_with_typed_reply_and_frees_the_slot() {
    for io in io_models() {
        let server = tiny_server(); // never run; the cap check is at accept
        let cfg = NetConfig {
            max_conns_per_peer: 2,
            ..net_cfg(io)
        };
        let router = Router::single(server.model_name(), server.handle(), 4);
        let listener = spawn_listener("127.0.0.1:0", router, None, cfg).expect("bind");
        let addr = listener.addr();
        let a = connect(addr);
        let b = connect(addr);
        // Accepts are sequential in both io models, so by the time the
        // third connection from this peer IP is accepted, the first two
        // hold both slots: typed `per_peer_limit` reject, then close —
        // without ever reading a request line.
        let c = connect(addr);
        let mut rc = BufReader::new(c);
        let j = read_reply(&mut rc);
        assert_eq!(j.req_str("outcome").unwrap(), "rejected", "{io}");
        assert_eq!(j.req_str("reason").unwrap(), "per_peer_limit", "{io}");
        let mut rest = String::new();
        assert_eq!(
            rc.read_line(&mut rest).unwrap(),
            0,
            "closed after the typed reject ({io})"
        );
        // Releasing one in-cap connection frees its slot for a newcomer.
        drop(a);
        std::thread::sleep(Duration::from_millis(300));
        let d = connect(addr);
        let mut rd = BufReader::new(d);
        rd.get_ref()
            .set_read_timeout(Some(Duration::from_millis(500)))
            .unwrap();
        let mut line = String::new();
        // No typed reject arrives: the read times out (or the idle reap
        // eventually EOFs) instead of returning a `per_peer_limit` line.
        if rd.read_line(&mut line).is_ok() && !line.is_empty() {
            let j = Json::parse(line.trim()).unwrap();
            assert_ne!(
                j.req_str("reason").ok(),
                Some("per_peer_limit"),
                "slot was freed ({io})"
            );
        }
        drop(b);
        drop(rd);
        assert!(listener.wait_drained(Duration::from_secs(10)), "{io}");
        let net = listener.net_metrics();
        assert_eq!(net.shed_per_peer, 1, "{io}");
        // The rejected connection is never counted as accepted, identically
        // in both models.
        assert_eq!(net.net_connections, 3, "{io}");
        listener.shutdown();
    }
}

/// A byte-at-a-time client (the classic slowloris shape) must still get a
/// complete reply: line assembly is incremental, bounded, and per-connection
/// — one slow writer cannot stall anyone else.
#[test]
fn slowloris_byte_at_a_time_request_still_completes() {
    for io in io_models() {
        let mut server = tiny_server();
        let router = Router::single(server.model_name(), server.handle(), 64);
        let listener = spawn_listener("127.0.0.1:0", router, None, net_cfg(io)).expect("bind");
        let addr = listener.addr();
        let client = std::thread::spawn(move || {
            let mut s = connect(addr);
            let line = "{\"ids\": [1, 2], \"output_tokens\": 2, \"latency_req\": 30.0}\n";
            for b in line.as_bytes() {
                s.write_all(std::slice::from_ref(b)).expect("write byte");
                s.flush().unwrap();
                std::thread::sleep(Duration::from_millis(2));
            }
            read_reply(&mut BufReader::new(s))
        });
        server.run_for(40);
        let j = client.join().unwrap();
        assert_eq!(j.req_str("outcome").unwrap(), "completed", "{io}");
        assert!(listener.wait_drained(Duration::from_secs(10)), "{io}");
        listener.shutdown();
    }
}

/// A streaming client that stops reading mid-generation must still receive
/// every token line, in order, before the final reply — queued writes park
/// in the out buffer (evented: re-armed on EPOLLOUT) instead of being
/// dropped or reordered.
#[test]
fn slow_stream_reader_still_gets_every_token_in_order() {
    for io in io_models() {
        let mut server = tiny_server();
        let router = Router::single(server.model_name(), server.handle(), 64);
        let listener = spawn_listener("127.0.0.1:0", router, None, net_cfg(io)).expect("bind");
        let addr = listener.addr();
        let client = std::thread::spawn(move || {
            let mut s = connect(addr);
            send_line(
                &mut s,
                r#"{"ids": [1, 2, 3], "output_tokens": 8, "latency_req": 30.0, "stream": true}"#,
            );
            // Let the whole generation finish before reading a single byte:
            // every token event is queued server-side by now.
            std::thread::sleep(Duration::from_millis(1500));
            let mut reader = BufReader::new(s);
            let mut streamed: Vec<i32> = Vec::new();
            loop {
                let j = read_reply(&mut reader);
                if let Some(tok) = j.get("token") {
                    streamed.push(tok.as_f64().unwrap() as i32);
                } else {
                    return (streamed, j);
                }
            }
        });
        server.run_for(40);
        let (streamed, fin) = client.join().unwrap();
        assert_eq!(fin.req_str("outcome").unwrap(), "completed", "{io}");
        let ids: Vec<i32> = fin
            .get("ids")
            .and_then(|v| v.as_arr())
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as i32)
            .collect();
        assert_eq!(streamed, ids, "late reader sees the full stream ({io})");
        listener.shutdown();
    }
}

/// A client that vanishes with its request in flight must not leak the gate
/// permit or the connection slot: the eventual reply hits a dead socket and
/// the teardown releases everything.
#[test]
fn disconnect_mid_flight_releases_permit_and_connection() {
    for io in io_models() {
        let mut server = tiny_server();
        let router = Router::single(server.model_name(), server.handle(), 1);
        let listener = spawn_listener("127.0.0.1:0", router, None, net_cfg(io)).expect("bind");
        let addr = listener.addr();
        {
            let mut s = connect(addr);
            send_line(
                &mut s,
                r#"{"ids": [1, 2], "output_tokens": 2, "latency_req": 30.0}"#,
            );
            // Dropped here: the client is gone before its reply exists.
        }
        server.run_for(20);
        assert!(listener.wait_drained(Duration::from_secs(10)), "{io}");
        assert_eq!(
            listener.gate_depths().iter().sum::<usize>(),
            0,
            "permit released ({io})"
        );
        assert_eq!(listener.open_connections(), 0, "{io}");
        listener.shutdown();
    }
}

/// The evented model must produce byte-identical wire traffic to the
/// threaded oracle across completions, typed rejections, and streaming —
/// after dropping the two wall-clock fields (`latency`, `epoch`) that are
/// nondeterministic run to run even within one io model.
#[cfg(target_os = "linux")]
#[test]
fn replies_are_byte_identical_across_io_models() {
    fn session(io: IoModel) -> Vec<String> {
        let mut server = tiny_server();
        let router = Router::single(server.model_name(), server.handle(), 64);
        let listener = spawn_listener("127.0.0.1:0", router, None, net_cfg(io)).expect("bind");
        let addr = listener.addr();
        let client = std::thread::spawn(move || {
            let mut s = connect(addr);
            let mut reader = BufReader::new(s.try_clone().unwrap());
            let script = [
                r#"{"ids": [1, 2, 3], "output_tokens": 4, "latency_req": 30.0}"#,
                "not json at all",
                r#"{"ids": [1], "output_tokens": 0}"#,
                r#"{"ids": [1], "output_tokens": 4, "model": "no-such-deployment"}"#,
                r#"{"ids": [1, 2, 3], "output_tokens": 4, "latency_req": 30.0, "stream": true}"#,
            ];
            let mut lines = Vec::new();
            for line in script {
                send_line(&mut s, line);
                // Collect every raw wire line up to and including the final
                // reply for this request (stream events have no "outcome").
                loop {
                    let mut reply = String::new();
                    let n = reader.read_line(&mut reply).expect("read");
                    assert!(n > 0, "connection closed mid-script");
                    let done = Json::parse(reply.trim())
                        .expect("well-formed")
                        .get("outcome")
                        .is_some();
                    lines.push(reply.trim_end().to_string());
                    if done {
                        break;
                    }
                }
            }
            lines
        });
        server.run_for(40);
        let lines = client.join().unwrap();
        assert!(listener.wait_drained(Duration::from_secs(10)));
        listener.shutdown();
        lines
    }

    fn normalize(lines: &[String]) -> Vec<String> {
        lines
            .iter()
            .map(|l| {
                let mut j = Json::parse(l).expect("wire line parses");
                if let Json::Obj(m) = &mut j {
                    m.remove("latency");
                    m.remove("epoch");
                }
                j.to_string()
            })
            .collect()
    }

    let threaded = session(IoModel::Threaded);
    let evented = session(IoModel::Evented);
    assert_eq!(
        normalize(&threaded),
        normalize(&evented),
        "wire replies diverge between io models"
    );
}
