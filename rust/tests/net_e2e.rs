//! TCP front-end end-to-end tests on real loopback sockets: typed
//! rejections, connection reuse after errors, deterministic shed under a
//! full admission gate, model-name routing across shards, streaming, and
//! liveness timeouts. Synthetic host engines only — no artifacts needed.
#![cfg(not(feature = "pjrt"))]

use edgellm::coordinator::{Dftsp, EpochParams};
use edgellm::quant::Precision;
use edgellm::runtime::{Engine, SyntheticSpec};
use edgellm::serving::{
    serve_sharded, spawn_listener, EpochServer, NetConfig, Router, ServerConfig,
};
use edgellm::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

fn tiny_server() -> EpochServer {
    let cfg = ServerConfig {
        epoch: EpochParams {
            duration: 0.05,
            t_u: 0.005,
            t_d: 0.005,
        },
        ..Default::default()
    };
    EpochServer::new(
        Engine::synthetic(&SyntheticSpec::tiny(), Precision::W16A16),
        cfg,
        Box::new(Dftsp::new()),
    )
}

fn connect(addr: std::net::SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s
}

fn send_line(s: &mut TcpStream, line: &str) {
    writeln!(s, "{line}").expect("write request");
}

fn read_reply(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    let n = reader.read_line(&mut line).expect("read reply");
    assert!(n > 0, "connection closed instead of replying");
    Json::parse(line.trim()).expect("reply is well-formed JSON")
}

#[test]
fn well_formed_ids_request_completes_and_matches_direct_engine() {
    let mut server = tiny_server();
    let router = Router::single(server.model_name(), server.handle(), 64);
    let listener =
        spawn_listener("127.0.0.1:0", router, None, NetConfig::default()).expect("bind");
    let addr = listener.addr();
    // The served tokens must equal the engine's direct greedy decode — the
    // wire adds transport, not nondeterminism. This also pins the single
    // shard `--listen` path to the unsharded reply content.
    let want = Engine::synthetic(&SyntheticSpec::tiny(), Precision::W16A16)
        .generate_greedy(&[vec![1, 2, 3]], 4, None)
        .unwrap()[0]
        .clone();

    let client = std::thread::spawn(move || {
        let mut s = connect(addr);
        send_line(
            &mut s,
            r#"{"ids": [1, 2, 3], "output_tokens": 4, "latency_req": 30.0}"#,
        );
        let mut reader = BufReader::new(s);
        read_reply(&mut reader)
    });
    server.run_for(20);
    let j = client.join().unwrap();
    assert_eq!(j.req_str("outcome").unwrap(), "completed");
    let ids: Vec<i32> = j
        .get("ids")
        .and_then(|v| v.as_arr())
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as i32)
        .collect();
    assert_eq!(ids, want);
    assert!(listener.wait_drained(Duration::from_secs(10)));
    assert_eq!(listener.net_metrics().net_connections, 1);
    listener.shutdown();
}

#[test]
fn malformed_requests_get_typed_errors_and_connection_survives() {
    let mut server = tiny_server();
    let router = Router::single(server.model_name(), server.handle(), 64);
    let listener =
        spawn_listener("127.0.0.1:0", router, None, NetConfig::default()).expect("bind");
    let addr = listener.addr();

    let client = std::thread::spawn(move || {
        let mut s = connect(addr);
        let mut reader = BufReader::new(s.try_clone().unwrap());
        // Every malformed class gets a typed `bad_request` on the SAME
        // connection — a client bug must not kill the transport.
        let malformed = [
            "not json at all",
            r#"{"output_tokens": 4}"#,
            r#"{"ids": [], "output_tokens": 4}"#,
            r#"{"ids": [1.5], "output_tokens": 4}"#,
            r#"{"ids": [1], "output_tokens": 0}"#,
            r#"{"ids": [1], "output_tokens": -5}"#,
            r#"{"ids": [1], "output_tokens": 3.5}"#,
            r#"{"ids": [1], "output_tokens": 1e400}"#,
            r#"{"ids": [1], "output_tokens": 1e12}"#,
            r#"{"ids": [1], "output_tokens": 4, "latency_req": "2.0"}"#,
            r#"{"ids": [1], "output_tokens": 4, "accuracy_req": true}"#,
            r#"{"ids": [1], "output_tokens": 4, "model": 7}"#,
            r#"{"ids": [1], "output_tokens": 4, "stream": "yes"}"#,
            r#"{"ids": [1], "output_tokens": 4, "model": "no-such-deployment"}"#,
        ];
        for line in malformed {
            send_line(&mut s, line);
            let j = read_reply(&mut reader);
            assert_eq!(j.req_str("outcome").unwrap(), "rejected", "{line}");
            assert_eq!(j.req_str("reason").unwrap(), "bad_request", "{line}");
        }
        // The connection is still usable for a good request afterwards.
        send_line(
            &mut s,
            r#"{"ids": [1, 2], "output_tokens": 2, "latency_req": 30.0}"#,
        );
        read_reply(&mut reader)
    });
    server.run_for(20);
    let j = client.join().unwrap();
    assert_eq!(j.req_str("outcome").unwrap(), "completed");
    let net = listener.net_metrics();
    assert_eq!(net.bad_requests, 14, "every malformed line counted");
    assert!(listener.wait_drained(Duration::from_secs(10)));
    listener.shutdown();
}

#[test]
fn full_gate_sheds_with_typed_overloaded_reply() {
    let mut server = tiny_server();
    // cap = 1: with the epoch loop not yet running, the first admitted
    // request parks on its reply and holds the only permit; the other is
    // shed immediately with a typed `overloaded`. Exactly one of each,
    // whatever the arrival order.
    let router = Router::single(server.model_name(), server.handle(), 1);
    let listener =
        spawn_listener("127.0.0.1:0", router, None, NetConfig::default()).expect("bind");
    let addr = listener.addr();

    let mut a = connect(addr);
    send_line(
        &mut a,
        r#"{"ids": [1, 2], "output_tokens": 2, "latency_req": 30.0}"#,
    );
    // Give A's handler time to take the permit before B arrives (the
    // assertion below holds for either winner; this just makes the common
    // path deterministic).
    std::thread::sleep(Duration::from_millis(300));
    let mut b = connect(addr);
    send_line(
        &mut b,
        r#"{"ids": [3, 4], "output_tokens": 2, "latency_req": 30.0}"#,
    );
    std::thread::sleep(Duration::from_millis(300));

    // Only now does the server start serving: the shed happened under a
    // genuinely full gate, not a race with completions.
    server.run_for(20);
    let mut ra = BufReader::new(a);
    let mut rb = BufReader::new(b);
    let ja = read_reply(&mut ra);
    let jb = read_reply(&mut rb);
    let outcomes = [
        ja.req_str("outcome").unwrap().to_string(),
        jb.req_str("outcome").unwrap().to_string(),
    ];
    assert!(
        outcomes.contains(&"completed".to_string()),
        "the permit holder completes: {outcomes:?}"
    );
    assert!(
        outcomes.contains(&"rejected".to_string()),
        "the other is shed: {outcomes:?}"
    );
    let shed = if outcomes[0] == "rejected" { &ja } else { &jb };
    assert_eq!(shed.req_str("reason").unwrap(), "overloaded");
    assert_eq!(listener.net_metrics().shed_overloaded, 1);
    drop(ra);
    drop(rb);
    assert!(listener.wait_drained(Duration::from_secs(10)));
    listener.shutdown();
}

#[test]
fn model_name_routes_to_the_matching_shard() {
    let make = |shard: usize| {
        let mut engine = Engine::synthetic(&SyntheticSpec::tiny(), Precision::W16A16);
        engine.meta.model_name = format!("m{shard}");
        let cfg = ServerConfig {
            epoch: EpochParams {
                duration: 0.05,
                t_u: 0.005,
                t_d: 0.005,
            },
            seed: 7 + shard as u64,
            ..Default::default()
        };
        EpochServer::new(engine, cfg, Box::new(Dftsp::new()))
    };
    let per_shard = serve_sharded(2, 40, make, |handles| {
        assert_eq!(handles[0].model, "m0");
        assert_eq!(handles[1].model, "m1");
        let router = Router::new(
            handles
                .iter()
                .map(|h| (h.model.clone(), h.handle.clone()))
                .collect(),
            64,
        );
        let listener =
            spawn_listener("127.0.0.1:0", router, None, NetConfig::default()).expect("bind");
        let addr = listener.addr();
        // One request per model name, both over the same wire endpoint.
        for model in ["m0", "m1"] {
            let mut s = connect(addr);
            send_line(
                &mut s,
                &format!(
                    r#"{{"ids": [1, 2], "output_tokens": 2, "latency_req": 30.0, "model": "{model}"}}"#
                ),
            );
            let j = read_reply(&mut BufReader::new(s));
            assert_eq!(j.req_str("outcome").unwrap(), "completed", "{model}");
        }
        assert!(listener.wait_drained(Duration::from_secs(10)));
        listener.shutdown();
    });
    // Affinity, not load, decided the shard: one request landed on each.
    assert_eq!(per_shard[0].offered, 1, "m0 went to shard 0");
    assert_eq!(per_shard[1].offered, 1, "m1 went to shard 1");
}

#[test]
fn streamed_tokens_arrive_before_and_match_the_final_reply() {
    let mut server = tiny_server();
    let router = Router::single(server.model_name(), server.handle(), 64);
    let listener =
        spawn_listener("127.0.0.1:0", router, None, NetConfig::default()).expect("bind");
    let addr = listener.addr();

    let client = std::thread::spawn(move || {
        let mut s = connect(addr);
        send_line(
            &mut s,
            r#"{"ids": [1, 2, 3], "output_tokens": 4, "latency_req": 30.0, "stream": true}"#,
        );
        let mut reader = BufReader::new(s);
        let mut streamed: Vec<i32> = Vec::new();
        loop {
            let j = read_reply(&mut reader);
            if let Some(tok) = j.get("token") {
                streamed.push(tok.as_f64().unwrap() as i32);
            } else {
                return (streamed, j);
            }
        }
    });
    server.run_for(20);
    let (streamed, fin) = client.join().unwrap();
    assert_eq!(fin.req_str("outcome").unwrap(), "completed");
    let ids: Vec<i32> = fin
        .get("ids")
        .and_then(|v| v.as_arr())
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as i32)
        .collect();
    assert_eq!(streamed.len(), 4, "one event per generated token");
    assert_eq!(streamed, ids, "stream and final reply agree");
    listener.shutdown();
}

#[test]
fn reply_timeout_is_typed_and_releases_the_connection() {
    let server = tiny_server(); // never run: every reply wait times out
    let cfg = NetConfig {
        reply_timeout: Duration::from_millis(200),
        ..Default::default()
    };
    let router = Router::single(server.model_name(), server.handle(), 4);
    let listener = spawn_listener("127.0.0.1:0", router, None, cfg).expect("bind");
    let mut s = connect(listener.addr());
    send_line(
        &mut s,
        r#"{"ids": [1], "output_tokens": 1, "latency_req": 30.0}"#,
    );
    let mut reader = BufReader::new(s);
    let j = read_reply(&mut reader);
    assert_eq!(j.req_str("outcome").unwrap(), "rejected");
    assert_eq!(j.req_str("reason").unwrap(), "timeout");
    // The server closes after a timeout (a late reply would desync the
    // line protocol): the next read sees EOF, and the handler exits.
    let mut rest = String::new();
    assert_eq!(reader.read_line(&mut rest).unwrap(), 0);
    assert!(listener.wait_drained(Duration::from_secs(10)));
    assert_eq!(listener.net_metrics().net_timeouts, 1);
    listener.shutdown();
}

#[test]
fn idle_connections_are_reaped_not_leaked() {
    let server = tiny_server(); // never run; nothing is ever submitted
    let cfg = NetConfig {
        idle_timeout: Duration::from_millis(200),
        ..Default::default()
    };
    let router = Router::single(server.model_name(), server.handle(), 4);
    let listener = spawn_listener("127.0.0.1:0", router, None, cfg).expect("bind");
    let s = connect(listener.addr());
    // Send nothing: the server must hang up on us, not park a thread
    // forever on a silent connection.
    let mut reader = BufReader::new(s);
    let mut line = String::new();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "server hangs up");
    assert!(listener.wait_drained(Duration::from_secs(10)));
    assert_eq!(listener.open_connections(), 0);
    listener.shutdown();
}
