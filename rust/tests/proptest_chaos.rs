//! Property tests of the chaos-injection harness and crash conservation.
//!
//! Seeded-case harness (no proptest crate offline): `PROPTEST_CASES`
//! controls the case count (CI pins it to 64); failures report the
//! offending seed for replay.

use edgellm::driver::{BatchingMode, ChaosConfig};
use edgellm::coordinator::Dftsp;
use edgellm::sim::{self, SimConfig};
use edgellm::util::rng::Rng;
use edgellm::workload::WorkloadParams;

fn cases(default: u64) -> u64 {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Injected panics are caught by the shard supervisor, but the default
/// panic hook still prints each one — suppress the expected spew so a
/// 64-case run does not bury real failures in noise.
fn silence_injected_panics() {
    use std::sync::Once;
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let default_hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            let injected = payload
                .downcast_ref::<&str>()
                .map(|m| m.contains("chaos: injected"))
                .or_else(|| {
                    payload
                        .downcast_ref::<String>()
                        .map(|m| m.contains("chaos: injected"))
                })
                .unwrap_or(false);
            if !injected {
                default_hook(info);
            }
        }));
    });
}

fn random_scenario(rng: &mut Rng, seed: u64) -> SimConfig {
    SimConfig {
        workload: WorkloadParams {
            arrival_rate: rng.uniform(5.0, 60.0),
            ..Default::default()
        },
        epochs: rng.int_range(2, 7) as usize,
        seed,
        batching: if rng.below(2) == 0 {
            BatchingMode::Epoch
        } else {
            BatchingMode::Continuous
        },
        shards: rng.int_range(1, 4) as usize,
        ..SimConfig::paper_default()
    }
}

/// Stall-free fault mix: stalls burn real wall time and only move the
/// wall-dependent counters `Metrics` equality already ignores, so the
/// properties here exercise the schedule-visible faults.
fn random_chaos(rng: &mut Rng, seed: u64) -> ChaosConfig {
    ChaosConfig {
        seed: seed ^ 0xC4A05,
        panic_prob: rng.uniform(0.0, 0.35),
        error_prob: rng.uniform(0.0, 0.3),
        kv_fail_prob: rng.uniform(0.0, 0.3),
        ..ChaosConfig::default()
    }
}

/// PROPERTY: request conservation survives injected crashes. Across random
/// scenarios and fault mixes, every offered request ends in exactly one
/// terminal bucket — `offered == completed_in_deadline + completed_late +
/// dropped + shard_failed` — and the fault schedule never invents or
/// duplicates work: `offered` matches the fault-free run bit-exactly
/// (intake is chaos-independent) and redispatched requests are not counted
/// twice.
#[test]
fn prop_crash_conservation_under_random_fault_mixes() {
    silence_injected_panics();
    for seed in 0..cases(64).min(32) {
        let mut rng = Rng::new(0xC4A05_0 + seed);
        let cfg = SimConfig {
            chaos: random_chaos(&mut rng, seed),
            ..random_scenario(&mut rng, seed)
        };
        let clean = SimConfig {
            chaos: ChaosConfig::default(),
            ..cfg.clone()
        };
        let m = sim::run_chaos(&cfg, |_| Box::new(Dftsp::new()));
        let baseline = sim::run_sharded(&clean, |_| Box::new(Dftsp::new()));
        assert_eq!(
            m.offered, baseline.offered,
            "seed {seed}: intake must be chaos-independent"
        );
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped + m.shard_failed,
            "seed {seed}: conservation must close through {} crashes",
            m.shard_crashes
        );
        // Crashes without restarts can only come from parking; restarts
        // never exceed crashes.
        assert!(
            m.shard_restarts <= m.shard_crashes,
            "seed {seed}: restarts {} > crashes {}",
            m.shard_restarts,
            m.shard_crashes
        );
        if m.shard_failed > 0 {
            assert!(
                m.shard_crashes > 0,
                "seed {seed}: shard_failed implies at least one crash"
            );
        }
    }
}

/// PROPERTY: the fault schedule is a pure function of the chaos seed — the
/// same scenario run twice is bit-identical, crashes included.
#[test]
fn prop_seeded_chaos_is_bit_reproducible() {
    silence_injected_panics();
    for seed in 0..cases(64).min(16) {
        let mut rng = Rng::new(0xC4A05_1 + seed);
        let cfg = SimConfig {
            chaos: random_chaos(&mut rng, seed),
            ..random_scenario(&mut rng, seed)
        };
        let a = sim::run_chaos(&cfg, |_| Box::new(Dftsp::new()));
        let b = sim::run_chaos(&cfg, |_| Box::new(Dftsp::new()));
        assert_eq!(
            a, b,
            "seed {seed}: same chaos seed must replay the same run ({} crashes)",
            a.shard_crashes
        );
    }
}

/// PROPERTY: chaos disabled is free — the supervised path with an all-zero
/// fault mix is bit-identical to the unsupervised sharded run on every
/// random scenario and both batching modes.
#[test]
fn prop_disabled_chaos_is_bit_identical_to_unsupervised() {
    for seed in 0..cases(64).min(24) {
        let mut rng = Rng::new(0xC4A05_2 + seed);
        let cfg = random_scenario(&mut rng, seed);
        assert!(!cfg.chaos.enabled(), "paper default has chaos off");
        let supervised = sim::run_chaos(&cfg, |_| Box::new(Dftsp::new()));
        let plain = sim::run_sharded(&cfg, |_| Box::new(Dftsp::new()));
        assert_eq!(
            supervised, plain,
            "seed {seed} ({:?}): disabled chaos must cost nothing",
            cfg.batching
        );
    }
}
