//! Cross-module integration tests: scheduler × wireless × cluster × quant
//! interactions that no single module's unit tests cover.

use edgellm::cluster::{ClusterSpec, GpuSpec};
use edgellm::coordinator::{
    BruteForce, Dftsp, EpochParams, FeasibilityChecker, NoBatching, ProblemInstance, Scheduler,
    StaticBatching,
};
use edgellm::model::{CostModel, LlmSpec};
use edgellm::quant::{self, Precision, QuantAlgo};
use edgellm::request::{EpochRequest, RequestBuilder};
use edgellm::util::rng::Rng;
use edgellm::wireless::{ChannelParams, RadioParams};

fn paper_inst(model: LlmSpec, quant: quant::QuantSpec) -> ProblemInstance {
    ProblemInstance::new(
        CostModel::new(model),
        quant,
        ClusterSpec::paper_default(),
        EpochParams::default(),
        512,
        0.0,
    )
}

/// Random request set in the paper's distributions with per-request fading.
fn random_requests(n: usize, seed: u64) -> Vec<EpochRequest> {
    let mut rng = Rng::new(seed);
    let mut b = RequestBuilder::new();
    let radio = RadioParams::default();
    let channel = ChannelParams::default();
    let levels = [128u32, 256, 512];
    (0..n)
        .map(|_| {
            let req = b.build(
                -rng.uniform(0.0, 2.0),
                *rng.choice(&levels),
                *rng.choice(&levels),
                rng.uniform(0.5, 2.0),
                rng.uniform(0.0, 1.0),
            );
            let h = channel.draw_h(&mut rng);
            EpochRequest::annotate(req, h, &radio, 0.25, 0.25)
        })
        .collect()
}

/// Every scheduler must return a subset of the candidates with no
/// duplicates, and (except StB, which is deadline-oblivious by design) a
/// feasible one.
#[test]
fn all_schedulers_return_valid_subsets() {
    let reqs = random_requests(40, 1);
    let inst = paper_inst(LlmSpec::bloom_3b(), quant::default_quant());
    let mut schedulers: Vec<(Box<dyn Scheduler>, bool)> = vec![
        (Box::new(Dftsp::new()), true),
        (Box::new(BruteForce::default()), true),
        (Box::new(StaticBatching::new()), false),
        (Box::new(NoBatching::new()), false),
    ];
    for (s, must_be_feasible) in schedulers.iter_mut() {
        let sched = s.schedule(&inst, &reqs);
        let ids: Vec<u64> = sched.scheduled.clone();
        let mut dedup = ids.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len(), "{}: duplicate ids", s.name());
        for id in &ids {
            assert!(
                reqs.iter().any(|r| r.id() == *id),
                "{}: unknown id {id}",
                s.name()
            );
        }
        if *must_be_feasible && !ids.is_empty() {
            let subset: Vec<&EpochRequest> =
                reqs.iter().filter(|r| ids.contains(&r.id())).collect();
            assert!(
                FeasibilityChecker::new(&inst).check(&subset).is_ok(),
                "{}: returned infeasible schedule",
                s.name()
            );
        }
    }
}

/// DFTSP and brute force are both exact: identical cardinality on dozens of
/// random instances (the sets themselves may differ).
#[test]
fn dftsp_cardinality_equals_brute_force() {
    for seed in 0..12 {
        let reqs = random_requests(14, seed);
        let inst = ProblemInstance::new(
            CostModel::new(LlmSpec::bloom_3b()),
            quant::default_quant(),
            ClusterSpec::new(GpuSpec::jetson_tx2(), 3),
            EpochParams::default(),
            512,
            0.0,
        );
        let d = Dftsp::new().schedule(&inst, &reqs);
        let bf = BruteForce::default().schedule(&inst, &reqs);
        assert!(!bf.stats.budget_exhausted, "seed {seed}");
        assert_eq!(
            d.batch_size(),
            bf.batch_size(),
            "seed {seed}: DFTSP {} vs brute {}",
            d.batch_size(),
            bf.batch_size()
        );
    }
}

/// Lower precision admits larger batches when accuracy requirements are lax
/// (memory + beta effects), but loses accuracy-strict requests.
#[test]
fn quantization_tradeoff_visible_in_schedules() {
    // All requests very lax on accuracy: W4 should schedule >= W16.
    let mut rng = Rng::new(3);
    let mut b = RequestBuilder::new();
    let radio = RadioParams::default();
    let lax: Vec<EpochRequest> = (0..30)
        .map(|_| {
            let req = b.build(0.0, 512, 512, rng.uniform(1.5, 2.0), 0.05);
            EpochRequest::annotate(req, (1e-3f64).sqrt(), &radio, 0.25, 0.25)
        })
        .collect();
    // Small cluster so memory/compute actually bind.
    let small = ClusterSpec::new(
        GpuSpec {
            name: "tx2".into(),
            flops: 1.33e12,
            mem_bytes: 8 << 30,
        },
        4,
    );
    let mk = |q: quant::QuantSpec| {
        ProblemInstance::new(
            CostModel::new(LlmSpec::bloom_3b()),
            q,
            small.clone(),
            EpochParams::default(),
            512,
            0.0,
        )
    };
    let w16 = Dftsp::new().schedule(&mk(quant::QuantSpec::fp16()), &lax);
    let w4 = Dftsp::new().schedule(
        &mk(quant::by_label(Precision::W4A16, QuantAlgo::Gptq).unwrap()),
        &lax,
    );
    assert!(
        w4.batch_size() >= w16.batch_size(),
        "W4 {} < W16 {}",
        w4.batch_size(),
        w16.batch_size()
    );

    // Accuracy-strict requests flip the ordering.
    let strict: Vec<EpochRequest> = (0..30)
        .map(|i| {
            let req = b.build(0.0, 128, 128, 1.8, 0.5 + 0.01 * (i as f64 % 10.0));
            EpochRequest::annotate(req, (1e-3f64).sqrt(), &radio, 0.25, 0.25)
        })
        .collect();
    let w16s = Dftsp::new().schedule(&mk(quant::QuantSpec::fp16()), &strict);
    let w4s = Dftsp::new().schedule(
        &mk(quant::by_label(Precision::W4A16, QuantAlgo::ZqLocal).unwrap()),
        &strict,
    );
    assert!(w4s.batch_size() == 0, "W4/ZQ admits no a>=0.5 on BLOOM-3B");
    assert!(w16s.batch_size() > 0);
}

/// Worse channels shrink the schedulable set through ρ_min growth.
#[test]
fn channel_quality_affects_scheduling() {
    let mut b = RequestBuilder::new();
    let radio = RadioParams::default();
    let inst = paper_inst(LlmSpec::bloom_3b(), quant::default_quant());
    let mk = |h: f64, b: &mut RequestBuilder| -> Vec<EpochRequest> {
        (0..12)
            .map(|_| {
                EpochRequest::annotate(b.build(0.0, 512, 128, 60.0, 0.1), h, &radio, 0.25, 0.25)
            })
            .collect()
    };
    let mut inst_long = inst.clone();
    inst_long.epoch.duration = 60.0; // compute never binds
    let good = Dftsp::new().schedule(&inst_long, &mk(1e-2, &mut b));
    let bad = Dftsp::new().schedule(&inst_long, &mk(4e-8, &mut b));
    assert!(good.batch_size() > bad.batch_size());
    assert!(bad.batch_size() >= 1);
}

/// The P2 reformulation and the direct checker agree on concrete subsets
/// (uniform h).
#[test]
fn reformulation_consistent_with_checker() {
    use edgellm::coordinator::P2Coefficients;
    let inst = paper_inst(LlmSpec::bloom_7b(), quant::default_quant());
    let radio = RadioParams::default();
    let h = (1e-3f64).sqrt();
    let k = P2Coefficients::derive(&inst, &radio, h);
    let mut b = RequestBuilder::new();
    let reqs: Vec<EpochRequest> = (0..6)
        .map(|i| {
            EpochRequest::annotate(
                b.build(0.0, 128 + 64 * i, 256, 1.9, 0.1),
                h,
                &radio,
                0.25,
                0.25,
            )
        })
        .collect();
    let subset: Vec<&EpochRequest> = reqs.iter().collect();
    // (2b): sum k_u * s_i == sum rho_min_u
    let via_k: f64 = subset
        .iter()
        .map(|r| k.k_u * r.req.prompt_tokens as f64)
        .sum();
    let direct: f64 = subset.iter().map(|r| r.rho_min_u).sum();
    assert!((via_k - direct).abs() < 1e-12);
    // (2e): decode flops via quadratic form equals cost model's
    for r in &subset {
        let via_q = k.decode_flops(&inst, r.req.output_tokens);
        let via_c = inst
            .cost
            .decode_flops_per_req(inst.s_pad, r.req.output_tokens);
        assert!((via_q - via_c).abs() / via_c < 1e-12);
    }
}

/// OPT-13B at fp16 exceeds a 16 GB GPU: quantization is what makes it
/// deployable — the paper's motivating scenario.
#[test]
fn quantization_enables_large_model_deployment() {
    let small_gpu = ClusterSpec::new(
        GpuSpec {
            name: "tx2-16g".into(),
            flops: 1.33e12,
            mem_bytes: 16 << 30,
        },
        20,
    );
    let mk = |q: quant::QuantSpec| {
        ProblemInstance::new(
            CostModel::new(LlmSpec::opt_13b()),
            q,
            small_gpu.clone(),
            EpochParams {
                duration: 30.0,
                t_u: 0.25,
                t_d: 0.25,
            },
            512,
            0.0,
        )
    };
    let mut b = RequestBuilder::new();
    let radio = RadioParams::default();
    let reqs: Vec<EpochRequest> = (0..5)
        .map(|_| {
            EpochRequest::annotate(b.build(0.0, 128, 128, 40.0, 0.1), 0.03, &radio, 0.25, 0.25)
        })
        .collect();
    let fp = Dftsp::new().schedule(&mk(quant::QuantSpec::fp16()), &reqs);
    assert_eq!(fp.batch_size(), 0, "fp16 OPT-13B cannot fit 16 GB");
    let w8 = Dftsp::new().schedule(
        &mk(quant::by_label(Precision::W8A16, QuantAlgo::Gptq).unwrap()),
        &reqs,
    );
    assert!(w8.batch_size() > 0, "W8A16 makes OPT-13B servable");
}
