//! Property tests of the sharded dispatch layer.
//!
//! Seeded-case harness (no proptest crate offline): `PROPTEST_CASES`
//! controls the case count (CI pins it to 64); failures report the
//! offending seed for replay.

use edgellm::cluster::ClusterSpec;
use edgellm::coordinator::{Deployment, Dftsp, EpochParams, PartitionPolicy};
use edgellm::driver::{
    AnalyticBackend, BatchingMode, DriverPolicy, SPadPolicy, ShardedConfig, ShardedDriver,
    StalePolicy,
};
use edgellm::model::LlmSpec;
use edgellm::quant;
use edgellm::request::RequestBuilder;
use edgellm::sim::{self, SimConfig};
use edgellm::util::rng::Rng;
use edgellm::wireless::{AllocationPolicy, ChannelParams, RadioParams};
use edgellm::workload::WorkloadParams;

fn cases(default: u64) -> u64 {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn random_deployment(rng: &mut Rng) -> Deployment {
    let quants = quant::catalog();
    Deployment {
        model: LlmSpec::bloom_3b(),
        quant: quants[rng.below(quants.len() as u64) as usize].clone(),
    }
}

/// PROPERTY: every arrival lands in exactly one shard (Σ per-shard offered
/// equals the number of offers), the partition always sums to the pool and
/// keeps min-1 per shard, and the merged `Metrics` totals equal the sum of
/// the per-shard totals bit-exactly — for every counter the dispatch layer
/// aggregates.
#[test]
fn prop_sharded_conservation_and_exact_merge() {
    for seed in 0..cases(64) {
        let mut rng = Rng::new(0x5AA_2D + seed);
        let shards = rng.int_range(1, 4) as usize;
        let total_gpus = rng.int_range(shards as u64, 24) as usize;
        let cfg = ShardedConfig {
            deployments: (0..shards).map(|_| random_deployment(&mut rng)).collect(),
            cluster: ClusterSpec::new(ClusterSpec::paper_default().gpu, total_gpus),
            partition: if rng.below(2) == 0 {
                PartitionPolicy::Equal
            } else {
                PartitionPolicy::LoadProportional
            },
            policy: DriverPolicy {
                stale: StalePolicy::BestCaseInfeasible,
                s_pad: SPadPolicy::LongestQueued { fallback: 512 },
                allocation: AllocationPolicy::MinOnly,
            },
            epoch: EpochParams::default(),
            radio: RadioParams::default(),
            channel: ChannelParams::default(),
            seed,
        };
        let mut sd: ShardedDriver<(), AnalyticBackend> =
            ShardedDriver::new(cfg, |_| AnalyticBackend, |_| Box::new(Dftsp::new())).unwrap();
        let mut b = RequestBuilder::new();
        let epochs = rng.int_range(2, 5);
        let levels = [128u32, 256, 512];
        let mut offered = 0u64;
        for e in 0..epochs {
            let now = e as f64 * 2.0;
            for _ in 0..rng.int_range(0, 12) {
                let req = b.build(
                    now,
                    levels[rng.below(3) as usize],
                    levels[rng.below(3) as usize],
                    rng.uniform(0.5, 3.0),
                    rng.uniform(0.0, 1.0),
                );
                let affinity = rng.below(shards as u64) as usize;
                let landed = sd.offer(req, (), affinity);
                assert!(landed < shards, "seed {seed}: shard index in range");
                offered += 1;
            }
            sd.step_epoch(now);
            assert_eq!(
                sd.partition().iter().sum::<usize>(),
                total_gpus,
                "seed {seed}: partition sums to the pool"
            );
            assert!(
                sd.partition().iter().all(|&g| g >= 1),
                "seed {seed}: min-1 GPU per shard"
            );
        }
        sd.finish(epochs as f64 * 2.0);

        // Exactly-one-shard landing: per-shard offered counts close the sum.
        let per_shard: Vec<_> = (0..shards).map(|i| sd.shard_metrics(i).clone()).collect();
        assert_eq!(
            per_shard.iter().map(|m| m.offered).sum::<u64>(),
            offered,
            "seed {seed}: every arrival lands in exactly one shard"
        );

        // Bit-exact merge: merged totals == per-shard sums, counter by
        // counter (u64 additions — no tolerance).
        let merged = sd.merged_metrics();
        let sum = |f: &dyn Fn(&edgellm::metrics::Metrics) -> u64| -> u64 {
            per_shard.iter().map(|m| f(m)).sum()
        };
        assert_eq!(merged.offered, sum(&|m| m.offered), "seed {seed}");
        assert_eq!(merged.scheduled, sum(&|m| m.scheduled), "seed {seed}");
        assert_eq!(
            merged.completed_in_deadline,
            sum(&|m| m.completed_in_deadline),
            "seed {seed}"
        );
        assert_eq!(
            merged.completed_late,
            sum(&|m| m.completed_late),
            "seed {seed}"
        );
        assert_eq!(merged.dropped, sum(&|m| m.dropped), "seed {seed}");
        assert_eq!(
            merged.schedule_calls,
            sum(&|m| m.schedule_calls),
            "seed {seed}"
        );
        assert_eq!(
            merged.latency.count(),
            sum(&|m| m.latency.count()),
            "seed {seed}"
        );
        assert_eq!(
            merged.search.nodes_visited,
            sum(&|m| m.search.nodes_visited),
            "seed {seed}"
        );
        assert_eq!(
            merged.search.subproblems,
            sum(&|m| m.search.subproblems),
            "seed {seed}"
        );
        assert_eq!(
            merged.offered,
            merged.completed_in_deadline + merged.completed_late + merged.dropped,
            "seed {seed}: merged accounting closes"
        );
    }
}

/// PROPERTY: the dispatch layer with one shard is bit-identical to the
/// unsharded driver across random scenarios and both batching modes.
#[test]
fn prop_one_shard_parity_with_unsharded_driver() {
    for seed in 0..cases(64).min(24) {
        let mut rng = Rng::new(0x1_5AA_2D + seed);
        let cfg = SimConfig {
            workload: WorkloadParams {
                arrival_rate: rng.uniform(5.0, 80.0),
                ..Default::default()
            },
            epochs: rng.int_range(2, 8) as usize,
            seed,
            batching: if rng.below(2) == 0 {
                BatchingMode::Epoch
            } else {
                BatchingMode::Continuous
            },
            shards: 1,
            ..SimConfig::paper_default()
        };
        let unsharded = sim::run(&cfg, &mut Dftsp::new());
        let sharded = sim::run_sharded(&cfg, |_| Box::new(Dftsp::new()));
        assert_eq!(
            unsharded, sharded,
            "seed {seed} ({:?}): one-shard dispatch must be bit-identical",
            cfg.batching
        );
    }
}
