//! Property tests of the sharded dispatch layer.
//!
//! Seeded-case harness (no proptest crate offline): `PROPTEST_CASES`
//! controls the case count (CI pins it to 64); failures report the
//! offending seed for replay.

use edgellm::cluster::{ClusterSpec, ClusterTopology, GpuSpec, ShardSpec};
use edgellm::coordinator::{Deployment, Dftsp, PartitionPolicy, Schedule};
use edgellm::driver::{
    AnalyticBackend, BatchingMode, DriverBuilder, EpochContext, ExecutionBackend, QueuedRequest,
    ShardedDriver,
};
use edgellm::metrics::Metrics;
use edgellm::model::LlmSpec;
use edgellm::quant;
use edgellm::request::{Request, RequestBuilder};
use edgellm::sim::{self, SimConfig};
use edgellm::util::rng::Rng;
use edgellm::workload::WorkloadParams;

fn cases(default: u64) -> u64 {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn random_deployment(rng: &mut Rng) -> Deployment {
    let quants = quant::catalog();
    Deployment {
        model: LlmSpec::bloom_3b(),
        quant: quants[rng.below(quants.len() as u64) as usize].clone(),
    }
}

/// PROPERTY: every arrival lands in exactly one shard (Σ per-shard offered
/// equals the number of offers), the partition always sums to the pool and
/// keeps min-1 per shard, and the merged `Metrics` totals equal the sum of
/// the per-shard totals bit-exactly — for every counter the dispatch layer
/// aggregates.
#[test]
fn prop_sharded_conservation_and_exact_merge() {
    for seed in 0..cases(64) {
        let mut rng = Rng::new(0x5AA_2D + seed);
        let shards = rng.int_range(1, 4) as usize;
        let total_gpus = rng.int_range(shards as u64, 24) as usize;
        let mut sd: ShardedDriver<(), AnalyticBackend> = DriverBuilder::homogeneous(
            (0..shards).map(|_| random_deployment(&mut rng)).collect(),
            ClusterSpec::new(ClusterSpec::paper_default().gpu, total_gpus),
        )
        .partition(if rng.below(2) == 0 {
            PartitionPolicy::Equal
        } else {
            PartitionPolicy::LoadProportional
        })
        .seed(seed)
        .build(|_| AnalyticBackend, |_| Box::new(Dftsp::new()))
        .unwrap();
        let mut b = RequestBuilder::new();
        let epochs = rng.int_range(2, 5);
        let levels = [128u32, 256, 512];
        let mut offered = 0u64;
        for e in 0..epochs {
            let now = e as f64 * 2.0;
            for _ in 0..rng.int_range(0, 12) {
                let req = b.build(
                    now,
                    levels[rng.below(3) as usize],
                    levels[rng.below(3) as usize],
                    rng.uniform(0.5, 3.0),
                    rng.uniform(0.0, 1.0),
                );
                let affinity = rng.below(shards as u64) as usize;
                let landed = sd.offer(req, (), affinity);
                assert!(landed < shards, "seed {seed}: shard index in range");
                offered += 1;
            }
            sd.step_epoch(now);
            assert_eq!(
                sd.partition().iter().sum::<usize>(),
                total_gpus,
                "seed {seed}: partition sums to the pool"
            );
            assert!(
                sd.partition().iter().all(|&g| g >= 1),
                "seed {seed}: min-1 GPU per shard"
            );
        }
        sd.finish(epochs as f64 * 2.0);

        // Exactly-one-shard landing: per-shard offered counts close the sum.
        let per_shard: Vec<_> = (0..shards).map(|i| sd.shard_metrics(i).clone()).collect();
        assert_eq!(
            per_shard.iter().map(|m| m.offered).sum::<u64>(),
            offered,
            "seed {seed}: every arrival lands in exactly one shard"
        );

        // Bit-exact merge: merged totals == per-shard sums, counter by
        // counter (u64 additions — no tolerance).
        let merged = sd.merged_metrics();
        let sum = |f: &dyn Fn(&edgellm::metrics::Metrics) -> u64| -> u64 {
            per_shard.iter().map(|m| f(m)).sum()
        };
        assert_eq!(merged.offered, sum(&|m| m.offered), "seed {seed}");
        assert_eq!(merged.scheduled, sum(&|m| m.scheduled), "seed {seed}");
        assert_eq!(
            merged.completed_in_deadline,
            sum(&|m| m.completed_in_deadline),
            "seed {seed}"
        );
        assert_eq!(
            merged.completed_late,
            sum(&|m| m.completed_late),
            "seed {seed}"
        );
        assert_eq!(merged.dropped, sum(&|m| m.dropped), "seed {seed}");
        assert_eq!(
            merged.schedule_calls,
            sum(&|m| m.schedule_calls),
            "seed {seed}"
        );
        assert_eq!(
            merged.latency.count(),
            sum(&|m| m.latency.count()),
            "seed {seed}"
        );
        assert_eq!(
            merged.search.nodes_visited,
            sum(&|m| m.search.nodes_visited),
            "seed {seed}"
        );
        assert_eq!(
            merged.search.subproblems,
            sum(&|m| m.search.subproblems),
            "seed {seed}"
        );
        assert_eq!(
            merged.offered,
            merged.completed_in_deadline + merged.completed_late + merged.dropped,
            "seed {seed}: merged accounting closes"
        );
    }
}

/// PROPERTY: the dispatch layer with one shard is bit-identical to the
/// unsharded driver across random scenarios and both batching modes.
#[test]
fn prop_one_shard_parity_with_unsharded_driver() {
    for seed in 0..cases(64).min(24) {
        let mut rng = Rng::new(0x1_5AA_2D + seed);
        let cfg = SimConfig {
            workload: WorkloadParams {
                arrival_rate: rng.uniform(5.0, 80.0),
                ..Default::default()
            },
            epochs: rng.int_range(2, 8) as usize,
            seed,
            batching: if rng.below(2) == 0 {
                BatchingMode::Epoch
            } else {
                BatchingMode::Continuous
            },
            shards: 1,
            ..SimConfig::paper_default()
        };
        let unsharded = sim::run(&cfg, &mut Dftsp::new());
        let sharded = sim::run_sharded(&cfg, |_| Box::new(Dftsp::new()));
        assert_eq!(
            unsharded, sharded,
            "seed {seed} ({:?}): one-shard dispatch must be bit-identical",
            cfg.batching
        );
    }
}

/// A random fleet of same-deployment replicas on mixed silicon: full-speed
/// TX2s next to quarter-speed ones, random per-shard GPU counts. The spec
/// mix is what makes stealing reachable (distinct [`GpuSpec`]s are separate
/// migration groups, so LoadProportional alone cannot rebalance them).
fn random_mixed_topology(rng: &mut Rng, shards: usize) -> ClusterTopology {
    let fast = GpuSpec::jetson_tx2();
    let slow = GpuSpec {
        name: "jetson-tx2-underclocked".into(),
        flops: fast.flops / 4.0,
        mem_bytes: fast.mem_bytes,
    };
    ClusterTopology {
        shards: (0..shards)
            .map(|_| ShardSpec {
                gpu: if rng.below(2) == 0 {
                    fast.clone()
                } else {
                    slow.clone()
                },
                num_gpus: rng.int_range(1, 8) as usize,
            })
            .collect(),
    }
}

/// Group id per shard (first shard with an equal spec), and the per-group
/// GPU sums of a partition — the pool-conservation invariant GPUs must
/// never cross.
fn group_sums(specs: &[GpuSpec], partition: &[usize]) -> Vec<usize> {
    let mut sums = vec![0usize; specs.len()];
    for (i, spec) in specs.iter().enumerate() {
        let g = specs.iter().position(|s| s == spec).unwrap();
        sums[g] += partition[i];
    }
    sums
}

/// Drive a sharded driver through a random trace (shared by the stealing
/// and gating properties so gated-vs-plain runs see identical offers).
fn drive_random<B>(sd: &mut ShardedDriver<(), B>, seed: u64) -> Metrics
where
    B: ExecutionBackend<Payload = ()> + Send,
{
    let mut rng = Rng::new(0xD21_7E + seed);
    let shards = sd.shard_count();
    let mut b = RequestBuilder::new();
    let epochs = rng.int_range(2, 6);
    let levels = [128u32, 256, 512];
    for e in 0..epochs {
        let now = e as f64 * 2.0;
        for _ in 0..rng.int_range(0, 10) {
            let req = b.build(
                now,
                levels[rng.below(3) as usize],
                levels[rng.below(3) as usize],
                rng.uniform(0.5, 3.0),
                0.05,
            );
            sd.offer(req, (), rng.below(shards as u64) as usize);
        }
        sd.step_epoch(now);
    }
    sd.finish(epochs as f64 * 2.0);
    sd.merged_metrics()
}

/// PROPERTY: with work stealing ON over random heterogeneous fleets, every
/// conservation law the elastic-off layer obeys still holds — Σ per-shard
/// offered equals the offer count (`offered` travels with a stolen
/// request), the merge stays bit-exact counter by counter, request
/// accounting closes, and GPUs never cross migration groups. Stealing must
/// also actually fire somewhere in the sweep (non-vacuity).
#[test]
fn prop_stealing_preserves_conservation_and_exact_merge() {
    let mut total_stolen = 0u64;
    let n = cases(64);
    for seed in 0..n {
        let mut rng = Rng::new(0x57EA_1 + seed);
        let shards = rng.int_range(2, 4) as usize;
        let deployment = Deployment {
            model: LlmSpec::bloom_3b(),
            quant: quant::default_quant(),
        };
        let mut sd: ShardedDriver<(), AnalyticBackend> = DriverBuilder::new(
            vec![deployment; shards],
            random_mixed_topology(&mut rng, shards),
        )
        .seed(seed)
        .stealing(true)
        .build(|_| AnalyticBackend, |_| Box::new(Dftsp::new()))
        .unwrap();
        let specs = sd.gpu_specs().to_vec();
        let pools = group_sums(&specs, sd.partition());

        let mut b = RequestBuilder::new();
        let epochs = rng.int_range(2, 6);
        let mut offered = 0u64;
        for e in 0..epochs {
            let now = e as f64 * 2.0;
            // Heavy same-size requests all aimed at shard 0: queue-depth
            // routing splits them by count, so the slow shards back up and
            // the fast ones have something worth stealing.
            for _ in 0..rng.int_range(0, 12) {
                sd.offer(b.build(now, 256, 256, rng.uniform(0.5, 3.0), 0.05), (), 0);
                offered += 1;
            }
            sd.step_epoch(now);
            assert!(
                sd.partition().iter().all(|&g| g >= 1),
                "seed {seed}: min-1 GPU per shard"
            );
            assert_eq!(
                group_sums(&specs, sd.partition()),
                pools,
                "seed {seed}: stealing moves requests, never GPUs — group \
                 pools are invariant"
            );
        }
        sd.finish(epochs as f64 * 2.0);

        let per_shard: Vec<_> = (0..shards).map(|i| sd.shard_metrics(i).clone()).collect();
        assert_eq!(
            per_shard.iter().map(|m| m.offered).sum::<u64>(),
            offered,
            "seed {seed}: `offered` travels with stolen requests — the \
             per-shard sum still closes"
        );
        let merged = sd.merged_metrics();
        assert_eq!(
            merged.offered,
            per_shard.iter().map(|m| m.offered).sum::<u64>(),
            "seed {seed}"
        );
        assert_eq!(
            merged.requests_stolen,
            per_shard.iter().map(|m| m.requests_stolen).sum::<u64>(),
            "seed {seed}"
        );
        assert_eq!(
            merged.offered,
            merged.completed_in_deadline + merged.completed_late + merged.dropped,
            "seed {seed}: accounting closes with stealing on"
        );
        total_stolen += merged.requests_stolen;
    }
    if n >= 16 {
        assert!(
            total_stolen > 0,
            "the sweep never exercised a steal — the property is vacuous"
        );
    }
}

/// Analytic execution behind a permanently closed admission gate: the
/// thief-side KV check must veto every steal.
struct Gated(AnalyticBackend);

impl ExecutionBackend for Gated {
    type Payload = ();
    fn execute(
        &mut self,
        ctx: &EpochContext<'_>,
        schedule: &Schedule,
        batch: Vec<QueuedRequest<()>>,
        metrics: &mut Metrics,
    ) {
        self.0.execute(ctx, schedule, batch, metrics);
    }
    fn can_admit(&self, _req: &Request) -> bool {
        false
    }
}

/// PROPERTY: a fleet whose every backend refuses admission behaves — bit
/// for bit — as if stealing were off: the KV gate is an absolute veto, not
/// a heuristic. Checked across random heterogeneous fleets and traces.
#[test]
fn prop_closed_kv_gates_make_stealing_a_no_op() {
    for seed in 0..cases(64).min(32) {
        let mut rng = Rng::new(0x6A7E + seed);
        let shards = rng.int_range(2, 4) as usize;
        let topology = random_mixed_topology(&mut rng, shards);
        let deployment = Deployment {
            model: LlmSpec::bloom_3b(),
            quant: quant::default_quant(),
        };
        let mut gated: ShardedDriver<(), Gated> =
            DriverBuilder::new(vec![deployment.clone(); shards], topology.clone())
                .seed(seed)
                .stealing(true)
                .build(|_| Gated(AnalyticBackend), |_| Box::new(Dftsp::new()))
                .unwrap();
        let with_gate = drive_random(&mut gated, seed);
        let mut plain: ShardedDriver<(), AnalyticBackend> =
            DriverBuilder::new(vec![deployment; shards], topology)
                .seed(seed)
                .build(|_| AnalyticBackend, |_| Box::new(Dftsp::new()))
                .unwrap();
        let without_stealing = drive_random(&mut plain, seed);
        assert_eq!(with_gate.requests_stolen, 0, "seed {seed}: gate held");
        assert_eq!(
            with_gate, without_stealing,
            "seed {seed}: stealing against closed gates must be bit-identical \
             to stealing off"
        );
    }
}

/// Analytic execution that pins a fixed number of GPUs in flight — the
/// integration-level stand-in for the continuous backend's KV floor.
struct Floored {
    inner: AnalyticBackend,
    floor: usize,
}

impl ExecutionBackend for Floored {
    type Payload = ();
    fn execute(
        &mut self,
        ctx: &EpochContext<'_>,
        schedule: &Schedule,
        batch: Vec<QueuedRequest<()>>,
        metrics: &mut Metrics,
    ) {
        self.inner.execute(ctx, schedule, batch, metrics);
    }
    fn min_gpus_for_inflight(&self) -> usize {
        self.floor
    }
}

/// PROPERTY: heterogeneous re-partitioning honors the backends' in-flight
/// memory floors — however skewed the demand, no shard's partition drops
/// below what its backend reports resident, GPUs stay inside their
/// migration groups, and the pool total is conserved.
#[test]
fn prop_heterogeneous_partition_respects_memory_floors() {
    for seed in 0..cases(64).min(32) {
        let mut rng = Rng::new(0xF100_12 + seed);
        let shards = rng.int_range(2, 4) as usize;
        let floor = rng.int_range(1, 3) as usize;
        // Every shard brings at least `floor` GPUs, so the floors are
        // jointly satisfiable within every migration group.
        let mut topology = random_mixed_topology(&mut rng, shards);
        for s in &mut topology.shards {
            s.num_gpus = rng.int_range(floor as u64, floor as u64 + 4) as usize;
        }
        let deployment = Deployment {
            model: LlmSpec::bloom_3b(),
            quant: quant::default_quant(),
        };
        let mut sd: ShardedDriver<(), Floored> =
            DriverBuilder::new(vec![deployment; shards], topology)
                .partition(PartitionPolicy::LoadProportional)
                .seed(seed)
                .build(
                    move |_| Floored {
                        inner: AnalyticBackend,
                        floor,
                    },
                    |_| Box::new(Dftsp::new()),
                )
                .unwrap();
        let specs = sd.gpu_specs().to_vec();
        let pools = group_sums(&specs, sd.partition());
        let mut b = RequestBuilder::new();
        let epochs = rng.int_range(2, 6);
        for e in 0..epochs {
            let now = e as f64 * 2.0;
            // All demand on one random shard: maximal pressure to strip
            // the idle shards below their floors.
            let hot = rng.below(shards as u64) as usize;
            for _ in 0..rng.int_range(0, 20) {
                sd.offer(b.build(now, 256, 256, rng.uniform(0.5, 3.0), 0.05), (), hot);
            }
            sd.step_epoch(now);
            assert!(
                sd.partition().iter().all(|&g| g >= floor),
                "seed {seed}: partition {:?} dropped below the in-flight \
                 floor {floor}",
                sd.partition()
            );
            assert_eq!(
                group_sums(&specs, sd.partition()),
                pools,
                "seed {seed}: GPUs never cross migration groups"
            );
        }
        sd.finish(epochs as f64 * 2.0);
        let m = sd.merged_metrics();
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped,
            "seed {seed}: accounting closes under floored re-partitioning"
        );
    }
}

/// PROPERTY: with every elastic behaviour off (the default), fixed-count
/// sharded runs are bit-identical run to run at any shard count and in
/// both batching modes — the determinism contract the elastic issue pins.
#[test]
fn prop_elastic_off_fixed_count_is_deterministic() {
    for seed in 0..cases(64).min(16) {
        let mut rng = Rng::new(0xDE7_E12 + seed);
        let cfg = SimConfig {
            workload: WorkloadParams {
                arrival_rate: rng.uniform(5.0, 60.0),
                ..Default::default()
            },
            epochs: rng.int_range(2, 6) as usize,
            seed,
            batching: if rng.below(2) == 0 {
                BatchingMode::Epoch
            } else {
                BatchingMode::Continuous
            },
            shards: rng.int_range(1, 4) as usize,
            ..SimConfig::paper_default()
        };
        let a = sim::run_sharded(&cfg, |_| Box::new(Dftsp::new()));
        let b = sim::run_sharded(&cfg, |_| Box::new(Dftsp::new()));
        assert_eq!(a, b, "seed {seed} ({:?}, {} shards)", cfg.batching, cfg.shards);
        assert_eq!(a.requests_stolen, 0, "seed {seed}: elastic-off never steals");
        assert_eq!(a.shards_spawned, 0, "seed {seed}");
        assert_eq!(a.shards_retired, 0, "seed {seed}");
    }
}
