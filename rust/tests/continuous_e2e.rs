//! Continuous vs. epoch batching, end to end (issue acceptance test).
//!
//! The workload is a *bursty mid-epoch* trace: every epoch, a burst of
//! requests lands exactly at the epoch midpoint with a deadline tight enough
//! that the barrier's aggregation wait (half an epoch) eats most of the
//! latency budget. Under the paper's Fig. 2 protocol those requests cannot
//! be scheduled before the next boundary, so most of the burst is
//! infeasible by the time the scheduler sees it; decode-step admission
//! starts them the moment they arrive. Same scheduler (DFTSP), same cost
//! model, same cluster, same arrival trace — only the execution backend and
//! its intake rule differ (continuous mode offers a window's arrivals to
//! the scheduler at the window start — see the documented approximation on
//! `sim::run_continuous`; admission itself never precedes the arrival
//! timestamp, and the margin asserted here comes from admission timing:
//! the barrier *cannot start* a mid-epoch burst before the next boundary,
//! preview or not).

use edgellm::cluster::ClusterSpec;
use edgellm::coordinator::{Dftsp, EpochParams, ProblemInstance, Schedule, Scheduler};
use edgellm::driver::{
    run_epochs, AnalyticBackend, ContinuousBackend, DriverPolicy, EpochDriver, InstanceTemplate,
    SPadPolicy, SimClock, StalePolicy,
};
use edgellm::metrics::Metrics;
use edgellm::model::{CostModel, LlmSpec};
use edgellm::quant;
use edgellm::request::{EpochRequest, RequestBuilder};
use edgellm::util::rng::Rng;
use edgellm::wireless::{AllocationPolicy, ChannelParams, RadioParams};

const EPOCHS: u64 = 10;
const BURST: usize = 6;
const DURATION: f64 = 2.0;
/// Tight enough that waiting half an epoch for the barrier (1.0 s) plus the
/// T_U/T_D slots (0.5 s) leaves almost no compute slack.
const LATENCY_REQ: f64 = 1.6;

fn template() -> InstanceTemplate {
    InstanceTemplate {
        cost: CostModel::new(LlmSpec::bloom_3b()),
        quant: quant::default_quant(),
        cluster: ClusterSpec::paper_default(),
        epoch: EpochParams {
            duration: DURATION,
            t_u: 0.25,
            t_d: 0.25,
        },
    }
}

fn driver() -> EpochDriver<()> {
    EpochDriver::new(
        template(),
        DriverPolicy {
            stale: StalePolicy::BestCaseInfeasible,
            s_pad: SPadPolicy::LongestQueued { fallback: 512 },
            allocation: AllocationPolicy::MinOnly,
        },
        RadioParams::default(),
        ChannelParams::default(),
        Rng::new(7),
    )
}

/// Offer epoch `e`'s burst: BURST identical requests arriving at the epoch
/// midpoint.
fn offer_burst(b: &mut RequestBuilder, d: &mut EpochDriver<()>, e: u64) {
    let t = e as f64 * DURATION + DURATION / 2.0;
    for _ in 0..BURST {
        d.offer(b.build(t, 128, 128, LATENCY_REQ, 0.2), ());
    }
}

/// Wraps DFTSP and records the barrier waiting time (schedule boundary −
/// arrival) of every scheduled request — the epoch-mode counterpart of
/// `Metrics::admission_latency`.
struct WaitProbe {
    inner: Dftsp,
    total_wait: f64,
    scheduled: u64,
}

impl WaitProbe {
    fn new() -> Self {
        WaitProbe {
            inner: Dftsp::new(),
            total_wait: 0.0,
            scheduled: 0,
        }
    }

    fn mean_wait(&self) -> f64 {
        if self.scheduled == 0 {
            f64::NAN
        } else {
            self.total_wait / self.scheduled as f64
        }
    }
}

impl Scheduler for WaitProbe {
    fn name(&self) -> &'static str {
        "DFTSP+probe"
    }

    fn schedule(&mut self, inst: &ProblemInstance, candidates: &[EpochRequest]) -> Schedule {
        let s = self.inner.schedule(inst, candidates);
        for c in candidates {
            if s.scheduled.contains(&c.id()) {
                self.total_wait += c.req.waited(inst.now);
                self.scheduled += 1;
            }
        }
        s
    }
}

/// The Fig. 2 barrier: epoch e's burst becomes schedulable at boundary e+1.
fn run_epoch_mode(probe: &mut WaitProbe) -> Metrics {
    let mut d = driver();
    let mut backend = AnalyticBackend;
    let mut clock = SimClock::new();
    let mut b = RequestBuilder::new();
    run_epochs(
        &mut d,
        probe,
        &mut backend,
        &mut clock,
        EPOCHS,
        |d, _backend, now| {
            let e = (now / DURATION).round() as u64;
            if e >= 1 {
                offer_burst(&mut b, d, e - 1);
            }
        },
    );
    // The final epoch's burst arrives before the horizon but after the last
    // boundary — offered, never schedulable (the barrier's structural loss).
    offer_burst(&mut b, &mut d, EPOCHS - 1);
    d.finish(&mut backend, EPOCHS as f64 * DURATION);
    d.into_metrics()
}

/// Decode-step admission: each window's burst is offered at the window's
/// start boundary carrying its true mid-epoch arrival timestamp.
fn run_continuous_mode(sched: &mut dyn Scheduler) -> Metrics {
    let mut d = driver();
    let mut backend = ContinuousBackend::new(&template());
    let mut clock = SimClock::new();
    let mut b = RequestBuilder::new();
    run_epochs(
        &mut d,
        sched,
        &mut backend,
        &mut clock,
        EPOCHS,
        |d, _backend, now| {
            let e = (now / DURATION).round() as u64;
            offer_burst(&mut b, d, e);
        },
    );
    d.finish(&mut backend, EPOCHS as f64 * DURATION);
    d.into_metrics()
}

#[test]
fn continuous_beats_epoch_barrier_on_bursty_midepoch_trace() {
    let mut probe = WaitProbe::new();
    let epoch = run_epoch_mode(&mut probe);
    let cont = run_continuous_mode(&mut Dftsp::new());

    // Identical offered load in both modes.
    assert_eq!(epoch.offered, (EPOCHS as u64) * BURST as u64);
    assert_eq!(cont.offered, epoch.offered);

    // Accounting closes in both modes.
    assert_eq!(
        epoch.offered,
        epoch.completed_in_deadline + epoch.completed_late + epoch.dropped
    );
    assert_eq!(
        cont.offered,
        cont.completed_in_deadline + cont.completed_late + cont.dropped
    );

    // The barrier serves *something* (this is a comparison, not a knockout)…
    assert!(
        epoch.completed_in_deadline > 0,
        "epoch mode should still serve part of each burst"
    );

    // …but decode-step admission achieves strictly higher throughput…
    assert!(
        cont.throughput() > epoch.throughput(),
        "continuous {:.3} req/s must beat epoch {:.3} req/s",
        cont.throughput(),
        epoch.throughput()
    );

    // …and strictly lower mean waiting (arrival → service start): the
    // barrier waits out the rest of the epoch, continuous admission starts
    // at the next decode step.
    let epoch_wait = probe.mean_wait();
    let cont_wait = cont.mean_admission_latency();
    assert!(cont.admission_latency.count() > 0);
    assert!(
        cont_wait < epoch_wait,
        "continuous mean wait {cont_wait:.3} s must beat the barrier's {epoch_wait:.3} s"
    );
    // The barrier's wait is structural: bursts land mid-epoch, so scheduled
    // requests waited about half an epoch.
    assert!(epoch_wait > 0.4 * DURATION);
    assert!(cont_wait < 0.2 * DURATION);
}

#[test]
fn modes_agree_when_arrivals_align_with_boundaries() {
    // Control experiment: when every arrival lands exactly on a boundary
    // with a relaxed deadline, the barrier costs nothing and both modes
    // serve everything — the win above really is about mid-epoch arrivals.
    let run = |continuous: bool| -> Metrics {
        let mut d = driver();
        let mut clock = SimClock::new();
        let mut b = RequestBuilder::new();
        let mut sched = Dftsp::new();
        let mut offer = |d: &mut EpochDriver<()>, now: f64| {
            for _ in 0..BURST {
                d.offer(b.build(now, 128, 128, 30.0, 0.2), ());
            }
        };
        if continuous {
            let mut backend = ContinuousBackend::new(&template());
            run_epochs(&mut d, &mut sched, &mut backend, &mut clock, EPOCHS, |d, _b, now| {
                offer(d, now)
            });
            d.finish(&mut backend, EPOCHS as f64 * DURATION);
        } else {
            let mut backend = AnalyticBackend;
            run_epochs(&mut d, &mut sched, &mut backend, &mut clock, EPOCHS, |d, _b, now| {
                offer(d, now)
            });
            d.finish(&mut backend, EPOCHS as f64 * DURATION);
        }
        d.into_metrics()
    };
    let e = run(false);
    let c = run(true);
    assert_eq!(e.completed_in_deadline, e.offered);
    assert_eq!(c.completed_in_deadline, c.offered);
    assert_eq!(e.offered, c.offered);
}
