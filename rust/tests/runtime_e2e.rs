//! End-to-end runtime validation: the Rust PJRT engine must reproduce the
//! Python/JAX (Pallas) numerics exactly-enough from the AOT artifacts, and
//! behave sanely across batch variants and cache reuse.
//!
//! All tests skip gracefully when `make artifacts` has not been run.

use edgellm::runtime::{argmax, artifacts_available, Engine};
use edgellm::util::json::Json;
use std::path::PathBuf;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Load a fresh fp16 engine with the given batch variants (the PJRT handles
/// are not Sync, so each test owns its engine; compiling only the variants a
/// test needs keeps this cheap).
fn engine_with(variants: &[usize]) -> Option<Engine> {
    if !artifacts_available(&artifact_dir()) {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::load_with_variants(&artifact_dir(), "W16A16", variants).expect("engine load"))
}

fn golden() -> Option<Json> {
    let p = artifact_dir().join("golden.json");
    let src = std::fs::read_to_string(p).ok()?;
    Some(Json::parse(&src).expect("golden.json parses"))
}

fn golden_prompts(g: &Json) -> Vec<Vec<i32>> {
    g.get("prompts")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| {
            p.as_arr()
                .unwrap()
                .iter()
                .map(|t| t.as_f64().unwrap() as i32)
                .collect()
        })
        .collect()
}

#[test]
fn prefill_logits_match_python_golden() {
    let (Some(engine), Some(g)) = (engine_with(&[4]), golden()) else {
        return;
    };
    let prompts = golden_prompts(&g);
    let (logits, cache) = engine.prefill(&prompts).expect("prefill");
    assert_eq!(cache.active, prompts.len());
    let want = g.get("prefill_logits_head").unwrap().as_arr().unwrap();
    for (i, row) in want.iter().enumerate() {
        let row: Vec<f64> = row
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap())
            .collect();
        for (j, &w) in row.iter().enumerate() {
            let got = logits[i][j] as f64;
            assert!(
                (got - w).abs() < 1e-3 + 1e-3 * w.abs(),
                "logits[{i}][{j}]: rust {got} vs python {w}"
            );
        }
    }
}

#[test]
fn greedy_generation_matches_python_golden() {
    let (Some(engine), Some(g)) = (engine_with(&[4]), golden()) else {
        return;
    };
    let prompts = golden_prompts(&g);
    let gen = engine.generate_greedy(&prompts, 8, None).expect("generate");
    let want = g.get("greedy_tokens").unwrap().as_arr().unwrap();
    for (i, row) in want.iter().enumerate() {
        let row: Vec<i32> = row
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as i32)
            .collect();
        assert_eq!(gen[i], row, "sequence {i} diverged from python");
    }
}

#[test]
fn batch_variant_invariance() {
    // The same prompt must generate the same tokens whether it runs alone
    // (b=1 variant) or padded into the b=4 variant with co-batched prompts.
    let Some(engine) = engine_with(&[1, 4]) else { return };
    let p1 = vec![vec![11, 22, 33, 44, 55]];
    let p4 = vec![
        vec![11, 22, 33, 44, 55],
        vec![100, 101],
        vec![200; 40],
        vec![300, 301, 302],
    ];
    let solo = engine.generate_greedy(&p1, 6, None).unwrap();
    let batched = engine.generate_greedy(&p4, 6, None).unwrap();
    assert_eq!(solo[0], batched[0], "padding must not leak across the batch");
}

#[test]
fn decode_is_deterministic() {
    let Some(engine) = engine_with(&[2]) else { return };
    let prompts = vec![vec![1, 2, 3], vec![9, 8, 7, 6]];
    let a = engine.generate_greedy(&prompts, 5, None).unwrap();
    let b = engine.generate_greedy(&prompts, 5, None).unwrap();
    assert_eq!(a, b);
}

#[test]
fn quant_variants_load_and_diverge() {
    // The W4A16 weights must load through the same engine and eventually
    // produce different tokens than fp16 (quantization noise is real).
    let Some(fp) = engine_with(&[1]) else { return };
    let w4 = Engine::load_with_variants(&artifact_dir(), "W4A16/ZQ-Local", &[1])
        .expect("w4 engine");
    let prompt = vec![(0..20).map(|i| (i * 7) % 512).collect::<Vec<i32>>()];
    let (lf, _) = fp.prefill(&prompt).unwrap();
    let (lq, _) = w4.prefill(&prompt).unwrap();
    let max_diff = lf[0]
        .iter()
        .zip(lq[0].iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_diff > 1e-3,
        "W4A16 weights must perturb the logits (max diff {max_diff})"
    );
    // and both engines remain internally deterministic
    let (lq2, _) = w4.prefill(&prompt).unwrap();
    assert_eq!(lq[0], lq2[0]);
}

#[test]
fn cache_exhaustion_is_an_error() {
    let Some(engine) = engine_with(&[1]) else { return };
    let max_prompt = engine.meta.max_prompt;
    let max_seq = engine.meta.max_seq;
    let prompts = vec![vec![5i32; max_prompt]];
    // max_seq - max_prompt decode steps fit; the next must fail cleanly.
    let budget = max_seq - max_prompt;
    let (logits, mut cache) = engine.prefill(&prompts).unwrap();
    let mut next = vec![argmax(&logits[0])];
    for _ in 0..budget {
        let l = engine.decode(&next, &mut cache).unwrap();
        next = vec![argmax(&l[0])];
    }
    assert!(engine.decode(&next, &mut cache).is_err());
}

#[test]
fn oversized_batch_rejected() {
    let Some(engine) = engine_with(&[1, 2]) else { return };
    let too_many: Vec<Vec<i32>> =
        (0..engine.max_batch() + 1).map(|_| vec![1, 2]).collect();
    assert!(engine.prefill(&too_many).is_err());
}

#[test]
fn empty_and_oversized_prompts_rejected() {
    let Some(engine) = engine_with(&[1]) else { return };
    assert!(engine.prefill(&[vec![]]).is_err());
    let huge = vec![vec![1i32; engine.meta.max_prompt + 1]];
    assert!(engine.prefill(&huge).is_err());
}
