#![cfg(not(feature = "pjrt"))]
//! Property tests of the host engine's batched decode and quantized kernels.
//!
//! 1. Batched decode ≡ the retained per-sequence reference path, *bit-
//!    exactly*, on arbitrary active-slot patterns — including holes left by
//!    `release` and mid-flight `prefill_into` admissions — across all four
//!    kernel precisions (f32, W8A16, W8A8, W8A8KV8).
//! 2. The W8A16 kernel matches a dequantize-then-f32-matmul oracle
//!    bit-for-bit; the W8A8 kernel matches it within one quantization step
//!    per accumulated product.
//! 3. The steady-state decode loop never grows its tracked buffers
//!    (scratch or KV arena) — the allocation-free property.
//! 4. The tiled cache-blocked kernels are bit-identical to the k-ascending
//!    reference kernels on ragged shapes (k = 0, n not a multiple of the
//!    register tile, blocks larger than the cache tiles).
//! 5. The int8-KV dot primitive stays within the documented
//!    one-quantization-step-per-product bound of the exact f32 dot, and an
//!    int8-KV engine tracks its f32-KV sibling within that bound through
//!    release holes and mid-flight admissions.
//!
//! Seeded-case harness (no proptest crate offline): `PROPTEST_CASES`
//! controls the case count (CI pins it to 64 for deterministic, bounded
//! runtime); failures report the offending seed for replay.

use edgellm::quant::Precision;
use edgellm::runtime::kernels::{
    dot, dot_i8_dequant, matmul_f32_into, matmul_f32_tiled_into, matmul_w8a16_into,
    matmul_w8a16_tiled_into, matmul_w8a8_into, matmul_w8a8_tiled_into, pack_codes_col_blocked,
    quantize_per_tensor_i8, quantize_row_i8,
};
use edgellm::runtime::{argmax, Engine, KvCache, SyntheticSpec};
use edgellm::util::rng::Rng;

fn cases(default: u64) -> u64 {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn precisions() -> [Precision; 4] {
    [
        Precision::W16A16,
        Precision::W8A16,
        Precision::W8A8,
        Precision::W8A8KV8,
    ]
}

fn random_prompt(rng: &mut Rng, max_prompt: usize, vocab: usize) -> Vec<i32> {
    let len = rng.int_range(1, max_prompt as u64) as usize;
    (0..len).map(|_| rng.below(vocab as u64) as i32).collect()
}

fn assert_rows_bitexact(a: &[Vec<f32>], b: &[Vec<f32>], what: &str, seed: u64) {
    assert_eq!(a.len(), b.len(), "seed {seed}: {what}: row count");
    for (i, (ra, rb)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(ra.len(), rb.len(), "seed {seed}: {what}: row {i} len");
        for (j, (x, y)) in ra.iter().zip(rb.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "seed {seed}: {what}: row {i} col {j}: {x} vs {y}"
            );
        }
    }
}

/// PROPERTY: on a randomized schedule of decode steps, releases (leaving
/// holes the swap-remove fills) and mid-flight admissions, the batched
/// decode produces bit-identical logits to the per-sequence reference path,
/// for every kernel precision.
#[test]
fn prop_batched_decode_equals_reference_on_arbitrary_slot_patterns() {
    for seed in 0..cases(48) {
        let mut rng = Rng::new(0xE17_0001 + seed);
        let precision = precisions()[rng.below(4) as usize];
        let mut spec = SyntheticSpec::tiny();
        spec.seed = 0xBADA55 + seed; // new weights per case
        let engine = Engine::synthetic(&spec, precision);
        let max_batch = engine.max_batch();

        let n0 = rng.int_range(1, max_batch as u64) as usize;
        let prompts: Vec<Vec<i32>> = (0..n0)
            .map(|_| random_prompt(&mut rng, spec.max_prompt, spec.vocab))
            .collect();
        let (logits, mut cache_b) = engine.prefill(&prompts).unwrap();
        let mut cache_r = cache_b.clone();
        let mut tokens: Vec<i32> = logits.iter().map(|r| argmax(r)).collect();

        for _step in 0..rng.int_range(3, 10) {
            match rng.below(10) {
                // Release a random slot (keep at least one sequence).
                0 | 1 if cache_b.active > 1 => {
                    let victim = rng.below(cache_b.active as u64) as usize;
                    cache_b.release(victim);
                    cache_r.release(victim);
                    tokens.swap_remove(victim);
                }
                // Mid-flight admission when a batch variant still fits.
                2 | 3 if cache_b.active < max_batch => {
                    let p = random_prompt(&mut rng, spec.max_prompt, spec.vocab);
                    let lb = engine.prefill_into(&p, &mut cache_b).unwrap();
                    let lr = engine.prefill_into(&p, &mut cache_r).unwrap();
                    assert_rows_bitexact(
                        std::slice::from_ref(&lb),
                        std::slice::from_ref(&lr),
                        "prefill_into",
                        seed,
                    );
                    tokens.push(argmax(&lb));
                }
                // Decode one step on both paths and compare bit-for-bit.
                _ => {
                    if cache_b.pos.iter().any(|&p| p as usize >= spec.max_seq) {
                        break; // a sequence filled its KV budget
                    }
                    let lb = engine.decode(&tokens, &mut cache_b).unwrap();
                    let lr = engine.decode_reference(&tokens, &mut cache_r).unwrap();
                    assert_rows_bitexact(&lb, &lr, "decode", seed);
                    assert_eq!(cache_b.pos, cache_r.pos, "seed {seed}: positions");
                    tokens = lb.iter().map(|r| argmax(r)).collect();
                }
            }
        }
    }
}

/// PROPERTY: W8A16 ≡ dequantize-then-f32 oracle bit-for-bit; W8A8 within one
/// quantization step per accumulated product.
#[test]
fn prop_quant_kernels_match_dequantize_oracle() {
    for seed in 0..cases(64) {
        let mut rng = Rng::new(0xE17_0002 + seed);
        let m = rng.int_range(1, 6) as usize;
        let k = rng.int_range(1, 24) as usize;
        let n = rng.int_range(1, 24) as usize;
        let amp = rng.uniform(0.01, 4.0);
        let w: Vec<f32> = (0..k * n)
            .map(|_| (rng.uniform(-amp, amp)) as f32)
            .collect();
        let x: Vec<f32> = (0..m * k)
            .map(|_| (rng.uniform(-2.0, 2.0)) as f32)
            .collect();
        let (codes, w_scale) = quantize_per_tensor_i8(&w);
        let dense: Vec<f32> = codes.iter().map(|&c| c as f32 * w_scale).collect();
        let mut oracle = vec![0f32; m * n];
        matmul_f32_into(&x, m, k, &dense, n, &mut oracle);

        let mut got16 = vec![0f32; m * n];
        matmul_w8a16_into(&x, m, k, &codes, w_scale, n, &mut got16);
        for (i, (a, b)) in oracle.iter().zip(got16.iter()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "seed {seed}: W8A16 elem {i}: {a} vs {b}"
            );
        }

        let mut got8 = vec![0f32; m * n];
        let mut qrow = vec![0i8; k];
        matmul_w8a8_into(&x, m, k, &codes, w_scale, n, &mut qrow, &mut got8);
        for i in 0..m {
            let mut q = vec![0i8; k];
            let a_scale = quantize_row_i8(&x[i * k..(i + 1) * k], &mut q);
            // Each of the k products can be off by at most half an
            // activation step times the (dequantized) weight magnitude.
            let tol = k as f32 * (a_scale / 2.0) * 127.0 * w_scale + 1e-4;
            for j in 0..n {
                let d = (got8[i * n + j] - oracle[i * n + j]).abs();
                assert!(
                    d <= tol,
                    "seed {seed}: W8A8 ({i},{j}): |{d}| > {tol} (a_scale {a_scale})"
                );
            }
        }
    }
}

/// PROPERTY: after the first step, a decode loop at constant batch size
/// never grows the tracked scratch/arena buffers, whatever the precision.
#[test]
fn prop_steady_state_decode_is_allocation_free() {
    for seed in 0..cases(24) {
        let mut rng = Rng::new(0xE17_0003 + seed);
        let precision = precisions()[rng.below(4) as usize];
        let spec = SyntheticSpec::tiny();
        let engine = Engine::synthetic(&spec, precision);
        let n = rng.int_range(1, engine.max_batch() as u64) as usize;
        let prompts: Vec<Vec<i32>> = (0..n)
            .map(|_| random_prompt(&mut rng, 4, spec.vocab)) // short: room to decode
            .collect();
        let (logits, mut cache) = engine.prefill(&prompts).unwrap();
        let mut tokens: Vec<i32> = logits.iter().map(|r| argmax(r)).collect();
        let mut flat = Vec::new();
        engine.decode_into(&tokens, &mut cache, &mut flat).unwrap();
        let scratch0 = engine.scratch_allocs();
        let cap0 = flat.capacity();
        for _ in 0..6 {
            let got = engine.decode_into(&tokens, &mut cache, &mut flat).unwrap();
            tokens = (0..got)
                .map(|i| argmax(&flat[i * spec.vocab..(i + 1) * spec.vocab]))
                .collect();
        }
        assert_eq!(
            engine.scratch_allocs(),
            scratch0,
            "seed {seed}: scratch grew mid-loop ({precision:?})"
        );
        assert_eq!(cache.grow_events(), 0, "seed {seed}: arena grew");
        assert_eq!(flat.capacity(), cap0, "seed {seed}: logits buffer grew");
    }
}

/// PROPERTY: the tiled cache-blocked kernels are bit-identical to the
/// k-ascending reference kernels on ragged shapes — k = 0, n not a multiple
/// of the register tile, and dimensions straddling the cache tiles.
#[test]
fn prop_tiled_kernels_equal_reference_bitexact() {
    use edgellm::runtime::kernels::{TILE_KC, TILE_MC, TILE_NC};
    for seed in 0..cases(64) {
        let mut rng = Rng::new(0xE17_0004 + seed);
        // Bias toward ragged edges: k = 0 and n ≢ 0 (mod TILE_NR) must occur.
        let m = rng.int_range(1, (TILE_MC + 9) as u64) as usize;
        let k = match rng.below(8) {
            0 => 0,
            1 => rng.int_range(TILE_KC as u64, (2 * TILE_KC + 5) as u64) as usize,
            _ => rng.int_range(1, 48) as usize,
        };
        let n = match rng.below(8) {
            0 => rng.int_range(TILE_NC as u64, (TILE_NC + 13) as u64) as usize,
            _ => rng.int_range(1, 48) as usize,
        };
        let x: Vec<f32> = (0..m * k)
            .map(|_| rng.uniform(-2.0, 2.0) as f32)
            .collect();
        let w: Vec<f32> = (0..k * n)
            .map(|_| rng.uniform(-1.5, 1.5) as f32)
            .collect();
        let (codes, w_scale) = quantize_per_tensor_i8(&w);
        let packed = pack_codes_col_blocked(&codes, k, n);
        let ctx = format!("seed {seed}: m={m} k={k} n={n}");

        let mut reference = vec![0f32; m * n];
        let mut tiled = vec![1f32; m * n]; // poison: tiled must overwrite
        matmul_f32_into(&x, m, k, &w, n, &mut reference);
        matmul_f32_tiled_into(&x, m, k, &w, n, &mut tiled);
        for (i, (a, b)) in reference.iter().zip(tiled.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: f32 elem {i}: {a} vs {b}");
        }

        matmul_w8a16_into(&x, m, k, &codes, w_scale, n, &mut reference);
        tiled.fill(1.0);
        matmul_w8a16_tiled_into(&x, m, k, &packed, w_scale, n, &mut tiled);
        for (i, (a, b)) in reference.iter().zip(tiled.iter()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{ctx}: W8A16 elem {i}: {a} vs {b}"
            );
        }

        let mut qrow = vec![0i8; k];
        matmul_w8a8_into(&x, m, k, &codes, w_scale, n, &mut qrow, &mut reference);
        tiled.fill(1.0);
        matmul_w8a8_tiled_into(&x, m, k, &packed, w_scale, n, &mut qrow, &mut tiled);
        for (i, (a, b)) in reference.iter().zip(tiled.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: W8A8 elem {i}: {a} vs {b}");
        }
    }
}

/// PROPERTY: int8-KV error stays within the documented bound.
///
/// Kernel level: `dot_i8_dequant` against the exact f32 `dot` differs by at
/// most `Σ_d |q_d| · step/2` — one quantization step per accumulated product
/// (plus f32 rounding slop), the same shape of bound the W8A8 matmul
/// carries. Engine level: a W8A8KV8 engine fed the *same* token stream as
/// its f32-KV W8A8 sibling keeps prefill logits bit-identical (prefill
/// attends over exact f32 K/V before quantize-on-write) and decode logits
/// within a small relative drift, through release holes and mid-flight
/// admissions.
#[test]
fn prop_int8_kv_error_is_bounded_vs_f32_kv_oracle() {
    for seed in 0..cases(32) {
        let mut rng = Rng::new(0xE17_0005 + seed);

        // Kernel-level bound on random rows.
        let d = rng.int_range(1, 64) as usize;
        let amp = rng.uniform(0.01, 8.0);
        let row: Vec<f32> = (0..d).map(|_| rng.uniform(-amp, amp) as f32).collect();
        let q: Vec<f32> = (0..d).map(|_| rng.uniform(-2.0, 2.0) as f32).collect();
        let mut codes = vec![0i8; d];
        let step = quantize_row_i8(&row, &mut codes);
        let exact = dot(&q, &row);
        let approx = dot_i8_dequant(&q, &codes, step);
        let bound = q.iter().map(|v| v.abs()).sum::<f32>() * (step / 2.0) + 1e-4;
        assert!(
            (approx - exact).abs() <= bound,
            "seed {seed}: |{approx} - {exact}| > {bound} (d={d} step={step})"
        );

        // Engine-level drift through an arbitrary slot schedule.
        let mut spec = SyntheticSpec::tiny();
        spec.seed = 0xC0FFEE + seed; // new weights per case
        let base = Engine::synthetic(&spec, Precision::W8A8);
        let kv8 = Engine::synthetic(&spec, Precision::W8A8KV8);
        let max_batch = kv8.max_batch();
        let n0 = rng.int_range(1, max_batch as u64) as usize;
        let prompts: Vec<Vec<i32>> = (0..n0)
            .map(|_| random_prompt(&mut rng, spec.max_prompt, spec.vocab))
            .collect();
        let (lf, mut cache_f) = base.prefill(&prompts).unwrap();
        let (lq, mut cache_q) = kv8.prefill(&prompts).unwrap();
        assert_rows_bitexact(&lf, &lq, "kv8 prefill", seed);
        let mut tokens: Vec<i32> = lq.iter().map(|r| argmax(r)).collect();

        for _step in 0..rng.int_range(3, 10) {
            match rng.below(10) {
                0 | 1 if cache_q.active > 1 => {
                    let victim = rng.below(cache_q.active as u64) as usize;
                    cache_f.release(victim);
                    cache_q.release(victim);
                    tokens.swap_remove(victim);
                }
                2 | 3 if cache_q.active < max_batch => {
                    let p = random_prompt(&mut rng, spec.max_prompt, spec.vocab);
                    let lf = base.prefill_into(&p, &mut cache_f).unwrap();
                    let lq = kv8.prefill_into(&p, &mut cache_q).unwrap();
                    assert_rows_bitexact(
                        std::slice::from_ref(&lf),
                        std::slice::from_ref(&lq),
                        "kv8 prefill_into",
                        seed,
                    );
                    tokens.push(argmax(&lq));
                }
                _ => {
                    if cache_q.pos.iter().any(|&p| p as usize >= spec.max_seq) {
                        break;
                    }
                    let lf = base.decode(&tokens, &mut cache_f).unwrap();
                    let lq = kv8.decode(&tokens, &mut cache_q).unwrap();
                    for (i, (rf, rq)) in lf.iter().zip(lq.iter()).enumerate() {
                        let norm = rf.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-6);
                        for (j, (a, b)) in rf.iter().zip(rq.iter()).enumerate() {
                            let drift = (a - b).abs() / norm;
                            assert!(
                                drift < 0.5,
                                "seed {seed}: kv8 decode row {i} col {j}: \
                                 drift {drift} ({a} vs {b})"
                            );
                        }
                    }
                    // Drive both caches with the same token stream so they
                    // stay comparable.
                    tokens = lq.iter().map(|r| argmax(r)).collect();
                }
            }
        }
    }
}

/// A prefill-sized cache admits up to its batch variant without growing the
/// arena; only admissions past the sized capacity grow it.
#[test]
fn arena_growth_only_past_sized_capacity() {
    let engine = Engine::synthetic(&SyntheticSpec::tiny(), Precision::W16A16);
    // prefill of 3 selects the b=4 variant: one admission is headroom.
    let (_, mut cache): (_, KvCache) = engine.prefill(&[vec![1], vec![2], vec![3]]).unwrap();
    engine.prefill_into(&[4], &mut cache).unwrap();
    assert_eq!(cache.grow_events(), 0, "within the sized variant: no growth");
    assert_eq!(cache.active, 4);
}
