//! End-to-end tests of the sharded dispatch layer (acceptance criteria of
//! the sharding issue):
//!
//! 1. `shards = 1` through `sim::run_sharded` is **bit-identical** to the
//!    unsharded `EpochDriver` path (`sim::run`) in both batching modes.
//! 2. On a two-deployment skewed trace, `LoadProportional` re-partitioning
//!    strictly beats `Equal` on merged throughput — the dispatch layer's
//!    reason to exist. (Scenario cross-checked numerically against the
//!    toolchain-free mirror before commit: at 40 heavy req/epoch the loaded
//!    shard serves ~9/epoch on its Equal half-pool vs ~17/epoch on the
//!    ~19-GPU load-proportional partition, while the light shard's
//!    1 req/epoch is served either way — a ~1.8× merged margin.)

use edgellm::cluster::ClusterSpec;
use edgellm::coordinator::{
    Deployment, Dftsp, EpochParams, PartitionPolicy, Scheduler, SchedulerConfig,
};
use edgellm::driver::{
    AnalyticBackend, BatchingMode, DriverPolicy, SPadPolicy, ShardedConfig, ShardedDriver,
    StalePolicy,
};
use edgellm::metrics::Metrics;
use edgellm::model::LlmSpec;
use edgellm::quant;
use edgellm::request::RequestBuilder;
use edgellm::sim::{self, SimConfig};
use edgellm::wireless::{AllocationPolicy, ChannelParams, RadioParams};
use edgellm::workload::WorkloadParams;

#[test]
fn one_shard_is_bit_identical_to_the_unsharded_driver() {
    for batching in [BatchingMode::Epoch, BatchingMode::Continuous] {
        let cfg = SimConfig {
            workload: WorkloadParams {
                arrival_rate: 45.0,
                ..Default::default()
            },
            epochs: 12,
            seed: 99,
            batching,
            shards: 1,
            ..SimConfig::paper_default()
        };
        let unsharded = sim::run(&cfg, &mut Dftsp::new());
        let sharded = sim::run_sharded(&cfg, |_| Box::new(Dftsp::new()));
        assert_eq!(
            unsharded, sharded,
            "{batching:?}: dispatch layer with one shard must be a no-op"
        );
        assert!(unsharded.completed_in_deadline > 0, "non-degenerate run");
    }
}

/// Two deployments of BLOOM-3B under different quantizations (so affinity
/// binds), 20 TX2 GPUs, 2 s epochs. Deployment 0 takes 40 requests per
/// epoch, deployment 1 takes 1 — the skew the equal split wastes half the
/// pool on.
fn skewed_run(policy: PartitionPolicy) -> Metrics {
    let epochs = 8u64;
    let cfg = ShardedConfig {
        deployments: vec![
            Deployment {
                model: LlmSpec::bloom_3b(),
                quant: quant::default_quant(), // W8A16/GPTQ
            },
            Deployment {
                model: LlmSpec::bloom_3b(),
                quant: quant::by_label(quant::Precision::W4A16, quant::QuantAlgo::Gptq).unwrap(),
            },
        ],
        cluster: ClusterSpec::paper_default(),
        partition: policy,
        policy: DriverPolicy {
            stale: StalePolicy::BestCaseInfeasible,
            s_pad: SPadPolicy::LongestQueued { fallback: 512 },
            allocation: AllocationPolicy::MinOnly,
        },
        epoch: EpochParams::default(),
        radio: RadioParams::default(),
        channel: ChannelParams::default(),
        seed: 4242,
    };
    let sequential = |_: usize| {
        Box::new(Dftsp::with_config(SchedulerConfig { workers: 0 })) as Box<dyn Scheduler + Send>
    };
    let mut sd: ShardedDriver<(), AnalyticBackend> =
        ShardedDriver::new(cfg, |_| AnalyticBackend, sequential).unwrap();
    let mut b = RequestBuilder::new();
    for e in 0..epochs {
        let now = e as f64 * 2.0;
        for _ in 0..40 {
            // Admissible on both deployments (W4A16/GPTQ on 3B admits
            // a <= 0.25), latency tight enough that unserved leftovers go
            // stale at the next boundary instead of piling up.
            sd.offer(b.build(now, 256, 256, 1.9, 0.05), (), 0);
        }
        sd.offer(b.build(now, 128, 128, 1.9, 0.05), (), 1);
        sd.step_epoch(now);
        assert_eq!(sd.partition().iter().sum::<usize>(), 20, "pool conserved");
    }
    sd.finish(epochs as f64 * 2.0);
    let m = sd.merged_metrics();
    assert_eq!(m.offered, epochs * 41);
    assert_eq!(
        m.offered,
        m.completed_in_deadline + m.completed_late + m.dropped,
        "{policy:?}: conservation through the dispatch layer"
    );
    m
}

#[test]
fn load_proportional_strictly_beats_equal_on_skewed_trace() {
    let equal = skewed_run(PartitionPolicy::Equal);
    let load = skewed_run(PartitionPolicy::LoadProportional);
    assert!(
        load.throughput() > equal.throughput(),
        "LoadProportional ({:.2} req/s, {} in-deadline) must strictly beat \
         Equal ({:.2} req/s, {} in-deadline) when demand is skewed",
        load.throughput(),
        load.completed_in_deadline,
        equal.throughput(),
        equal.completed_in_deadline
    );
    // The margin is structural (≈2× more GPUs on the hot shard), not noise:
    // demand re-partitioning must buy well over a third more goodput.
    assert!(
        load.completed_in_deadline as f64 >= 1.35 * equal.completed_in_deadline as f64,
        "expected a structural win, got {} vs {}",
        load.completed_in_deadline,
        equal.completed_in_deadline
    );
    // Both policies serve the light deployment: min-1 GPU means no
    // starvation even when 97% of the load lives elsewhere.
    assert!(equal.completed_in_deadline >= 8);
    assert!(load.completed_in_deadline >= 8);
}
