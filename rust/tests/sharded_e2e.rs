//! End-to-end tests of the sharded dispatch layer (acceptance criteria of
//! the sharding and elastic-sharding issues):
//!
//! 1. `shards = 1` through `sim::run_sharded` is **bit-identical** to the
//!    unsharded `EpochDriver` path (`sim::run`) in both batching modes.
//! 2. On a two-deployment skewed trace, `LoadProportional` re-partitioning
//!    strictly beats `Equal` on merged throughput — the dispatch layer's
//!    reason to exist. (Scenario cross-checked numerically against the
//!    toolchain-free mirror before commit: at 40 heavy req/epoch the loaded
//!    shard serves ~9/epoch on its Equal half-pool vs ~17/epoch on the
//!    ~19-GPU load-proportional partition, while the light shard's
//!    1 req/epoch is served either way — a ~1.8× merged margin.)
//! 3. On a heterogeneous fast/slow replica pair (two migration groups, so
//!    GPUs cannot migrate between them), cross-shard work stealing strictly
//!    beats queue-depth routing + LoadProportional alone on merged
//!    in-deadline completions.
//! 4. On a diurnal (alternating heavy/light) trace, between-epoch shard
//!    autoscaling lands within 10% of the best *static* shard count — no
//!    hand-picked fleet size required.
//! 5. With every elastic behaviour off, fixed-count runs stay bit-identical
//!    run to run (the determinism contract the parity tests pin against the
//!    unsharded driver).

use edgellm::cluster::{ClusterSpec, ClusterTopology, GpuSpec, ShardSpec};
use edgellm::coordinator::{
    Deployment, Dftsp, PartitionPolicy, Scheduler, SchedulerConfig,
};
use edgellm::driver::{AnalyticBackend, AutoscalePolicy, BatchingMode, DriverBuilder, ShardedDriver};
use edgellm::metrics::Metrics;
use edgellm::model::LlmSpec;
use edgellm::quant;
use edgellm::request::RequestBuilder;
use edgellm::sim::{self, SimConfig};
use edgellm::workload::WorkloadParams;

fn sequential(_: usize) -> Box<dyn Scheduler + Send> {
    Box::new(Dftsp::with_config(SchedulerConfig { workers: 0 }))
}

#[test]
fn one_shard_is_bit_identical_to_the_unsharded_driver() {
    for batching in [BatchingMode::Epoch, BatchingMode::Continuous] {
        let cfg = SimConfig {
            workload: WorkloadParams {
                arrival_rate: 45.0,
                ..Default::default()
            },
            epochs: 12,
            seed: 99,
            batching,
            shards: 1,
            ..SimConfig::paper_default()
        };
        let unsharded = sim::run(&cfg, &mut Dftsp::new());
        let sharded = sim::run_sharded(&cfg, |_| Box::new(Dftsp::new()));
        assert_eq!(
            unsharded, sharded,
            "{batching:?}: dispatch layer with one shard must be a no-op"
        );
        assert!(unsharded.completed_in_deadline > 0, "non-degenerate run");
    }
}

/// Two deployments of BLOOM-3B under different quantizations (so affinity
/// binds), 20 TX2 GPUs, 2 s epochs. Deployment 0 takes 40 requests per
/// epoch, deployment 1 takes 1 — the skew the equal split wastes half the
/// pool on.
fn skewed_run(policy: PartitionPolicy) -> Metrics {
    let epochs = 8u64;
    let mut sd: ShardedDriver<(), AnalyticBackend> = DriverBuilder::homogeneous(
        vec![
            Deployment {
                model: LlmSpec::bloom_3b(),
                quant: quant::default_quant(), // W8A16/GPTQ
            },
            Deployment {
                model: LlmSpec::bloom_3b(),
                quant: quant::by_label(quant::Precision::W4A16, quant::QuantAlgo::Gptq).unwrap(),
            },
        ],
        ClusterSpec::paper_default(),
    )
    .partition(policy)
    .seed(4242)
    .build(|_| AnalyticBackend, sequential)
    .unwrap();
    let mut b = RequestBuilder::new();
    for e in 0..epochs {
        let now = e as f64 * 2.0;
        for _ in 0..40 {
            // Admissible on both deployments (W4A16/GPTQ on 3B admits
            // a <= 0.25), latency tight enough that unserved leftovers go
            // stale at the next boundary instead of piling up.
            sd.offer(b.build(now, 256, 256, 1.9, 0.05), (), 0);
        }
        sd.offer(b.build(now, 128, 128, 1.9, 0.05), (), 1);
        sd.step_epoch(now);
        assert_eq!(sd.partition().iter().sum::<usize>(), 20, "pool conserved");
    }
    sd.finish(epochs as f64 * 2.0);
    let m = sd.merged_metrics();
    assert_eq!(m.offered, epochs * 41);
    assert_eq!(
        m.offered,
        m.completed_in_deadline + m.completed_late + m.dropped,
        "{policy:?}: conservation through the dispatch layer"
    );
    m
}

#[test]
fn load_proportional_strictly_beats_equal_on_skewed_trace() {
    let equal = skewed_run(PartitionPolicy::Equal);
    let load = skewed_run(PartitionPolicy::LoadProportional);
    assert!(
        load.throughput() > equal.throughput(),
        "LoadProportional ({:.2} req/s, {} in-deadline) must strictly beat \
         Equal ({:.2} req/s, {} in-deadline) when demand is skewed",
        load.throughput(),
        load.completed_in_deadline,
        equal.throughput(),
        equal.completed_in_deadline
    );
    // The margin is structural (≈2× more GPUs on the hot shard), not noise:
    // demand re-partitioning must buy well over a third more goodput.
    assert!(
        load.completed_in_deadline as f64 >= 1.35 * equal.completed_in_deadline as f64,
        "expected a structural win, got {} vs {}",
        load.completed_in_deadline,
        equal.completed_in_deadline
    );
    // Both policies serve the light deployment: min-1 GPU means no
    // starvation even when 97% of the load lives elsewhere.
    assert!(equal.completed_in_deadline >= 8);
    assert!(load.completed_in_deadline >= 8);
}

/// Two replicas of the paper deployment on unequal silicon: 10 full-speed
/// TX2s next to 10 8×-underclocked ones. Distinct [`GpuSpec`]s mean two
/// single-member migration groups — LoadProportional cannot move GPUs
/// between them, and queue-depth routing splits arrivals by *count*, so the
/// slow replica accumulates a backlog the fast one could clear. Work
/// stealing is the only cross-shard remedy.
fn fast_slow_run(stealing: bool) -> Metrics {
    let epochs = 10u64;
    let fast = GpuSpec::jetson_tx2();
    let slow = GpuSpec {
        name: "jetson-tx2-underclocked".into(),
        flops: fast.flops / 8.0,
        mem_bytes: fast.mem_bytes,
    };
    let deployment = Deployment {
        model: LlmSpec::bloom_3b(),
        quant: quant::default_quant(),
    };
    let mut sd: ShardedDriver<(), AnalyticBackend> = DriverBuilder::new(
        vec![deployment.clone(), deployment],
        ClusterTopology {
            shards: vec![
                ShardSpec {
                    gpu: fast,
                    num_gpus: 10,
                },
                ShardSpec {
                    gpu: slow,
                    num_gpus: 10,
                },
            ],
        },
    )
    .seed(4242)
    .stealing(stealing)
    .build(|_| AnalyticBackend, sequential)
    .unwrap();
    let mut b = RequestBuilder::new();
    for e in 0..epochs {
        let now = e as f64 * 2.0;
        // 8 heavy requests per epoch, affinity alternating; the deployments
        // are identical, so routing balances them by queue depth anyway.
        for i in 0..8 {
            sd.offer(b.build(now, 256, 256, 1.9, 0.05), (), (i % 2) as usize);
        }
        sd.step_epoch(now);
    }
    sd.finish(epochs as f64 * 2.0);
    let m = sd.merged_metrics();
    assert_eq!(m.offered, epochs * 8);
    assert_eq!(
        m.offered,
        m.completed_in_deadline + m.completed_late + m.dropped,
        "stealing={stealing}: conservation through the dispatch layer"
    );
    assert_eq!(
        m.requests_stolen == 0,
        !stealing,
        "stealing={stealing}: the steal pass ran iff enabled \
         (stole {})",
        m.requests_stolen
    );
    m
}

#[test]
fn work_stealing_strictly_beats_routing_alone_on_a_heterogeneous_fleet() {
    let routed = fast_slow_run(false);
    let stolen = fast_slow_run(true);
    assert!(
        stolen.completed_in_deadline > routed.completed_in_deadline,
        "stealing ({} in-deadline, {} stolen) must strictly beat queue-depth \
         routing + LoadProportional alone ({} in-deadline) when replicas are \
         heterogeneous",
        stolen.completed_in_deadline,
        stolen.requests_stolen,
        routed.completed_in_deadline
    );
}

/// Diurnal trace driven through a fleet of `k` static shards — or, with
/// `autoscale`, a fleet that starts at one shard and sizes itself between
/// epochs (bounds [1, 4], one spawn/retire per boundary, GPUs bootstrapped
/// from the same homogeneous migration group).
fn diurnal_run(k: usize, autoscale: bool) -> Metrics {
    let epochs = 24u64;
    let deployment = Deployment {
        model: LlmSpec::bloom_3b(),
        quant: quant::default_quant(),
    };
    let mut builder = DriverBuilder::homogeneous(
        vec![deployment; k],
        ClusterSpec::paper_default(),
    )
    .seed(7);
    if autoscale {
        builder = builder.autoscale(AutoscalePolicy::new(1, 4));
    }
    let mut sd: ShardedDriver<(), AnalyticBackend> =
        builder.build(|_| AnalyticBackend, sequential).unwrap();
    let mut b = RequestBuilder::new();
    for e in 0..epochs {
        let now = e as f64 * 2.0;
        // Six-epoch day/night blocks: 30 heavy requests at peak, 2 at
        // trough.
        let arrivals: usize = if (e / 6) % 2 == 0 { 30 } else { 2 };
        for i in 0..arrivals {
            sd.offer(b.build(now, 256, 256, 1.9, 0.05), (), i % k.max(1));
        }
        sd.step_epoch(now);
        assert_eq!(
            sd.partition().iter().sum::<usize>(),
            20,
            "autoscaling conserves the GPU pool"
        );
    }
    sd.finish(epochs as f64 * 2.0);
    let m = sd.merged_metrics();
    assert_eq!(
        m.offered,
        m.completed_in_deadline + m.completed_late + m.dropped,
        "k={k} autoscale={autoscale}: conservation"
    );
    m
}

#[test]
fn autoscaling_lands_within_ten_percent_of_the_best_static_fleet() {
    let best_static = [1usize, 2, 4]
        .into_iter()
        .map(|k| diurnal_run(k, false).completed_in_deadline)
        .max()
        .unwrap();
    let auto = diurnal_run(1, true);
    assert!(
        auto.completed_in_deadline as f64 >= 0.9 * best_static as f64,
        "autoscaled fleet served {} in-deadline vs best static {} — more than \
         10% behind (spawned {}, retired {})",
        auto.completed_in_deadline,
        best_static,
        auto.shards_spawned,
        auto.shards_retired
    );
    assert!(auto.offered > 0);
}

#[test]
fn elastic_off_fixed_count_runs_are_bit_identical() {
    // The determinism contract: with every elastic behaviour off (the
    // default), repeated fixed-count runs through the full sim intake are
    // bit-identical — chaining with the shards=1 parity test, this pins the
    // whole tower sim == sharded == elastic-off sharded.
    for batching in [BatchingMode::Epoch, BatchingMode::Continuous] {
        let cfg = SimConfig {
            workload: WorkloadParams {
                arrival_rate: 40.0,
                ..Default::default()
            },
            epochs: 10,
            seed: 7,
            batching,
            shards: 3,
            ..SimConfig::paper_default()
        };
        let a = sim::run_sharded(&cfg, |_| Box::new(Dftsp::new()));
        let b = sim::run_sharded(&cfg, |_| Box::new(Dftsp::new()));
        assert_eq!(a, b, "{batching:?}");
        assert_eq!(a.requests_stolen, 0);
        assert_eq!(a.shards_spawned, 0);
        assert_eq!(a.shards_retired, 0);
    }
}
