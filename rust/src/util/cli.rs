//! Tiny command-line argument parser (no `clap` in the offline environment).
//!
//! Supports `subcommand --key value --key=value --flag positional` layouts,
//! typed accessors with defaults, and collects unknown keys for error
//! reporting.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        // first non-flag token is the subcommand
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    out.kv
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.kv.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.consumed.borrow_mut().push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(name.to_string());
        self.kv.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        match self.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{name} expects a number, got `{v}`")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        match self.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.u64_or(name, default as u64) as usize
    }

    /// Keys present on the command line that were never queried — catches
    /// typos like `--arival-rate`.
    pub fn unknown_keys(&self) -> Vec<String> {
        let seen = self.consumed.borrow();
        self.kv
            .keys()
            .cloned()
            .chain(self.flags.iter().cloned())
            .filter(|k| !seen.contains(k))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_subcommand_kv_flags() {
        // NOTE `--key value` is greedy: a bare `--flag` must come last or be
        // followed by another `--` token, otherwise it consumes the next
        // positional as its value.
        let a = args("serve input.txt --port 8080 --model=bloom-3b --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("model"), Some("bloom-3b"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["input.txt"]);
    }

    #[test]
    fn typed_accessors_with_defaults() {
        let a = args("run --rate 12.5 --epochs 30");
        assert_eq!(a.f64_or("rate", 1.0), 12.5);
        assert_eq!(a.u64_or("epochs", 5), 30);
        assert_eq!(a.u64_or("seed", 42), 42);
        assert_eq!(a.str_or("out", "x.json"), "x.json");
    }

    #[test]
    fn unknown_key_tracking() {
        let a = args("run --known 1 --typo 2");
        let _ = a.get("known");
        let unknown = a.unknown_keys();
        assert_eq!(unknown, vec!["typo".to_string()]);
    }

    #[test]
    fn no_subcommand_when_leading_flag() {
        let a = args("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }

    #[test]
    #[should_panic]
    fn bad_number_panics() {
        let a = args("run --rate abc");
        let _ = a.f64_or("rate", 0.0);
    }
}
