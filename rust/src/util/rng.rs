//! Deterministic pseudo-random number generation.
//!
//! The simulator must be exactly reproducible from a seed (trace replay diffs
//! schedules bit-for-bit), and the environment has no `rand` crate, so we
//! implement SplitMix64 (seeding) + xoshiro256++ (stream) by hand, following
//! the reference implementations of Blackman & Vigna.

/// SplitMix64 step — used to expand a single `u64` seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG with convenience distributions used across the simulator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire-style rejection for uniformity.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Rejection sampling over the top bits; bias is negligible only if we
        // reject, so loop until the sample falls in the unbiased zone.
        let zone = u64::MAX - (u64::MAX - n + 1) % n;
        loop {
            let v = self.next_u64();
            if v <= zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Uniformly pick one element of a slice.
    #[inline]
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Exponential with rate `lambda` (mean 1/lambda) — Poisson interarrivals.
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // in (0,1], avoids ln(0)
        -u.ln() / lambda
    }

    /// Poisson-distributed count with mean `lambda` (inversion for small
    /// lambda, normal approximation above 60 to avoid O(lambda) loops).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 60.0 {
            // Knuth inversion.
            let l = (-lambda).exp();
            let mut k: u64 = 0;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // Normal approximation with continuity correction.
            let g = self.gaussian();
            let v = lambda + lambda.sqrt() * g + 0.5;
            if v < 0.0 {
                0
            } else {
                v as u64
            }
        }
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn gaussian(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Rayleigh-distributed magnitude with scale `sigma`.
    ///
    /// Used for small-scale fading: |h| ~ Rayleigh(sigma) means the complex
    /// channel coefficient has i.i.d. N(0, sigma^2) real/imag parts.
    #[inline]
    pub fn rayleigh(&mut self, sigma: f64) -> f64 {
        let u = 1.0 - self.f64();
        sigma * (-2.0 * u.ln()).sqrt()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork a statistically independent child stream (for per-component RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA02_BDBF7BB3C0A7)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(4);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.uniform(2.0, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.03, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(6);
        let lambda = 4.0;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_small_lambda_mean_var() {
        let mut r = Rng::new(8);
        let lambda = 9.5;
        let n = 30_000;
        let xs: Vec<f64> = (0..n).map(|_| r.poisson(lambda) as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - lambda).abs() < 0.15, "mean={mean}");
        assert!((var - lambda).abs() < 0.5, "var={var}");
    }

    #[test]
    fn poisson_large_lambda_mean() {
        let mut r = Rng::new(9);
        let lambda = 250.0;
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
        assert!((mean - lambda).abs() < 1.0, "mean={mean}");
    }

    #[test]
    fn rayleigh_mean() {
        // E[Rayleigh(sigma)] = sigma * sqrt(pi/2)
        let mut r = Rng::new(10);
        let sigma = 2.0;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.rayleigh(sigma)).sum::<f64>() / n as f64;
        let expect = sigma * (std::f64::consts::PI / 2.0).sqrt();
        assert!((mean - expect).abs() < 0.03, "mean={mean} expect={expect}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(12);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(13);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }
}
