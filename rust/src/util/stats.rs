//! Small statistics helpers: online moments, percentiles, fixed-bucket
//! latency histograms (HDR-style, log-spaced) used by the metrics layer.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator into this one (parallel Welford).
    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 =
            self.m2 + other.m2 + delta * delta * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact percentile of a sample (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile out of range: {p}");
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Log-spaced latency histogram covering [1 µs, ~100 s] with fixed relative
/// error, recording values in seconds. No allocation after construction.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyHistogram {
    /// bucket i covers [lo * ratio^i, lo * ratio^(i+1))
    buckets: Vec<u64>,
    lo: f64,
    log_ratio: f64,
    count: u64,
    sum: f64,
    max: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        // 256 buckets, 1e-6 .. ~1e2 s => ratio = (1e8)^(1/256)
        let lo = 1e-6;
        let hi = 1e2;
        let n = 256;
        LatencyHistogram {
            buckets: vec![0; n],
            lo,
            log_ratio: (hi / lo).ln() / n as f64,
            count: 0,
            sum: 0.0,
            max: 0.0,
        }
    }

    fn index(&self, v: f64) -> usize {
        if v <= self.lo {
            return 0;
        }
        let idx = ((v / self.lo).ln() / self.log_ratio) as usize;
        idx.min(self.buckets.len() - 1)
    }

    pub fn record(&mut self, seconds: f64) {
        debug_assert!(seconds >= 0.0);
        let idx = self.index(seconds);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += seconds;
        if seconds > self.max {
            self.max = seconds;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Bucket index a value lands in. Bucket 0 also absorbs everything at or
    /// below the low edge; the last bucket absorbs the overflow tail.
    /// Exposed so bucket math is testable without reaching into internals.
    pub fn bucket_index(&self, v: f64) -> usize {
        self.index(v)
    }

    pub fn bucket_count(&self) -> usize {
        self.buckets.len()
    }

    /// Approximate quantile from bucket midpoints (relative error ≈ ratio).
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.count == 0 {
            return f64::NAN;
        }
        let target = (q * self.count as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                // geometric midpoint of the bucket
                return self.lo * ((i as f64 + 0.5) * self.log_ratio).exp();
            }
        }
        self.max
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        assert_eq!(self.buckets.len(), other.buckets.len());
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_basic() {
        let mut s = OnlineStats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn online_stats_merge_matches_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64) * 0.37 - 5.0).collect();
        let mut whole = OnlineStats::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
        assert!((percentile(&xs, 99.0) - 99.0).abs() <= 1.0);
    }

    #[test]
    fn histogram_quantiles_close() {
        let mut h = LatencyHistogram::new();
        // uniform latencies 1..1000 ms
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!((p50 - 0.5).abs() / 0.5 < 0.1, "p50={p50}");
        assert!((p99 - 0.99).abs() / 0.99 < 0.1, "p99={p99}");
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for i in 1..=500 {
            a.record(i as f64 * 1e-3);
        }
        for i in 501..=1000 {
            b.record(i as f64 * 1e-3);
        }
        a.merge(&b);
        assert_eq!(a.count(), 1000);
        let p50 = a.quantile(0.5);
        assert!((p50 - 0.5).abs() / 0.5 < 0.1, "p50={p50}");
    }

    #[test]
    fn histogram_extremes_clamped() {
        let mut h = LatencyHistogram::new();
        h.record(0.0);
        h.record(1e9);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(1.0) > 0.0);
    }

    #[test]
    fn histogram_bucket_index_monotone_and_clamped() {
        let h = LatencyHistogram::new();
        // Below-range and at-edge values land in bucket 0; far-overflow in
        // the last bucket; and the mapping never decreases as values grow.
        assert_eq!(h.bucket_index(0.0), 0);
        assert_eq!(h.bucket_index(1e-9), 0);
        assert_eq!(h.bucket_index(1e-6), 0);
        assert_eq!(h.bucket_index(1e9), h.bucket_count() - 1);
        let mut prev = 0usize;
        let mut v = 1e-7;
        while v < 1e3 {
            let idx = h.bucket_index(v);
            assert!(idx >= prev, "index must be monotone in the value");
            assert!(idx < h.bucket_count());
            prev = idx;
            v *= 1.07;
        }
        // The full range actually spreads over the bucket space (log-spaced,
        // not collapsed into a few buckets).
        assert!(h.bucket_index(50.0) > h.bucket_count() / 2);
    }

    #[test]
    fn histogram_merge_equals_sequential_bitexact() {
        // Merging two shards must equal recording everything into one
        // histogram — bucket-for-bucket (PartialEq covers buckets, count,
        // sum and max), the exact-merge contract `Metrics::merge` relies on.
        let mut whole = LatencyHistogram::new();
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for i in 0..400 {
            let v = 1e-5 * (1.05f64).powi(i % 97) * (1 + i % 7) as f64;
            whole.record(v);
            if i % 3 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        assert_eq!(a, whole);
        // Merging an empty histogram is the identity.
        let snapshot = a.clone();
        a.merge(&LatencyHistogram::new());
        assert_eq!(a, snapshot);
    }

    #[test]
    fn histogram_p99_tail_resolution() {
        let mut h = LatencyHistogram::new();
        // 985 fast requests at ~2 ms, 15 stragglers at ~1.5 s: p99 must see
        // the straggler tail, not the bulk.
        for _ in 0..985 {
            h.record(0.002);
        }
        for _ in 0..15 {
            h.record(1.5);
        }
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        assert!((p50 - 0.002).abs() / 0.002 < 0.1, "p50={p50}");
        assert!(p99 > 1.0, "p99={p99} must resolve the tail");
        assert!((p99 - 1.5).abs() / 1.5 < 0.1, "p99={p99}");
    }
}
