//! Human-readable formatting (bytes, FLOPs, durations) and an aligned
//! plain-text table printer used by the bench harness to emit paper-style
//! rows.

/// Format a byte count with binary prefixes.
pub fn bytes(n: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n;
    let mut u = 0;
    while v.abs() >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{:.0} {}", v, UNITS[u])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a FLOP count with SI prefixes.
pub fn flops(n: f64) -> String {
    const UNITS: [&str; 6] = ["", "K", "M", "G", "T", "P"];
    let mut v = n;
    let mut u = 0;
    while v.abs() >= 1000.0 && u < UNITS.len() - 1 {
        v /= 1000.0;
        u += 1;
    }
    format!("{:.2} {}FLOPs", v, UNITS[u])
}

/// Format seconds adaptively (ns/µs/ms/s).
pub fn duration(secs: f64) -> String {
    let a = secs.abs();
    if a >= 1.0 {
        format!("{secs:.3} s")
    } else if a >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// A simple aligned text table. Columns are sized to the widest cell.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header"
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncols {
                line.push_str(&format!(" {:width$} |", cells[i], width = widths[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_prefixes() {
        assert_eq!(bytes(512.0), "512 B");
        assert_eq!(bytes(2048.0), "2.00 KiB");
        assert!(bytes(3.5 * 1024.0 * 1024.0 * 1024.0).contains("GiB"));
    }

    #[test]
    fn flops_prefixes() {
        assert!(flops(1.33e12).contains("TFLOPs"));
        assert!(flops(2.5e9).starts_with("2.50 G"));
    }

    #[test]
    fn duration_scales() {
        assert!(duration(2.0).ends_with(" s"));
        assert!(duration(0.002).ends_with(" ms"));
        assert!(duration(3e-6).ends_with(" µs"));
        assert!(duration(5e-8).ends_with(" ns"));
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["name", "value"]);
        t.row_strs(&["x", "1"]).row_strs(&["longer-name", "22"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        // all lines same width
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(lines[0].contains("name"));
        assert!(lines[3].contains("longer-name"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }
}
