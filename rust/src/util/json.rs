//! Minimal JSON parser/serializer (no serde in the offline environment).
//!
//! Covers the full JSON grammar we need for artifact manifests
//! (`artifacts/meta.json`, `artifacts/ppl.json`), workload traces (JSONL),
//! and bench result dumps. Numbers are kept as f64 (all our payloads fit).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for deterministic output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Fetch a required numeric field from an object.
    pub fn req_f64(&self, key: &str) -> Result<f64, String> {
        self.get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("missing or non-numeric field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("missing or non-string field `{key}`"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let rest = &self.b[self.pos..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos += chunk.len();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        let v2 = Json::parse(&out).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "hi", "b": false, "a": [1,2]}"#).unwrap();
        assert_eq!(v.req_f64("n").unwrap(), 3.0);
        assert_eq!(v.req_str("s").unwrap(), "hi");
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.req_f64("missing").is_err());
    }

    #[test]
    fn parses_nested_and_unicode() {
        let v = Json::parse(r#"{"u": "éé", "deep": [[[1]]]}"#).unwrap();
        assert_eq!(v.req_str("u").unwrap(), "éé");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn integer_formatting_exact() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(-0.5).to_string(), "-0.5");
    }

    #[test]
    fn escaped_output_reparses() {
        let s = Json::Str("line1\nline2\t\"q\"\\".to_string());
        let back = Json::parse(&s.to_string()).unwrap();
        assert_eq!(back, s);
    }
}
