//! Coarse lazy timer wheel for the evented front-end.
//!
//! Connection deadlines (idle reap, reply wait) need thousands of cheap
//! timers with ~10 ms precision, not a heap of exact ones. The wheel hashes
//! each entry into `slots[due_tick % slots]` and fires it lazily: entries
//! are only examined when their slot is visited, and an entry whose due tick
//! lies one or more laps ahead simply stays in the slot until the clock
//! actually reaches it. There is no cancel operation — payloads are
//! validated by the caller when they fire (the event loop checks the
//! generational [`SlabKey`](crate::util::slab::SlabKey) packed into the
//! payload), which keeps arm/disarm O(1) and allocation-free on the hot
//! path.
//!
//! The wheel has no thread of its own: the owner calls
//! [`TimerWheel::advance_to`] with the current tick (derived from a
//! monotonic clock) whenever it wakes up — in the event loop, from
//! `epoll_wait`'s timeout.

use std::time::Duration;

pub struct TimerWheel {
    /// `slots[t % slots.len()]` holds entries due at tick `t` (or `t + k·laps`).
    slots: Vec<Vec<TimerEntry>>,
    granularity: Duration,
    /// Last tick fully processed by `advance_to`.
    now: u64,
    len: usize,
}

#[derive(Debug, Clone, Copy)]
struct TimerEntry {
    payload: u64,
    due_tick: u64,
}

impl TimerWheel {
    /// `granularity` is the tick length; `slots` bounds how many ticks fit
    /// in one lap (longer delays are fine — they just wait extra laps).
    pub fn new(granularity: Duration, slots: usize) -> Self {
        assert!(slots > 0, "timer wheel needs at least one slot");
        assert!(
            granularity > Duration::ZERO,
            "timer wheel granularity must be positive"
        );
        TimerWheel {
            slots: (0..slots).map(|_| Vec::new()).collect(),
            granularity,
            now: 0,
            len: 0,
        }
    }

    pub fn granularity(&self) -> Duration {
        self.granularity
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn now_tick(&self) -> u64 {
        self.now
    }

    /// Converts an elapsed wall duration into a tick count (ceiling, so a
    /// deadline never fires early; minimum one tick so `schedule_after`
    /// never lands in the past).
    pub fn ticks_for(&self, delay: Duration) -> u64 {
        let g = self.granularity.as_nanos();
        let d = delay.as_nanos();
        (d.div_ceil(g).max(1)) as u64
    }

    /// Schedules `payload` to fire once the wheel advances past `delay`.
    pub fn schedule_after(&mut self, payload: u64, delay: Duration) {
        let due_tick = self.now + self.ticks_for(delay);
        let slot = (due_tick % self.slots.len() as u64) as usize;
        self.slots[slot].push(TimerEntry { payload, due_tick });
        self.len += 1;
    }

    /// Advances the wheel to `tick`, returning every payload whose deadline
    /// has passed. Visits at most one full lap of slots, which covers any
    /// jump size; entries due beyond `tick` stay put for a later lap.
    pub fn advance_to(&mut self, tick: u64) -> Vec<u64> {
        let mut fired = Vec::new();
        if tick <= self.now || self.len == 0 {
            self.now = self.now.max(tick);
            return fired;
        }
        let nslots = self.slots.len() as u64;
        let steps = (tick - self.now).min(nslots);
        for i in 1..=steps {
            let slot = ((self.now + i) % nslots) as usize;
            let entries = &mut self.slots[slot];
            let mut j = 0;
            while j < entries.len() {
                if entries[j].due_tick <= tick {
                    fired.push(entries.swap_remove(j).payload);
                    self.len -= 1;
                } else {
                    j += 1;
                }
            }
        }
        self.now = tick;
        fired
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wheel() -> TimerWheel {
        TimerWheel::new(Duration::from_millis(10), 8)
    }

    #[test]
    fn fires_at_or_after_the_due_tick_never_before() {
        let mut w = wheel();
        w.schedule_after(7, Duration::from_millis(30)); // due tick 3
        assert!(w.advance_to(2).is_empty());
        assert_eq!(w.advance_to(3), vec![7]);
        assert!(w.is_empty());
    }

    #[test]
    fn sub_granularity_delay_rounds_up_to_one_tick() {
        let mut w = wheel();
        w.schedule_after(1, Duration::from_millis(1));
        assert_eq!(w.advance_to(1), vec![1]);
    }

    #[test]
    fn multi_lap_entries_wait_for_their_lap() {
        let mut w = wheel(); // 8 slots: due tick 10 shares a slot with tick 2
        w.schedule_after(42, Duration::from_millis(100)); // due tick 10
        assert!(w.advance_to(2).is_empty(), "slot visited, entry not yet due");
        assert!(w.advance_to(9).is_empty());
        assert_eq!(w.advance_to(10), vec![42]);
    }

    #[test]
    fn large_jump_fires_everything_due() {
        let mut w = wheel();
        w.schedule_after(1, Duration::from_millis(20));
        w.schedule_after(2, Duration::from_millis(50));
        w.schedule_after(3, Duration::from_millis(500)); // due tick 50, beyond jump
        let mut fired = w.advance_to(30); // > one lap past both deadlines
        fired.sort_unstable();
        assert_eq!(fired, vec![1, 2]);
        assert_eq!(w.len(), 1);
        assert_eq!(w.advance_to(50), vec![3]);
    }

    #[test]
    fn advancing_backwards_is_a_no_op() {
        let mut w = wheel();
        w.schedule_after(9, Duration::from_millis(10));
        assert_eq!(w.advance_to(5), vec![9]);
        assert!(w.advance_to(3).is_empty());
        assert_eq!(w.now_tick(), 5);
    }
}
