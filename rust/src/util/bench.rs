//! Micro-benchmark harness (no `criterion` in the offline environment).
//!
//! Provides warmup + repeated timed runs, outlier-robust summary statistics,
//! and a black_box to defeat constant folding. Used by `rust/benches/*` and
//! the §Perf pass.

use std::hint::black_box as std_black_box;
use std::time::Instant;

/// Re-export of the standard black box; benchmark bodies should wrap both
/// inputs and outputs.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Summary of a benchmark run (times in seconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: f64,
    pub median: f64,
    pub p95: f64,
    pub min: f64,
    pub std: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  median {:>12}  p95 {:>12}  (n={})",
            self.name,
            super::fmt::duration(self.mean),
            super::fmt::duration(self.median),
            super::fmt::duration(self.p95),
            self.iters,
        )
    }

    /// Machine-readable view (seconds/iteration) for tracked bench baselines.
    pub fn to_json(&self) -> super::json::Json {
        use super::json::Json;
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_s", Json::Num(self.mean)),
            ("median_s", Json::Num(self.median)),
            ("p95_s", Json::Num(self.p95)),
            ("min_s", Json::Num(self.min)),
            ("std_s", Json::Num(self.std)),
        ])
    }
}

/// Collects benchmark rows and writes them as one tracked JSON artifact
/// (e.g. `BENCH_dftsp.json` at the repository root) so the bench trajectory
/// is diffable commit-over-commit and uploadable from CI.
#[derive(Debug, Default)]
pub struct BenchSuite {
    rows: Vec<super::json::Json>,
}

impl BenchSuite {
    pub fn new() -> Self {
        BenchSuite::default()
    }

    pub fn push(&mut self, row: super::json::Json) {
        self.rows.push(row);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// `{"rows": [...], "provenance": ...}` — provenance names the command
    /// that regenerates the file, so a stale baseline is always one
    /// invocation away from fresh.
    pub fn to_json(&self, provenance: &str) -> super::json::Json {
        use super::json::Json;
        Json::obj(vec![
            ("provenance", Json::Str(provenance.to_string())),
            ("rows", Json::Arr(self.rows.clone())),
        ])
    }

    pub fn write(&self, path: &std::path::Path, provenance: &str) -> std::io::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json(provenance)))
    }
}

/// Benchmark runner: calibrates iteration count toward `target_time`,
/// then takes `samples` timed samples.
pub struct Bencher {
    pub warmup_time: f64,
    pub target_time: f64,
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_time: 0.2,
            target_time: 1.0,
            samples: 20,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup_time: 0.05,
            target_time: 0.25,
            samples: 10,
        }
    }

    /// Run `f` repeatedly and summarize per-iteration latency.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup + calibration: find iters/sample such that one sample takes
        // roughly target_time / samples.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed().as_secs_f64() < self.warmup_time {
            f();
            warm_iters += 1;
        }
        let per_iter = self.warmup_time / warm_iters.max(1) as f64;
        let sample_budget = self.target_time / self.samples as f64;
        let iters_per_sample = ((sample_budget / per_iter) as u64).max(1);

        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            times.push(t0.elapsed().as_secs_f64() / iters_per_sample as f64);
        }
        times.sort_by(f64::total_cmp);
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let var = times.iter().map(|t| (t - mean).powi(2)).sum::<f64>()
            / times.len().max(1) as f64;
        BenchResult {
            name: name.to_string(),
            iters: iters_per_sample * self.samples as u64,
            mean,
            median: times[times.len() / 2],
            p95: times[((times.len() as f64 * 0.95) as usize).min(times.len() - 1)],
            min: times[0],
            std: var.sqrt(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let b = Bencher {
            warmup_time: 0.01,
            target_time: 0.05,
            samples: 5,
        };
        let mut acc = 0u64;
        let r = b.run("spin", || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(r.mean > 0.0);
        assert!(r.median > 0.0);
        assert!(r.iters >= 5);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn bench_suite_json_round_trips() {
        let b = Bencher {
            warmup_time: 0.01,
            target_time: 0.02,
            samples: 3,
        };
        let r = b.run("suite/row", || {
            black_box(1 + 1);
        });
        let mut suite = BenchSuite::new();
        suite.push(r.to_json());
        assert_eq!(suite.len(), 1);
        let s = suite.to_json("cargo bench --bench perf_hotpath -- --json").to_string();
        let back = crate::util::json::Json::parse(&s).unwrap();
        assert!(back.req_str("provenance").unwrap().contains("perf_hotpath"));
        let rows = back.get("rows").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].req_str("name").unwrap(), "suite/row");
        assert!(rows[0].req_f64("median_s").unwrap() >= 0.0);
    }

    #[test]
    fn faster_code_benches_faster() {
        let b = Bencher {
            warmup_time: 0.01,
            target_time: 0.08,
            samples: 8,
        };
        let small = b.run("small", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        let big = b.run("big", || {
            let mut s = 0u64;
            for i in 0..100_000u64 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(big.median > small.median * 5.0);
    }
}
