//! Cross-cutting utilities built from scratch for the offline environment:
//! deterministic RNG, JSON, CLI parsing, formatting, statistics, and a
//! micro-benchmark harness.

pub mod bench;
pub mod cli;
pub mod fmt;
pub mod json;
pub mod rng;
pub mod stats;
