//! Cross-cutting utilities built from scratch for the offline environment:
//! deterministic RNG, JSON, CLI parsing, formatting, statistics, a
//! micro-benchmark harness, and the slab/timer-wheel pair backing the
//! evented front-end.

pub mod bench;
pub mod cli;
pub mod fmt;
pub mod json;
pub mod rng;
pub mod slab;
pub mod stats;
pub mod timer;
