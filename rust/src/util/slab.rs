//! Generational slab: dense storage with stable, ABA-safe keys.
//!
//! The evented front-end keeps one state machine per live connection and
//! refers to it from epoll tokens and timer-wheel payloads — both of which
//! can outlive the connection (a timer entry is never cancelled, an epoll
//! event can already be queued when the fd is closed). A plain `Vec` index
//! would let a stale token resolve to a *new* connection that recycled the
//! slot; the generation counter makes such lookups miss instead.
//!
//! Capacity grows on demand and freed slots are recycled LIFO, so a steady
//! churn of N concurrent connections touches only N slots regardless of how
//! many connections have come and gone.

/// Key into a [`Slab`]: slot index plus the generation the slot had when the
/// value was inserted. Lookups with a stale generation return `None`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SlabKey {
    pub index: u32,
    pub generation: u32,
}

enum Entry<T> {
    /// Free slot; `next_generation` is what the next occupant will stamp.
    Vacant { next_generation: u32 },
    Occupied { generation: u32, value: T },
}

pub struct Slab<T> {
    entries: Vec<Entry<T>>,
    free: Vec<u32>,
    live: usize,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Slab::new()
    }
}

impl<T> Slab<T> {
    pub fn new() -> Self {
        Slab {
            entries: Vec::new(),
            free: Vec::new(),
            live: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    pub fn insert(&mut self, value: T) -> SlabKey {
        self.live += 1;
        if let Some(index) = self.free.pop() {
            let generation = match self.entries[index as usize] {
                Entry::Vacant { next_generation } => next_generation,
                Entry::Occupied { .. } => unreachable!("free list points at occupied slot"),
            };
            self.entries[index as usize] = Entry::Occupied { generation, value };
            return SlabKey { index, generation };
        }
        let index = u32::try_from(self.entries.len()).expect("slab capacity exceeds u32");
        self.entries.push(Entry::Occupied {
            generation: 0,
            value,
        });
        SlabKey {
            index,
            generation: 0,
        }
    }

    pub fn get(&self, key: SlabKey) -> Option<&T> {
        match self.entries.get(key.index as usize) {
            Some(Entry::Occupied { generation, value }) if *generation == key.generation => {
                Some(value)
            }
            _ => None,
        }
    }

    pub fn get_mut(&mut self, key: SlabKey) -> Option<&mut T> {
        match self.entries.get_mut(key.index as usize) {
            Some(Entry::Occupied { generation, value }) if *generation == key.generation => {
                Some(value)
            }
            _ => None,
        }
    }

    /// Removes and returns the value, or `None` if the key is stale.
    pub fn remove(&mut self, key: SlabKey) -> Option<T> {
        let slot = self.entries.get_mut(key.index as usize)?;
        match slot {
            Entry::Occupied { generation, .. } if *generation == key.generation => {
                let next_generation = generation.wrapping_add(1);
                let old = std::mem::replace(slot, Entry::Vacant { next_generation });
                self.free.push(key.index);
                self.live -= 1;
                match old {
                    Entry::Occupied { value, .. } => Some(value),
                    Entry::Vacant { .. } => unreachable!(),
                }
            }
            _ => None,
        }
    }

    /// Keys of every live entry, in slot order. Collected (rather than
    /// borrowed) so the caller can mutate the slab while walking them.
    pub fn keys(&self) -> Vec<SlabKey> {
        self.entries
            .iter()
            .enumerate()
            .filter_map(|(i, e)| match e {
                Entry::Occupied { generation, .. } => Some(SlabKey {
                    index: i as u32,
                    generation: *generation,
                }),
                Entry::Vacant { .. } => None,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut slab = Slab::new();
        let a = slab.insert("a");
        let b = slab.insert("b");
        assert_eq!(slab.len(), 2);
        assert_eq!(slab.get(a), Some(&"a"));
        assert_eq!(slab.get(b), Some(&"b"));
        assert_eq!(slab.remove(a), Some("a"));
        assert_eq!(slab.get(a), None);
        assert_eq!(slab.len(), 1);
    }

    #[test]
    fn stale_keys_miss_after_slot_reuse() {
        let mut slab = Slab::new();
        let a = slab.insert(1u32);
        slab.remove(a);
        let b = slab.insert(2u32);
        // Same slot, new generation: the stale key must not alias.
        assert_eq!(b.index, a.index);
        assert_ne!(b.generation, a.generation);
        assert_eq!(slab.get(a), None);
        assert_eq!(slab.remove(a), None);
        assert_eq!(slab.get(b), Some(&2));
    }

    #[test]
    fn keys_walks_only_live_entries() {
        let mut slab = Slab::new();
        let a = slab.insert(10);
        let b = slab.insert(20);
        let c = slab.insert(30);
        slab.remove(b);
        let keys = slab.keys();
        assert_eq!(keys, vec![a, c]);
        let sum: i32 = keys.iter().map(|&k| *slab.get(k).unwrap()).sum();
        assert_eq!(sum, 40);
    }

    #[test]
    fn churn_recycles_slots() {
        let mut slab = Slab::new();
        for round in 0..100u32 {
            let k = slab.insert(round);
            assert!(k.index < 1, "steady churn of one value must reuse slot 0");
            assert_eq!(slab.remove(k), Some(round));
        }
        assert!(slab.is_empty());
    }
}
