//! Post-training quantization model — paper §II-B(3) and Table II.
//!
//! A `QuantSpec` bundles the three scalars the optimization consumes:
//! α (memory-saving factor), β (compute-time factor), and ΔPPL (perplexity
//! degradation, per model). All three are "measured via offline exhaustive
//! evaluations" in the paper; we ship the paper's Table II ΔPPL values for
//! the Table I models and additionally load *measured* values for the tiny
//! real model from `artifacts/ppl.json` (produced by `python/compile/ppl.py`
//! at build time), so both sources flow through the same code path.

use std::collections::BTreeMap;

/// Weight/activation/KV-cache bit-widths, e.g. W8A16 or W8A8KV8.
///
/// `kv_bits` is the *stored* width of the KV-cache arenas, independent of the
/// activation compute width: W8A8 still stores f32 KV (kv_bits = 16-class
/// baseline), while a `KV8` suffix on the label selects per-row symmetric
/// int8 KV storage in the host engine and halves the per-element KV
/// footprint the memory ledger accounts for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Precision {
    pub w_bits: u8,
    pub a_bits: u8,
    /// KV-cache storage width (16 = baseline, 8 = int8 KV arenas).
    pub kv_bits: u8,
}

impl Precision {
    pub const W16A16: Precision = Precision {
        w_bits: 16,
        a_bits: 16,
        kv_bits: 16,
    };
    pub const W8A16: Precision = Precision {
        w_bits: 8,
        a_bits: 16,
        kv_bits: 16,
    };
    pub const W4A16: Precision = Precision {
        w_bits: 4,
        a_bits: 16,
        kv_bits: 16,
    };
    pub const W8A8: Precision = Precision {
        w_bits: 8,
        a_bits: 8,
        kv_bits: 16,
    };
    /// W8A8 compute plus int8 KV-cache storage (label "W8A8KV8").
    pub const W8A8KV8: Precision = Precision {
        w_bits: 8,
        a_bits: 8,
        kv_bits: 8,
    };

    pub fn label(&self) -> String {
        if self.kv_bits == 16 {
            format!("W{}A{}", self.w_bits, self.a_bits)
        } else {
            format!("W{}A{}KV{}", self.w_bits, self.a_bits, self.kv_bits)
        }
    }

    /// Weight-memory scaling vs the 16-bit baseline.
    pub fn weight_scale(&self) -> f64 {
        self.w_bits as f64 / 16.0
    }

    /// Activation/KV-cache memory scaling vs the 16-bit baseline.
    pub fn act_scale(&self) -> f64 {
        self.a_bits as f64 / 16.0
    }

    /// KV-cache bytes-per-element scaling vs the 16-bit baseline: 1.0 for
    /// f32/fp16-class KV storage, 0.5 when the KV arenas are int8.
    pub fn kv_scale(&self) -> f64 {
        self.kv_bits as f64 / 16.0
    }
}

/// The PTQ algorithm family (distinct tensor-rounding strategies give
/// distinct ΔPPL at identical precision — paper Fig. 6(b)).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum QuantAlgo {
    /// No quantization (fp16 baseline).
    None,
    /// GPTQ-style second-order weight rounding.
    Gptq,
    /// ZeroQuant-Local style group-wise rounding.
    ZqLocal,
    /// Plain round-to-nearest (used by the tiny real model's W8A16 default).
    Rtn,
}

impl QuantAlgo {
    pub fn label(&self) -> &'static str {
        match self {
            QuantAlgo::None => "none",
            QuantAlgo::Gptq => "GPTQ",
            QuantAlgo::ZqLocal => "ZQ-Local",
            QuantAlgo::Rtn => "RTN",
        }
    }
}

/// A deployable quantization configuration with its measured effect scalars.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantSpec {
    pub precision: Precision,
    pub algo: QuantAlgo,
    /// α — aggregate memory-saving factor applied to (m1 + m2^I + m2^A) as in
    /// constraint (1c). 1.0 = no saving. Derived from bit-widths.
    pub alpha: f64,
    /// β — compute-time factor applied to (t^I + t^A) as in constraint (1d).
    /// <1 speeds up inference (narrower loads ⇒ less memory traffic), but
    /// dequantization overhead keeps it above the pure bit-ratio.
    pub beta: f64,
    /// ΔPPL per model name (perplexity degradation vs fp16; larger = worse).
    pub dppl: BTreeMap<String, f64>,
}

impl QuantSpec {
    /// fp16 baseline: no memory saving, no speedup, no degradation.
    pub fn fp16() -> QuantSpec {
        QuantSpec {
            precision: Precision::W16A16,
            algo: QuantAlgo::None,
            alpha: 1.0,
            beta: 1.0,
            dppl: BTreeMap::new(),
        }
    }

    /// Label like "W4A16/GPTQ".
    pub fn label(&self) -> String {
        if self.algo == QuantAlgo::None {
            self.precision.label()
        } else {
            format!("{}/{}", self.precision.label(), self.algo.label())
        }
    }

    /// ΔPPL for a given model (0.0 when unquantized or unknown-but-baseline).
    pub fn dppl_for(&self, model: &str) -> f64 {
        if self.algo == QuantAlgo::None {
            return 0.0;
        }
        *self.dppl.get(model).unwrap_or(&f64::INFINITY)
    }

    /// The accuracy function f — monotonically decreasing in ΔPPL, mapping
    /// perplexity degradation into the same [0, 1] scale as the user accuracy
    /// requirement a_i: f(Δ) = max(0, 1 − Δ).
    pub fn accuracy_for(&self, model: &str) -> f64 {
        f_accuracy(self.dppl_for(model))
    }

    /// Does this deployment satisfy user accuracy requirement `a` in [0,1]
    /// for `model` — constraint (1e): a_i ≤ f(ΔPPL).
    pub fn satisfies_accuracy(&self, model: &str, a: f64) -> bool {
        a <= self.accuracy_for(model)
    }

    /// KV-cache bytes-per-element factor vs the unscaled baseline the cost
    /// model quotes: 1.0 for f32/fp16-class KV, 0.5 when the KV arenas are
    /// stored int8 (kv_bits = 8). `ClusterSpec::kv_budget_per_gpu` divides
    /// by this, so the same physical headroom admits 1/factor× the unscaled
    /// KV bytes — the memory win of KV quantization, threaded through
    /// `max_batch_by_memory`, the DFTSP memory bound and the `KvLedger`.
    pub fn kv_bytes_factor(&self) -> f64 {
        self.precision.kv_scale()
    }
}

/// f(ΔPPL) — paper's monotonically-decreasing accuracy map.
pub fn f_accuracy(dppl: f64) -> f64 {
    (1.0 - dppl).max(0.0)
}

fn dppl_map(entries: &[(&str, f64)]) -> BTreeMap<String, f64> {
    entries
        .iter()
        .map(|(k, v)| (k.to_string(), *v))
        .collect()
}

/// The catalog of quantization deployments used in the paper's evaluation.
///
/// - W8A16 (default in §IV): small, nearly-lossless degradation.
/// - W4A16 GPTQ and ZQ-Local: the exact Table II ΔPPL values.
/// - α is the memory ratio of (weights at w_bits + KV at a_bits) to the
///   fp16 baseline, weight-dominated for large models; β reflects the
///   memory-bandwidth-bound speedup minus dequantization overhead, per the
///   offline-profiling framing of [10].
pub fn catalog() -> Vec<QuantSpec> {
    vec![
        QuantSpec::fp16(),
        QuantSpec {
            precision: Precision::W8A16,
            algo: QuantAlgo::Gptq,
            alpha: 0.55,
            beta: 0.80,
            dppl: dppl_map(&[
                ("BLOOM-3B", 0.06),
                ("BLOOM-7.1B", 0.04),
                ("OPT-13B", 0.02),
            ]),
        },
        QuantSpec {
            precision: Precision::W8A16,
            algo: QuantAlgo::ZqLocal,
            alpha: 0.55,
            beta: 0.83,
            dppl: dppl_map(&[
                ("BLOOM-3B", 0.09),
                ("BLOOM-7.1B", 0.06),
                ("OPT-13B", 0.05),
            ]),
        },
        QuantSpec {
            precision: Precision::W4A16,
            algo: QuantAlgo::Gptq,
            alpha: 0.35,
            beta: 0.70,
            // Table II, row GPTQ.
            dppl: dppl_map(&[
                ("BLOOM-3B", 0.75),
                ("BLOOM-7.1B", 0.54),
                ("OPT-13B", 0.20),
            ]),
        },
        QuantSpec {
            precision: Precision::W4A16,
            algo: QuantAlgo::ZqLocal,
            alpha: 0.35,
            beta: 0.74,
            // Table II, row ZQ-Local.
            dppl: dppl_map(&[
                ("BLOOM-3B", 0.92),
                ("BLOOM-7.1B", 0.59),
                ("OPT-13B", 0.42),
            ]),
        },
    ]
}

/// The paper's default deployment (§IV: "Default quantization is 8-bit
/// weight, 16-bit activation (W8A16)").
pub fn default_quant() -> QuantSpec {
    catalog()
        .into_iter()
        .find(|q| q.precision == Precision::W8A16 && q.algo == QuantAlgo::Gptq)
        .expect("catalog contains W8A16/GPTQ")
}

/// Find a catalog entry by precision + algorithm.
pub fn by_label(precision: Precision, algo: QuantAlgo) -> Option<QuantSpec> {
    catalog()
        .into_iter()
        .find(|q| q.precision == precision && q.algo == algo)
}

/// Parse a label like "W8A16/RTN", "W8A8KV8/RTN" or "W16A16" into its
/// parts. An optional `KV8` suffix on the precision selects int8 KV-cache
/// storage (kv_bits = 8); without it the KV arenas stay at the baseline
/// width.
pub fn parse_label(label: &str) -> Option<(Precision, QuantAlgo)> {
    if label.eq_ignore_ascii_case("W16A16") || label.eq_ignore_ascii_case("fp16") {
        return Some((Precision::W16A16, QuantAlgo::None));
    }
    let (prec_s, algo_s) = label.split_once('/')?;
    let prec_upper = prec_s.to_ascii_uppercase();
    let (base_s, kv_bits) = match prec_upper.strip_suffix("KV8") {
        Some(base) => (base, 8u8),
        None => (prec_upper.as_str(), 16u8),
    };
    let base = match base_s {
        "W8A16" => Precision::W8A16,
        "W4A16" => Precision::W4A16,
        "W8A8" => Precision::W8A8,
        _ => return None,
    };
    let precision = Precision { kv_bits, ..base };
    let algo = match algo_s.to_ascii_uppercase().as_str() {
        "GPTQ" => QuantAlgo::Gptq,
        "ZQ-LOCAL" | "ZQLOCAL" => QuantAlgo::ZqLocal,
        "RTN" => QuantAlgo::Rtn,
        "NONE" => QuantAlgo::None,
        _ => return None,
    };
    Some((precision, algo))
}

/// A usable spec for any parsable label: the catalog entry when one exists,
/// otherwise a synthesized spec with precision-derived α/β and an empty
/// ΔPPL map (callers merge measured values, e.g. from artifacts/ppl.json).
pub fn spec_for_label(label: &str) -> Option<QuantSpec> {
    let (precision, algo) = parse_label(label)?;
    if algo == QuantAlgo::None {
        return Some(QuantSpec::fp16());
    }
    if let Some(spec) = by_label(precision, algo) {
        return Some(spec);
    }
    // KV-int8 variants share their base precision's α/β: α already covers
    // the aggregate weight saving, and the KV-storage win is threaded
    // separately through `kv_bytes_factor` — keeping the pair identical
    // isolates the KV factor when comparing e.g. W8A8 vs W8A8KV8.
    let (alpha, beta) = match (precision.w_bits, precision.a_bits) {
        (16, 16) => (1.0, 1.0),
        (8, 16) => (0.55, 0.82),
        (4, 16) => (0.35, 0.72),
        _ => (0.40, 0.75), // W8A8-class
    };
    Some(QuantSpec {
        precision,
        algo,
        alpha,
        beta,
        dppl: BTreeMap::new(),
    })
}

/// Load measured ΔPPL entries (from `artifacts/ppl.json`) and merge them into
/// a catalog spec, so the tiny real model's measured degradation flows through
/// the same admission path as Table II. The JSON shape is
/// `{"model": "tiny-decoder", "entries": [{"label": "W8A16/RTN", "dppl": 0.01}, ...]}`.
pub fn merge_measured_dppl(
    specs: &mut [QuantSpec],
    json: &crate::util::json::Json,
) -> Result<usize, String> {
    let model = json.req_str("model")?.to_string();
    let entries = json
        .get("entries")
        .and_then(|e| e.as_arr())
        .ok_or("missing `entries` array")?;
    let mut merged = 0;
    for e in entries {
        let label = e.req_str("label")?;
        let dppl = e.req_f64("dppl")?;
        for spec in specs.iter_mut() {
            if spec.label() == label {
                spec.dppl.insert(model.clone(), dppl);
                merged += 1;
            }
        }
    }
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_values_present() {
        let w4_gptq = by_label(Precision::W4A16, QuantAlgo::Gptq).unwrap();
        assert_eq!(w4_gptq.dppl_for("BLOOM-3B"), 0.75);
        assert_eq!(w4_gptq.dppl_for("BLOOM-7.1B"), 0.54);
        assert_eq!(w4_gptq.dppl_for("OPT-13B"), 0.20);
        let w4_zq = by_label(Precision::W4A16, QuantAlgo::ZqLocal).unwrap();
        assert_eq!(w4_zq.dppl_for("BLOOM-3B"), 0.92);
        assert_eq!(w4_zq.dppl_for("BLOOM-7.1B"), 0.59);
        assert_eq!(w4_zq.dppl_for("OPT-13B"), 0.42);
    }

    #[test]
    fn alpha_beta_monotone_in_precision() {
        // Fewer bits ⇒ more memory saving (smaller α) and faster (smaller β).
        let fp = QuantSpec::fp16();
        let w8 = by_label(Precision::W8A16, QuantAlgo::Gptq).unwrap();
        let w4 = by_label(Precision::W4A16, QuantAlgo::Gptq).unwrap();
        assert!(fp.alpha > w8.alpha && w8.alpha > w4.alpha);
        assert!(fp.beta > w8.beta && w8.beta > w4.beta);
    }

    #[test]
    fn accuracy_function_decreasing_and_clamped() {
        assert_eq!(f_accuracy(0.0), 1.0);
        assert!(f_accuracy(0.3) > f_accuracy(0.7));
        assert_eq!(f_accuracy(1.5), 0.0);
    }

    #[test]
    fn accuracy_admission() {
        let w4_zq = by_label(Precision::W4A16, QuantAlgo::ZqLocal).unwrap();
        // BLOOM-3B dPPL 0.92 => f = 0.08: only very lax users admitted.
        assert!(w4_zq.satisfies_accuracy("BLOOM-3B", 0.05));
        assert!(!w4_zq.satisfies_accuracy("BLOOM-3B", 0.5));
        // fp16 admits everyone.
        assert!(QuantSpec::fp16().satisfies_accuracy("BLOOM-3B", 1.0));
    }

    #[test]
    fn unknown_model_is_never_accurate() {
        let w4 = by_label(Precision::W4A16, QuantAlgo::Gptq).unwrap();
        assert_eq!(w4.accuracy_for("mystery-model"), 0.0);
        assert!(!w4.satisfies_accuracy("mystery-model", 0.1));
    }

    #[test]
    fn gptq_beats_zq_local_at_same_precision() {
        // Paper Fig. 6(b): distinct algorithms at identical precision differ.
        let g = by_label(Precision::W4A16, QuantAlgo::Gptq).unwrap();
        let z = by_label(Precision::W4A16, QuantAlgo::ZqLocal).unwrap();
        for m in ["BLOOM-3B", "BLOOM-7.1B", "OPT-13B"] {
            assert!(g.dppl_for(m) < z.dppl_for(m), "{m}");
        }
    }

    #[test]
    fn merge_measured_dppl_from_json() {
        let mut specs = catalog();
        let json = crate::util::json::Json::parse(
            r#"{"model": "tiny-decoder",
                "entries": [{"label": "W4A16/GPTQ", "dppl": 0.33},
                             {"label": "W8A16/GPTQ", "dppl": 0.02}]}"#,
        )
        .unwrap();
        let n = merge_measured_dppl(&mut specs, &json).unwrap();
        assert_eq!(n, 2);
        let w4 = specs
            .iter()
            .find(|s| s.label() == "W4A16/GPTQ")
            .unwrap();
        assert_eq!(w4.dppl_for("tiny-decoder"), 0.33);
    }

    #[test]
    fn labels() {
        assert_eq!(QuantSpec::fp16().label(), "W16A16");
        assert_eq!(
            by_label(Precision::W4A16, QuantAlgo::ZqLocal).unwrap().label(),
            "W4A16/ZQ-Local"
        );
        assert_eq!(Precision::W8A8KV8.label(), "W8A8KV8");
    }

    #[test]
    fn kv8_label_round_trips_and_halves_kv_factor() {
        let (p, a) = parse_label("W8A8KV8/RTN").unwrap();
        assert_eq!(p, Precision::W8A8KV8);
        assert_eq!(a, QuantAlgo::Rtn);
        assert_eq!(p.kv_bits, 8);
        assert_eq!(p.kv_scale(), 0.5);
        // Existing labels keep baseline KV storage.
        let (p16, _) = parse_label("W8A8/RTN").unwrap();
        assert_eq!(p16.kv_bits, 16);
        assert_eq!(p16.kv_scale(), 1.0);
        // Label formatting round-trips through the parser.
        assert_eq!(parse_label(&format!("{}/RTN", p.label())).unwrap().0, p);
    }

    #[test]
    fn kv8_spec_isolates_the_kv_factor() {
        // Same α/β as the base W8A8 spec, so any admission difference in the
        // e2e trace is the KV-bytes factor and nothing else.
        let base = spec_for_label("W8A8/RTN").unwrap();
        let kv8 = spec_for_label("W8A8KV8/RTN").unwrap();
        assert_eq!(base.alpha, kv8.alpha);
        assert_eq!(base.beta, kv8.beta);
        assert_eq!(base.kv_bytes_factor(), 1.0);
        assert_eq!(kv8.kv_bytes_factor(), 0.5);
        assert_eq!(QuantSpec::fp16().kv_bytes_factor(), 1.0);
    }
}
