//! # edgellm
//!
//! A production-grade reproduction of *"Edge Intelligence Optimization for
//! Large Language Model Inference with Batching and Quantization"* (Zhang et
//! al., 2024): epoch-based batched LLM serving on a wireless edge node, with
//! the DFTSP optimal batch scheduler, OFDMA bandwidth allocation, a
//! quantization catalog with perplexity-aware admission, a discrete-event
//! simulator reproducing every figure/table of the paper, and a real tiny
//! transformer served end-to-end by the Rust coordinator (JAX/Pallas
//! authored, AOT-compiled; Python never on the request path).
//!
//! ## Architecture: one epoch loop, two worlds
//!
//! The paper's Fig. 2 protocol — aggregate arrivals, schedule at the epoch
//! boundary, upload during T_U, compute during T_C, download during T_D,
//! account deadlines — is implemented **once**, in [`driver::EpochDriver`].
//! Everything that differs between evaluation and production is injected:
//!
//! | seam                        | simulator (`sim`)        | server (`serving`)          |
//! |-----------------------------|--------------------------|-----------------------------|
//! | [`driver::Clock`]           | `SimClock` (exact jumps) | `WallClock` (sleeps)        |
//! | [`driver::ExecutionBackend`]| `AnalyticBackend` (cost model) | `EngineBackend` (real tokens) |
//! | intake                      | seeded Poisson generator | mpsc ingress + validation   |
//! | [`driver::StalePolicy`]     | best-case-infeasible     | max-wait                    |
//! | [`driver::SPadPolicy`]      | longest queued prompt    | engine's compiled max       |
//!
//! Schedulers ([`coordinator::Scheduler`]: DFTSP, brute force, greedy,
//! static, no-batching, multi-LLM) see identical inputs in both worlds, so a
//! policy validated in simulation runs unchanged in production. The joint
//! bandwidth allocation (`wireless::allocate`) is invoked at exactly one
//! call site, inside the driver.
//!
//! A third execution backend, [`driver::ContinuousBackend`], relaxes the
//! epoch barrier: requests join the running batch at *decode-step*
//! granularity, gated by a persistent per-request KV-cache ledger
//! (`batching = "epoch" | "continuous"` in scenario files; the serving
//! layer's continuous mode does the same on the real engine). See the
//! `driver::continuous` module docs for the state machine.
//!
//! Above the single-pool loop sits [`driver::ShardedDriver`]: one
//! `EpochDriver` per GPU partition behind a dispatch layer that routes
//! arrivals by deployment affinity and re-balances GPU headroom between
//! epochs (`[cluster] shards` / `--shards`; `serving::serve_sharded` is the
//! live counterpart, one engine instance per shard). See the
//! `driver::sharded` module docs for the routing and re-partitioning state
//! machines.
//!
//! The runtime engine comes in two flavours behind one API: a pure-Rust CPU
//! engine (default — zero external crates) and PJRT execution of the AOT
//! HLO programs (feature `"pjrt"`). See `runtime` and README.md.

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod driver;
pub mod metrics;
pub mod model;
pub mod quant;
pub mod request;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod tokenizer;
pub mod util;
pub mod wireless;
pub mod workload;
