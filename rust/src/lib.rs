//! # edgellm
//!
//! A production-grade reproduction of *"Edge Intelligence Optimization for
//! Large Language Model Inference with Batching and Quantization"* (Zhang et
//! al., 2024): epoch-based batched LLM serving on a wireless edge node, with
//! the DFTSP optimal batch scheduler, OFDMA bandwidth allocation, a
//! quantization catalog with perplexity-aware admission, a discrete-event
//! simulator reproducing every figure/table of the paper, and a real
//! PJRT-executed tiny transformer served end-to-end by the Rust coordinator
//! (JAX/Pallas authored, AOT-compiled; Python never on the request path).

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod metrics;
pub mod model;
pub mod quant;
pub mod request;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod tokenizer;
pub mod util;
pub mod wireless;
pub mod workload;
