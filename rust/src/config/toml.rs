//! Minimal TOML-subset parser for scenario files (no `toml` crate offline).
//!
//! Supported grammar — everything the scenario schema needs:
//! `[section]` and `[section.sub]` headers, `[[section.sub]]` array-of-tables
//! headers, `key = value` pairs with string, integer, float, boolean and
//! homogeneous-array values, `#` comments, and blank lines. Keys are flattened
//! to `section.sub.key` paths; the i-th `[[section.sub]]` table flattens to
//! `section.sub.<i>.key` (zero-based), so `[[cluster.shard]]` entries read back
//! as `cluster.shard.0.num_gpus`, `cluster.shard.1.num_gpus`, … and
//! [`TomlDoc::array_table_len`] reports how many tables were declared.

use std::collections::BTreeMap;

/// A parsed scalar/array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Int(i) => Some(*i as f64),
            TomlValue::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// A flattened TOML document: `section.key` → value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn u64_or(&self, path: &str, default: u64) -> u64 {
        self.get(path)
            .and_then(|v| v.as_i64())
            .map(|i| i.max(0) as u64)
            .unwrap_or(default)
    }

    pub fn str_or(&self, path: &str, default: &str) -> String {
        self.get(path)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn u32_list(&self, path: &str) -> Option<Vec<u32>> {
        self.get(path).and_then(|v| v.as_array()).map(|a| {
            a.iter()
                .filter_map(|x| x.as_i64())
                .map(|i| i as u32)
                .collect()
        })
    }

    /// Number of `[[prefix]]` array-of-tables entries in the document.
    ///
    /// Tables flatten to `prefix.<i>.key`, so this scans for the smallest
    /// index with no keys under it. An empty `[[prefix]]` table (header with
    /// no keys) is invisible here — every schema that uses array tables
    /// requires at least one key per entry, so this is not a practical loss.
    pub fn array_table_len(&self, prefix: &str) -> usize {
        let mut n = 0;
        loop {
            let needle = format!("{prefix}.{n}.");
            let found = self
                .entries
                .range(needle.clone()..)
                .next()
                .map(|(k, _)| k.starts_with(&needle))
                .unwrap_or(false);
            if !found {
                return n;
            }
            n += 1;
        }
    }
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse a TOML-subset document.
pub fn parse(src: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    let mut array_counts: BTreeMap<String, usize> = BTreeMap::new();
    for (idx, raw) in src.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with("[[") {
            if !line.ends_with("]]") {
                return Err(TomlError {
                    line: lineno,
                    msg: "unterminated array-of-tables header".into(),
                });
            }
            let name = line[2..line.len() - 2].trim().to_string();
            if name.is_empty() {
                return Err(TomlError {
                    line: lineno,
                    msg: "empty array-of-tables name".into(),
                });
            }
            let index = array_counts.entry(name.clone()).or_insert(0);
            section = format!("{name}.{index}");
            *index += 1;
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(TomlError {
                    line: lineno,
                    msg: "unterminated section header".into(),
                });
            }
            section = line[1..line.len() - 1].trim().to_string();
            if section.is_empty() {
                return Err(TomlError {
                    line: lineno,
                    msg: "empty section name".into(),
                });
            }
            continue;
        }
        let Some(eq) = line.find('=') else {
            return Err(TomlError {
                line: lineno,
                msg: format!("expected `key = value`, got `{line}`"),
            });
        };
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(TomlError {
                line: lineno,
                msg: "empty key".into(),
            });
        }
        let value = parse_value(line[eq + 1..].trim()).map_err(|msg| TomlError {
            line: lineno,
            msg,
        })?;
        let path = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        doc.entries.insert(path, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let Some(end) = inner.find('"') else {
            return Err("unterminated string".into());
        };
        if !inner[end + 1..].trim().is_empty() {
            return Err("trailing characters after string".into());
        }
        return Ok(TomlValue::Str(inner[..end].to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let Some(inner) = inner.strip_suffix(']') else {
            return Err("unterminated array".into());
        };
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items: Result<Vec<TomlValue>, String> = inner
            .split(',')
            .map(|p| parse_value(p.trim()))
            .collect();
        return Ok(TomlValue::Array(items?));
    }
    if s.contains('.') || s.contains('e') || s.contains('E') {
        if let Ok(f) = s.parse::<f64>() {
            return Ok(TomlValue::Float(f));
        }
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
# scenario
title = "demo"
[workload]
arrival_rate = 50.5
epochs = 30
levels = [128, 256, 512]
enabled = true
[cluster.gpu]
flops = 1.33e12
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("title", ""), "demo");
        assert_eq!(doc.f64_or("workload.arrival_rate", 0.0), 50.5);
        assert_eq!(doc.u64_or("workload.epochs", 0), 30);
        assert_eq!(doc.u32_list("workload.levels").unwrap(), vec![128, 256, 512]);
        assert_eq!(doc.get("workload.enabled").unwrap().as_bool(), Some(true));
        assert_eq!(doc.f64_or("cluster.gpu.flops", 0.0), 1.33e12);
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let doc = parse("a = 1 # trailing\n\n# full line\nb = \"x # not comment\"\n").unwrap();
        assert_eq!(doc.u64_or("a", 0), 1);
        assert_eq!(doc.str_or("b", ""), "x # not comment");
    }

    #[test]
    fn defaults_on_missing() {
        let doc = parse("").unwrap();
        assert_eq!(doc.f64_or("nope", 7.5), 7.5);
        assert_eq!(doc.str_or("nope", "d"), "d");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse("ok = 1\nbroken line\n").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(parse("[unterminated\n").is_err());
        assert!(parse("k = \"unterminated\n").is_err());
        assert!(parse("k = [1, 2\n").is_err());
    }

    #[test]
    fn underscored_ints() {
        let doc = parse("big = 1_000_000\n").unwrap();
        assert_eq!(doc.u64_or("big", 0), 1_000_000);
    }

    #[test]
    fn array_of_tables_flatten_to_indexed_paths() {
        let doc = parse(
            r#"
[cluster]
partition_policy = "load"
[[cluster.shard]]
gpu_name = "jetson-tx2"
num_gpus = 12
[[cluster.shard]]
gpu_name = "agx-orin"
gpu_flops = 5.0e12
num_gpus = 8
[workload]
epochs = 3
"#,
        )
        .unwrap();
        assert_eq!(doc.array_table_len("cluster.shard"), 2);
        assert_eq!(doc.str_or("cluster.shard.0.gpu_name", ""), "jetson-tx2");
        assert_eq!(doc.u64_or("cluster.shard.0.num_gpus", 0), 12);
        assert_eq!(doc.str_or("cluster.shard.1.gpu_name", ""), "agx-orin");
        assert_eq!(doc.f64_or("cluster.shard.1.gpu_flops", 0.0), 5.0e12);
        assert_eq!(doc.u64_or("cluster.shard.1.num_gpus", 0), 8);
        // A later plain section ends the array table scope.
        assert_eq!(doc.u64_or("workload.epochs", 0), 3);
        // Independent array names keep independent counters.
        assert_eq!(doc.array_table_len("workload"), 0);
    }

    #[test]
    fn array_of_tables_header_errors() {
        assert!(parse("[[unterminated\n").is_err());
        assert!(parse("[[ ]]\nx = 1\n").is_err());
    }
}
