//! Scenario configuration: a TOML file describing the model, quantization,
//! cluster, radio, epoch protocol and workload, mapped onto `SimConfig`.
//!
//! Every field is optional — omitted keys fall back to the paper's §IV
//! defaults, so a minimal scenario file can be just a couple of lines.

pub mod toml;

use crate::cluster::{ClusterSpec, GpuSpec};
use crate::coordinator::{EpochParams, PartitionPolicy};
use crate::driver::BatchingMode;
use crate::model::LlmSpec;
use crate::quant::{self, QuantSpec};
use crate::sim::SimConfig;
use crate::wireless::{dbm_to_watts, ChannelParams, RadioParams};
use crate::workload::WorkloadParams;
use std::path::Path;

/// Parse a quantization label like "W8A16/GPTQ", "W4A16/ZQ-Local",
/// "W8A8KV8/RTN" or "W16A16". Catalog entries resolve to their measured
/// α/β/ΔPPL; off-catalog precisions (the W8A8 class, and any `KV8` KV-int8
/// variant) get the synthesized spec from `quant::spec_for_label`.
pub fn parse_quant_label(label: &str) -> Result<QuantSpec, String> {
    quant::spec_for_label(label).ok_or_else(|| {
        format!("quant label `{label}` must be `W<w>A<a>[KV8]/<algo>` or `W16A16`")
    })
}

/// Build a `SimConfig` from a parsed TOML document.
pub fn sim_config_from_doc(doc: &toml::TomlDoc) -> Result<SimConfig, String> {
    let base = SimConfig::paper_default();

    let model_name = doc.str_or("model.name", &base.model.name);
    let model = LlmSpec::by_name(&model_name)
        .ok_or_else(|| format!("unknown model `{model_name}` (catalog: BLOOM-3B, BLOOM-7.1B, OPT-13B)"))?;

    let quant_label = doc.str_or("quant.label", "W8A16/GPTQ");
    let quant = parse_quant_label(&quant_label)?;

    let gpu = GpuSpec {
        name: doc.str_or("cluster.gpu_name", &base.cluster.gpu.name),
        flops: doc.f64_or("cluster.gpu_flops", base.cluster.gpu.flops),
        mem_bytes: doc.u64_or("cluster.gpu_mem_bytes", base.cluster.gpu.mem_bytes),
    };
    let cluster = ClusterSpec::new(gpu, doc.u64_or("cluster.num_gpus", base.cluster.num_gpus as u64) as usize);

    let epoch = EpochParams {
        duration: doc.f64_or("epoch.duration", base.epoch.duration),
        t_u: doc.f64_or("epoch.t_u", base.epoch.t_u),
        t_d: doc.f64_or("epoch.t_d", base.epoch.t_d),
    };

    let radio = RadioParams {
        uplink_hz: doc.f64_or("radio.uplink_hz", base.radio.uplink_hz),
        downlink_hz: doc.f64_or("radio.downlink_hz", base.radio.downlink_hz),
        uplink_tx_w: doc
            .get("radio.uplink_tx_dbm")
            .and_then(|v| v.as_f64())
            .map(dbm_to_watts)
            .unwrap_or(base.radio.uplink_tx_w),
        downlink_tx_w: doc
            .get("radio.downlink_tx_dbm")
            .and_then(|v| v.as_f64())
            .map(dbm_to_watts)
            .unwrap_or(base.radio.downlink_tx_w),
        noise_w_per_hz: base.radio.noise_w_per_hz,
        bits_per_token: doc.f64_or("radio.bits_per_token", base.radio.bits_per_token),
    };

    let channel = ChannelParams {
        path_loss: doc.f64_or("channel.path_loss", base.channel.path_loss),
        rayleigh_sigma: base.channel.rayleigh_sigma,
    };

    let workload = WorkloadParams {
        arrival_rate: doc.f64_or("workload.arrival_rate", base.workload.arrival_rate),
        prompt_levels: doc
            .u32_list("workload.prompt_levels")
            .unwrap_or(base.workload.prompt_levels),
        output_levels: doc
            .u32_list("workload.output_levels")
            .unwrap_or(base.workload.output_levels),
        latency_range: (
            doc.f64_or("workload.latency_lo", base.workload.latency_range.0),
            doc.f64_or("workload.latency_hi", base.workload.latency_range.1),
        ),
        accuracy_range: (
            doc.f64_or("workload.accuracy_lo", base.workload.accuracy_range.0),
            doc.f64_or("workload.accuracy_hi", base.workload.accuracy_range.1),
        ),
    };
    workload.validate()?;

    let s_pad = doc.get("sim.s_pad").and_then(|v| v.as_i64()).map(|v| v as u32);

    // `batching = "epoch" | "continuous"`: which ExecutionBackend runs the
    // scheduled batches (epoch barrier vs decode-step admission).
    let batching = BatchingMode::parse(&doc.str_or("sim.batching", "epoch"))?;

    // `[scheduler] workers = N`: opt-in parallel DFTSP d-pool search
    // (0 or 1 keeps the sequential chained search).
    let scheduler = crate::coordinator::SchedulerConfig {
        workers: doc.u64_or("scheduler.workers", 0) as usize,
    };

    // `[cluster] shards = N` + `[cluster] partition_policy`: split the GPU
    // pool into N partitions behind the sharded dispatch layer. Validated
    // here so the min-1-GPU-per-shard guarantee fails at load time with a
    // config error, not mid-run.
    let shards = doc.u64_or("cluster.shards", 1) as usize;
    if shards == 0 {
        return Err("cluster.shards must be >= 1".into());
    }
    if shards > cluster.num_gpus {
        return Err(format!(
            "cluster.shards = {shards} exceeds cluster.num_gpus = {} \
             (every shard needs at least one GPU)",
            cluster.num_gpus
        ));
    }
    let partition =
        PartitionPolicy::parse(&doc.str_or("cluster.partition_policy", "load-proportional"))?;

    // `[chaos]`: deterministic fault injection for the supervised sharded
    // path. All probabilities default to 0.0 — an absent section leaves
    // chaos disabled and the run byte-identical to an unsupervised one.
    let chaos = crate::driver::ChaosConfig {
        seed: doc.u64_or("chaos.seed", base.chaos.seed),
        panic_prob: doc.f64_or("chaos.panic_prob", base.chaos.panic_prob),
        stall_prob: doc.f64_or("chaos.stall_prob", base.chaos.stall_prob),
        stall_ms: doc.u64_or("chaos.stall_ms", base.chaos.stall_ms),
        error_prob: doc.f64_or("chaos.error_prob", base.chaos.error_prob),
        kv_fail_prob: doc.f64_or("chaos.kv_fail_prob", base.chaos.kv_fail_prob),
    };
    for (key, p) in [
        ("chaos.panic_prob", chaos.panic_prob),
        ("chaos.stall_prob", chaos.stall_prob),
        ("chaos.error_prob", chaos.error_prob),
        ("chaos.kv_fail_prob", chaos.kv_fail_prob),
    ] {
        if !(0.0..=1.0).contains(&p) {
            return Err(format!("{key} = {p} must be within [0, 1]"));
        }
    }

    Ok(SimConfig {
        model,
        quant,
        cluster,
        epoch,
        radio,
        channel,
        workload,
        epochs: doc.u64_or("sim.epochs", base.epochs as u64) as usize,
        seed: doc.u64_or("sim.seed", base.seed),
        s_pad,
        batching,
        scheduler,
        shards,
        partition,
        chaos,
    })
}

/// Load a scenario file from disk.
pub fn load_scenario(path: &Path) -> Result<SimConfig, String> {
    let src = std::fs::read_to_string(path).map_err(|e| format!("read {path:?}: {e}"))?;
    let doc = toml::parse(&src).map_err(|e| e.to_string())?;
    sim_config_from_doc(&doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_doc_gives_paper_defaults() {
        let doc = toml::parse("").unwrap();
        let cfg = sim_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.model.name, "BLOOM-3B");
        assert_eq!(cfg.cluster.num_gpus, 20);
        assert_eq!(cfg.epoch.duration, 2.0);
        assert_eq!(cfg.quant.label(), "W8A16/GPTQ");
    }

    #[test]
    fn full_scenario_parses() {
        let doc = toml::parse(
            r#"
[model]
name = "OPT-13B"
[quant]
label = "W4A16/ZQ-Local"
[cluster]
num_gpus = 8
gpu_flops = 2.0e12
[epoch]
duration = 1.5
t_u = 0.2
t_d = 0.2
[workload]
arrival_rate = 120
output_levels = [128, 512]
latency_lo = 1.0
latency_hi = 3.0
[sim]
epochs = 50
seed = 9
s_pad = 256
"#,
        )
        .unwrap();
        let cfg = sim_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.model.name, "OPT-13B");
        assert_eq!(cfg.quant.label(), "W4A16/ZQ-Local");
        assert_eq!(cfg.cluster.num_gpus, 8);
        assert_eq!(cfg.cluster.gpu.flops, 2.0e12);
        assert_eq!(cfg.epoch.duration, 1.5);
        assert_eq!(cfg.workload.arrival_rate, 120.0);
        assert_eq!(cfg.workload.output_levels, vec![128, 512]);
        assert_eq!(cfg.epochs, 50);
        assert_eq!(cfg.s_pad, Some(256));
    }

    #[test]
    fn batching_knob_parses() {
        let doc = toml::parse("[sim]\nbatching = \"continuous\"\n").unwrap();
        let cfg = sim_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.batching, BatchingMode::Continuous);
        // Default is the paper's epoch barrier.
        let cfg = sim_config_from_doc(&toml::parse("").unwrap()).unwrap();
        assert_eq!(cfg.batching, BatchingMode::Epoch);
        // Unknown modes are a config error, not a silent fallback.
        let doc = toml::parse("[sim]\nbatching = \"rolling\"\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
    }

    #[test]
    fn scheduler_workers_knob_parses() {
        let doc = toml::parse("[scheduler]\nworkers = 4\n").unwrap();
        let cfg = sim_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.scheduler.workers, 4);
        // Default is the sequential chained search.
        let cfg = sim_config_from_doc(&toml::parse("").unwrap()).unwrap();
        assert_eq!(cfg.scheduler.workers, 0);
    }

    #[test]
    fn cluster_shards_knob_parses_and_validates() {
        let doc = toml::parse("[cluster]\nshards = 4\npartition_policy = \"equal\"\n").unwrap();
        let cfg = sim_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.partition, PartitionPolicy::Equal);
        // Defaults: one pool, load-proportional re-partitioning.
        let cfg = sim_config_from_doc(&toml::parse("").unwrap()).unwrap();
        assert_eq!(cfg.shards, 1);
        assert_eq!(cfg.partition, PartitionPolicy::LoadProportional);
        // min-1 GPU per shard is a load-time config error.
        let doc = toml::parse("[cluster]\nnum_gpus = 3\nshards = 4\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
        let doc = toml::parse("[cluster]\nshards = 0\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
        // Unknown policies are a config error, not a silent fallback.
        let doc = toml::parse("[cluster]\npartition_policy = \"fair\"\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
    }

    #[test]
    fn chaos_section_parses_and_validates() {
        let doc = toml::parse(
            "[chaos]\nseed = 42\npanic_prob = 0.05\nstall_prob = 0.1\nstall_ms = 20\nerror_prob = 0.02\nkv_fail_prob = 0.01\n",
        )
        .unwrap();
        let cfg = sim_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.chaos.seed, 42);
        assert_eq!(cfg.chaos.panic_prob, 0.05);
        assert_eq!(cfg.chaos.stall_prob, 0.1);
        assert_eq!(cfg.chaos.stall_ms, 20);
        assert_eq!(cfg.chaos.error_prob, 0.02);
        assert_eq!(cfg.chaos.kv_fail_prob, 0.01);
        assert!(cfg.chaos.enabled());
        // Absent section leaves chaos disabled (all-zero probabilities).
        let cfg = sim_config_from_doc(&toml::parse("").unwrap()).unwrap();
        assert!(!cfg.chaos.enabled());
        // Probabilities outside [0, 1] are a config error, not a clamp.
        let doc = toml::parse("[chaos]\npanic_prob = 1.5\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
    }

    #[test]
    fn bad_model_rejected() {
        let doc = toml::parse("[model]\nname = \"GPT-99\"\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
    }

    #[test]
    fn quant_labels() {
        assert_eq!(parse_quant_label("W16A16").unwrap().label(), "W16A16");
        assert_eq!(parse_quant_label("fp16").unwrap().label(), "W16A16");
        assert_eq!(
            parse_quant_label("w8a16/gptq").unwrap().label(),
            "W8A16/GPTQ"
        );
        assert_eq!(
            parse_quant_label("W4A16/ZQ-Local").unwrap().label(),
            "W4A16/ZQ-Local"
        );
        assert!(parse_quant_label("W2A2/GPTQ").is_err());
        assert!(parse_quant_label("W8A16").is_err());
        // Off-catalog precisions synthesize a spec instead of erroring; the
        // KV8 suffix halves the KV-bytes factor and nothing else.
        let w8a8 = parse_quant_label("W8A8/RTN").unwrap();
        let kv8 = parse_quant_label("w8a8kv8/rtn").unwrap();
        assert_eq!(kv8.label(), "W8A8KV8/RTN");
        assert_eq!(kv8.alpha, w8a8.alpha);
        assert_eq!(kv8.kv_bytes_factor(), 0.5);
    }

    #[test]
    fn invalid_workload_rejected() {
        let doc = toml::parse("[workload]\nlatency_lo = 5.0\nlatency_hi = 1.0\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
    }
}
