//! Scenario configuration: a TOML file describing the model, quantization,
//! cluster, radio, epoch protocol and workload, mapped onto `SimConfig`.
//!
//! Every field is optional — omitted keys fall back to the paper's §IV
//! defaults, so a minimal scenario file can be just a couple of lines.

pub mod toml;

use crate::cluster::{ClusterSpec, ClusterTopology, GpuSpec, ShardSpec};
use crate::coordinator::{EpochParams, PartitionPolicy};
use crate::driver::{AutoscalePolicy, BatchingMode, ElasticPolicy, EpochTunePolicy};
use crate::model::LlmSpec;
use crate::quant::{self, QuantSpec};
use crate::sim::SimConfig;
use crate::wireless::{dbm_to_watts, ChannelParams, RadioParams};
use crate::workload::WorkloadParams;
use std::path::Path;

/// Parse a quantization label like "W8A16/GPTQ", "W4A16/ZQ-Local",
/// "W8A8KV8/RTN" or "W16A16". Catalog entries resolve to their measured
/// α/β/ΔPPL; off-catalog precisions (the W8A8 class, and any `KV8` KV-int8
/// variant) get the synthesized spec from `quant::spec_for_label`.
pub fn parse_quant_label(label: &str) -> Result<QuantSpec, String> {
    quant::spec_for_label(label).ok_or_else(|| {
        format!("quant label `{label}` must be `W<w>A<a>[KV8]/<algo>` or `W16A16`")
    })
}

/// Build a `SimConfig` from a parsed TOML document.
pub fn sim_config_from_doc(doc: &toml::TomlDoc) -> Result<SimConfig, String> {
    let base = SimConfig::paper_default();

    let model_name = doc.str_or("model.name", &base.model.name);
    let model = LlmSpec::by_name(&model_name)
        .ok_or_else(|| format!("unknown model `{model_name}` (catalog: BLOOM-3B, BLOOM-7.1B, OPT-13B)"))?;

    let quant_label = doc.str_or("quant.label", "W8A16/GPTQ");
    let quant = parse_quant_label(&quant_label)?;

    let gpu = GpuSpec {
        name: doc.str_or("cluster.gpu_name", &base.cluster.gpu.name),
        flops: doc.f64_or("cluster.gpu_flops", base.cluster.gpu.flops),
        mem_bytes: doc.u64_or("cluster.gpu_mem_bytes", base.cluster.gpu.mem_bytes),
    };
    let cluster = ClusterSpec::new(gpu, doc.u64_or("cluster.num_gpus", base.cluster.num_gpus as u64) as usize);

    let epoch = EpochParams {
        duration: doc.f64_or("epoch.duration", base.epoch.duration),
        t_u: doc.f64_or("epoch.t_u", base.epoch.t_u),
        t_d: doc.f64_or("epoch.t_d", base.epoch.t_d),
    };

    let radio = RadioParams {
        uplink_hz: doc.f64_or("radio.uplink_hz", base.radio.uplink_hz),
        downlink_hz: doc.f64_or("radio.downlink_hz", base.radio.downlink_hz),
        uplink_tx_w: doc
            .get("radio.uplink_tx_dbm")
            .and_then(|v| v.as_f64())
            .map(dbm_to_watts)
            .unwrap_or(base.radio.uplink_tx_w),
        downlink_tx_w: doc
            .get("radio.downlink_tx_dbm")
            .and_then(|v| v.as_f64())
            .map(dbm_to_watts)
            .unwrap_or(base.radio.downlink_tx_w),
        noise_w_per_hz: base.radio.noise_w_per_hz,
        bits_per_token: doc.f64_or("radio.bits_per_token", base.radio.bits_per_token),
    };

    let channel = ChannelParams {
        path_loss: doc.f64_or("channel.path_loss", base.channel.path_loss),
        rayleigh_sigma: base.channel.rayleigh_sigma,
    };

    let workload = WorkloadParams {
        arrival_rate: doc.f64_or("workload.arrival_rate", base.workload.arrival_rate),
        prompt_levels: doc
            .u32_list("workload.prompt_levels")
            .unwrap_or(base.workload.prompt_levels),
        output_levels: doc
            .u32_list("workload.output_levels")
            .unwrap_or(base.workload.output_levels),
        latency_range: (
            doc.f64_or("workload.latency_lo", base.workload.latency_range.0),
            doc.f64_or("workload.latency_hi", base.workload.latency_range.1),
        ),
        accuracy_range: (
            doc.f64_or("workload.accuracy_lo", base.workload.accuracy_range.0),
            doc.f64_or("workload.accuracy_hi", base.workload.accuracy_range.1),
        ),
    };
    workload.validate()?;

    let s_pad = doc.get("sim.s_pad").and_then(|v| v.as_i64()).map(|v| v as u32);

    // `batching = "epoch" | "continuous"`: which ExecutionBackend runs the
    // scheduled batches (epoch barrier vs decode-step admission).
    let batching = BatchingMode::parse(&doc.str_or("sim.batching", "epoch"))?;

    // `[scheduler] workers = N`: opt-in parallel DFTSP d-pool search
    // (0 or 1 keeps the sequential chained search).
    let scheduler = crate::coordinator::SchedulerConfig {
        workers: doc.u64_or("scheduler.workers", 0) as usize,
    };

    // `[[cluster.shard]]` tables: the explicit (possibly heterogeneous)
    // shard layout. Each table carves out its own partition — `gpu_name`,
    // `gpu_flops` and `gpu_mem_bytes` default to the `[cluster]` GPU model,
    // `num_gpus` to 1 — and overrides both `cluster` and `shards` for the
    // sharded paths.
    let shard_tables = doc.array_table_len("cluster.shard");
    let topology = if shard_tables > 0 {
        let mut specs = Vec::with_capacity(shard_tables);
        for i in 0..shard_tables {
            let key = |k: &str| format!("cluster.shard.{i}.{k}");
            specs.push(ShardSpec {
                gpu: GpuSpec {
                    name: doc.str_or(&key("gpu_name"), &cluster.gpu.name),
                    flops: doc.f64_or(&key("gpu_flops"), cluster.gpu.flops),
                    mem_bytes: doc.u64_or(&key("gpu_mem_bytes"), cluster.gpu.mem_bytes),
                },
                num_gpus: doc.u64_or(&key("num_gpus"), 1) as usize,
            });
        }
        let t = ClusterTopology { shards: specs };
        t.validate().map_err(|e| format!("[[cluster.shard]]: {e}"))?;
        Some(t)
    } else {
        None
    };

    // `[cluster] shards = N` + `[cluster] partition_policy`: split the GPU
    // pool into N partitions behind the sharded dispatch layer. Validated
    // here so the min-1-GPU-per-shard guarantee fails at load time with a
    // config error, not mid-run. The legacy shim must agree with an
    // explicit topology when both are present.
    let shards = doc.u64_or("cluster.shards", 1) as usize;
    if shards == 0 {
        return Err("cluster.shards must be >= 1".into());
    }
    let shards = match &topology {
        Some(t) => {
            if doc.get("cluster.shards").is_some() && shards != t.shard_count() {
                return Err(format!(
                    "cluster.shards = {shards} disagrees with {} [[cluster.shard]] tables \
                     (drop the shim or make them match)",
                    t.shard_count()
                ));
            }
            t.shard_count()
        }
        None => {
            if shards > cluster.num_gpus {
                return Err(format!(
                    "cluster.shards = {shards} exceeds cluster.num_gpus = {} \
                     (every shard needs at least one GPU)",
                    cluster.num_gpus
                ));
            }
            shards
        }
    };
    let partition =
        PartitionPolicy::parse(&doc.str_or("cluster.partition_policy", "load-proportional"))?;

    // `[elastic]`: opt-in elastic behaviours for the sharded paths. An
    // absent section leaves everything off — which is what keeps fixed-count
    // runs bit-identical to earlier revisions. Autoscaling arms when either
    // bound is given; epoch tuning arms when either duration bound is given.
    let autoscale = if doc.get("elastic.autoscale_min").is_some()
        || doc.get("elastic.autoscale_max").is_some()
    {
        let min = doc.u64_or("elastic.autoscale_min", 1) as usize;
        let max = doc.u64_or("elastic.autoscale_max", min.max(shards) as u64) as usize;
        if min == 0 || max < min {
            return Err(format!(
                "elastic.autoscale bounds [{min}, {max}] must satisfy 1 <= min <= max"
            ));
        }
        let mut p = AutoscalePolicy::new(min, max);
        p.scale_up_ratio = doc.f64_or("elastic.scale_up_ratio", p.scale_up_ratio);
        p.scale_down_ratio = doc.f64_or("elastic.scale_down_ratio", p.scale_down_ratio);
        if !(p.scale_up_ratio > 0.0) || !(p.scale_down_ratio >= 0.0) {
            return Err("elastic scale ratios must be positive".into());
        }
        Some(p)
    } else {
        None
    };
    let tune_epoch = if doc.get("elastic.tune_epoch_min").is_some()
        || doc.get("elastic.tune_epoch_max").is_some()
    {
        let min = doc.f64_or("elastic.tune_epoch_min", epoch.duration);
        let max = doc.f64_or("elastic.tune_epoch_max", min.max(epoch.duration));
        if !(min > 0.0 && max >= min) {
            return Err(format!(
                "elastic.tune_epoch bounds [{min}, {max}] must satisfy 0 < min <= max"
            ));
        }
        let mut p = EpochTunePolicy::new(min, max);
        p.grow = doc.f64_or("elastic.tune_grow", p.grow);
        p.shrink = doc.f64_or("elastic.tune_shrink", p.shrink);
        p.calm_epochs = doc.u64_or("elastic.tune_calm_epochs", p.calm_epochs);
        if !(p.grow >= 1.0) || !(p.shrink > 0.0 && p.shrink <= 1.0) || p.calm_epochs == 0 {
            return Err(
                "elastic.tune_grow must be >= 1, tune_shrink in (0, 1], tune_calm_epochs >= 1"
                    .into(),
            );
        }
        Some(p)
    } else {
        None
    };
    let elastic = ElasticPolicy {
        stealing: doc
            .get("elastic.stealing")
            .and_then(|v| v.as_bool())
            .unwrap_or(false),
        autoscale,
        tune_epoch,
    };

    // `[chaos]`: deterministic fault injection for the supervised sharded
    // path. All probabilities default to 0.0 — an absent section leaves
    // chaos disabled and the run byte-identical to an unsupervised one.
    let chaos = crate::driver::ChaosConfig {
        seed: doc.u64_or("chaos.seed", base.chaos.seed),
        panic_prob: doc.f64_or("chaos.panic_prob", base.chaos.panic_prob),
        stall_prob: doc.f64_or("chaos.stall_prob", base.chaos.stall_prob),
        stall_ms: doc.u64_or("chaos.stall_ms", base.chaos.stall_ms),
        error_prob: doc.f64_or("chaos.error_prob", base.chaos.error_prob),
        kv_fail_prob: doc.f64_or("chaos.kv_fail_prob", base.chaos.kv_fail_prob),
    };
    for (key, p) in [
        ("chaos.panic_prob", chaos.panic_prob),
        ("chaos.stall_prob", chaos.stall_prob),
        ("chaos.error_prob", chaos.error_prob),
        ("chaos.kv_fail_prob", chaos.kv_fail_prob),
    ] {
        if !(0.0..=1.0).contains(&p) {
            return Err(format!("{key} = {p} must be within [0, 1]"));
        }
    }
    // The supervised (chaos) path indexes health state by a fixed shard
    // count; autoscaling changes it. Reject the combination at load time
    // rather than tripping the driver's assertion mid-run.
    if chaos.enabled() && elastic.autoscale.is_some() {
        return Err("[chaos] fault injection and [elastic] autoscaling are \
                    mutually exclusive (supervision needs a fixed shard set)"
            .into());
    }

    Ok(SimConfig {
        model,
        quant,
        cluster,
        epoch,
        radio,
        channel,
        workload,
        epochs: doc.u64_or("sim.epochs", base.epochs as u64) as usize,
        seed: doc.u64_or("sim.seed", base.seed),
        s_pad,
        batching,
        scheduler,
        shards,
        partition,
        topology,
        elastic,
        chaos,
    })
}

/// Load a scenario file from disk.
pub fn load_scenario(path: &Path) -> Result<SimConfig, String> {
    let src = std::fs::read_to_string(path).map_err(|e| format!("read {path:?}: {e}"))?;
    let doc = toml::parse(&src).map_err(|e| e.to_string())?;
    sim_config_from_doc(&doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_doc_gives_paper_defaults() {
        let doc = toml::parse("").unwrap();
        let cfg = sim_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.model.name, "BLOOM-3B");
        assert_eq!(cfg.cluster.num_gpus, 20);
        assert_eq!(cfg.epoch.duration, 2.0);
        assert_eq!(cfg.quant.label(), "W8A16/GPTQ");
    }

    #[test]
    fn full_scenario_parses() {
        let doc = toml::parse(
            r#"
[model]
name = "OPT-13B"
[quant]
label = "W4A16/ZQ-Local"
[cluster]
num_gpus = 8
gpu_flops = 2.0e12
[epoch]
duration = 1.5
t_u = 0.2
t_d = 0.2
[workload]
arrival_rate = 120
output_levels = [128, 512]
latency_lo = 1.0
latency_hi = 3.0
[sim]
epochs = 50
seed = 9
s_pad = 256
"#,
        )
        .unwrap();
        let cfg = sim_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.model.name, "OPT-13B");
        assert_eq!(cfg.quant.label(), "W4A16/ZQ-Local");
        assert_eq!(cfg.cluster.num_gpus, 8);
        assert_eq!(cfg.cluster.gpu.flops, 2.0e12);
        assert_eq!(cfg.epoch.duration, 1.5);
        assert_eq!(cfg.workload.arrival_rate, 120.0);
        assert_eq!(cfg.workload.output_levels, vec![128, 512]);
        assert_eq!(cfg.epochs, 50);
        assert_eq!(cfg.s_pad, Some(256));
    }

    #[test]
    fn batching_knob_parses() {
        let doc = toml::parse("[sim]\nbatching = \"continuous\"\n").unwrap();
        let cfg = sim_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.batching, BatchingMode::Continuous);
        // Default is the paper's epoch barrier.
        let cfg = sim_config_from_doc(&toml::parse("").unwrap()).unwrap();
        assert_eq!(cfg.batching, BatchingMode::Epoch);
        // Unknown modes are a config error, not a silent fallback.
        let doc = toml::parse("[sim]\nbatching = \"rolling\"\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
    }

    #[test]
    fn scheduler_workers_knob_parses() {
        let doc = toml::parse("[scheduler]\nworkers = 4\n").unwrap();
        let cfg = sim_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.scheduler.workers, 4);
        // Default is the sequential chained search.
        let cfg = sim_config_from_doc(&toml::parse("").unwrap()).unwrap();
        assert_eq!(cfg.scheduler.workers, 0);
    }

    #[test]
    fn cluster_shards_knob_parses_and_validates() {
        let doc = toml::parse("[cluster]\nshards = 4\npartition_policy = \"equal\"\n").unwrap();
        let cfg = sim_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.partition, PartitionPolicy::Equal);
        // Defaults: one pool, load-proportional re-partitioning.
        let cfg = sim_config_from_doc(&toml::parse("").unwrap()).unwrap();
        assert_eq!(cfg.shards, 1);
        assert_eq!(cfg.partition, PartitionPolicy::LoadProportional);
        // min-1 GPU per shard is a load-time config error.
        let doc = toml::parse("[cluster]\nnum_gpus = 3\nshards = 4\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
        let doc = toml::parse("[cluster]\nshards = 0\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
        // Unknown policies are a config error, not a silent fallback.
        let doc = toml::parse("[cluster]\npartition_policy = \"fair\"\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
    }

    #[test]
    fn chaos_section_parses_and_validates() {
        let doc = toml::parse(
            "[chaos]\nseed = 42\npanic_prob = 0.05\nstall_prob = 0.1\nstall_ms = 20\nerror_prob = 0.02\nkv_fail_prob = 0.01\n",
        )
        .unwrap();
        let cfg = sim_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.chaos.seed, 42);
        assert_eq!(cfg.chaos.panic_prob, 0.05);
        assert_eq!(cfg.chaos.stall_prob, 0.1);
        assert_eq!(cfg.chaos.stall_ms, 20);
        assert_eq!(cfg.chaos.error_prob, 0.02);
        assert_eq!(cfg.chaos.kv_fail_prob, 0.01);
        assert!(cfg.chaos.enabled());
        // Absent section leaves chaos disabled (all-zero probabilities).
        let cfg = sim_config_from_doc(&toml::parse("").unwrap()).unwrap();
        assert!(!cfg.chaos.enabled());
        // Probabilities outside [0, 1] are a config error, not a clamp.
        let doc = toml::parse("[chaos]\npanic_prob = 1.5\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
    }

    #[test]
    fn shard_tables_build_a_heterogeneous_topology() {
        let doc = toml::parse(
            r#"
[cluster]
gpu_flops = 1.33e12
gpu_mem_bytes = 8_000_000_000
[[cluster.shard]]
num_gpus = 12
[[cluster.shard]]
gpu_name = "agx-orin"
gpu_flops = 5.0e12
gpu_mem_bytes = 32_000_000_000
num_gpus = 4
"#,
        )
        .unwrap();
        let cfg = sim_config_from_doc(&doc).unwrap();
        let t = cfg.topology.expect("tables produce a topology");
        assert_eq!(t.shard_count(), 2);
        assert_eq!(cfg.shards, 2);
        assert_eq!(cfg.shard_count(), 2);
        // First table inherits the [cluster] GPU model; second overrides it.
        assert_eq!(t.shards[0].gpu.flops, 1.33e12);
        assert_eq!(t.shards[0].num_gpus, 12);
        assert_eq!(t.shards[1].gpu.name, "agx-orin");
        assert_eq!(t.shards[1].gpu.flops, 5.0e12);
        assert_eq!(t.shards[1].num_gpus, 4);
        assert_eq!(t.groups().len(), 2);
        // No tables → no topology; the shim path is untouched.
        let cfg = sim_config_from_doc(&toml::parse("").unwrap()).unwrap();
        assert!(cfg.topology.is_none());
        // The shim must agree with an explicit topology when both appear.
        let doc = toml::parse("[cluster]\nshards = 3\n[[cluster.shard]]\nnum_gpus = 2\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
        let doc = toml::parse("[cluster]\nshards = 1\n[[cluster.shard]]\nnum_gpus = 2\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_ok());
        // Zero-GPU shard entries are a load-time error.
        let doc = toml::parse("[[cluster.shard]]\nnum_gpus = 0\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
    }

    #[test]
    fn elastic_section_parses_and_validates() {
        let doc = toml::parse(
            r#"
[cluster]
shards = 2
[elastic]
stealing = true
autoscale_min = 1
autoscale_max = 6
scale_down_ratio = 0.1
tune_epoch_min = 1.0
tune_epoch_max = 8.0
tune_calm_epochs = 2
"#,
        )
        .unwrap();
        let cfg = sim_config_from_doc(&doc).unwrap();
        assert!(cfg.elastic.stealing);
        let a = cfg.elastic.autoscale.expect("bounds arm the autoscaler");
        assert_eq!((a.min_shards, a.max_shards), (1, 6));
        assert_eq!(a.scale_up_ratio, 1.0, "default preserved");
        assert_eq!(a.scale_down_ratio, 0.1);
        let t = cfg.elastic.tune_epoch.expect("bounds arm the tuner");
        assert_eq!((t.min_duration, t.max_duration), (1.0, 8.0));
        assert_eq!(t.calm_epochs, 2);
        // Absent section: everything off (the bit-parity default).
        let cfg = sim_config_from_doc(&toml::parse("").unwrap()).unwrap();
        assert!(!cfg.elastic.stealing);
        assert!(cfg.elastic.autoscale.is_none());
        assert!(cfg.elastic.tune_epoch.is_none());
        // Inverted bounds are a config error.
        let doc = toml::parse("[elastic]\nautoscale_min = 4\nautoscale_max = 2\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
        let doc = toml::parse("[elastic]\ntune_epoch_min = 5.0\ntune_epoch_max = 1.0\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
        // Autoscaling and chaos are mutually exclusive.
        let doc = toml::parse("[elastic]\nautoscale_max = 4\n[chaos]\npanic_prob = 0.1\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
    }

    #[test]
    fn bad_model_rejected() {
        let doc = toml::parse("[model]\nname = \"GPT-99\"\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
    }

    #[test]
    fn quant_labels() {
        assert_eq!(parse_quant_label("W16A16").unwrap().label(), "W16A16");
        assert_eq!(parse_quant_label("fp16").unwrap().label(), "W16A16");
        assert_eq!(
            parse_quant_label("w8a16/gptq").unwrap().label(),
            "W8A16/GPTQ"
        );
        assert_eq!(
            parse_quant_label("W4A16/ZQ-Local").unwrap().label(),
            "W4A16/ZQ-Local"
        );
        assert!(parse_quant_label("W2A2/GPTQ").is_err());
        assert!(parse_quant_label("W8A16").is_err());
        // Off-catalog precisions synthesize a spec instead of erroring; the
        // KV8 suffix halves the KV-bytes factor and nothing else.
        let w8a8 = parse_quant_label("W8A8/RTN").unwrap();
        let kv8 = parse_quant_label("w8a8kv8/rtn").unwrap();
        assert_eq!(kv8.label(), "W8A8KV8/RTN");
        assert_eq!(kv8.alpha, w8a8.alpha);
        assert_eq!(kv8.kv_bytes_factor(), 0.5);
    }

    #[test]
    fn invalid_workload_rejected() {
        let doc = toml::parse("[workload]\nlatency_lo = 5.0\nlatency_hi = 1.0\n").unwrap();
        assert!(sim_config_from_doc(&doc).is_err());
    }
}
