//! User inference requests — the ⟨sᵢ, nᵢ, τᵢ, aᵢ⟩ tuples of §II, plus the
//! per-epoch derived quantities (channel gain, ρ_min fractions) the
//! coordinator consumes.

use crate::wireless::RadioParams;

/// Unique request identifier.
pub type RequestId = u64;

/// A user inference request as submitted through the API (paper Fig. 1).
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: RequestId,
    /// Arrival wall-clock time in seconds (simulation time).
    pub arrival: f64,
    /// Input prompt length in tokens (paper: s_i).
    pub prompt_tokens: u32,
    /// Desired maximum output length in tokens (paper: n_i), drawn from the
    /// level set {N_1, ..., N}.
    pub output_tokens: u32,
    /// End-to-end latency requirement in seconds (paper: τ_i).
    pub latency_req: f64,
    /// Required text accuracy in [0,1] (paper: a_i). Admission demands
    /// a_i ≤ f(ΔPPL) of the deployed quantization.
    pub accuracy_req: f64,
}

impl Request {
    /// Time this request has already waited if the batch starts at `now`.
    pub fn waited(&self, now: f64) -> f64 {
        (now - self.arrival).max(0.0)
    }

    /// Remaining latency budget at time `now`.
    pub fn remaining_budget(&self, now: f64) -> f64 {
        self.latency_req - self.waited(now)
    }
}

/// A request annotated with this epoch's channel state and minimum bandwidth
/// fractions — the unit the schedulers operate on.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochRequest {
    pub req: Request,
    /// Channel amplitude h_i for this epoch (constant within the epoch).
    pub h: f64,
    /// ρ_{i,min}^U — minimum uplink bandwidth fraction (constraint 1a term).
    pub rho_min_u: f64,
    /// ρ_{i,min}^D — minimum downlink bandwidth fraction (constraint 1b term).
    pub rho_min_d: f64,
}

impl EpochRequest {
    /// Annotate a request with channel-dependent quantities for one epoch.
    pub fn annotate(req: Request, h: f64, radio: &RadioParams, t_u: f64, t_d: f64) -> Self {
        let rho_min_u = radio.rho_min_uplink(req.prompt_tokens, h, t_u);
        let rho_min_d = radio.rho_min_downlink(req.output_tokens, h, t_d);
        EpochRequest {
            req,
            h,
            rho_min_u,
            rho_min_d,
        }
    }

    pub fn id(&self) -> RequestId {
        self.req.id
    }
}

/// The discrete output-length levels {N_1 < N_2 < ... < N_N} present in a
/// request set — the tree depth axis of DFTSP (§III-B).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OutputLevels {
    levels: Vec<u32>,
}

impl OutputLevels {
    /// Derive sorted distinct levels from a request slice.
    pub fn from_requests(reqs: &[EpochRequest]) -> Self {
        let mut levels: Vec<u32> = reqs.iter().map(|r| r.req.output_tokens).collect();
        levels.sort_unstable();
        levels.dedup();
        OutputLevels { levels }
    }

    /// The paper's default level set {128, 256, 512}.
    pub fn standard() -> Self {
        OutputLevels {
            levels: vec![128, 256, 512],
        }
    }

    pub fn count(&self) -> usize {
        self.levels.len()
    }

    pub fn levels(&self) -> &[u32] {
        &self.levels
    }

    /// Index of the level a given n_i belongs to (exact match expected).
    pub fn index_of(&self, n: u32) -> Option<usize> {
        self.levels.binary_search(&n).ok()
    }
}

/// Builder for hand-constructing requests in tests and examples.
#[derive(Debug, Clone)]
pub struct RequestBuilder {
    next_id: RequestId,
}

impl Default for RequestBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl RequestBuilder {
    pub fn new() -> Self {
        RequestBuilder { next_id: 0 }
    }

    pub fn build(
        &mut self,
        arrival: f64,
        prompt_tokens: u32,
        output_tokens: u32,
        latency_req: f64,
        accuracy_req: f64,
    ) -> Request {
        let id = self.next_id;
        self.next_id += 1;
        Request {
            id,
            arrival,
            prompt_tokens,
            output_tokens,
            latency_req,
            accuracy_req,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_req(n: u32) -> Request {
        Request {
            id: 1,
            arrival: 10.0,
            prompt_tokens: 128,
            output_tokens: n,
            latency_req: 1.5,
            accuracy_req: 0.5,
        }
    }

    #[test]
    fn waited_and_budget() {
        let r = sample_req(128);
        assert_eq!(r.waited(12.0), 2.0);
        assert_eq!(r.waited(9.0), 0.0);
        assert!((r.remaining_budget(11.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn annotate_computes_rho_min() {
        let radio = RadioParams::default();
        let r = EpochRequest::annotate(sample_req(256), 0.03, &radio, 0.25, 0.25);
        assert!(r.rho_min_u > 0.0 && r.rho_min_u < 1.0);
        assert!(r.rho_min_d > 0.0 && r.rho_min_d < 1.0);
        // downlink tokens (256) > uplink tokens (128) but downlink power is
        // higher; just check both present and uplink matches formula.
        let expect = radio.rho_min_uplink(128, 0.03, 0.25);
        assert_eq!(r.rho_min_u, expect);
    }

    #[test]
    fn output_levels_from_requests() {
        let radio = RadioParams::default();
        let mk = |n| EpochRequest::annotate(sample_req(n), 0.03, &radio, 0.25, 0.25);
        let reqs = vec![mk(512), mk(128), mk(512), mk(256)];
        let levels = OutputLevels::from_requests(&reqs);
        assert_eq!(levels.levels(), &[128, 256, 512]);
        assert_eq!(levels.index_of(256), Some(1));
        assert_eq!(levels.index_of(300), None);
        assert_eq!(levels.count(), 3);
    }

    #[test]
    fn builder_assigns_unique_ids() {
        let mut b = RequestBuilder::new();
        let r1 = b.build(0.0, 128, 128, 1.0, 0.5);
        let r2 = b.build(0.0, 128, 128, 1.0, 0.5);
        assert_ne!(r1.id, r2.id);
    }
}
