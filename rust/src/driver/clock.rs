//! Time sources for the epoch protocol.
//!
//! The Fig. 2 loop only ever needs two operations — "what time is it" and
//! "get me to the next epoch boundary" — so that is the whole trait. The
//! simulator's clock jumps instantly and lands *exactly* on boundaries
//! (which is what makes analytic runs bit-reproducible); the wall clock
//! sleeps, lands slightly after boundaries, and simply refuses to sleep
//! backwards when an epoch overran (the driver counts those overruns in
//! `Metrics::epoch_overruns`).

use std::time::Instant;

/// A monotonic time source measured in seconds since the run started.
pub trait Clock {
    /// Current time.
    fn now(&mut self) -> f64;

    /// Advance (sim) or sleep (wall) until `t`, clamped to never go
    /// backwards. Returns the time actually reached: exactly `t` for the
    /// simulated clock, `>= t` for the wall clock — or the current time
    /// unchanged when `t` is already in the past.
    fn wait_until(&mut self, t: f64) -> f64;
}

/// Discrete simulated time: `wait_until` jumps straight to the target, so
/// every epoch starts at exactly `e * duration`.
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    now: f64,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock { now: 0.0 }
    }
}

impl Clock for SimClock {
    fn now(&mut self) -> f64 {
        self.now
    }

    fn wait_until(&mut self, t: f64) -> f64 {
        if t > self.now {
            self.now = t;
        }
        self.now
    }
}

/// Real time anchored at construction; `wait_until` sleeps the remaining
/// gap (and sleeps nothing when the boundary has already passed).
#[derive(Debug)]
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn start() -> Self {
        WallClock {
            start: Instant::now(),
        }
    }
}

impl Clock for WallClock {
    fn now(&mut self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn wait_until(&mut self, t: f64) -> f64 {
        let now = self.start.elapsed().as_secs_f64();
        if t > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(t - now));
        }
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_hits_boundaries_exactly() {
        let mut c = SimClock::new();
        assert_eq!(c.now(), 0.0);
        assert_eq!(c.wait_until(2.0), 2.0);
        assert_eq!(c.wait_until(4.0), 4.0);
        // never goes backwards
        assert_eq!(c.wait_until(1.0), 4.0);
        assert_eq!(c.now(), 4.0);
    }

    #[test]
    fn wall_clock_monotone_and_clamped() {
        let mut c = WallClock::start();
        let t0 = c.now();
        let reached = c.wait_until(t0 + 0.01);
        assert!(reached >= t0 + 0.01);
        // A boundary in the past returns without sleeping backwards. (No
        // upper-bound assertion: scheduler preemption on a loaded runner can
        // stretch back-to-back reads arbitrarily.)
        let before = c.now();
        let r2 = c.wait_until(0.0);
        assert!(r2 >= before);
    }
}
