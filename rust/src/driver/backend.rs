//! Execution backends: what happens to a batch after the scheduler picks it.
//!
//! The epoch protocol (admission, channel annotation, scheduling, rejection
//! bookkeeping) is identical between the analytic simulator and the live
//! server; only the *execution* of the chosen batch differs. This trait is
//! that seam:
//!
//! - [`AnalyticBackend`] resolves completions from the paper's cost model —
//!   the batch "finishes" at `now + T_up + t_compute + T_down` — and feeds
//!   the outcome straight into `Metrics`. No tokens exist.
//! - The serving layer's `EngineBackend` (see `serving::server`) runs real
//!   prefill/decode on the loaded `runtime::Engine`, measures wall-clock
//!   latency, and answers the clients' reply channels.

use crate::coordinator::{ProblemInstance, Schedule};
use crate::metrics::{Metrics, Outcome};
use crate::request::{EpochRequest, Request, RequestId};
use crate::wireless::Allocation;

/// A request waiting in the driver's queue, together with whatever payload
/// the backend needs to serve it (nothing for the simulator; prompt tokens
/// and a reply channel for the live server).
pub struct QueuedRequest<P> {
    pub req: Request,
    pub payload: P,
}

/// Why the driver is handing a request back unserved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The stale policy decided the request can no longer be served in time.
    Stale,
    /// The deployed quantization cannot meet its accuracy requirement
    /// (constraint 1e) — it would starve in the queue forever.
    Inadmissible,
    /// The run ended with the request still queued.
    Shutdown,
    /// Shed by the degradation ladder: the shard is sustainedly overrunning
    /// its epoch budget and drops its loosest-deadline arrivals to recover
    /// instead of falling behind unboundedly.
    Overloaded,
    /// The execution step failed transiently (chaos-injected or a real
    /// engine error); the batch's requests get a typed rejection instead of
    /// taking the shard down.
    Execution,
    /// KV-cache admission failed: the backend could not reserve cache for
    /// the request (chaos-injected admission failure, or a genuinely full
    /// ledger surfacing as a typed drop).
    KvFull,
}

/// Everything a backend may need about the epoch being executed.
pub struct EpochContext<'a> {
    pub inst: &'a ProblemInstance,
    /// This epoch's channel-annotated view of the whole queue (scheduled
    /// requests included), in queue order.
    pub annotated: &'a [EpochRequest],
    /// Joint bandwidth allocations for the scheduled batch (one per
    /// scheduled request; the driver's single `wireless::allocate` call).
    pub allocations: &'a [Allocation],
    /// The epoch boundary this batch started at.
    pub now: f64,
    pub epoch_idx: u64,
}

impl EpochContext<'_> {
    /// Allocated (upload, download) seconds for a scheduled request. Under
    /// `AllocationPolicy::MinOnly` these are exactly the protocol slots
    /// T_U/T_D; surplus-distributing policies shorten them.
    pub fn comm_times(&self, id: RequestId) -> (f64, f64) {
        match self.allocations.iter().find(|a| a.id == id) {
            Some(a) => (a.upload_time, a.download_time),
            None => (self.inst.epoch.t_u, self.inst.epoch.t_d),
        }
    }
}

/// How scheduled batches are executed and unserved requests disposed of.
pub trait ExecutionBackend {
    /// Per-request payload carried through the driver queue.
    type Payload;

    /// Execute the scheduled batch. `batch` holds the scheduled queue
    /// entries in queue order; implementations must record exactly one
    /// outcome per scheduled request into `metrics`.
    fn execute(
        &mut self,
        ctx: &EpochContext<'_>,
        schedule: &Schedule,
        batch: Vec<QueuedRequest<Self::Payload>>,
        metrics: &mut Metrics,
    );

    /// A request leaves the system unserved. The default just counts the
    /// drop; live backends also answer the client.
    fn reject(
        &mut self,
        entry: QueuedRequest<Self::Payload>,
        reason: RejectReason,
        metrics: &mut Metrics,
    ) {
        let _ = (entry, reason);
        metrics.record_outcome(Outcome::Dropped, 0.0);
    }

    /// The run is over: flush whatever the backend still holds in flight.
    /// Epoch backends complete every batch inside `execute` and need no
    /// flush; the continuous backend drains its persistent in-flight set
    /// here so request accounting always closes (`horizon` is the nominal
    /// end of the run).
    fn finish(&mut self, horizon: f64, metrics: &mut Metrics) {
        let _ = (horizon, metrics);
    }

    /// Fewest GPUs this backend needs to keep its *in-flight* work resident
    /// — the KV-safety floor for between-epoch re-partitioning (the sharded
    /// driver never migrates in-flight work between shards, only headroom,
    /// so a shard's partition cannot shrink below what its running batch
    /// occupies). Epoch backends complete everything within `execute` and
    /// hold nothing across boundaries: floor 1. The continuous backend
    /// overrides this from its KV ledger.
    fn min_gpus_for_inflight(&self) -> usize {
        1
    }

    /// The shard this backend serves was re-partitioned to `cluster`
    /// (called between epochs, never mid-batch). Backends tracking cluster
    /// capacity (the continuous KV ledger) resize their budgets here; the
    /// guarantee from `min_gpus_for_inflight` is that the new cluster still
    /// covers everything currently in flight.
    fn cluster_resized(&mut self, cluster: &crate::cluster::ClusterSpec) {
        let _ = cluster;
    }

    /// Could this backend *ever* admit `req` — the thief-side KV gate of
    /// elastic work stealing. The sharded driver only migrates a queued
    /// request onto another shard when that shard's backend answers yes, so
    /// a steal never parks work behind an admission gate that can never
    /// open. Epoch backends hold no admission state: always yes. The
    /// continuous backend answers from its KV ledger (`fits_alone`).
    fn can_admit(&self, req: &Request) -> bool {
        let _ = req;
        true
    }

    /// Does this backend hold no in-flight or gate-pending work at all —
    /// the autoscaler's KV-safe retirement check (a shard is only drained
    /// and retired when both its driver queue and its backend are empty, so
    /// scale-down can never strand admitted work). Epoch backends complete
    /// everything inside `execute`: always idle between epochs.
    fn is_idle(&self) -> bool {
        true
    }
}

/// Cost-model execution: the testbed stand-in used by the simulator.
#[derive(Debug, Clone, Copy, Default)]
pub struct AnalyticBackend;

impl ExecutionBackend for AnalyticBackend {
    type Payload = ();

    fn execute(
        &mut self,
        ctx: &EpochContext<'_>,
        schedule: &Schedule,
        _batch: Vec<QueuedRequest<()>>,
        metrics: &mut Metrics,
    ) {
        for &(id, t_compute) in &schedule.per_request_compute {
            // An id the annotation pass never saw was never queued, so it
            // was never pulled into `batch` either — skipping it records
            // nothing and conservation still closes. A panic here would cost
            // the whole shard for what is a scheduler bug, not an engine bug.
            let Some(req) = ctx.annotated.iter().find(|r| r.id() == id) else {
                debug_assert!(false, "scheduler returned unknown request id");
                continue;
            };
            let (t_up, t_down) = ctx.comm_times(id);
            let completion = ctx.now + t_up + t_compute + t_down;
            let latency = completion - req.req.arrival;
            let outcome = if latency <= req.req.latency_req + 1e-9 {
                Outcome::CompletedInDeadline
            } else {
                Outcome::CompletedLate
            };
            metrics.record_outcome(outcome, latency);
        }
    }
}
