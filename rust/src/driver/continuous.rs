//! Continuous batching — the third [`ExecutionBackend`]: decode-step
//! admission into a persistent running batch, relaxing the paper's epoch
//! barrier (ROADMAP item; surveyed in "Network Edge Inference for Large
//! Language Models").
//!
//! ## State machine
//!
//! ```text
//!              scheduler picks the set          KV headroom + arrival due
//!   queued ───────────────────────────▶ pending ─────────────────────────▶ uploading
//!   (driver)        (epoch boundary)      │        (decode-step boundary)      │ T_U elapsed
//!                                         │ best-case-infeasible               ▼
//!                                         ▼                                 prefill ─▶ decoding
//!                                      dropped                                          │ n_i tokens
//!                                                                                       ▼
//!                                                          ledger.release ◀── completed (+ T_D)
//! ```
//!
//! The driver's Fig. 2 pipeline is unchanged: arrivals are annotated and the
//! [`Scheduler`](crate::coordinator::Scheduler) still picks a feasible set at
//! every epoch boundary, so DFTSP/greedy/static remain comparable across
//! batching modes. What changes is *execution*: instead of the whole batch
//! starting at the barrier and finishing together, this backend keeps a
//! persistent per-request KV-cache ledger across `step_epoch` calls and
//! walks the window decode step by decode step —
//!
//! 1. **Admission gate**: a scheduled request joins the running batch at the
//!    first decode-step boundary after its *arrival timestamp* (not the
//!    epoch barrier), provided the [`KvLedger`] can reserve its peak KV
//!    bytes. Entries that do not fit yet wait; completions return headroom
//!    to the gate. Admission latency (arrival → upload start) is recorded in
//!    [`Metrics::admission_latency`](crate::metrics::Metrics).
//! 2. **Upload**: the request uploads for its allocated T_U, then its
//!    prefill FLOPs join the next step.
//! 3. **Decode**: every step advances each in-flight request by one token;
//!    the step's duration is β·ΣFLOPs/C over the *current* batch (prefills
//!    of freshly-ready requests plus one `decode_step_flops` per decoding
//!    request — the same cost model as the epoch path, so the two modes are
//!    directly comparable). No cross-batch padding: each request is costed
//!    at its own prompt length.
//! 4. **Completion/eviction**: a request that has produced its n_i tokens
//!    completes at `t + T_D`, releases its ledger reservation, and the gate
//!    re-scans the pending set. Pending entries that can no longer meet
//!    their deadline even best-case are dropped (stale).
//!
//! The simulation clock is *internal* to the backend (work-conserving: a
//! window whose decode backlog overruns the boundary simply starts the next
//! window late), which is what makes the backend persistent across
//! `step_epoch` calls. `finish` drains everything still in flight so
//! request conservation always closes.

use crate::cluster::ClusterSpec;
use crate::driver::backend::{EpochContext, ExecutionBackend, QueuedRequest};
use crate::driver::InstanceTemplate;
use crate::metrics::{Metrics, Outcome};
use crate::request::{Request, RequestId};
use std::collections::BTreeMap;

/// How scheduled batches are executed: at the epoch barrier (the paper's
/// Fig. 2 protocol) or with decode-step admission into a running batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BatchingMode {
    /// Admission quantized to epoch boundaries; the batch starts and
    /// finishes together (paper §II).
    #[default]
    Epoch,
    /// Decode-step admission with a persistent KV ledger
    /// ([`ContinuousBackend`]).
    Continuous,
}

impl BatchingMode {
    /// Parse the `batching = "epoch" | "continuous"` scenario knob.
    pub fn parse(s: &str) -> Result<BatchingMode, String> {
        match s.to_ascii_lowercase().as_str() {
            "epoch" => Ok(BatchingMode::Epoch),
            "continuous" => Ok(BatchingMode::Continuous),
            other => Err(format!(
                "unknown batching mode `{other}` (expected `epoch` or `continuous`)"
            )),
        }
    }
}

impl std::fmt::Display for BatchingMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BatchingMode::Epoch => write!(f, "epoch"),
            BatchingMode::Continuous => write!(f, "continuous"),
        }
    }
}

/// Per-request KV-cache reservations against the cluster's memory budget.
/// Admission reserves a request's *peak* bytes up front and checks the same
/// worst-GPU packing bound as [`ClusterSpec::batch_fits_memory`] — not just
/// an aggregate sum — so the cross-epoch in-flight union always satisfies
/// constraint (1c) under the paper's per-GPU memory model; completion
/// returns the headroom to the admission gate.
///
/// [`ClusterSpec::batch_fits_memory`]: crate::cluster::ClusterSpec::batch_fits_memory
#[derive(Debug, Clone)]
pub struct KvLedger {
    per_gpu_budget: u64,
    num_gpus: usize,
    in_use: u64,
    peak: u64,
    held: BTreeMap<RequestId, u64>,
}

impl KvLedger {
    pub fn new(per_gpu_budget: u64, num_gpus: usize) -> Self {
        KvLedger {
            per_gpu_budget,
            num_gpus: num_gpus.max(1),
            in_use: 0,
            peak: 0,
            held: BTreeMap::new(),
        }
    }

    /// Ledger for a cluster deployment: per-GPU memory after α-scaled
    /// weights (the shared [`ClusterSpec::kv_budget_per_gpu`] formula
    /// DFTSP's memory bound and the feasibility checker also use).
    ///
    /// [`ClusterSpec::kv_budget_per_gpu`]: crate::cluster::ClusterSpec::kv_budget_per_gpu
    pub fn for_template(template: &InstanceTemplate) -> Self {
        let per_gpu = template
            .cluster
            .kv_budget_per_gpu(&template.cost, &template.quant)
            .max(0.0);
        KvLedger::new(per_gpu as u64, template.cluster.num_gpus)
    }

    /// Aggregate budget across GPUs (upper bound for `in_use`).
    pub fn capacity(&self) -> u64 {
        self.per_gpu_budget.saturating_mul(self.num_gpus as u64)
    }

    pub fn in_use(&self) -> u64 {
        self.in_use
    }

    /// High-water mark of `in_use` over the whole run.
    pub fn peak(&self) -> u64 {
        self.peak
    }

    /// Requests currently holding a reservation.
    pub fn holders(&self) -> usize {
        self.held.len()
    }

    /// Would the in-flight union still fit per-GPU with one more request of
    /// `bytes`? Same worst-loaded-GPU bound as `batch_fits_memory`: with at
    /// most one request per GPU the worst GPU holds the largest request;
    /// beyond that, the LPT makespan bound `total/G + max`.
    fn fits_with(&self, bytes: u64) -> bool {
        let count = self.held.len() + 1;
        let total = (self.in_use + bytes) as f64;
        let max = self
            .held
            .values()
            .copied()
            .max()
            .unwrap_or(0)
            .max(bytes) as f64;
        let worst_gpu = if count <= self.num_gpus {
            max
        } else {
            total / self.num_gpus as f64 + max
        };
        worst_gpu <= self.per_gpu_budget as f64
    }

    /// Can a request of `bytes` ever be admitted, even on an empty ledger?
    pub fn fits_alone(&self, bytes: u64) -> bool {
        bytes <= self.per_gpu_budget
    }

    /// Reserve `bytes` for `id`; false (and no state change) when the
    /// packing bound cannot cover it.
    pub fn try_admit(&mut self, id: RequestId, bytes: u64) -> bool {
        if !self.fits_with(bytes) {
            return false;
        }
        self.in_use += bytes;
        if self.in_use > self.peak {
            self.peak = self.in_use;
        }
        self.held.insert(id, bytes);
        true
    }

    /// Return `id`'s reservation to the gate (no-op for unknown ids).
    pub fn release(&mut self, id: RequestId) {
        if let Some(bytes) = self.held.remove(&id) {
            self.in_use -= bytes;
        }
    }

    /// Resize the GPU pool backing this ledger (sharded re-partitioning;
    /// the per-GPU budget is a property of the GPU model and stays put).
    /// Callers guarantee `num_gpus >= self.min_gpus_for_inflight()` — the
    /// held reservations were admitted under the packing bound and must
    /// keep satisfying it.
    pub fn set_num_gpus(&mut self, num_gpus: usize) {
        self.num_gpus = num_gpus.max(1);
    }

    /// Smallest GPU count under which every *currently held* reservation
    /// still satisfies the worst-GPU packing bound — the KV-safety floor
    /// handed to the sharded driver's re-partitioner (in-flight work never
    /// migrates; only headroom does). An empty ledger floors at 1.
    pub fn min_gpus_for_inflight(&self) -> usize {
        if self.held.is_empty() {
            return 1;
        }
        let total = self.in_use as f64;
        let max = *self.held.values().max().unwrap() as f64;
        let budget = self.per_gpu_budget as f64;
        for g in 1..=self.num_gpus.max(1) {
            let worst = if self.held.len() <= g {
                max
            } else {
                total / g as f64 + max
            };
            if worst <= budget {
                return g;
            }
        }
        // Degenerate (shrunken-budget tests): nothing smaller fits — keep
        // the pool as is.
        self.num_gpus.max(1)
    }
}

/// A scheduled request waiting at the admission gate.
#[derive(Debug, Clone)]
struct PendingEntry {
    req: Request,
    kv_bytes: u64,
    t_up: f64,
    t_down: f64,
}

/// A request in the running batch.
#[derive(Debug, Clone)]
struct Flight {
    req: Request,
    /// Upload completes here; the prefill joins the first step at or after.
    ready_at: f64,
    t_down: f64,
    /// Tokens produced so far (prefill emits the first).
    produced: u32,
    prefilled: bool,
}

/// Analytic continuous-batching execution: the cost-model counterpart of the
/// serving layer's continuous mode, plugged into the same [`EpochDriver`]
/// (see module docs for the state machine).
///
/// [`EpochDriver`]: crate::driver::EpochDriver
pub struct ContinuousBackend {
    template: InstanceTemplate,
    ledger: KvLedger,
    /// Internal work-conserving simulation clock (seconds).
    clock: f64,
    pending: Vec<PendingEntry>,
    flights: Vec<Flight>,
}

impl ContinuousBackend {
    pub fn new(template: &InstanceTemplate) -> Self {
        ContinuousBackend {
            ledger: KvLedger::for_template(template),
            template: template.clone(),
            clock: 0.0,
            pending: Vec::new(),
            flights: Vec::new(),
        }
    }

    /// The KV admission gate's ledger (inspection for tests/diagnostics).
    pub fn ledger(&self) -> &KvLedger {
        &self.ledger
    }

    /// Requests admitted and not yet completed.
    pub fn in_flight(&self) -> usize {
        self.flights.len()
    }

    /// Scheduled requests still waiting at the admission gate.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Peak KV bytes a request reserves — costed at its *own* prompt length
    /// (continuous batching does not pad across the batch).
    fn kv_bytes(&self, req: &Request) -> u64 {
        self.template
            .cost
            .kv_peak_bytes_per_req(req.prompt_tokens, req.output_tokens)
    }

    /// Even an immediate solo run cannot meet the deadline any more (the
    /// driver's `BestCaseInfeasible` rule, via the shared template helper).
    fn hopeless(&self, req: &Request, now: f64) -> bool {
        let best_case = self
            .template
            .best_case_latency(req.prompt_tokens, req.output_tokens);
        req.waited(now) + best_case > req.latency_req
    }

    /// Drop pending entries that can no longer make their deadline.
    fn drop_stale_pending(&mut self, metrics: &mut Metrics) {
        let pending = std::mem::take(&mut self.pending);
        for p in pending {
            if self.hopeless(&p.req, self.clock) {
                metrics.record_outcome(Outcome::Dropped, 0.0);
            } else {
                self.pending.push(p);
            }
        }
    }

    /// Scan the gate in arrival order and admit due entries whose KV
    /// reservation fits — strict FCFS: a due entry blocked on headroom also
    /// holds back everything that arrived after it (the same no-leapfrog
    /// discipline as the serving gate), so a large request cannot be starved
    /// by a stream of smaller later ones. Entries that can *never* fit
    /// (peak KV above one GPU's budget) are rejected outright rather than
    /// deadlocking the gate.
    fn admit_due(&mut self, metrics: &mut Metrics) {
        let pending = std::mem::take(&mut self.pending);
        let mut blocked = false;
        for p in pending {
            if blocked || p.req.arrival > self.clock {
                self.pending.push(p);
                continue;
            }
            if !self.ledger.fits_alone(p.kv_bytes) {
                metrics.record_outcome(Outcome::Dropped, 0.0);
            } else if self.ledger.try_admit(p.req.id, p.kv_bytes) {
                metrics.record_admission(self.clock - p.req.arrival);
                self.flights.push(Flight {
                    ready_at: self.clock + p.t_up,
                    t_down: p.t_down,
                    produced: 0,
                    prefilled: false,
                    req: p.req,
                });
            } else {
                blocked = true;
                self.pending.push(p);
            }
        }
    }

    /// Advance the continuous machine until `until` (or, when `drain_all`,
    /// until every pending and in-flight request has resolved).
    fn simulate(&mut self, until: f64, drain_all: bool, metrics: &mut Metrics) {
        loop {
            self.drop_stale_pending(metrics);
            self.admit_due(metrics);

            // The step's workload: prefill for freshly-ready flights, one
            // decode iteration for everyone already prefilled.
            let step_start = self.clock;
            let mut step_flops = 0.0;
            let mut active = 0usize;
            for f in &self.flights {
                if f.ready_at > step_start {
                    continue;
                }
                active += 1;
                step_flops += if f.prefilled {
                    self.template
                        .cost
                        .decode_step_flops(f.req.prompt_tokens, f.produced)
                } else {
                    self.template.cost.prefill_flops_per_req(f.req.prompt_tokens)
                };
            }

            if active == 0 {
                // Idle: jump to the next event (an upload finishing or a
                // pending arrival coming due).
                let mut next = f64::INFINITY;
                for f in &self.flights {
                    if f.ready_at > self.clock && f.ready_at < next {
                        next = f.ready_at;
                    }
                }
                for p in &self.pending {
                    if p.req.arrival > self.clock && p.req.arrival < next {
                        next = p.req.arrival;
                    }
                }
                if drain_all {
                    if next.is_finite() {
                        self.clock = next;
                        continue;
                    }
                    // Nothing can ever start again: anything left at the
                    // gate is starved by its own KV demand — reject it.
                    for _ in self.pending.drain(..) {
                        metrics.record_outcome(Outcome::Dropped, 0.0);
                    }
                    return;
                }
                if next >= until {
                    if self.clock < until {
                        self.clock = until;
                    }
                    return;
                }
                self.clock = next;
                continue;
            }

            metrics.record_step_occupancy(active);
            let dt = self.template.quant.beta * step_flops / self.template.cluster.total_flops();
            self.clock = step_start + dt;

            // Advance every participating flight by one token and resolve
            // completions (releasing KV headroom back to the gate).
            let now = self.clock;
            let flights = std::mem::take(&mut self.flights);
            for mut f in flights {
                if f.ready_at > step_start {
                    // Was not part of this step (still uploading).
                    self.flights.push(f);
                    continue;
                }
                if f.prefilled {
                    f.produced += 1;
                } else {
                    f.prefilled = true;
                    f.produced = 1;
                }
                if f.produced >= f.req.output_tokens {
                    let completion = now + f.t_down;
                    let latency = completion - f.req.arrival;
                    let outcome = if latency <= f.req.latency_req + 1e-9 {
                        Outcome::CompletedInDeadline
                    } else {
                        Outcome::CompletedLate
                    };
                    metrics.record_outcome(outcome, latency);
                    self.ledger.release(f.req.id);
                } else {
                    self.flights.push(f);
                }
            }

            if !drain_all && self.clock >= until {
                return;
            }
            if drain_all && self.flights.is_empty() && self.pending.is_empty() {
                return;
            }
        }
    }
}

impl ExecutionBackend for ContinuousBackend {
    type Payload = ();

    fn execute(
        &mut self,
        ctx: &EpochContext<'_>,
        _schedule: &crate::coordinator::Schedule,
        batch: Vec<QueuedRequest<()>>,
        metrics: &mut Metrics,
    ) {
        // Work-conserving clock: catch up to the boundary when idle, keep
        // the backlog when the previous window overran.
        if self.clock < ctx.now {
            self.clock = ctx.now;
        }
        for entry in batch {
            let (t_up, t_down) = ctx.comm_times(entry.req.id);
            self.pending.push(PendingEntry {
                kv_bytes: self.kv_bytes(&entry.req),
                t_up,
                t_down,
                req: entry.req,
            });
        }
        // Admission order is arrival order (FCFS gate), not schedule order.
        self.pending.sort_by(|a, b| {
            a.req
                .arrival
                .total_cmp(&b.req.arrival)
                .then(a.req.id.cmp(&b.req.id))
        });
        self.simulate(ctx.now + self.template.epoch.duration, false, metrics);
    }

    fn finish(&mut self, horizon: f64, metrics: &mut Metrics) {
        // Shutdown semantics mirror the epoch path: no new admissions —
        // whatever still waits at the gate is unserved (the driver rejects
        // its queue the same way) — and only the already-running batch
        // decodes to completion, so past-horizon serving is bounded by the
        // in-flight work instead of draining an unbounded backlog into the
        // throughput numerator.
        for _ in self.pending.drain(..) {
            metrics.record_outcome(Outcome::Dropped, 0.0);
        }
        let until = horizon.max(self.clock);
        self.simulate(until, true, metrics);
    }

    /// KV-safety floor for re-partitioning: the ledger's current in-flight
    /// reservations pin this many GPUs to the shard.
    fn min_gpus_for_inflight(&self) -> usize {
        self.ledger.min_gpus_for_inflight()
    }

    /// Re-partition handoff: adopt the new pool size for both the compute
    /// model (step durations, best-case screens) and the KV admission gate.
    /// In-flight reservations are untouched — the caller honored
    /// `min_gpus_for_inflight`, so they still satisfy the packing bound.
    fn cluster_resized(&mut self, cluster: &ClusterSpec) {
        self.template.cluster = cluster.clone();
        self.ledger.set_num_gpus(cluster.num_gpus);
    }

    /// Thief-side KV gate for elastic work stealing: only accept a stolen
    /// request this shard's ledger could ever admit on its own (the same
    /// hopelessness screen `admit_due` applies), so a steal never parks work
    /// behind a gate that cannot open.
    fn can_admit(&self, req: &Request) -> bool {
        self.ledger.fits_alone(self.kv_bytes(req))
    }

    /// Idle means nothing decoding and nothing waiting at the admission
    /// gate — the autoscaler's KV-safe retirement condition.
    fn is_idle(&self) -> bool {
        self.flights.is_empty() && self.pending.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::coordinator::{Dftsp, EpochParams};
    use crate::driver::{DriverPolicy, EpochDriver, SPadPolicy, StalePolicy};
    use crate::model::{CostModel, LlmSpec};
    use crate::quant;
    use crate::request::RequestBuilder;
    use crate::util::rng::Rng;
    use crate::wireless::{AllocationPolicy, ChannelParams, RadioParams};

    fn template() -> InstanceTemplate {
        InstanceTemplate {
            cost: CostModel::new(LlmSpec::bloom_3b()),
            quant: quant::default_quant(),
            cluster: ClusterSpec::paper_default(),
            epoch: EpochParams::default(),
        }
    }

    fn driver() -> EpochDriver<()> {
        EpochDriver::new(
            template(),
            DriverPolicy {
                stale: StalePolicy::BestCaseInfeasible,
                s_pad: SPadPolicy::LongestQueued { fallback: 512 },
                allocation: AllocationPolicy::MinOnly,
            },
            RadioParams::default(),
            ChannelParams::default(),
            Rng::new(42),
        )
    }

    #[test]
    fn batching_mode_parses() {
        assert_eq!(BatchingMode::parse("epoch").unwrap(), BatchingMode::Epoch);
        assert_eq!(
            BatchingMode::parse("Continuous").unwrap(),
            BatchingMode::Continuous
        );
        assert!(BatchingMode::parse("rolling").is_err());
        assert_eq!(BatchingMode::Continuous.to_string(), "continuous");
        assert_eq!(BatchingMode::default(), BatchingMode::Epoch);
    }

    #[test]
    fn ledger_enforces_worst_gpu_packing() {
        // 2 GPUs, 100 bytes of per-GPU budget.
        let mut l = KvLedger::new(100, 2);
        assert!(l.try_admit(1, 60), "one per GPU: worst GPU holds 60");
        assert!(l.try_admit(2, 50), "one per GPU: worst GPU holds 60");
        assert_eq!(l.in_use(), 110);
        assert_eq!(l.holders(), 2);
        // A third request exceeds one-per-GPU: LPT bound total/G + max.
        assert!(!l.try_admit(3, 80), "190/2 + 80 = 175 > 100");
        assert!(!l.try_admit(3, 10), "120/2 + 60 = 120 > 100");
        l.release(1);
        assert_eq!(l.in_use(), 50);
        assert!(l.try_admit(3, 40), "back to one per GPU: max 50 <= 100");
        assert_eq!(l.peak(), 110, "high-water mark kept");
        assert!(l.fits_alone(100));
        assert!(!l.fits_alone(101), "bigger than one GPU can never fit");
        l.release(99); // unknown id is a no-op
        assert_eq!(l.in_use(), 90);
        assert!(l.capacity() >= l.peak());
    }

    #[test]
    fn ledger_kv_safe_resize_floor() {
        // 4 GPUs, 100 bytes per GPU; three 60-byte holders need the LPT
        // bound 180/g + 60 <= 100 => g >= 4.5 … but with holders <= g the
        // worst GPU holds only max: g = 3 fits one-per-GPU.
        let mut l = KvLedger::new(100, 4);
        assert_eq!(l.min_gpus_for_inflight(), 1, "empty ledger floors at 1");
        assert!(l.try_admit(1, 60));
        assert!(l.try_admit(2, 60));
        assert!(l.try_admit(3, 60));
        assert_eq!(l.min_gpus_for_inflight(), 3, "one-per-GPU regime");
        // Shrinking to the floor keeps every later admit consistent.
        l.set_num_gpus(3);
        assert!(!l.try_admit(4, 60), "240/3 + 60 = 140 > 100");
        l.release(1);
        assert_eq!(l.min_gpus_for_inflight(), 2);
        l.set_num_gpus(2);
        assert_eq!(l.holders(), 2);
        // Growing again restores headroom.
        l.set_num_gpus(4);
        assert!(l.try_admit(5, 60));
    }

    #[test]
    fn backend_cluster_resize_updates_ledger_and_compute() {
        let t = template();
        let mut backend = ContinuousBackend::new(&t);
        let before = backend.ledger().capacity();
        let half = ClusterSpec::new(t.cluster.gpu.clone(), t.cluster.num_gpus / 2);
        backend.cluster_resized(&half);
        assert_eq!(backend.ledger().capacity(), before / 2);
        assert_eq!(backend.template.cluster.num_gpus, t.cluster.num_gpus / 2);
        assert_eq!(backend.min_gpus_for_inflight(), 1, "nothing in flight");
    }

    #[test]
    fn ledger_capacity_positive_for_paper_cluster() {
        let l = KvLedger::for_template(&template());
        assert!(l.capacity() > 0);
        // 20 GPUs × 32 GiB minus α-scaled BLOOM-3B weights: hundreds of GiB.
        assert!(l.capacity() > 100 * (1 << 30) as u64);
        assert!(l.fits_alone(1 << 30), "a 1 GiB KV request fits one GPU");
    }

    #[test]
    fn mid_epoch_arrival_admitted_before_next_barrier() {
        // One request arriving mid-window must start (and here: finish)
        // before the next epoch boundary.
        let mut d = driver();
        let mut sched = Dftsp::new();
        let mut backend = ContinuousBackend::new(&template());
        let mut b = RequestBuilder::new();
        // Offered at boundary 0 with arrival 1.0 (mid-window intake).
        d.offer(b.build(1.0, 128, 128, 1.9, 0.1), ());
        d.step_epoch(&mut sched, &mut backend, 0.0);
        d.finish(&mut backend, 2.0);
        let m = d.into_metrics();
        assert_eq!(m.offered, 1);
        assert_eq!(m.completed_in_deadline, 1, "admitted at ~1.0, not 2.0");
        assert_eq!(m.admission_latency.count(), 1);
        assert!(
            m.mean_admission_latency() < 0.2,
            "waited {} s, continuous admission should be ~immediate",
            m.mean_admission_latency()
        );
        assert!(m.inflight_occupancy.count() > 0);
    }

    #[test]
    fn conservation_and_ledger_bounds_under_load() {
        let mut d = driver();
        let mut sched = Dftsp::new();
        let mut backend = ContinuousBackend::new(&template());
        let mut b = RequestBuilder::new();
        for e in 0..6u64 {
            let now = e as f64 * 2.0;
            for i in 0..5 {
                // Arrivals spread through the window.
                d.offer(b.build(now + 0.3 * i as f64, 128, 128, 1.8, 0.3), ());
            }
            d.step_epoch(&mut sched, &mut backend, now);
        }
        d.finish(&mut backend, 12.0);
        assert_eq!(backend.in_flight(), 0, "finish drains every flight");
        assert_eq!(backend.pending(), 0);
        assert_eq!(backend.ledger().in_use(), 0);
        assert!(backend.ledger().peak() <= backend.ledger().capacity());
        let m = d.into_metrics();
        assert_eq!(m.offered, 30);
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped,
            "conservation of requests"
        );
        assert!(m.completed_in_deadline > 0);
    }

    #[test]
    fn kv_pressure_defers_admission_until_headroom_returns() {
        // Shrink the ledger so only one 512-output request fits at a time:
        // the second must wait for the first to complete, then be admitted
        // (not dropped).
        let t = template();
        let mut backend = ContinuousBackend::new(&t);
        let kv_one = t.cost.kv_peak_bytes_per_req(128, 512);
        backend.ledger = KvLedger::new(kv_one + kv_one / 2, 1);
        let mut d = driver();
        let mut sched = Dftsp::new();
        let mut b = RequestBuilder::new();
        d.offer(b.build(0.0, 128, 512, 60.0, 0.0), ());
        d.offer(b.build(0.0, 128, 512, 60.0, 0.0), ());
        d.step_epoch(&mut sched, &mut backend, 0.0);
        d.finish(&mut backend, 2.0);
        let m = d.into_metrics();
        assert_eq!(m.completed_in_deadline + m.completed_late, 2);
        assert_eq!(m.dropped, 0);
        assert!(backend.ledger().peak() <= backend.ledger().capacity());
        // Serialized, never both in flight at once.
        assert!(m.inflight_occupancy.max() <= 1.0 + 1e-12);
    }

    #[test]
    fn kv_int8_admits_larger_concurrent_set_and_lifts_throughput() {
        // End-to-end memory win of the int8 KV cache: on a KV-bound
        // deployment the W8A8 gate serializes (budget ≈ 2.6 requests' KV,
        // but the worst-GPU bound `total + max` caps co-residency at one),
        // while W8A8KV8 — identical α/β, half the stored KV bytes — admits
        // all four at once. Uploads then overlap decode instead of queueing
        // behind it, and the later requests stop missing their deadlines.
        let cost = CostModel::new(LlmSpec::bloom_3b());
        let kv_one = cost.kv_peak_bytes_per_req(128, 512);
        let alpha = quant::spec_for_label("W8A8/RTN").unwrap().alpha;
        // One GPU sized so the unscaled-KV budget is 2.6 × one request.
        let mem = (alpha * (cost.weight_bytes() as f64 + 2.6 * kv_one as f64)) as u64;
        let run = |label: &str| {
            let template = InstanceTemplate {
                cost: CostModel::new(LlmSpec::bloom_3b()),
                quant: quant::spec_for_label(label).unwrap(),
                cluster: ClusterSpec::new(
                    crate::cluster::GpuSpec {
                        name: "kv-bound".into(),
                        flops: 1.33e12,
                        mem_bytes: mem,
                    },
                    1,
                ),
                epoch: EpochParams::default(),
            };
            let mut backend = ContinuousBackend::new(&template);
            let mut metrics = Metrics::new();
            let mut b = RequestBuilder::new();
            for _ in 0..4 {
                let req = b.build(0.0, 128, 512, 10.0, 0.0);
                backend.pending.push(PendingEntry {
                    kv_bytes: template.cost.kv_peak_bytes_per_req(128, 512),
                    t_up: 2.0, // upload comparable to compute: overlap matters
                    t_down: 0.0,
                    req,
                });
            }
            backend.simulate(20.0, true, &mut metrics);
            metrics.horizon = 20.0;
            (metrics, backend)
        };
        let (base, base_backend) = run("W8A8/RTN");
        let (kv8, kv8_backend) = run("W8A8KV8/RTN");

        // Same physical memory, twice the unscaled-KV capacity.
        assert_eq!(
            kv8_backend.ledger().capacity(),
            2 * base_backend.ledger().capacity()
        );
        // Strictly larger concurrent set…
        assert_eq!(base.inflight_occupancy.max(), 1.0, "base gate serializes");
        assert_eq!(kv8.inflight_occupancy.max(), 4.0, "kv8 admits all four");
        assert!(kv8_backend.ledger().peak() > base_backend.ledger().peak());
        // …and strictly higher throughput on the same trace and horizon.
        assert_eq!(kv8.completed_in_deadline, 4, "kv8 serves the whole trace");
        assert!(
            base.completed_in_deadline < 4,
            "base must miss deadlines for the comparison to bite (got {})",
            base.completed_in_deadline
        );
        assert!(kv8.throughput() > base.throughput());
        assert!(kv8.mean_admission_latency() < base.mean_admission_latency());
    }

    #[test]
    fn oversized_request_rejected_not_deadlocked() {
        let t = template();
        let mut backend = ContinuousBackend::new(&t);
        backend.ledger = KvLedger::new(16, 1); // absurdly small per-GPU budget
        let mut d = driver();
        let mut sched = Dftsp::new();
        let mut b = RequestBuilder::new();
        d.offer(b.build(0.0, 128, 128, 60.0, 0.0), ());
        d.step_epoch(&mut sched, &mut backend, 0.0);
        d.finish(&mut backend, 2.0);
        let m = d.into_metrics();
        assert_eq!(m.dropped, 1, "can never fit: rejected, not starved");
        assert_eq!(m.completed_in_deadline + m.completed_late, 0);
    }

    #[test]
    fn deterministic_across_runs() {
        let run = || {
            let mut d = driver();
            let mut sched = Dftsp::new();
            let mut backend = ContinuousBackend::new(&template());
            let mut b = RequestBuilder::new();
            for e in 0..4u64 {
                let now = e as f64 * 2.0;
                for i in 0..4 {
                    d.offer(b.build(now + 0.4 * i as f64, 256, 256, 2.0, 0.2), ());
                }
                d.step_epoch(&mut sched, &mut backend, now);
            }
            d.finish(&mut backend, 8.0);
            d.into_metrics()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "continuous simulation must be bit-deterministic");
    }
}
