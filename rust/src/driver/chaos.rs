//! Deterministic chaos injection — the fault harness that proves the
//! supervision layer.
//!
//! [`ChaosBackend`] decorates any [`ExecutionBackend`] and injects faults at
//! the `execute` seam from its own seeded RNG stream:
//!
//! - **panic** — `panic!` mid-step, exactly what a poisoned request or an
//!   engine bug looks like to the supervisor (`catch_unwind` catches it,
//!   marks the shard degraded, redispatches its queue and restarts it);
//! - **stall** — sleep past the epoch budget before executing, driving the
//!   epoch watchdog and the degradation ladder;
//! - **error** — a transient step failure: the whole batch gets a typed
//!   [`RejectReason::Execution`] rejection instead of outcomes (conservation
//!   still closes — one terminal event per scheduled request);
//! - **kv-fail** — one admission failure: the first scheduled request is
//!   rejected [`RejectReason::KvFull`], the rest of the batch executes.
//!
//! ## Determinism contract
//!
//! `EpochDriver::step_epoch` calls `execute` unconditionally every epoch, so
//! the decorator draws **exactly one** uniform per epoch when enabled (and
//! none when disabled — a disabled `ChaosBackend` is bit-identical to the
//! bare backend). The fault schedule is therefore a pure function of
//! `(chaos seed, shard, restart generation, epoch index)`: independent of
//! traffic, of wall time, and of the other shards. The same chaos seed
//! reproduces the same crashes, stalls, errors and merged fault counters
//! bit-for-bit (`tests/proptest_chaos.rs`), and the Python mirror
//! (`python/chaos_mirror.py`) predicts every fault from the seed alone.
//!
//! Restarted shards resume with a fresh stream split by generation
//! ([`chaos_stream`]) so the post-restart schedule is just as deterministic:
//! which generation a shard is in at epoch e is itself a function of the
//! fault schedule, closing the loop.

use crate::coordinator::Schedule;
use crate::driver::backend::{EpochContext, ExecutionBackend, QueuedRequest, RejectReason};
use crate::metrics::Metrics;
use crate::util::rng::{splitmix64, Rng};

/// Fault probabilities and the chaos seed, as parsed from `[chaos]` scenario
/// TOML or the `--chaos-*` CLI flags. All probabilities are per-epoch (one
/// roll per `execute`); cumulative thresholds are taken in the order panic,
/// stall, error, kv-fail, so earlier faults shadow later ones when the sum
/// exceeds 1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChaosConfig {
    /// Chaos stream seed — independent of the run seed, so enabling chaos
    /// never perturbs workload or channel randomness.
    pub seed: u64,
    /// P(panic mid-execute) per epoch.
    pub panic_prob: f64,
    /// P(stall before executing) per epoch.
    pub stall_prob: f64,
    /// Stall length in milliseconds of real sleep (wall-clock faults only
    /// make sense against the wall clock; the sim clock just records them).
    pub stall_ms: u64,
    /// P(transient step error → whole batch rejected `Execution`) per epoch.
    pub error_prob: f64,
    /// P(one KV-admission failure → first scheduled request rejected
    /// `KvFull`) per epoch.
    pub kv_fail_prob: f64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            seed: 0,
            panic_prob: 0.0,
            stall_prob: 0.0,
            stall_ms: 0,
            error_prob: 0.0,
            kv_fail_prob: 0.0,
        }
    }
}

impl ChaosConfig {
    /// True when any fault can fire. A disabled config never draws from the
    /// chaos stream (bit-identical passthrough).
    pub fn enabled(&self) -> bool {
        self.panic_prob > 0.0
            || self.stall_prob > 0.0
            || self.error_prob > 0.0
            || self.kv_fail_prob > 0.0
    }
}

/// Per-(shard, restart-generation) chaos stream seed. Generation 0 of shard
/// 0 keeps the chaos seed verbatim, mirroring the run-RNG split rule;
/// every other (shard, generation) pair gets an independent
/// SplitMix64-derived stream.
pub fn chaos_stream(seed: u64, shard: u64, generation: u64) -> u64 {
    if shard == 0 && generation == 0 {
        return seed;
    }
    let mut s = seed
        ^ shard.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ generation.wrapping_mul(0xD1B5_4A32_D192_ED03);
    splitmix64(&mut s)
}

/// Restart backoff in epochs for the sharded driver's supervisor: 1, 2, 4,
/// 8, 8, ... — the accept-loop shape (capped doubling), denominated in
/// epochs because the driver world has no wall clock.
pub fn backoff_epochs(consecutive_failures: u32) -> u64 {
    (1u64 << consecutive_failures.min(4)).min(8)
}

/// Restart backoff in milliseconds for the serving supervisor — the same
/// capped doubling the accept loop uses (`serving::net`): 1, 2, 4, ...,
/// capped at 500 ms.
pub fn restart_backoff_ms(consecutive_failures: u32) -> u64 {
    (1u64 << consecutive_failures.min(9)).min(500)
}

/// What the single per-epoch roll resolved to (exposed for tests and the
/// Python mirror's fault-schedule cross-check).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    None,
    Panic,
    Stall,
    Error,
    KvFail,
}

/// Resolve one uniform draw against the cumulative fault thresholds — the
/// single decision rule shared by [`ChaosBackend::execute`], the unit tests
/// and (re-implemented bit-for-bit) `python/chaos_mirror.py`.
pub fn resolve_fault(cfg: &ChaosConfig, u: f64) -> Fault {
    let mut edge = cfg.panic_prob;
    if u < edge {
        return Fault::Panic;
    }
    edge += cfg.stall_prob;
    if u < edge {
        return Fault::Stall;
    }
    edge += cfg.error_prob;
    if u < edge {
        return Fault::Error;
    }
    edge += cfg.kv_fail_prob;
    if u < edge {
        return Fault::KvFail;
    }
    Fault::None
}

/// The decorator. Wraps any backend; when disabled it is a zero-cost
/// passthrough (no RNG draw, no behavior change).
pub struct ChaosBackend<B> {
    inner: B,
    cfg: ChaosConfig,
    rng: Rng,
    enabled: bool,
}

impl<B> ChaosBackend<B> {
    /// Wrap `inner` with the fault stream for `(shard, generation)`. Pass
    /// the same config with `generation + 1` when rebuilding a crashed
    /// shard's backend.
    pub fn new(inner: B, cfg: ChaosConfig, shard: u64, generation: u64) -> Self {
        let enabled = cfg.enabled();
        ChaosBackend {
            inner,
            cfg,
            rng: Rng::new(chaos_stream(cfg.seed, shard, generation)),
            enabled,
        }
    }

    /// A disabled wrapper (identity decoration) — lets call sites hold a
    /// `ChaosBackend<B>` unconditionally.
    pub fn passthrough(inner: B) -> Self {
        Self::new(inner, ChaosConfig::default(), 0, 0)
    }

    pub fn inner(&self) -> &B {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut B {
        &mut self.inner
    }

    pub fn into_inner(self) -> B {
        self.inner
    }
}

impl<B: ExecutionBackend> ExecutionBackend for ChaosBackend<B> {
    type Payload = B::Payload;

    fn execute(
        &mut self,
        ctx: &EpochContext<'_>,
        schedule: &Schedule,
        mut batch: Vec<QueuedRequest<B::Payload>>,
        metrics: &mut Metrics,
    ) {
        if !self.enabled {
            return self.inner.execute(ctx, schedule, batch, metrics);
        }
        match resolve_fault(&self.cfg, self.rng.f64()) {
            Fault::None => self.inner.execute(ctx, schedule, batch, metrics),
            Fault::Panic => {
                panic!("chaos: injected panic at epoch {}", ctx.epoch_idx);
            }
            Fault::Stall => {
                if self.cfg.stall_ms > 0 {
                    std::thread::sleep(std::time::Duration::from_millis(self.cfg.stall_ms));
                }
                self.inner.execute(ctx, schedule, batch, metrics);
            }
            Fault::Error => {
                // The whole step fails transiently: every scheduled request
                // gets exactly one typed rejection, nothing executes.
                for entry in batch {
                    self.inner
                        .reject(entry, RejectReason::Execution, metrics);
                }
            }
            Fault::KvFail => {
                // One admission failure: the first scheduled request is
                // bounced, the rest of the batch executes normally. The
                // victim must leave *both* the batch and the schedule, or a
                // schedule-driven inner backend would record a second
                // outcome for it.
                if batch.is_empty() {
                    return self.inner.execute(ctx, schedule, batch, metrics);
                }
                let victim = batch.remove(0);
                let victim_id = victim.req.id;
                self.inner
                    .reject(victim, RejectReason::KvFull, metrics);
                let mut filtered = schedule.clone();
                filtered.scheduled.retain(|&id| id != victim_id);
                filtered
                    .per_request_compute
                    .retain(|&(id, _)| id != victim_id);
                self.inner.execute(ctx, &filtered, batch, metrics);
            }
        }
    }

    fn reject(
        &mut self,
        entry: QueuedRequest<B::Payload>,
        reason: RejectReason,
        metrics: &mut Metrics,
    ) {
        self.inner.reject(entry, reason, metrics);
    }

    fn finish(&mut self, horizon: f64, metrics: &mut Metrics) {
        self.inner.finish(horizon, metrics);
    }

    fn min_gpus_for_inflight(&self) -> usize {
        self.inner.min_gpus_for_inflight()
    }

    fn cluster_resized(&mut self, cluster: &crate::cluster::ClusterSpec) {
        self.inner.cluster_resized(cluster);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::coordinator::{Dftsp, EpochParams};
    use crate::driver::{
        AnalyticBackend, DriverPolicy, EpochDriver, InstanceTemplate, SPadPolicy, StalePolicy,
    };
    use crate::model::{CostModel, LlmSpec};
    use crate::quant;
    use crate::request::RequestBuilder;
    use crate::wireless::{AllocationPolicy, ChannelParams, RadioParams};

    fn driver() -> EpochDriver<()> {
        EpochDriver::new(
            InstanceTemplate {
                cost: CostModel::new(LlmSpec::bloom_3b()),
                quant: quant::default_quant(),
                cluster: ClusterSpec::paper_default(),
                epoch: EpochParams::default(),
            },
            DriverPolicy {
                stale: StalePolicy::BestCaseInfeasible,
                s_pad: SPadPolicy::LongestQueued { fallback: 512 },
                allocation: AllocationPolicy::MinOnly,
            },
            RadioParams::default(),
            ChannelParams::default(),
            Rng::new(42),
        )
    }

    fn run(chaos: Option<ChaosConfig>) -> Metrics {
        let mut d = driver();
        let mut sched = Dftsp::new();
        let mut backend = match chaos {
            Some(cfg) => ChaosBackend::new(AnalyticBackend, cfg, 0, 0),
            None => ChaosBackend::passthrough(AnalyticBackend),
        };
        let mut b = RequestBuilder::new();
        for e in 0..6u64 {
            let now = e as f64 * 2.0;
            for _ in 0..4 {
                d.offer(b.build(now, 128, 128, 1.8, 0.3), ());
            }
            d.step_epoch(&mut sched, &mut backend, now);
        }
        d.finish(&mut backend, 12.0);
        d.into_metrics()
    }

    #[test]
    fn disabled_wrapper_is_bit_identical_to_bare_backend() {
        let mut d = driver();
        let mut sched = Dftsp::new();
        let mut bare = AnalyticBackend;
        let mut b = RequestBuilder::new();
        for e in 0..6u64 {
            let now = e as f64 * 2.0;
            for _ in 0..4 {
                d.offer(b.build(now, 128, 128, 1.8, 0.3), ());
            }
            d.step_epoch(&mut sched, &mut bare, now);
        }
        d.finish(&mut bare, 12.0);
        assert_eq!(d.into_metrics(), run(None));
    }

    #[test]
    fn resolve_fault_thresholds_are_cumulative() {
        let cfg = ChaosConfig {
            seed: 0,
            panic_prob: 0.1,
            stall_prob: 0.2,
            stall_ms: 0,
            error_prob: 0.3,
            kv_fail_prob: 0.2,
        };
        assert_eq!(resolve_fault(&cfg, 0.05), Fault::Panic);
        assert_eq!(resolve_fault(&cfg, 0.1), Fault::Stall);
        assert_eq!(resolve_fault(&cfg, 0.29), Fault::Stall);
        // The edges are accumulated f64 sums (0.1 + 0.2 ≠ exactly 0.3);
        // the mirror reproduces the same rounding, so the boundary draw
        // lands identically on both sides.
        assert_eq!(resolve_fault(&cfg, 0.35), Fault::Error);
        assert_eq!(resolve_fault(&cfg, 0.65), Fault::KvFail);
        assert_eq!(resolve_fault(&cfg, 0.85), Fault::None);
        // Disabled config: every draw is a no-op.
        assert_eq!(resolve_fault(&ChaosConfig::default(), 0.0), Fault::None);
    }

    #[test]
    fn error_fault_rejects_whole_batch_and_conserves() {
        let cfg = ChaosConfig {
            seed: 11,
            error_prob: 1.0,
            ..ChaosConfig::default()
        };
        let m = run(Some(cfg));
        assert_eq!(m.offered, 24);
        assert_eq!(m.completed_in_deadline + m.completed_late, 0);
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped,
            "every request still gets exactly one terminal event"
        );
    }

    #[test]
    fn kv_fault_bounces_one_request_per_epoch_and_conserves() {
        let cfg = ChaosConfig {
            seed: 11,
            kv_fail_prob: 1.0,
            ..ChaosConfig::default()
        };
        let m = run(Some(cfg));
        assert_eq!(m.offered, 24);
        assert!(m.completed_in_deadline + m.completed_late > 0, "rest of batch executes");
        assert!(m.dropped > 0, "one victim per non-empty epoch");
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped
        );
    }

    #[test]
    fn panic_fault_panics_deterministically() {
        let cfg = ChaosConfig {
            seed: 5,
            panic_prob: 1.0,
            ..ChaosConfig::default()
        };
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run(Some(cfg))));
        assert!(boom.is_err(), "p=1 panic fires on the first epoch");
    }

    #[test]
    fn same_seed_same_faults_different_seed_different_faults() {
        let cfg = ChaosConfig {
            seed: 99,
            error_prob: 0.5,
            ..ChaosConfig::default()
        };
        let a = run(Some(cfg));
        let b = run(Some(cfg));
        assert_eq!(a, b, "same chaos seed → bit-identical metrics");
        let c = run(Some(ChaosConfig { seed: 100, ..cfg }));
        assert_ne!(
            (a.completed_in_deadline, a.dropped),
            (c.completed_in_deadline, c.dropped),
            "different chaos seed → different fault schedule (with these probs)"
        );
    }

    #[test]
    fn chaos_streams_split_by_shard_and_generation() {
        assert_eq!(chaos_stream(7, 0, 0), 7, "shard 0 gen 0 keeps the seed");
        assert_ne!(chaos_stream(7, 0, 0), chaos_stream(7, 0, 1));
        assert_ne!(chaos_stream(7, 1, 0), chaos_stream(7, 2, 0));
        assert_ne!(chaos_stream(7, 1, 0), chaos_stream(7, 1, 1));
        assert_eq!(chaos_stream(7, 3, 2), chaos_stream(7, 3, 2));
    }

    #[test]
    fn backoff_shapes_are_capped_doubling() {
        assert_eq!(
            (0..7).map(backoff_epochs).collect::<Vec<_>>(),
            vec![1, 2, 4, 8, 8, 8, 8]
        );
        assert_eq!(restart_backoff_ms(0), 1);
        assert_eq!(restart_backoff_ms(8), 256);
        assert_eq!(restart_backoff_ms(9), 500);
        assert_eq!(restart_backoff_ms(40), 500);
    }
}
