//! Sharded multi-deployment serving — one [`EpochDriver`] per GPU
//! partition behind a dispatch layer (the last big ROADMAP scaling item,
//! unlocked by the PR 1 driver refactor).
//!
//! The paper schedules a single deployment's GPU pool; its own multi-LLM
//! extension (`coordinator::multi`) already *partitions* GPUs across
//! deployments but was schedule-only. This module drives N partitions
//! through the full epoch protocol: the edge node hosts several
//! (model, quantization) deployments, each shard owns one partition — its
//! own [`EpochDriver`], [`ExecutionBackend`], scheduler, RNG stream and
//! [`Metrics`] — and a dispatch layer routes arrivals and re-balances GPU
//! headroom between epochs.
//!
//! ## Routing
//!
//! Every arrival names a *deployment affinity* (which model/quant it wants).
//! Dispatch picks the least-loaded shard (queue depth, ties to the lowest
//! shard index) among the shards hosting that deployment whose quantization
//! admits the request's accuracy requirement (constraint 1e). When no
//! affinity shard can admit it, the request spills over to the least-loaded
//! *feasible* shard of any deployment; when nothing at all can serve it, it
//! still lands on the affinity shard so the driver's admission step rejects
//! it and accounting closes — every arrival lands in exactly one shard,
//! always (property-tested in `tests/proptest_sharded.rs`).
//!
//! ## Re-partitioning (headroom moves, in-flight work never does)
//!
//! Between epochs the dispatch layer re-apportions the GPU pool from
//! observed per-shard demand (queued FLOPs weighted by each deployment's β)
//! under the configured [`PartitionPolicy`], with two guarantees:
//!
//! - **min-1**: every shard keeps at least one GPU
//!   ([`partition_gpus_by_load`] returns a typed error otherwise);
//! - **KV-safe handoff**: a shard never shrinks below
//!   [`ExecutionBackend::min_gpus_for_inflight`] — the continuous backend
//!   pins the GPUs its in-flight KV reservations occupy, so only *headroom*
//!   migrates and running batches are never squeezed out of memory. When the
//!   floors cannot be honored (every GPU pinned), the partition stays put
//!   for that epoch.
//!
//! ## Determinism
//!
//! Shards step **in parallel** via `std::thread::scope`, and the result is
//! bit-identical to stepping them sequentially: each shard's RNG stream is
//! split from the run seed by shard index (shard 0 inherits the run stream,
//! which is what makes a 1-shard run bit-identical to the unsharded
//! driver — `tests/sharded_e2e.rs`), shards share no mutable state during a
//! step, and metrics merge in fixed shard-index order.

//! ## Supervision (opt-in, [`ShardedDriver::with_supervision`])
//!
//! A supervised driver wraps every shard step in `catch_unwind` and runs a
//! per-shard state machine `Healthy → Degraded → Restarting → Healthy`
//! (or `→ Parked` after repeated crash-loops):
//!
//! - **crash**: the panic is caught, the shard turns `Degraded`, its lost
//!   in-flight work is accounted by conservation subtraction into
//!   [`Metrics::shard_failed`], and its *queued-but-not-admitted* requests
//!   are redispatched to surviving same-deployment shards through the same
//!   affinity/least-loaded rule as arrivals (KV-safe: in-flight work never
//!   migrates — it is failed, not moved);
//! - **restart**: after a capped-doubling backoff in epochs
//!   ([`crate::driver::chaos::backoff_epochs`]) the shard is rebuilt — fresh
//!   backend and scheduler from the stored factories, fresh driver with its
//!   RNG stream split by restart generation — and its metrics carry over;
//! - **park**: three consecutive *quick* crashes (an incarnation that died
//!   within its first two epochs) trip the circuit breaker; the shard stays
//!   down and routing permanently avoids it. A sparse random fault schedule
//!   never parks (survival between faults resets the counter); a genuine
//!   crash-loop does.
//!
//! Unsupervised drivers take none of these paths — not even the
//! `catch_unwind` — so the bit-parity contracts above are untouched.

//! ## Elasticity (opt-in, [`ElasticPolicy`] via [`DriverBuilder`])
//!
//! Three independent mechanisms, all off by default (an elastic-off driver
//! at fixed shard count is bit-identical to the pre-elastic module):
//!
//! - **Heterogeneous topologies** ([`ClusterTopology`]): each shard carries
//!   its own [`GpuSpec`], so the cost model, DFTSP feasibility and the KV
//!   ledger all see the shard's real per-GPU FLOPs/memory. Shards with an
//!   identical spec form a *migration group*; re-partitioning apportions
//!   headroom group-wise (a TX2 never becomes an Orin). A homogeneous
//!   topology is one group — group-wise apportionment then reduces
//!   bit-for-bit to the old single-pool apportionment.
//! - **Work stealing** ([`ElasticPolicy::stealing`]): after re-partitioning
//!   and before the fan-out, under-loaded shards pull *queued* (never
//!   in-flight) requests from overloaded same-deployment shards. Donor
//!   choice is deterministic (deepest queue, ties to the lowest index), the
//!   moved entry is the donor's newest arrival (strict FCFS among its
//!   remaining waiters), a steal must strictly reduce FLOPs-normalized
//!   imbalance, and the thief's backend must pass its KV gate
//!   ([`ExecutionBackend::can_admit`]) — stolen work is never parked behind
//!   an admission gate that cannot open. `offered` moves with the request
//!   (donor decrements, thief's `offer` re-counts) so conservation closes.
//! - **Autoscaling + epoch tuning** ([`AutoscalePolicy`],
//!   [`EpochTunePolicy`]): one scaling action per epoch tick (the
//!   psyche-style phase-tick rule). When queued demand exceeds what the
//!   fleet clears in an epoch, the most-loaded shard is cloned (same
//!   deployment and spec, bootstrap GPU borrowed inside its migration
//!   group); when demand collapses, the least-loaded *idle* shard (empty
//!   queue, idle backend — KV-safe) retires and its GPUs return to the
//!   group. Retired metrics are preserved and merged first. The epoch tuner
//!   watches `Metrics::epoch_overruns`: overruns grow the epoch, a calm
//!   streak shrinks it, both clamped. Autoscaling is incompatible with
//!   supervision (health state is indexed per shard) — the builder rejects
//!   the combination.

use crate::cluster::{ClusterSpec, ClusterTopology, GpuSpec};
use crate::coordinator::{
    partition_gpus_by_load, Deployment, EpochParams, PartitionError, PartitionPolicy, Scheduler,
};
use crate::driver::chaos::{backoff_epochs, chaos_stream};
use crate::driver::{
    DriverPolicy, EpochDriver, ExecutionBackend, InstanceTemplate, SPadPolicy, StalePolicy,
};
use crate::metrics::Metrics;
use crate::model::CostModel;
use crate::request::Request;
use crate::util::rng::{splitmix64, Rng};
use crate::wireless::{AllocationPolicy, ChannelParams, RadioParams};

/// Everything the dispatch layer needs to stand up its shards. Assembled
/// by [`DriverBuilder`] — call sites should go through the builder rather
/// than filling this struct positionally.
#[derive(Debug, Clone)]
pub struct ShardedConfig {
    /// One entry per shard: the (model, quantization) pair it serves.
    /// Several shards may host the same deployment (pure data-parallel
    /// scale-out); routing then balances across them.
    pub deployments: Vec<Deployment>,
    /// The GPU pool being partitioned, one
    /// [`ShardSpec`](crate::cluster::ShardSpec) per shard (same
    /// order and length as `deployments`). Use
    /// [`ClusterTopology::homogeneous`] for the legacy single-`ClusterSpec`
    /// shape.
    pub topology: ClusterTopology,
    pub partition: PartitionPolicy,
    /// Per-shard epoch-protocol policy (stale rule, s', allocation).
    pub policy: DriverPolicy,
    pub epoch: EpochParams,
    pub radio: RadioParams,
    pub channel: ChannelParams,
    /// Run seed; shard i draws from a stream split off it (shard 0 keeps
    /// the run stream itself — the 1-shard parity contract).
    pub seed: u64,
    /// Work stealing / autoscaling / epoch tuning (module docs §Elastic).
    /// All off by [`Default`]. Note `autoscale` needs shard factories and
    /// is therefore armed only through [`DriverBuilder::build`] — a config
    /// handed straight to [`ShardedDriver::new`] runs with stealing and
    /// epoch tuning only.
    pub elastic: ElasticPolicy,
}

/// Opt-in elastic behaviors (module docs §Elastic). `Default` turns every
/// mechanism off, which is the bit-parity configuration.
#[derive(Debug, Clone, Default)]
pub struct ElasticPolicy {
    /// Cross-shard work stealing at the epoch boundary.
    pub stealing: bool,
    /// Between-epoch shard autoscaling ([`DriverBuilder`] only).
    pub autoscale: Option<AutoscalePolicy>,
    /// Epoch-duration auto-tuning from observed `epoch_overruns`.
    pub tune_epoch: Option<EpochTunePolicy>,
}

/// Shard-count autoscaling bounds and thresholds. Utilization is queued
/// β-weighted FLOPs over the FLOPs the fleet's partitions deliver in one
/// epoch (demand the next epoch cannot clear ⇒ > 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscalePolicy {
    pub min_shards: usize,
    pub max_shards: usize,
    /// Scale up when fleet utilization exceeds this (default 1.0 — more
    /// than one epoch's worth of work is queued).
    pub scale_up_ratio: f64,
    /// Scale down when fleet utilization falls below this (default 0.25).
    pub scale_down_ratio: f64,
}

impl AutoscalePolicy {
    pub fn new(min_shards: usize, max_shards: usize) -> Self {
        AutoscalePolicy {
            min_shards: min_shards.max(1),
            max_shards: max_shards.max(min_shards.max(1)),
            scale_up_ratio: 1.0,
            scale_down_ratio: 0.25,
        }
    }
}

/// Epoch-duration auto-tuning: grow on observed overruns, shrink after a
/// calm streak, clamped to `[min_duration, max_duration]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpochTunePolicy {
    pub min_duration: f64,
    pub max_duration: f64,
    /// Multiplier applied when the last epoch overran (default 1.25).
    pub grow: f64,
    /// Multiplier applied after `calm_epochs` overrun-free epochs
    /// (default 0.9).
    pub shrink: f64,
    /// Overrun-free epochs before the duration shrinks (default 4).
    pub calm_epochs: u64,
}

impl EpochTunePolicy {
    pub fn new(min_duration: f64, max_duration: f64) -> Self {
        assert!(min_duration > 0.0 && max_duration >= min_duration);
        EpochTunePolicy {
            min_duration,
            max_duration,
            grow: 1.25,
            shrink: 0.9,
            calm_epochs: 4,
        }
    }
}

/// Least-loaded pick among candidate shard indices: minimum load, ties to
/// the lowest index. The one routing primitive shared by the simulator's
/// dispatch layer ([`ShardedDriver::offer`]) and the TCP front-end's
/// model-name router (`serving::net::Router`) — both implement
/// "affinity → least-loaded" in terms of this, so their tie-breaking
/// cannot diverge.
pub fn pick_least_loaded<I, L>(candidates: I, load: L) -> Option<usize>
where
    I: Iterator<Item = usize>,
    L: Fn(usize) -> usize,
{
    candidates.min_by_key(|&i| (load(i), i))
}

/// Per-shard RNG stream: shard 0 inherits the run stream bit-for-bit;
/// shard i > 0 gets an independent SplitMix64-derived stream.
fn shard_stream(seed: u64, shard: u64) -> u64 {
    if shard == 0 {
        return seed;
    }
    let mut s = seed ^ shard.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s)
}

/// One GPU partition: a deployment, its epoch driver, execution backend and
/// scheduler.
pub struct Shard<P, B> {
    pub deployment: Deployment,
    pub driver: EpochDriver<P>,
    pub backend: B,
    scheduler: Box<dyn Scheduler + Send>,
}

impl<P, B: ExecutionBackend<Payload = P>> Shard<P, B> {
    fn step(&mut self, now: f64) {
        let sched: &mut dyn Scheduler = &mut *self.scheduler;
        self.driver.step_epoch(sched, &mut self.backend, now);
    }
}

/// Supervisor view of one shard (module docs §Supervision).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardHealth {
    /// Serving: routed to and stepped.
    Healthy,
    /// Crashed this epoch; the supervisor schedules its restart next epoch.
    Degraded,
    /// Waiting out its restart backoff; rebuilt when `at_epoch` is reached.
    Restarting { at_epoch: u64 },
    /// Circuit breaker tripped: crash-looped, permanently out of rotation.
    Parked,
}

/// Consecutive quick crashes (incarnation died within its first two epochs)
/// that park a shard. Shared with the live serving supervisor
/// ([`crate::serving::serve_sharded`]) so both layers trip at the same
/// crash-loop threshold.
pub const PARK_AFTER_QUICK_CRASHES: u32 = 3;

/// Everything a supervised driver needs to rebuild a crashed shard: the
/// boxed factories plus the per-shard construction parameters `new` would
/// otherwise have consumed.
struct Supervision<B> {
    make_backend: Box<dyn FnMut(&InstanceTemplate, usize, u64) -> B>,
    make_scheduler: Box<dyn FnMut(usize) -> Box<dyn Scheduler + Send>>,
    policy: DriverPolicy,
    epoch: EpochParams,
    radio: RadioParams,
    channel: ChannelParams,
    seed: u64,
    health: Vec<ShardHealth>,
    /// Restart generation per shard (0 = the original incarnation); splits
    /// the rebuilt driver's RNG stream so replays stay deterministic.
    generation: Vec<u64>,
    /// Consecutive quick-crash count per shard (reset by an incarnation
    /// that survives past its second epoch).
    quick_crashes: Vec<u32>,
    /// Global epoch index at which the current incarnation started.
    born_epoch: Vec<u64>,
}

/// Everything the autoscaler needs to stand up a *new* shard between
/// epochs: the boxed factories plus the driver construction parameters
/// (the spawned shard's deployment, [`GpuSpec`] and epoch params are cloned
/// from the shard it scales out — so it inherits a tuned epoch duration).
struct Autoscaler<B> {
    policy: AutoscalePolicy,
    make_backend: Box<dyn FnMut(&InstanceTemplate) -> B>,
    make_scheduler: Box<dyn FnMut(usize) -> Box<dyn Scheduler + Send>>,
    driver_policy: DriverPolicy,
    radio: RadioParams,
    channel: ChannelParams,
    seed: u64,
    /// Next per-shard RNG stream id ([`shard_stream`]); starts at the
    /// initial shard count, so spawned shards draw fresh deterministic
    /// streams that never collide with the founding shards'.
    next_stream: u64,
}

/// Epoch-duration tuner state (module docs §Elastic).
struct EpochTuner {
    policy: EpochTunePolicy,
    duration: f64,
    /// Fleet-total `epoch_overruns` at the last tick (retired shards
    /// included, so retirement never fakes a delta).
    last_overruns: u64,
    calm: u64,
}

/// The dispatch layer: owns one [`EpochDriver`] per GPU partition, routes
/// arrivals, re-partitions headroom between epochs and steps the shards in
/// parallel (module docs).
pub struct ShardedDriver<P, B> {
    shards: Vec<Shard<P, B>>,
    /// Per-shard GPU model (same length/order as `shards`); equal specs
    /// form a migration group.
    gpu_specs: Vec<GpuSpec>,
    total_gpus: usize,
    partition: PartitionPolicy,
    gpus: Vec<usize>,
    epoch_idx: u64,
    supervise: Option<Supervision<B>>,
    /// Elastic mechanisms (module docs §Elastic); all dormant by default.
    stealing: bool,
    autoscale: Option<Autoscaler<B>>,
    tuner: Option<EpochTuner>,
    /// Frozen metrics of autoscale-retired shards, in retirement order;
    /// merged ahead of live shards so no served request ever disappears
    /// from the aggregate.
    retired: Vec<Metrics>,
}

/// Raise every below-floor entry to its floor by taking GPUs from the
/// largest-surplus donors (ties to the lowest index). Caller guarantees
/// `Σ floors ≤ Σ alloc`, so the loop always finds a donor and terminates
/// with the total preserved.
fn apply_floors(mut alloc: Vec<usize>, floors: &[usize]) -> Vec<usize> {
    loop {
        let Some(need) = (0..alloc.len()).find(|&i| alloc[i] < floors[i]) else {
            return alloc;
        };
        let donor = (0..alloc.len())
            .filter(|&i| alloc[i] > floors[i])
            .max_by_key(|&i| (alloc[i] - floors[i], usize::MAX - i))
            .expect("sum(floors) <= sum(alloc): a deficit implies a surplus");
        alloc[donor] -= 1;
        alloc[need] += 1;
    }
}

impl<P, B: ExecutionBackend<Payload = P>> ShardedDriver<P, B> {
    /// Stand up one shard per deployment. The initial partition apportions
    /// the pool under `cfg.partition` with zero observed demand (i.e.
    /// near-equal); demand-driven re-partitioning takes over from the first
    /// epoch. Returns the typed [`PartitionError`] when the pool cannot
    /// give every deployment its guaranteed GPU.
    pub fn new(
        cfg: ShardedConfig,
        mut make_backend: impl FnMut(&InstanceTemplate) -> B,
        mut make_scheduler: impl FnMut(usize) -> Box<dyn Scheduler + Send>,
    ) -> Result<Self, PartitionError> {
        let mut mb = |t: &InstanceTemplate, _shard: usize, _gen: u64| make_backend(t);
        Self::construct(cfg, &mut mb, &mut make_scheduler, false)
    }

    /// Like [`ShardedDriver::new`], but with the supervision layer armed
    /// (module docs §Supervision): shard steps run under `catch_unwind`, a
    /// crashed shard's queue is redispatched and the shard is rebuilt from
    /// the given factories under backoff. The factories take `'static`
    /// ownership because they outlive construction; `make_backend`
    /// additionally receives the shard index and restart generation so
    /// chaos-wrapped backends can split their fault streams
    /// ([`crate::driver::chaos::chaos_stream`]).
    pub fn with_supervision(
        cfg: ShardedConfig,
        mut make_backend: impl FnMut(&InstanceTemplate, usize, u64) -> B + 'static,
        mut make_scheduler: impl FnMut(usize) -> Box<dyn Scheduler + Send> + 'static,
    ) -> Result<Self, PartitionError> {
        assert!(
            cfg.elastic.autoscale.is_none(),
            "autoscaling is incompatible with supervision (health state is indexed per shard)"
        );
        let (policy, epoch, radio, channel, seed) = (
            cfg.policy,
            cfg.epoch.clone(),
            cfg.radio.clone(),
            cfg.channel.clone(),
            cfg.seed,
        );
        let mut sd = Self::construct(cfg, &mut make_backend, &mut make_scheduler, true)?;
        let k = sd.shards.len();
        sd.supervise = Some(Supervision {
            make_backend: Box::new(make_backend),
            make_scheduler: Box::new(make_scheduler),
            policy,
            epoch,
            radio,
            channel,
            seed,
            health: vec![ShardHealth::Healthy; k],
            generation: vec![0; k],
            quick_crashes: vec![0; k],
            born_epoch: vec![0; k],
        });
        Ok(sd)
    }

    fn construct(
        cfg: ShardedConfig,
        make_backend: &mut dyn FnMut(&InstanceTemplate, usize, u64) -> B,
        make_scheduler: &mut dyn FnMut(usize) -> Box<dyn Scheduler + Send>,
        _supervised: bool,
    ) -> Result<Self, PartitionError> {
        let k = cfg.deployments.len();
        assert_eq!(
            cfg.topology.shard_count(),
            k,
            "one topology entry per deployment (shard)"
        );
        for (i, s) in cfg.topology.shards.iter().enumerate() {
            assert!(
                s.gpu.flops.is_finite() && s.gpu.flops > 0.0 && s.gpu.mem_bytes > 0,
                "topology shard {i} has a degenerate GpuSpec"
            );
        }
        // Initial apportionment: zero observed demand (near-equal), one
        // migration group at a time. A homogeneous topology is a single
        // group over the whole pool — bit-identical to the pre-topology
        // global apportionment. An undersized group (fewer GPUs than
        // members) surfaces as the group-local `InsufficientGpus`.
        let total_gpus = cfg.topology.total_gpus();
        let mut gpus = vec![0usize; k];
        for group in cfg.topology.groups() {
            let group_total: usize = group
                .iter()
                .map(|&i| cfg.topology.shards[i].num_gpus)
                .sum();
            let alloc =
                partition_gpus_by_load(&vec![0.0; group.len()], group_total, cfg.partition)?;
            for (slot, &i) in group.iter().enumerate() {
                gpus[i] = alloc[slot];
            }
        }
        let gpu_specs: Vec<GpuSpec> = cfg
            .topology
            .shards
            .iter()
            .map(|s| s.gpu.clone())
            .collect();
        let epoch_duration = cfg.epoch.duration;
        let mut shards = Vec::with_capacity(k);
        for (i, dep) in cfg.deployments.into_iter().enumerate() {
            let template = InstanceTemplate {
                cost: CostModel::new(dep.model.clone()),
                quant: dep.quant.clone(),
                cluster: ClusterSpec::new(gpu_specs[i].clone(), gpus[i]),
                epoch: cfg.epoch.clone(),
            };
            let backend = make_backend(&template, i, 0);
            let driver = EpochDriver::new(
                template,
                cfg.policy,
                cfg.radio.clone(),
                cfg.channel.clone(),
                Rng::new(shard_stream(cfg.seed, i as u64)),
            );
            shards.push(Shard {
                deployment: dep,
                driver,
                backend,
                scheduler: make_scheduler(i),
            });
        }
        Ok(ShardedDriver {
            shards,
            gpu_specs,
            total_gpus,
            partition: cfg.partition,
            gpus,
            epoch_idx: 0,
            supervise: None,
            stealing: cfg.elastic.stealing,
            autoscale: None,
            tuner: cfg.elastic.tune_epoch.map(|p| EpochTuner {
                policy: p,
                duration: epoch_duration.clamp(p.min_duration, p.max_duration),
                last_overruns: 0,
                calm: 0,
            }),
            retired: Vec::new(),
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Current GPU counts, by shard index (always sums to the pool size).
    pub fn partition(&self) -> &[usize] {
        &self.gpus
    }

    /// Per-shard GPU models, by shard index (equal specs = one migration
    /// group).
    pub fn gpu_specs(&self) -> &[GpuSpec] {
        &self.gpu_specs
    }

    /// The current epoch length — the configured duration, or the tuner's
    /// latest choice. Callers driving wall-clock loops must advance `now`
    /// by this (re-read every epoch) rather than a fixed constant.
    pub fn epoch_duration(&self) -> f64 {
        match &self.tuner {
            Some(t) => t.duration,
            None => self.shards[0].driver.epoch_duration(),
        }
    }

    pub fn shards(&self) -> &[Shard<P, B>] {
        &self.shards
    }

    pub fn epoch_idx(&self) -> u64 {
        self.epoch_idx
    }

    /// Is shard `i` in rotation? Unsupervised drivers have no health state
    /// — every shard always is.
    fn shard_is_healthy(&self, i: usize) -> bool {
        match &self.supervise {
            Some(sup) => sup.health[i] == ShardHealth::Healthy,
            None => true,
        }
    }

    /// Pick the shard an arrival should land on (module docs: affinity
    /// first, least-loaded within the deployment, accuracy-feasible
    /// spill-over, affinity fallback so rejection is still accounted).
    /// Under supervision, non-`Healthy` shards are skipped; when no healthy
    /// shard admits the request, any healthy shard takes it (its driver
    /// rejects it typed and accounting closes), and only with *every* shard
    /// down does the affinity shard queue it until a restart.
    fn route(&self, req: &Request, affinity: usize) -> usize {
        let aff = affinity.min(self.shards.len() - 1);
        let healthy = |i: usize| self.shard_is_healthy(i);
        let admits = |i: usize| {
            let d = &self.shards[i].deployment;
            d.quant.satisfies_accuracy(&d.model.name, req.accuracy_req)
        };
        let load = |i: usize| self.shards[i].driver.queue_len();
        let target = &self.shards[aff].deployment;
        let same = (0..self.shards.len())
            .filter(|&i| healthy(i) && admits(i) && self.shards[i].deployment.same_as(target));
        if let Some(i) = pick_least_loaded(same, load) {
            return i;
        }
        let feasible = (0..self.shards.len()).filter(|&i| healthy(i) && admits(i));
        if let Some(i) = pick_least_loaded(feasible, load) {
            return i;
        }
        if self.supervise.is_some() {
            // Supervised-only fallback: an unhealthy affinity shard must not
            // black-hole requests another shard could at least answer with a
            // typed rejection.
            let any = (0..self.shards.len()).filter(|&i| healthy(i));
            if let Some(i) = pick_least_loaded(any, load) {
                return i;
            }
        }
        aff
    }

    /// Admit a request: route it to exactly one shard's queue. `affinity`
    /// is the index of the deployment the caller wants (clamped into
    /// range); the chosen shard index is returned.
    pub fn offer(&mut self, req: Request, payload: P, affinity: usize) -> usize {
        let shard = self.route(&req, affinity);
        self.shards[shard].driver.offer(req, payload);
        shard
    }

    /// Queued β-weighted FLOPs per shard — the demand signal shared by
    /// re-partitioning, work stealing and the autoscaler.
    fn queued_weights(&self) -> Vec<f64> {
        self.shards
            .iter()
            .map(|s| {
                s.driver
                    .queued_requests()
                    .map(|r| s.deployment.req_weight(r.prompt_tokens, r.output_tokens))
                    .sum()
            })
            .collect()
    }

    /// Shard indices partitioned by [`GpuSpec`] equality (first-occurrence
    /// order, members ascending) — recomputed per boundary because
    /// autoscaling changes the shard set.
    fn migration_groups(&self) -> Vec<Vec<usize>> {
        let mut groups: Vec<(&GpuSpec, Vec<usize>)> = Vec::new();
        for (i, spec) in self.gpu_specs.iter().enumerate() {
            match groups.iter_mut().find(|(g, _)| *g == spec) {
                Some((_, members)) => members.push(i),
                None => groups.push((spec, vec![i])),
            }
        }
        groups.into_iter().map(|(_, m)| m).collect()
    }

    /// Re-apportion each migration group's GPUs from observed queued
    /// demand, clamped to each backend's KV-safety floor. GPUs never cross
    /// groups (the devices are not interchangeable). No-ops for a
    /// single-shard group, when every GPU in a group is pinned by in-flight
    /// work, or when the apportionment is unchanged.
    fn repartition(&mut self) {
        if self.shards.len() <= 1 {
            return;
        }
        let loads = self.queued_weights();
        let healthy: Vec<bool> = (0..self.shards.len())
            .map(|i| self.shard_is_healthy(i))
            .collect();
        let floors: Vec<usize> = self
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                // A crashed shard's backend is gone with its KV state (its
                // in-flight work was failed, not preserved): pin nothing
                // beyond the min-1 guarantee and let survivors take the
                // headroom.
                if healthy[i] {
                    s.backend.min_gpus_for_inflight().clamp(1, self.total_gpus)
                } else {
                    1
                }
            })
            .collect();
        let mut alloc = self.gpus.clone();
        for group in self.migration_groups() {
            if group.len() <= 1 {
                continue;
            }
            let group_total: usize = group.iter().map(|&i| self.gpus[i]).sum();
            let g_loads: Vec<f64> = group.iter().map(|&i| loads[i]).collect();
            let Ok(desired) = partition_gpus_by_load(&g_loads, group_total, self.partition)
            else {
                continue; // group shrank below min-1 — cannot happen once up
            };
            let g_floors: Vec<usize> = group.iter().map(|&i| floors[i]).collect();
            if g_floors.iter().sum::<usize>() > group_total {
                continue; // every group GPU pinned in flight: no safe handoff
            }
            let g_alloc = apply_floors(desired, &g_floors);
            for (slot, &i) in group.iter().enumerate() {
                alloc[i] = g_alloc[slot];
            }
        }
        if alloc == self.gpus {
            return;
        }
        for (i, shard) in self.shards.iter_mut().enumerate() {
            if alloc[i] != self.gpus[i] {
                let cluster = ClusterSpec::new(self.gpu_specs[i].clone(), alloc[i]);
                shard.driver.set_cluster(cluster.clone());
                // A dead backend is never poked; its replacement is built
                // against the current partition at restart.
                if healthy[i] {
                    shard.backend.cluster_resized(&cluster);
                }
            }
        }
        self.gpus = alloc;
    }

    /// One epoch across every shard: autoscale (one action per tick),
    /// re-partition from current demand, steal queued work onto idle
    /// shards, then step all shards in parallel and let the epoch tuner
    /// react to overruns. Deterministic regardless of thread interleaving —
    /// shards are fully independent within a step and all cross-shard
    /// decisions (routing, autoscaling, re-partitioning, stealing) happen
    /// before the fan-out. Supervised drivers additionally advance the
    /// supervisor state machine at the boundary (restarts due, parks), step
    /// only `Healthy` shards under `catch_unwind`, and handle any crashes
    /// in shard order after the fan-out (module docs §Supervision). With
    /// every elastic mechanism off this reduces exactly to
    /// pre-step → repartition → fan-out, the bit-parity path.
    pub fn step_epoch(&mut self, now: f64)
    where
        P: Send,
        B: Send,
    {
        self.autoscale_tick();
        if self.supervise.is_some() {
            self.supervisor_pre_step();
        }
        self.repartition();
        if self.stealing {
            self.steal_pass();
        }
        if self.supervise.is_some() {
            let crashed = self.step_supervised(now);
            // Mark every crash before redispatching anything: two shards
            // dying in the same epoch must not redispatch onto each other.
            for &i in &crashed {
                self.mark_crashed(i);
            }
            for &i in &crashed {
                self.fail_and_redispatch(i);
            }
        } else if self.shards.len() == 1 {
            self.shards[0].step(now);
        } else {
            let shards = &mut self.shards;
            std::thread::scope(|scope| {
                for shard in shards.iter_mut() {
                    scope.spawn(move || shard.step(now));
                }
            });
        }
        self.tune_epoch_tick();
        self.epoch_idx += 1;
    }

    /// Cross-shard work stealing (module docs §Elastic). Runs after
    /// re-partitioning, before the fan-out; purely deterministic. Each
    /// healthy thief (ascending index) repeatedly takes the newest queued
    /// entry from the deepest-queued healthy same-deployment donor (ties to
    /// the lowest index) while the move strictly reduces FLOPs-normalized
    /// imbalance and the thief's backend KV gate admits the request. Only
    /// queued entries move — in-flight work never migrates (the KV-safety
    /// rule) — and `offered` travels with the request, so per-shard and
    /// merged conservation both keep closing.
    fn steal_pass(&mut self) {
        let k = self.shards.len();
        if k <= 1 {
            return;
        }
        let cap: Vec<f64> = (0..k)
            .map(|i| self.gpus[i] as f64 * self.gpu_specs[i].flops)
            .collect();
        let mut weight = self.queued_weights();
        for t in 0..k {
            if !self.shard_is_healthy(t) {
                continue;
            }
            loop {
                let donor = (0..k)
                    .filter(|&d| {
                        d != t
                            && self.shard_is_healthy(d)
                            && self.shards[d].driver.queue_len() > 0
                            && self.shards[d]
                                .deployment
                                .same_as(&self.shards[t].deployment)
                    })
                    .max_by_key(|&d| (self.shards[d].driver.queue_len(), usize::MAX - d));
                let Some(d) = donor else {
                    break;
                };
                let Some(req) = self.shards[d].driver.back_request() else {
                    break;
                };
                let w = self.shards[d]
                    .deployment
                    .req_weight(req.prompt_tokens, req.output_tokens);
                // Strict-improvement rule: after the move the thief must
                // still be less loaded (per FLOP of its partition) than the
                // donor is now — this both targets genuinely idle capacity
                // and guarantees termination.
                if (weight[t] + w) / cap[t] >= weight[d] / cap[d] {
                    break;
                }
                if !self.shards[t].backend.can_admit(req) {
                    break;
                }
                let Some(entry) = self.shards[d].driver.steal_from_back() else {
                    break;
                };
                let dm = &mut self.shards[d].driver.metrics;
                dm.offered = dm.offered.saturating_sub(1);
                self.shards[t].driver.offer(entry.req, entry.payload);
                self.shards[t].driver.metrics.requests_stolen += 1;
                weight[d] -= w;
                weight[t] += w;
            }
        }
    }

    /// One autoscaling action per epoch tick (module docs §Elastic): scale
    /// out the most-loaded shard when queued demand exceeds what the fleet
    /// clears in an epoch, or retire the least-loaded *idle* shard when
    /// demand collapses. Armed only through [`DriverBuilder`].
    fn autoscale_tick(&mut self) {
        let Some(policy) = self.autoscale.as_ref().map(|a| a.policy) else {
            return;
        };
        let k = self.shards.len();
        let weight = self.queued_weights();
        let cap: Vec<f64> = (0..k)
            .map(|i| {
                self.gpus[i] as f64
                    * self.gpu_specs[i].flops
                    * self.shards[i].driver.epoch_duration()
            })
            .collect();
        let total_cap: f64 = cap.iter().sum();
        let util = weight.iter().sum::<f64>() / total_cap.max(f64::MIN_POSITIVE);
        if util > policy.scale_up_ratio && k < policy.max_shards {
            // Source = most-utilized shard; its bootstrap GPU comes from
            // the same migration group's largest above-floor surplus (the
            // source itself qualifies), so the spawn is KV-safe. No donor →
            // every group GPU pinned → no action this tick.
            let mut order: Vec<usize> = (0..k).collect();
            order.sort_by(|&a, &b| {
                (weight[b] / cap[b].max(f64::MIN_POSITIVE))
                    .total_cmp(&(weight[a] / cap[a].max(f64::MIN_POSITIVE)))
                    .then(a.cmp(&b))
            });
            for src in order {
                if let Some(donor) = self.bootstrap_donor(src) {
                    self.spawn_shard(src, donor);
                    return;
                }
            }
        } else if util < policy.scale_down_ratio && k > policy.min_shards.max(1) {
            // Victim = least-utilized shard that is fully idle (empty
            // queue, idle backend — KV-safe), leaves its deployment served
            // and has a same-group heir for its GPUs. Ties retire the
            // highest index (latest spawn) to minimize index churn.
            let victim = (0..k)
                .filter(|&i| {
                    self.shards[i].driver.queue_len() == 0
                        && self.shards[i].backend.is_idle()
                        && (0..k).any(|j| {
                            j != i && self.shards[j].deployment.same_as(&self.shards[i].deployment)
                        })
                        && (0..k).any(|j| j != i && self.gpu_specs[j] == self.gpu_specs[i])
                })
                .min_by(|&a, &b| {
                    (weight[a] / cap[a].max(f64::MIN_POSITIVE))
                        .total_cmp(&(weight[b] / cap[b].max(f64::MIN_POSITIVE)))
                        .then(b.cmp(&a))
                });
            if let Some(v) = victim {
                self.retire_shard(v);
            }
        }
    }

    /// The same-group donor for a spawned shard's bootstrap GPU: largest
    /// above-floor surplus with at least 2 GPUs, ties to the lowest index
    /// (the source shard itself qualifies).
    fn bootstrap_donor(&self, src: usize) -> Option<usize> {
        (0..self.shards.len())
            .filter(|&d| {
                self.gpu_specs[d] == self.gpu_specs[src] && self.gpus[d] >= 2 && {
                    let floor = self.shards[d].backend.min_gpus_for_inflight().max(1);
                    self.gpus[d] > floor
                }
            })
            .max_by_key(|&d| {
                let floor = self.shards[d].backend.min_gpus_for_inflight().max(1);
                (self.gpus[d] - floor, usize::MAX - d)
            })
    }

    /// Stand up a clone of shard `src` (same deployment, spec and epoch
    /// params — a tuned epoch duration carries over) with one GPU borrowed
    /// from `donor`; the next repartition rebalances the group properly.
    fn spawn_shard(&mut self, src: usize, donor: usize) {
        let Some(auto) = self.autoscale.as_mut() else {
            return;
        };
        let stream = auto.next_stream;
        auto.next_stream += 1;
        let deployment = self.shards[src].deployment.clone();
        let spec = self.gpu_specs[src].clone();
        let template = InstanceTemplate {
            cost: CostModel::new(deployment.model.clone()),
            quant: deployment.quant.clone(),
            cluster: ClusterSpec::new(spec.clone(), 1),
            epoch: self.shards[src].driver.template().epoch.clone(),
        };
        let backend = (auto.make_backend)(&template);
        let driver = EpochDriver::new(
            template,
            auto.driver_policy,
            auto.radio.clone(),
            auto.channel.clone(),
            Rng::new(shard_stream(auto.seed, stream)),
        );
        let scheduler = (auto.make_scheduler)(self.shards.len());
        let donor_cluster = ClusterSpec::new(self.gpu_specs[donor].clone(), self.gpus[donor] - 1);
        self.gpus[donor] -= 1;
        self.shards[donor].driver.set_cluster(donor_cluster.clone());
        self.shards[donor].backend.cluster_resized(&donor_cluster);
        let mut shard = Shard {
            deployment,
            driver,
            backend,
            scheduler,
        };
        shard.driver.metrics.shards_spawned += 1;
        self.shards.push(shard);
        self.gpus.push(1);
        self.gpu_specs.push(spec);
    }

    /// Retire a fully idle shard: its GPUs go to the lowest-index
    /// same-group survivor and its metrics freeze into `retired` (merged
    /// ahead of live shards), so nothing it ever served disappears.
    fn retire_shard(&mut self, victim: usize) {
        debug_assert!(self.shards[victim].driver.queue_len() == 0);
        debug_assert!(self.shards[victim].backend.is_idle());
        let heir = (0..self.shards.len())
            .find(|&i| i != victim && self.gpu_specs[i] == self.gpu_specs[victim])
            .expect("retire requires a same-group survivor");
        self.gpus[heir] += self.gpus[victim];
        let cluster = ClusterSpec::new(self.gpu_specs[heir].clone(), self.gpus[heir]);
        self.shards[heir].driver.set_cluster(cluster.clone());
        self.shards[heir].backend.cluster_resized(&cluster);
        let shard = self.shards.remove(victim);
        self.gpus.remove(victim);
        self.gpu_specs.remove(victim);
        let mut metrics = shard.driver.into_metrics();
        metrics.shards_retired += 1;
        self.retired.push(metrics);
    }

    /// Epoch-duration tuning tick, run after the fan-out: any new overrun
    /// grows the next epoch, a calm streak shrinks it, both clamped
    /// (module docs §Elastic).
    fn tune_epoch_tick(&mut self) {
        if self.tuner.is_none() {
            return;
        }
        let total: u64 = self
            .retired
            .iter()
            .map(|m| m.epoch_overruns)
            .sum::<u64>()
            + self
                .shards
                .iter()
                .map(|s| s.driver.metrics.epoch_overruns)
                .sum::<u64>();
        let t = self.tuner.as_mut().expect("guarded above");
        let overran = total > t.last_overruns;
        t.last_overruns = total;
        if overran {
            t.duration = (t.duration * t.policy.grow).min(t.policy.max_duration);
            t.calm = 0;
        } else {
            t.calm += 1;
            if t.calm >= t.policy.calm_epochs {
                t.duration = (t.duration * t.policy.shrink).max(t.policy.min_duration);
                t.calm = 0;
            }
        }
        let d = t.duration;
        for s in &mut self.shards {
            s.driver.set_epoch_duration(d);
        }
    }

    /// Advance the supervisor state machine at an epoch boundary: last
    /// epoch's crashes either trip the circuit breaker (`Parked`) or get a
    /// restart scheduled under capped-doubling backoff, and shards whose
    /// backoff has elapsed are rebuilt.
    fn supervisor_pre_step(&mut self) {
        let epoch = self.epoch_idx;
        for i in 0..self.shards.len() {
            let state = match &self.supervise {
                Some(sup) => sup.health[i],
                None => return,
            };
            match state {
                ShardHealth::Degraded => {
                    if let Some(sup) = self.supervise.as_mut() {
                        if sup.quick_crashes[i] >= PARK_AFTER_QUICK_CRASHES {
                            sup.health[i] = ShardHealth::Parked;
                            self.shards[i].driver.metrics.shards_parked += 1;
                        } else {
                            sup.health[i] = ShardHealth::Restarting {
                                at_epoch: epoch + backoff_epochs(sup.quick_crashes[i]),
                            };
                        }
                    }
                }
                ShardHealth::Restarting { at_epoch } if epoch >= at_epoch => {
                    self.rebuild_shard(i);
                }
                _ => {}
            }
        }
    }

    /// Step every `Healthy` shard under `catch_unwind`; returns the indices
    /// that panicked, in shard order (so crash handling is deterministic).
    fn step_supervised(&mut self, now: f64) -> Vec<usize>
    where
        P: Send,
        B: Send,
    {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let healthy: Vec<bool> = (0..self.shards.len())
            .map(|i| self.shard_is_healthy(i))
            .collect();
        let live = healthy.iter().filter(|&&h| h).count();
        let shards = &mut self.shards;
        let mut crashed = Vec::new();
        if live <= 1 {
            for (i, shard) in shards.iter_mut().enumerate() {
                if healthy[i] && catch_unwind(AssertUnwindSafe(|| shard.step(now))).is_err() {
                    crashed.push(i);
                }
            }
            return crashed;
        }
        std::thread::scope(|scope| {
            let mut joins = Vec::with_capacity(live);
            for (i, shard) in shards.iter_mut().enumerate() {
                if !healthy[i] {
                    continue;
                }
                let join =
                    scope.spawn(move || catch_unwind(AssertUnwindSafe(|| shard.step(now))).is_err());
                joins.push((i, join));
            }
            for (i, join) in joins {
                // `join` only errs when the wrapper itself panicked, which
                // `catch_unwind` prevents; treat it as a crash if it ever
                // does rather than tearing down the supervisor.
                if join.join().unwrap_or(true) {
                    crashed.push(i);
                }
            }
        });
        crashed
    }

    /// A shard panicked mid-step: record the crash and mark it `Degraded`
    /// (module docs §Supervision).
    fn mark_crashed(&mut self, i: usize) {
        let epoch = self.epoch_idx;
        if let Some(sup) = self.supervise.as_mut() {
            // A quick crash is an incarnation that died within its first two
            // epochs; surviving longer resets the crash-loop streak (this
            // crash then counts 0 — it proved the shard can serve).
            sup.quick_crashes[i] = if epoch.saturating_sub(sup.born_epoch[i]) < 2 {
                sup.quick_crashes[i] + 1
            } else {
                0
            };
            sup.health[i] = ShardHealth::Degraded;
        }
        self.shards[i].driver.metrics.shard_crashes += 1;
    }

    /// Close a crashed shard's books and move its queue off it.
    fn fail_and_redispatch(&mut self, i: usize) {
        // Everything offered to this shard that has neither a recorded
        // outcome nor a queue slot was in flight when the panic hit — it is
        // lost with the backend (KV state and all) and closed out as
        // `shard_failed` by conservation subtraction.
        let drained = self.shards[i].driver.drain_queue();
        {
            let m = &mut self.shards[i].driver.metrics;
            let accounted =
                m.completed_in_deadline + m.completed_late + m.dropped + m.shard_failed;
            m.shard_failed += m.offered.saturating_sub(accounted + drained.len() as u64);
        }
        // Queued-but-not-admitted requests hold no KV state: they are the
        // only work allowed to migrate (the KV-safety rule). Each one moves
        // to a surviving shard and stays counted in `offered` exactly once
        // (decrement here, increment in the survivor's `offer`); with every
        // shard down they terminate typed as `shard_failed` instead.
        for entry in drained {
            let j = self.route(&entry.req, i);
            if j != i && self.shard_is_healthy(j) {
                let m = &mut self.shards[i].driver.metrics;
                m.offered = m.offered.saturating_sub(1);
                m.requests_redispatched += 1;
                self.shards[j].driver.offer(entry.req, entry.payload);
            } else {
                self.shards[i].driver.metrics.shard_failed += 1;
            }
        }
    }

    /// Rebuild a crashed shard: fresh backend and scheduler from the stored
    /// factories, fresh driver with its RNG stream split by restart
    /// generation ([`chaos_stream`] — at generation 0 it reproduces
    /// [`shard_stream`] bit-for-bit, so the split rule is one function, not
    /// two). Metrics and anything queued while the shard was down carry
    /// over; the new incarnation is built against the current partition.
    fn rebuild_shard(&mut self, i: usize) {
        let Some(sup) = self.supervise.as_mut() else {
            return;
        };
        sup.generation[i] += 1;
        let generation = sup.generation[i];
        sup.health[i] = ShardHealth::Healthy;
        sup.born_epoch[i] = self.epoch_idx;
        let deployment = self.shards[i].deployment.clone();
        let template = InstanceTemplate {
            cost: CostModel::new(deployment.model.clone()),
            quant: deployment.quant.clone(),
            cluster: ClusterSpec::new(self.gpu_specs[i].clone(), self.gpus[i]),
            epoch: sup.epoch.clone(),
        };
        let backend = (sup.make_backend)(&template, i, generation);
        let driver = EpochDriver::new(
            template,
            sup.policy,
            sup.radio.clone(),
            sup.channel.clone(),
            Rng::new(chaos_stream(sup.seed, i as u64, generation)),
        );
        let scheduler = (sup.make_scheduler)(i);
        let fresh = Shard {
            deployment,
            driver,
            backend,
            scheduler,
        };
        let old = std::mem::replace(&mut self.shards[i], fresh);
        let mut old_driver = old.driver;
        let parked_queue = old_driver.drain_queue();
        let mut metrics = old_driver.into_metrics();
        metrics.shard_restarts += 1;
        self.shards[i].driver.metrics = metrics;
        self.shards[i].driver.requeue(parked_queue);
    }

    /// Per-shard supervisor health, in shard order (all `Healthy` for an
    /// unsupervised driver).
    pub fn health(&self) -> Vec<ShardHealth> {
        match &self.supervise {
            Some(sup) => sup.health.clone(),
            None => vec![ShardHealth::Healthy; self.shards.len()],
        }
    }

    /// Close the run on every shard (queue leftovers rejected, in-flight
    /// work drained — see [`EpochDriver::finish`]). Supervised drivers
    /// cannot trust a down shard's backend to flush: its books are closed
    /// by the same conservation subtraction as a crash, and a panic inside
    /// a healthy shard's own `finish` is caught and closed the same way.
    pub fn finish(&mut self, horizon: f64) {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let supervised = self.supervise.is_some();
        for i in 0..self.shards.len() {
            let healthy = self.shard_is_healthy(i);
            let Shard {
                driver, backend, ..
            } = &mut self.shards[i];
            if !supervised {
                driver.finish(backend, horizon);
                continue;
            }
            let clean = healthy
                && catch_unwind(AssertUnwindSafe(|| driver.finish(backend, horizon))).is_ok();
            if clean {
                continue;
            }
            if healthy {
                driver.metrics.shard_crashes += 1;
            }
            drop(driver.drain_queue());
            let m = &mut driver.metrics;
            let accounted =
                m.completed_in_deadline + m.completed_late + m.dropped + m.shard_failed;
            m.shard_failed += m.offered.saturating_sub(accounted);
            m.horizon = horizon;
        }
    }

    /// Per-shard metrics (shard order = deployment order).
    pub fn shard_metrics(&self, shard: usize) -> &Metrics {
        &self.shards[shard].driver.metrics
    }

    /// Cross-shard aggregate: autoscale-retired shards first (retirement
    /// order), then live shards in fixed shard-index order
    /// ([`Metrics::merge`]: counters sum exactly, horizon takes the max).
    pub fn merged_metrics(&self) -> Metrics {
        let mut merged = Metrics::new();
        for m in &self.retired {
            merged.merge(m);
        }
        for shard in &self.shards {
            merged.merge(&shard.driver.metrics);
        }
        merged
    }
}

/// Fluent construction for [`ShardedDriver`] — the single place the shard
/// configuration surface (deployments, topology, partition policy, epoch
/// protocol, elasticity, supervision) comes together, replacing the old
/// positional-argument sprawl. Defaults follow the paper's protocol:
/// best-case-infeasible staleness, longest-queued s' with a 512 fallback,
/// min-only allocation, load-proportional partitioning, paper
/// epoch/radio/channel parameters, seed 0, every elastic mechanism off.
pub struct DriverBuilder {
    deployments: Vec<Deployment>,
    topology: ClusterTopology,
    partition: PartitionPolicy,
    policy: DriverPolicy,
    epoch: EpochParams,
    radio: RadioParams,
    channel: ChannelParams,
    seed: u64,
    elastic: ElasticPolicy,
}

impl DriverBuilder {
    /// One deployment per topology entry, in shard order.
    pub fn new(deployments: Vec<Deployment>, topology: ClusterTopology) -> Self {
        DriverBuilder {
            deployments,
            topology,
            partition: PartitionPolicy::LoadProportional,
            policy: DriverPolicy {
                stale: StalePolicy::BestCaseInfeasible,
                s_pad: SPadPolicy::LongestQueued { fallback: 512 },
                allocation: AllocationPolicy::MinOnly,
            },
            epoch: EpochParams::default(),
            radio: RadioParams::default(),
            channel: ChannelParams::default(),
            seed: 0,
            elastic: ElasticPolicy::default(),
        }
    }

    /// The `--shards N` shim: `deployments.len()` identical partitions
    /// carved out of one homogeneous pool
    /// ([`ClusterTopology::homogeneous`]).
    pub fn homogeneous(deployments: Vec<Deployment>, cluster: ClusterSpec) -> Self {
        let shards = deployments.len().max(1);
        Self::new(deployments, ClusterTopology::homogeneous(cluster, shards))
    }

    pub fn partition(mut self, partition: PartitionPolicy) -> Self {
        self.partition = partition;
        self
    }

    pub fn policy(mut self, policy: DriverPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn epoch(mut self, epoch: EpochParams) -> Self {
        self.epoch = epoch;
        self
    }

    pub fn radio(mut self, radio: RadioParams) -> Self {
        self.radio = radio;
        self
    }

    pub fn channel(mut self, channel: ChannelParams) -> Self {
        self.channel = channel;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Set the whole elastic policy at once (see also the
    /// [`stealing`](Self::stealing) / [`autoscale`](Self::autoscale) /
    /// [`tune_epoch`](Self::tune_epoch) shorthands).
    pub fn elastic(mut self, elastic: ElasticPolicy) -> Self {
        self.elastic = elastic;
        self
    }

    pub fn stealing(mut self, on: bool) -> Self {
        self.elastic.stealing = on;
        self
    }

    pub fn autoscale(mut self, policy: AutoscalePolicy) -> Self {
        self.elastic.autoscale = Some(policy);
        self
    }

    pub fn tune_epoch(mut self, policy: EpochTunePolicy) -> Self {
        self.elastic.tune_epoch = Some(policy);
        self
    }

    /// The assembled [`ShardedConfig`] (what `build` hands the driver) —
    /// exposed for call sites that still need the plain config, e.g. to
    /// feed [`ShardedDriver::new`] in generic test plumbing.
    pub fn into_config(self) -> ShardedConfig {
        ShardedConfig {
            deployments: self.deployments,
            topology: self.topology,
            partition: self.partition,
            policy: self.policy,
            epoch: self.epoch,
            radio: self.radio,
            channel: self.channel,
            seed: self.seed,
            elastic: self.elastic,
        }
    }

    /// Stand the driver up unsupervised. The factories are `'static`
    /// because autoscaling (when enabled) keeps them to build future
    /// shards; with autoscaling off they are dropped after construction.
    pub fn build<B: ExecutionBackend>(
        self,
        make_backend: impl FnMut(&InstanceTemplate) -> B + 'static,
        make_scheduler: impl FnMut(usize) -> Box<dyn Scheduler + Send> + 'static,
    ) -> Result<ShardedDriver<B::Payload, B>, PartitionError> {
        let cfg = self.into_config();
        let autoscale = cfg.elastic.autoscale;
        let (driver_policy, radio, channel, seed) = (
            cfg.policy,
            cfg.radio.clone(),
            cfg.channel.clone(),
            cfg.seed,
        );
        let next_stream = cfg.topology.shard_count() as u64;
        let mut mb: Box<dyn FnMut(&InstanceTemplate) -> B> = Box::new(make_backend);
        let mut ms: Box<dyn FnMut(usize) -> Box<dyn Scheduler + Send>> =
            Box::new(make_scheduler);
        let mut sd = {
            let mut wrap = |t: &InstanceTemplate, _shard: usize, _gen: u64| (mb)(t);
            ShardedDriver::construct(cfg, &mut wrap, &mut *ms, false)?
        };
        if let Some(policy) = autoscale {
            sd.autoscale = Some(Autoscaler {
                policy,
                make_backend: mb,
                make_scheduler: ms,
                driver_policy,
                radio,
                channel,
                seed,
                next_stream,
            });
        }
        Ok(sd)
    }

    /// Stand the driver up with the supervision layer armed
    /// ([`ShardedDriver::with_supervision`]). Panics if autoscaling was
    /// requested — supervision indexes health state per shard and cannot
    /// follow a changing shard set.
    pub fn build_supervised<B: ExecutionBackend>(
        self,
        make_backend: impl FnMut(&InstanceTemplate, usize, u64) -> B + 'static,
        make_scheduler: impl FnMut(usize) -> Box<dyn Scheduler + Send> + 'static,
    ) -> Result<ShardedDriver<B::Payload, B>, PartitionError> {
        ShardedDriver::with_supervision(self.into_config(), make_backend, make_scheduler)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Dftsp;
    use crate::driver::{AnalyticBackend, ContinuousBackend, SPadPolicy, StalePolicy};
    use crate::model::LlmSpec;
    use crate::quant;
    use crate::request::RequestBuilder;
    use crate::wireless::AllocationPolicy;

    fn policy() -> DriverPolicy {
        DriverPolicy {
            stale: StalePolicy::BestCaseInfeasible,
            s_pad: SPadPolicy::LongestQueued { fallback: 512 },
            allocation: AllocationPolicy::MinOnly,
        }
    }

    fn two_quant_config() -> ShardedConfig {
        // Same model, two quantizations: distinct deployments, so affinity
        // binds; W4A16/ZQ-Local on BLOOM-3B admits only a <= 0.08.
        ShardedConfig {
            deployments: vec![
                Deployment {
                    model: LlmSpec::bloom_3b(),
                    quant: quant::default_quant(),
                },
                Deployment {
                    model: LlmSpec::bloom_3b(),
                    quant: quant::by_label(quant::Precision::W4A16, quant::QuantAlgo::ZqLocal)
                        .unwrap(),
                },
            ],
            topology: ClusterTopology::homogeneous(ClusterSpec::paper_default(), 2),
            partition: PartitionPolicy::LoadProportional,
            policy: policy(),
            epoch: EpochParams::default(),
            radio: RadioParams::default(),
            channel: ChannelParams::default(),
            seed: 7,
            elastic: ElasticPolicy::default(),
        }
    }

    fn analytic(cfg: ShardedConfig) -> ShardedDriver<(), AnalyticBackend> {
        ShardedDriver::new(cfg, |_| AnalyticBackend, |_| Box::new(Dftsp::new())).unwrap()
    }

    #[test]
    fn new_rejects_more_deployments_than_gpus() {
        let mut cfg = two_quant_config();
        cfg.topology =
            ClusterTopology::homogeneous(ClusterSpec::new(GpuSpec::jetson_tx2(), 1), 2);
        let err = ShardedDriver::<(), _>::new(cfg, |_| AnalyticBackend, |_| {
            Box::new(Dftsp::new()) as Box<dyn Scheduler + Send>
        })
        .err()
        .expect("1 GPU cannot host 2 deployments");
        assert_eq!(
            err,
            PartitionError::InsufficientGpus {
                deployments: 2,
                total_gpus: 1
            }
        );
    }

    #[test]
    fn affinity_routes_to_the_named_deployment() {
        let mut sd = analytic(two_quant_config());
        let mut b = RequestBuilder::new();
        // Low accuracy requirement: both deployments admit it, so affinity
        // decides.
        let s = sd.offer(b.build(0.0, 128, 128, 2.0, 0.05), (), 1);
        assert_eq!(s, 1);
        assert_eq!(sd.shards()[1].driver.queue_len(), 1);
        assert_eq!(sd.shards()[0].driver.queue_len(), 0);
        let s = sd.offer(b.build(0.0, 128, 128, 2.0, 0.05), (), 0);
        assert_eq!(s, 0);
    }

    #[test]
    fn inadmissible_affinity_spills_to_feasible_shard() {
        let mut sd = analytic(two_quant_config());
        let mut b = RequestBuilder::new();
        // a=0.5: W4A16/ZQ-Local (affinity 1) cannot admit it; W8A16/GPTQ
        // can — the request must spill to shard 0, not starve on shard 1.
        let s = sd.offer(b.build(0.0, 128, 128, 2.0, 0.5), (), 1);
        assert_eq!(s, 0, "spill-over to the feasible deployment");
        // a=0.99: nobody admits it — affinity shard keeps it so the driver
        // rejects it and accounting closes.
        let s = sd.offer(b.build(0.0, 128, 128, 2.0, 0.99), (), 1);
        assert_eq!(s, 1);
        sd.step_epoch(0.0);
        sd.finish(2.0);
        let m = sd.merged_metrics();
        assert_eq!(m.offered, 2);
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped
        );
        assert!(m.dropped >= 1, "the un-admittable request was rejected");
    }

    #[test]
    fn same_deployment_shards_balance_by_queue_depth() {
        // Three identical deployments: routing ignores the affinity index
        // and balances by queue depth, ties to the lowest shard index.
        let dep = Deployment {
            model: LlmSpec::bloom_3b(),
            quant: quant::default_quant(),
        };
        let cfg = ShardedConfig {
            deployments: vec![dep.clone(), dep.clone(), dep],
            topology: ClusterTopology::homogeneous(ClusterSpec::paper_default(), 3),
            partition: PartitionPolicy::Equal,
            policy: policy(),
            epoch: EpochParams::default(),
            radio: RadioParams::default(),
            channel: ChannelParams::default(),
            seed: 3,
            elastic: ElasticPolicy::default(),
        };
        let mut sd = analytic(cfg);
        let mut b = RequestBuilder::new();
        let picks: Vec<usize> = (0..6)
            .map(|_| sd.offer(b.build(0.0, 128, 128, 2.0, 0.1), (), 0))
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2], "round-robin by depth");
    }

    #[test]
    fn repartition_follows_demand_and_respects_min_one() {
        let mut sd = analytic(two_quant_config());
        assert_eq!(sd.partition(), &[10, 10], "idle start is near-equal");
        let mut b = RequestBuilder::new();
        for _ in 0..30 {
            sd.offer(b.build(0.0, 256, 256, 1.9, 0.05), (), 0);
        }
        sd.offer(b.build(0.0, 128, 128, 1.9, 0.05), (), 1);
        sd.step_epoch(0.0);
        let p = sd.partition();
        assert_eq!(p.iter().sum::<usize>(), 20);
        assert!(p[0] > p[1], "loaded shard takes the headroom: {p:?}");
        assert!(p[1] >= 1, "min-1 floor holds: {p:?}");
        sd.finish(2.0);
        let m = sd.merged_metrics();
        assert_eq!(m.offered, 31);
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped
        );
    }

    #[test]
    fn parallel_step_is_deterministic() {
        let run = || {
            let mut sd = analytic(two_quant_config());
            let mut b = RequestBuilder::new();
            for e in 0..4u64 {
                let now = e as f64 * 2.0;
                for i in 0..12 {
                    sd.offer(b.build(now, 256, 256, 1.9, 0.05), (), (i % 2) as usize);
                }
                sd.step_epoch(now);
            }
            sd.finish(8.0);
            (
                sd.merged_metrics(),
                sd.shard_metrics(0).clone(),
                sd.shard_metrics(1).clone(),
            )
        };
        let (am, a0, a1) = run();
        let (bm, b0, b1) = run();
        assert_eq!(am, bm, "merged metrics bit-identical across runs");
        assert_eq!(a0, b0);
        assert_eq!(a1, b1);
        assert!(am.offered == 48);
    }

    #[test]
    fn continuous_backend_shards_conserve_and_keep_kv_floors() {
        let cfg = two_quant_config();
        let mut sd: ShardedDriver<(), ContinuousBackend> = ShardedDriver::new(
            cfg,
            ContinuousBackend::new,
            |_| Box::new(Dftsp::new()),
        )
        .unwrap();
        let mut b = RequestBuilder::new();
        for e in 0..4u64 {
            let now = e as f64 * 2.0;
            for i in 0..8 {
                sd.offer(b.build(now + 0.2 * i as f64, 256, 256, 1.9, 0.05), (), 0);
            }
            sd.offer(b.build(now, 128, 128, 1.9, 0.05), (), 1);
            sd.step_epoch(now);
            assert_eq!(sd.partition().iter().sum::<usize>(), 20);
        }
        sd.finish(8.0);
        let m = sd.merged_metrics();
        assert_eq!(m.offered, 36);
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped
        );
        for s in sd.shards() {
            assert_eq!(s.backend.in_flight(), 0, "finish drains every shard");
            assert_eq!(s.backend.ledger().in_use(), 0);
        }
    }

    #[test]
    fn apply_floors_preserves_total_and_raises_deficits() {
        assert_eq!(apply_floors(vec![8, 1, 1], &[1, 3, 1]), vec![6, 3, 1]);
        assert_eq!(apply_floors(vec![5, 5], &[1, 1]), vec![5, 5]);
        // Donor choice: largest surplus first, ties to the lowest index.
        assert_eq!(apply_floors(vec![4, 4, 0], &[1, 1, 2]), vec![3, 3, 2]);
        // Floors exactly exhaust the pool.
        assert_eq!(apply_floors(vec![3, 0, 0], &[1, 1, 1]), vec![1, 1, 1]);
    }

    #[test]
    fn shard_streams_split_deterministically() {
        assert_eq!(shard_stream(42, 0), 42, "shard 0 keeps the run stream");
        assert_ne!(shard_stream(42, 1), shard_stream(42, 2));
        assert_eq!(shard_stream(42, 1), shard_stream(42, 1));
        assert_ne!(shard_stream(42, 1), shard_stream(43, 1));
        // Generation 0 of the restart split reproduces the construction
        // split exactly — one split rule, not two.
        for shard in 0..4u64 {
            assert_eq!(chaos_stream(42, shard, 0), shard_stream(42, shard));
        }
    }

    // ------------------------------------------------------------------
    // Supervision (module docs §Supervision)
    // ------------------------------------------------------------------

    use crate::coordinator::{ProblemInstance, Schedule};
    use crate::driver::{ChaosBackend, ChaosConfig};
    use crate::request::EpochRequest;

    /// Scheduler that never schedules anything — everything it is shown
    /// stays queued, which makes redispatch counts exact.
    struct Never;
    impl Scheduler for Never {
        fn name(&self) -> &'static str {
            "never"
        }
        fn schedule(&mut self, _inst: &ProblemInstance, _c: &[EpochRequest]) -> Schedule {
            Schedule::empty()
        }
    }

    fn same_dep_config(seed: u64) -> ShardedConfig {
        let dep = Deployment {
            model: LlmSpec::bloom_3b(),
            quant: quant::default_quant(),
        };
        ShardedConfig {
            deployments: vec![dep.clone(), dep],
            topology: ClusterTopology::homogeneous(ClusterSpec::paper_default(), 2),
            partition: PartitionPolicy::Equal,
            policy: policy(),
            epoch: EpochParams::default(),
            radio: RadioParams::default(),
            channel: ChannelParams::default(),
            seed,
            elastic: ElasticPolicy::default(),
        }
    }

    type ChaosSharded = ShardedDriver<(), ChaosBackend<AnalyticBackend>>;

    #[test]
    fn supervised_without_faults_matches_unsupervised() {
        let run = |supervised: bool| {
            let cfg = two_quant_config();
            let mut sd: ChaosSharded = if supervised {
                ShardedDriver::with_supervision(
                    cfg,
                    |_, _, _| ChaosBackend::passthrough(AnalyticBackend),
                    |_| Box::new(Dftsp::new()),
                )
                .unwrap()
            } else {
                ShardedDriver::new(
                    cfg,
                    |_| ChaosBackend::passthrough(AnalyticBackend),
                    |_| Box::new(Dftsp::new()),
                )
                .unwrap()
            };
            let mut b = RequestBuilder::new();
            for e in 0..4u64 {
                let now = e as f64 * 2.0;
                for i in 0..12 {
                    sd.offer(b.build(now, 256, 256, 1.9, 0.05), (), (i % 2) as usize);
                }
                sd.step_epoch(now);
            }
            sd.finish(8.0);
            (
                sd.merged_metrics(),
                sd.shard_metrics(0).clone(),
                sd.shard_metrics(1).clone(),
            )
        };
        assert_eq!(
            run(true),
            run(false),
            "armed-but-fault-free supervision is bit-identical"
        );
    }

    #[test]
    fn crashed_shard_redispatches_queue_then_restarts() {
        // Shard 1 panics in its first incarnation only; its scheduler never
        // schedules, so its whole queue is still queued at crash time and
        // the redispatch count is exact.
        let mut sd: ChaosSharded = ShardedDriver::with_supervision(
            same_dep_config(7),
            |_, shard, generation| {
                let cfg = if shard == 1 && generation == 0 {
                    ChaosConfig {
                        seed: 1,
                        panic_prob: 1.0,
                        ..ChaosConfig::default()
                    }
                } else {
                    ChaosConfig::default()
                };
                ChaosBackend::new(AnalyticBackend, cfg, shard as u64, generation)
            },
            |shard| -> Box<dyn Scheduler + Send> {
                if shard == 1 {
                    Box::new(Never)
                } else {
                    Box::new(Dftsp::new())
                }
            },
        )
        .unwrap();
        let mut b = RequestBuilder::new();
        for _ in 0..3 {
            sd.offer(b.build(0.0, 128, 128, 1.9, 0.05), (), 0);
            sd.offer(b.build(0.0, 128, 128, 1.9, 0.05), (), 1);
        }
        assert_eq!(sd.shards()[1].driver.queue_len(), 3);
        sd.step_epoch(0.0);
        assert_eq!(sd.health()[1], ShardHealth::Degraded, "panic caught");
        let m1 = sd.shard_metrics(1);
        assert_eq!(m1.shard_crashes, 1);
        assert_eq!(m1.requests_redispatched, 3, "queued work moved off");
        assert_eq!(m1.offered, 0, "moved requests leave the crashed count");
        assert_eq!(sd.shard_metrics(0).offered, 6, "survivor took them");
        // While down, routing avoids the shard entirely.
        assert_eq!(sd.offer(b.build(2.0, 128, 128, 1.9, 0.05), (), 1), 0);
        sd.step_epoch(2.0);
        assert!(
            matches!(sd.health()[1], ShardHealth::Restarting { .. }),
            "restart scheduled under backoff"
        );
        sd.step_epoch(4.0);
        sd.step_epoch(6.0); // backoff elapsed: rebuilt at this boundary
        assert_eq!(sd.health()[1], ShardHealth::Healthy, "back in rotation");
        assert_eq!(sd.shard_metrics(1).shard_restarts, 1);
        sd.finish(8.0);
        let m = sd.merged_metrics();
        assert_eq!(m.offered, 7);
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped + m.shard_failed,
            "conservation closes through the crash"
        );
    }

    #[test]
    fn crash_loop_parks_the_shard_and_routing_avoids_it() {
        // Shard 1 panics in every incarnation: a genuine crash loop. Three
        // quick crashes trip the circuit breaker.
        let mut sd: ChaosSharded = ShardedDriver::with_supervision(
            same_dep_config(11),
            |_, shard, generation| {
                let cfg = if shard == 1 {
                    ChaosConfig {
                        seed: 2,
                        panic_prob: 1.0,
                        ..ChaosConfig::default()
                    }
                } else {
                    ChaosConfig::default()
                };
                ChaosBackend::new(AnalyticBackend, cfg, shard as u64, generation)
            },
            |_| Box::new(Dftsp::new()),
        )
        .unwrap();
        let mut b = RequestBuilder::new();
        for e in 0..12u64 {
            let now = e as f64 * 2.0;
            sd.offer(b.build(now, 128, 128, 1.9, 0.05), (), 0);
            sd.offer(b.build(now, 128, 128, 1.9, 0.05), (), 1);
            sd.step_epoch(now);
        }
        assert_eq!(sd.health()[1], ShardHealth::Parked, "circuit breaker");
        let m1 = sd.shard_metrics(1);
        assert_eq!(m1.shard_crashes, 3, "crash, restart, crash, …, park");
        assert_eq!(m1.shard_restarts, 2, "a parked shard never restarts");
        assert_eq!(m1.shards_parked, 1);
        // Parked: the affinity shard is permanently out of rotation.
        assert_eq!(sd.offer(b.build(24.0, 128, 128, 1.9, 0.05), (), 1), 0);
        sd.finish(26.0);
        let m = sd.merged_metrics();
        assert_eq!(m.offered, 25);
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped + m.shard_failed
        );
        assert!(m.shard_failed > 0, "in-flight work died with the shard");
    }

    #[test]
    fn seeded_chaos_is_deterministic_and_conserves() {
        let chaos = ChaosConfig {
            seed: 33,
            panic_prob: 0.25,
            error_prob: 0.25,
            kv_fail_prob: 0.25,
            ..ChaosConfig::default()
        };
        let run = || {
            let mut sd: ChaosSharded = ShardedDriver::with_supervision(
                same_dep_config(9),
                move |_, shard, generation| {
                    ChaosBackend::new(AnalyticBackend, chaos, shard as u64, generation)
                },
                |_| Box::new(Dftsp::new()),
            )
            .unwrap();
            let mut b = RequestBuilder::new();
            for e in 0..10u64 {
                let now = e as f64 * 2.0;
                for i in 0..4 {
                    sd.offer(b.build(now, 128, 128, 1.9, 0.05), (), (i % 2) as usize);
                }
                sd.step_epoch(now);
            }
            sd.finish(20.0);
            (sd.merged_metrics(), sd.health())
        };
        let (a, ha) = run();
        let (c, hc) = run();
        assert_eq!(a, c, "same chaos seed → bit-identical merged metrics");
        assert_eq!(ha, hc, "… and the same final health states");
        assert_eq!(a.offered, 40);
        assert_eq!(
            a.offered,
            a.completed_in_deadline + a.completed_late + a.dropped + a.shard_failed,
            "every request gets exactly one terminal outcome through chaos"
        );
        assert!(a.shard_crashes > 0, "the schedule did inject panics");
    }

    // ------------------------------------------------------------------
    // Elasticity (module docs §Elastic)
    // ------------------------------------------------------------------

    use crate::cluster::ShardSpec;
    use crate::driver::{EpochContext, QueuedRequest};

    /// Fast+slow replica pair of one deployment: two distinct GpuSpecs, so
    /// two single-member migration groups (no GPU ever crosses them).
    fn fast_slow_topology() -> ClusterTopology {
        let fast = GpuSpec {
            name: "fast-edge".into(),
            flops: 8.0 * 1.33e12,
            mem_bytes: 32 * (1 << 30),
        };
        ClusterTopology {
            shards: vec![
                ShardSpec {
                    gpu: fast,
                    num_gpus: 1,
                },
                ShardSpec {
                    gpu: GpuSpec::jetson_tx2(),
                    num_gpus: 1,
                },
            ],
        }
    }

    fn one_deployment() -> Deployment {
        Deployment {
            model: LlmSpec::bloom_3b(),
            quant: quant::default_quant(),
        }
    }

    #[test]
    fn builder_matches_positional_constructor_bit_for_bit() {
        let workload = |mut sd: ShardedDriver<(), AnalyticBackend>| {
            let mut b = RequestBuilder::new();
            for e in 0..4u64 {
                let now = e as f64 * 2.0;
                for i in 0..12 {
                    sd.offer(b.build(now, 256, 256, 1.9, 0.05), (), (i % 2) as usize);
                }
                sd.step_epoch(now);
            }
            sd.finish(8.0);
            (sd.merged_metrics(), sd.shard_metrics(0).clone())
        };
        let old = workload(analytic(two_quant_config()));
        let cfg = two_quant_config();
        let new = workload(
            DriverBuilder::new(cfg.deployments, cfg.topology)
                .partition(PartitionPolicy::LoadProportional)
                .policy(policy())
                .seed(7)
                .build(|_| AnalyticBackend, |_| -> Box<dyn Scheduler + Send> {
                    Box::new(Dftsp::new())
                })
                .unwrap(),
        );
        assert_eq!(old, new, "builder path is bit-identical to positional");
    }

    #[test]
    fn steal_moves_queued_work_toward_the_fast_replica() {
        // Queue-depth routing splits 10 arrivals 5/5, but shard 0 has 8×
        // the FLOPs: the steal pass pulls donor-back entries until the
        // FLOPs-normalized imbalance rule stops improving — 4 steals
        // ((5+n+1)/8 < 5-n holds for n=0..3).
        let dep = one_deployment();
        let mut sd = DriverBuilder::new(vec![dep.clone(), dep], fast_slow_topology())
            .policy(policy())
            .seed(5)
            .stealing(true)
            .build(|_| AnalyticBackend, |_| -> Box<dyn Scheduler + Send> {
                Box::new(Never)
            })
            .unwrap();
        let mut b = RequestBuilder::new();
        for _ in 0..10 {
            sd.offer(b.build(0.0, 256, 256, 1000.0, 0.05), (), 0);
        }
        assert_eq!(sd.shards()[0].driver.queue_len(), 5);
        assert_eq!(sd.shards()[1].driver.queue_len(), 5);
        sd.step_epoch(0.0);
        assert_eq!(sd.shards()[0].driver.queue_len(), 9, "thief holds 9");
        assert_eq!(sd.shards()[1].driver.queue_len(), 1, "donor keeps 1");
        assert_eq!(sd.shard_metrics(0).requests_stolen, 4);
        assert_eq!(sd.shard_metrics(0).offered, 9);
        assert_eq!(sd.shard_metrics(1).offered, 1);
        let m = sd.merged_metrics();
        assert_eq!(m.offered, 10, "offered conserved across steals");
        assert_eq!(m.requests_stolen, 4);
        // Determinism: the identical run steals identically.
        let dep = one_deployment();
        let mut sd2 = DriverBuilder::new(vec![dep.clone(), dep], fast_slow_topology())
            .policy(policy())
            .seed(5)
            .stealing(true)
            .build(|_| AnalyticBackend, |_| -> Box<dyn Scheduler + Send> {
                Box::new(Never)
            })
            .unwrap();
        let mut b = RequestBuilder::new();
        for _ in 0..10 {
            sd2.offer(b.build(0.0, 256, 256, 1000.0, 0.05), (), 0);
        }
        sd2.step_epoch(0.0);
        assert_eq!(sd2.shard_metrics(0).requests_stolen, 4);
    }

    /// Analytic execution behind a permanently closed admission gate.
    struct Gated(AnalyticBackend);
    impl ExecutionBackend for Gated {
        type Payload = ();
        fn execute(
            &mut self,
            ctx: &EpochContext<'_>,
            schedule: &Schedule,
            batch: Vec<QueuedRequest<()>>,
            metrics: &mut Metrics,
        ) {
            self.0.execute(ctx, schedule, batch, metrics);
        }
        fn can_admit(&self, _req: &Request) -> bool {
            false
        }
    }

    #[test]
    fn steal_respects_the_thief_kv_gate() {
        // Identical setup to the stealing test, but the thief's backend
        // refuses every admission: not a single request may move.
        let dep = one_deployment();
        let mut sd = DriverBuilder::new(vec![dep.clone(), dep], fast_slow_topology())
            .policy(policy())
            .seed(5)
            .stealing(true)
            .build(
                |_| Gated(AnalyticBackend),
                |_| -> Box<dyn Scheduler + Send> { Box::new(Never) },
            )
            .unwrap();
        let mut b = RequestBuilder::new();
        for _ in 0..10 {
            sd.offer(b.build(0.0, 256, 256, 1000.0, 0.05), (), 0);
        }
        sd.step_epoch(0.0);
        assert_eq!(sd.shards()[0].driver.queue_len(), 5, "gate held");
        assert_eq!(sd.shards()[1].driver.queue_len(), 5);
        assert_eq!(sd.merged_metrics().requests_stolen, 0);
    }

    #[test]
    fn stealing_off_leaves_queues_untouched() {
        let dep = one_deployment();
        let mut sd = DriverBuilder::new(vec![dep.clone(), dep], fast_slow_topology())
            .policy(policy())
            .seed(5)
            .build(|_| AnalyticBackend, |_| -> Box<dyn Scheduler + Send> {
                Box::new(Never)
            })
            .unwrap();
        let mut b = RequestBuilder::new();
        for _ in 0..10 {
            sd.offer(b.build(0.0, 256, 256, 1000.0, 0.05), (), 0);
        }
        sd.step_epoch(0.0);
        assert_eq!(sd.shards()[0].driver.queue_len(), 5);
        assert_eq!(sd.shards()[1].driver.queue_len(), 5);
        assert_eq!(sd.merged_metrics().requests_stolen, 0);
    }

    #[test]
    fn autoscaler_spawns_under_load_and_retires_idle_shards() {
        let mut sd = DriverBuilder::new(
            vec![one_deployment()],
            ClusterTopology::homogeneous(ClusterSpec::paper_default(), 1),
        )
        .policy(policy())
        .seed(13)
        .autoscale(AutoscalePolicy {
            min_shards: 1,
            max_shards: 2,
            scale_up_ratio: 0.05,
            scale_down_ratio: 0.02,
        })
        .build(|_| AnalyticBackend, |_| -> Box<dyn Scheduler + Send> {
            Box::new(Dftsp::new())
        })
        .unwrap();
        let mut b = RequestBuilder::new();
        for _ in 0..24 {
            sd.offer(b.build(0.0, 128, 128, 1000.0, 0.05), (), 0);
        }
        sd.step_epoch(0.0);
        assert_eq!(sd.shard_count(), 2, "burst spawned a replica");
        assert_eq!(sd.partition().iter().sum::<usize>(), 20, "pool conserved");
        for e in 1..8u64 {
            sd.step_epoch(e as f64 * 2.0);
        }
        assert_eq!(sd.shard_count(), 1, "idle fleet scaled back down");
        assert_eq!(sd.partition(), &[20], "GPUs returned to the survivor");
        sd.finish(16.0);
        let m = sd.merged_metrics();
        assert!(m.shards_spawned >= 1, "the burst spawned at least once");
        assert_eq!(m.shards_retired, m.shards_spawned, "fleet returned to 1");
        assert_eq!(m.offered, 24, "retired metrics stay in the aggregate");
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped
        );
    }

    #[test]
    fn epoch_tuner_grows_on_overruns_and_shrinks_when_calm() {
        let mut sd = DriverBuilder::new(
            vec![one_deployment()],
            ClusterTopology::homogeneous(ClusterSpec::paper_default(), 1),
        )
        .policy(policy())
        .tune_epoch(EpochTunePolicy {
            min_duration: 1.0,
            max_duration: 8.0,
            grow: 2.0,
            shrink: 0.5,
            calm_epochs: 2,
        })
        .build(|_| AnalyticBackend, |_| -> Box<dyn Scheduler + Send> {
            Box::new(Dftsp::new())
        })
        .unwrap();
        assert_eq!(sd.epoch_duration(), 2.0, "paper default to start");
        // Fake an overrun: the tuner reads the counter, not wall clocks.
        sd.shards[0].driver.metrics.epoch_overruns = 1;
        sd.step_epoch(0.0);
        assert_eq!(sd.epoch_duration(), 4.0, "overrun grew the epoch");
        assert_eq!(sd.shards()[0].driver.epoch_duration(), 4.0, "propagated");
        sd.step_epoch(2.0);
        assert_eq!(sd.epoch_duration(), 4.0, "one calm epoch: no change yet");
        sd.step_epoch(6.0);
        assert_eq!(sd.epoch_duration(), 2.0, "two calm epochs: shrank");
    }
}
