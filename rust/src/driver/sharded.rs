//! Sharded multi-deployment serving — one [`EpochDriver`] per GPU
//! partition behind a dispatch layer (the last big ROADMAP scaling item,
//! unlocked by the PR 1 driver refactor).
//!
//! The paper schedules a single deployment's GPU pool; its own multi-LLM
//! extension (`coordinator::multi`) already *partitions* GPUs across
//! deployments but was schedule-only. This module drives N partitions
//! through the full epoch protocol: the edge node hosts several
//! (model, quantization) deployments, each shard owns one partition — its
//! own [`EpochDriver`], [`ExecutionBackend`], scheduler, RNG stream and
//! [`Metrics`] — and a dispatch layer routes arrivals and re-balances GPU
//! headroom between epochs.
//!
//! ## Routing
//!
//! Every arrival names a *deployment affinity* (which model/quant it wants).
//! Dispatch picks the least-loaded shard (queue depth, ties to the lowest
//! shard index) among the shards hosting that deployment whose quantization
//! admits the request's accuracy requirement (constraint 1e). When no
//! affinity shard can admit it, the request spills over to the least-loaded
//! *feasible* shard of any deployment; when nothing at all can serve it, it
//! still lands on the affinity shard so the driver's admission step rejects
//! it and accounting closes — every arrival lands in exactly one shard,
//! always (property-tested in `tests/proptest_sharded.rs`).
//!
//! ## Re-partitioning (headroom moves, in-flight work never does)
//!
//! Between epochs the dispatch layer re-apportions the GPU pool from
//! observed per-shard demand (queued FLOPs weighted by each deployment's β)
//! under the configured [`PartitionPolicy`], with two guarantees:
//!
//! - **min-1**: every shard keeps at least one GPU
//!   ([`partition_gpus_by_load`] returns a typed error otherwise);
//! - **KV-safe handoff**: a shard never shrinks below
//!   [`ExecutionBackend::min_gpus_for_inflight`] — the continuous backend
//!   pins the GPUs its in-flight KV reservations occupy, so only *headroom*
//!   migrates and running batches are never squeezed out of memory. When the
//!   floors cannot be honored (every GPU pinned), the partition stays put
//!   for that epoch.
//!
//! ## Determinism
//!
//! Shards step **in parallel** via `std::thread::scope`, and the result is
//! bit-identical to stepping them sequentially: each shard's RNG stream is
//! split from the run seed by shard index (shard 0 inherits the run stream,
//! which is what makes a 1-shard run bit-identical to the unsharded
//! driver — `tests/sharded_e2e.rs`), shards share no mutable state during a
//! step, and metrics merge in fixed shard-index order.

use crate::cluster::{ClusterSpec, GpuSpec};
use crate::coordinator::{
    partition_gpus_by_load, Deployment, EpochParams, PartitionError, PartitionPolicy, Scheduler,
};
use crate::driver::{DriverPolicy, EpochDriver, ExecutionBackend, InstanceTemplate};
use crate::metrics::Metrics;
use crate::model::CostModel;
use crate::request::Request;
use crate::util::rng::{splitmix64, Rng};
use crate::wireless::{ChannelParams, RadioParams};

/// Everything the dispatch layer needs to stand up its shards.
#[derive(Debug, Clone)]
pub struct ShardedConfig {
    /// One entry per shard: the (model, quantization) pair it serves.
    /// Several shards may host the same deployment (pure data-parallel
    /// scale-out); routing then balances across them.
    pub deployments: Vec<Deployment>,
    /// The total GPU pool being partitioned.
    pub cluster: ClusterSpec,
    pub partition: PartitionPolicy,
    /// Per-shard epoch-protocol policy (stale rule, s', allocation).
    pub policy: DriverPolicy,
    pub epoch: EpochParams,
    pub radio: RadioParams,
    pub channel: ChannelParams,
    /// Run seed; shard i draws from a stream split off it (shard 0 keeps
    /// the run stream itself — the 1-shard parity contract).
    pub seed: u64,
}

/// Least-loaded pick among candidate shard indices: minimum load, ties to
/// the lowest index. The one routing primitive shared by the simulator's
/// dispatch layer ([`ShardedDriver::offer`]) and the TCP front-end's
/// model-name router (`serving::net::Router`) — both implement
/// "affinity → least-loaded" in terms of this, so their tie-breaking
/// cannot diverge.
pub fn pick_least_loaded<I, L>(candidates: I, load: L) -> Option<usize>
where
    I: Iterator<Item = usize>,
    L: Fn(usize) -> usize,
{
    candidates.min_by_key(|&i| (load(i), i))
}

/// Per-shard RNG stream: shard 0 inherits the run stream bit-for-bit;
/// shard i > 0 gets an independent SplitMix64-derived stream.
fn shard_stream(seed: u64, shard: u64) -> u64 {
    if shard == 0 {
        return seed;
    }
    let mut s = seed ^ shard.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s)
}

/// One GPU partition: a deployment, its epoch driver, execution backend and
/// scheduler.
pub struct Shard<P, B> {
    pub deployment: Deployment,
    pub driver: EpochDriver<P>,
    pub backend: B,
    scheduler: Box<dyn Scheduler + Send>,
}

impl<P, B: ExecutionBackend<Payload = P>> Shard<P, B> {
    fn step(&mut self, now: f64) {
        let sched: &mut dyn Scheduler = &mut *self.scheduler;
        self.driver.step_epoch(sched, &mut self.backend, now);
    }
}

/// The dispatch layer: owns one [`EpochDriver`] per GPU partition, routes
/// arrivals, re-partitions headroom between epochs and steps the shards in
/// parallel (module docs).
pub struct ShardedDriver<P, B> {
    shards: Vec<Shard<P, B>>,
    gpu: GpuSpec,
    total_gpus: usize,
    partition: PartitionPolicy,
    gpus: Vec<usize>,
    epoch_idx: u64,
}

/// Raise every below-floor entry to its floor by taking GPUs from the
/// largest-surplus donors (ties to the lowest index). Caller guarantees
/// `Σ floors ≤ Σ alloc`, so the loop always finds a donor and terminates
/// with the total preserved.
fn apply_floors(mut alloc: Vec<usize>, floors: &[usize]) -> Vec<usize> {
    loop {
        let Some(need) = (0..alloc.len()).find(|&i| alloc[i] < floors[i]) else {
            return alloc;
        };
        let donor = (0..alloc.len())
            .filter(|&i| alloc[i] > floors[i])
            .max_by_key(|&i| (alloc[i] - floors[i], usize::MAX - i))
            .expect("sum(floors) <= sum(alloc): a deficit implies a surplus");
        alloc[donor] -= 1;
        alloc[need] += 1;
    }
}

impl<P, B: ExecutionBackend<Payload = P>> ShardedDriver<P, B> {
    /// Stand up one shard per deployment. The initial partition apportions
    /// the pool under `cfg.partition` with zero observed demand (i.e.
    /// near-equal); demand-driven re-partitioning takes over from the first
    /// epoch. Returns the typed [`PartitionError`] when the pool cannot
    /// give every deployment its guaranteed GPU.
    pub fn new(
        cfg: ShardedConfig,
        mut make_backend: impl FnMut(&InstanceTemplate) -> B,
        mut make_scheduler: impl FnMut(usize) -> Box<dyn Scheduler + Send>,
    ) -> Result<Self, PartitionError> {
        let k = cfg.deployments.len();
        let gpus = partition_gpus_by_load(&vec![0.0; k], cfg.cluster.num_gpus, cfg.partition)?;
        let mut shards = Vec::with_capacity(k);
        for (i, dep) in cfg.deployments.into_iter().enumerate() {
            let template = InstanceTemplate {
                cost: CostModel::new(dep.model.clone()),
                quant: dep.quant.clone(),
                cluster: ClusterSpec::new(cfg.cluster.gpu.clone(), gpus[i]),
                epoch: cfg.epoch.clone(),
            };
            let backend = make_backend(&template);
            let driver = EpochDriver::new(
                template,
                cfg.policy,
                cfg.radio.clone(),
                cfg.channel.clone(),
                Rng::new(shard_stream(cfg.seed, i as u64)),
            );
            shards.push(Shard {
                deployment: dep,
                driver,
                backend,
                scheduler: make_scheduler(i),
            });
        }
        Ok(ShardedDriver {
            shards,
            gpu: cfg.cluster.gpu,
            total_gpus: cfg.cluster.num_gpus,
            partition: cfg.partition,
            gpus,
            epoch_idx: 0,
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Current GPU counts, by shard index (always sums to the pool size).
    pub fn partition(&self) -> &[usize] {
        &self.gpus
    }

    pub fn shards(&self) -> &[Shard<P, B>] {
        &self.shards
    }

    pub fn epoch_idx(&self) -> u64 {
        self.epoch_idx
    }

    /// Pick the shard an arrival should land on (module docs: affinity
    /// first, least-loaded within the deployment, accuracy-feasible
    /// spill-over, affinity fallback so rejection is still accounted).
    fn route(&self, req: &Request, affinity: usize) -> usize {
        let aff = affinity.min(self.shards.len() - 1);
        let admits = |i: usize| {
            let d = &self.shards[i].deployment;
            d.quant.satisfies_accuracy(&d.model.name, req.accuracy_req)
        };
        let load = |i: usize| self.shards[i].driver.queue_len();
        let target = &self.shards[aff].deployment;
        let same = (0..self.shards.len())
            .filter(|&i| admits(i) && self.shards[i].deployment.same_as(target));
        if let Some(i) = pick_least_loaded(same, load) {
            return i;
        }
        let feasible = (0..self.shards.len()).filter(|&i| admits(i));
        pick_least_loaded(feasible, load).unwrap_or(aff)
    }

    /// Admit a request: route it to exactly one shard's queue. `affinity`
    /// is the index of the deployment the caller wants (clamped into
    /// range); the chosen shard index is returned.
    pub fn offer(&mut self, req: Request, payload: P, affinity: usize) -> usize {
        let shard = self.route(&req, affinity);
        self.shards[shard].driver.offer(req, payload);
        shard
    }

    /// Re-apportion the GPU pool from observed queued demand, clamped to
    /// each backend's KV-safety floor. No-ops for a single shard, when
    /// every GPU is pinned by in-flight work, or when the apportionment is
    /// unchanged.
    fn repartition(&mut self) {
        if self.shards.len() <= 1 {
            return;
        }
        let loads: Vec<f64> = self
            .shards
            .iter()
            .map(|s| {
                s.driver
                    .queued_requests()
                    .map(|r| s.deployment.req_weight(r.prompt_tokens, r.output_tokens))
                    .sum()
            })
            .collect();
        let Ok(desired) = partition_gpus_by_load(&loads, self.total_gpus, self.partition) else {
            return; // pool shrank below min-1 — unreachable once constructed
        };
        let floors: Vec<usize> = self
            .shards
            .iter()
            .map(|s| s.backend.min_gpus_for_inflight().clamp(1, self.total_gpus))
            .collect();
        if floors.iter().sum::<usize>() > self.total_gpus {
            return; // every GPU pinned by in-flight work: no safe handoff
        }
        let alloc = apply_floors(desired, &floors);
        if alloc == self.gpus {
            return;
        }
        for (i, shard) in self.shards.iter_mut().enumerate() {
            if alloc[i] != self.gpus[i] {
                let cluster = ClusterSpec::new(self.gpu.clone(), alloc[i]);
                shard.driver.set_cluster(cluster.clone());
                shard.backend.cluster_resized(&cluster);
            }
        }
        self.gpus = alloc;
    }

    /// One epoch across every shard: re-partition from current demand, then
    /// step all shards in parallel. Deterministic regardless of thread
    /// interleaving — shards are fully independent within a step and all
    /// cross-shard decisions (routing, re-partitioning) happen before the
    /// fan-out.
    pub fn step_epoch(&mut self, now: f64)
    where
        P: Send,
        B: Send,
    {
        self.repartition();
        if self.shards.len() == 1 {
            self.shards[0].step(now);
        } else {
            let shards = &mut self.shards;
            std::thread::scope(|scope| {
                for shard in shards.iter_mut() {
                    scope.spawn(move || shard.step(now));
                }
            });
        }
        self.epoch_idx += 1;
    }

    /// Close the run on every shard (queue leftovers rejected, in-flight
    /// work drained — see [`EpochDriver::finish`]).
    pub fn finish(&mut self, horizon: f64) {
        for shard in &mut self.shards {
            let Shard {
                driver, backend, ..
            } = shard;
            driver.finish(backend, horizon);
        }
    }

    /// Per-shard metrics (shard order = deployment order).
    pub fn shard_metrics(&self, shard: usize) -> &Metrics {
        &self.shards[shard].driver.metrics
    }

    /// Cross-shard aggregate, merged in fixed shard-index order
    /// ([`Metrics::merge`]: counters sum exactly, horizon takes the max).
    pub fn merged_metrics(&self) -> Metrics {
        let mut merged = Metrics::new();
        for shard in &self.shards {
            merged.merge(&shard.driver.metrics);
        }
        merged
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Dftsp;
    use crate::driver::{AnalyticBackend, ContinuousBackend, SPadPolicy, StalePolicy};
    use crate::model::LlmSpec;
    use crate::quant;
    use crate::request::RequestBuilder;
    use crate::wireless::AllocationPolicy;

    fn policy() -> DriverPolicy {
        DriverPolicy {
            stale: StalePolicy::BestCaseInfeasible,
            s_pad: SPadPolicy::LongestQueued { fallback: 512 },
            allocation: AllocationPolicy::MinOnly,
        }
    }

    fn two_quant_config() -> ShardedConfig {
        // Same model, two quantizations: distinct deployments, so affinity
        // binds; W4A16/ZQ-Local on BLOOM-3B admits only a <= 0.08.
        ShardedConfig {
            deployments: vec![
                Deployment {
                    model: LlmSpec::bloom_3b(),
                    quant: quant::default_quant(),
                },
                Deployment {
                    model: LlmSpec::bloom_3b(),
                    quant: quant::by_label(quant::Precision::W4A16, quant::QuantAlgo::ZqLocal)
                        .unwrap(),
                },
            ],
            cluster: ClusterSpec::paper_default(),
            partition: PartitionPolicy::LoadProportional,
            policy: policy(),
            epoch: EpochParams::default(),
            radio: RadioParams::default(),
            channel: ChannelParams::default(),
            seed: 7,
        }
    }

    fn analytic(cfg: ShardedConfig) -> ShardedDriver<(), AnalyticBackend> {
        ShardedDriver::new(cfg, |_| AnalyticBackend, |_| Box::new(Dftsp::new())).unwrap()
    }

    #[test]
    fn new_rejects_more_deployments_than_gpus() {
        let mut cfg = two_quant_config();
        cfg.cluster = ClusterSpec::new(cfg.cluster.gpu.clone(), 1);
        let err = ShardedDriver::<(), _>::new(cfg, |_| AnalyticBackend, |_| {
            Box::new(Dftsp::new()) as Box<dyn Scheduler + Send>
        })
        .err()
        .expect("1 GPU cannot host 2 deployments");
        assert_eq!(
            err,
            PartitionError::InsufficientGpus {
                deployments: 2,
                total_gpus: 1
            }
        );
    }

    #[test]
    fn affinity_routes_to_the_named_deployment() {
        let mut sd = analytic(two_quant_config());
        let mut b = RequestBuilder::new();
        // Low accuracy requirement: both deployments admit it, so affinity
        // decides.
        let s = sd.offer(b.build(0.0, 128, 128, 2.0, 0.05), (), 1);
        assert_eq!(s, 1);
        assert_eq!(sd.shards()[1].driver.queue_len(), 1);
        assert_eq!(sd.shards()[0].driver.queue_len(), 0);
        let s = sd.offer(b.build(0.0, 128, 128, 2.0, 0.05), (), 0);
        assert_eq!(s, 0);
    }

    #[test]
    fn inadmissible_affinity_spills_to_feasible_shard() {
        let mut sd = analytic(two_quant_config());
        let mut b = RequestBuilder::new();
        // a=0.5: W4A16/ZQ-Local (affinity 1) cannot admit it; W8A16/GPTQ
        // can — the request must spill to shard 0, not starve on shard 1.
        let s = sd.offer(b.build(0.0, 128, 128, 2.0, 0.5), (), 1);
        assert_eq!(s, 0, "spill-over to the feasible deployment");
        // a=0.99: nobody admits it — affinity shard keeps it so the driver
        // rejects it and accounting closes.
        let s = sd.offer(b.build(0.0, 128, 128, 2.0, 0.99), (), 1);
        assert_eq!(s, 1);
        sd.step_epoch(0.0);
        sd.finish(2.0);
        let m = sd.merged_metrics();
        assert_eq!(m.offered, 2);
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped
        );
        assert!(m.dropped >= 1, "the un-admittable request was rejected");
    }

    #[test]
    fn same_deployment_shards_balance_by_queue_depth() {
        // Three identical deployments: routing ignores the affinity index
        // and balances by queue depth, ties to the lowest shard index.
        let dep = Deployment {
            model: LlmSpec::bloom_3b(),
            quant: quant::default_quant(),
        };
        let cfg = ShardedConfig {
            deployments: vec![dep.clone(), dep.clone(), dep],
            cluster: ClusterSpec::paper_default(),
            partition: PartitionPolicy::Equal,
            policy: policy(),
            epoch: EpochParams::default(),
            radio: RadioParams::default(),
            channel: ChannelParams::default(),
            seed: 3,
        };
        let mut sd = analytic(cfg);
        let mut b = RequestBuilder::new();
        let picks: Vec<usize> = (0..6)
            .map(|_| sd.offer(b.build(0.0, 128, 128, 2.0, 0.1), (), 0))
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2], "round-robin by depth");
    }

    #[test]
    fn repartition_follows_demand_and_respects_min_one() {
        let mut sd = analytic(two_quant_config());
        assert_eq!(sd.partition(), &[10, 10], "idle start is near-equal");
        let mut b = RequestBuilder::new();
        for _ in 0..30 {
            sd.offer(b.build(0.0, 256, 256, 1.9, 0.05), (), 0);
        }
        sd.offer(b.build(0.0, 128, 128, 1.9, 0.05), (), 1);
        sd.step_epoch(0.0);
        let p = sd.partition();
        assert_eq!(p.iter().sum::<usize>(), 20);
        assert!(p[0] > p[1], "loaded shard takes the headroom: {p:?}");
        assert!(p[1] >= 1, "min-1 floor holds: {p:?}");
        sd.finish(2.0);
        let m = sd.merged_metrics();
        assert_eq!(m.offered, 31);
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped
        );
    }

    #[test]
    fn parallel_step_is_deterministic() {
        let run = || {
            let mut sd = analytic(two_quant_config());
            let mut b = RequestBuilder::new();
            for e in 0..4u64 {
                let now = e as f64 * 2.0;
                for i in 0..12 {
                    sd.offer(b.build(now, 256, 256, 1.9, 0.05), (), (i % 2) as usize);
                }
                sd.step_epoch(now);
            }
            sd.finish(8.0);
            (
                sd.merged_metrics(),
                sd.shard_metrics(0).clone(),
                sd.shard_metrics(1).clone(),
            )
        };
        let (am, a0, a1) = run();
        let (bm, b0, b1) = run();
        assert_eq!(am, bm, "merged metrics bit-identical across runs");
        assert_eq!(a0, b0);
        assert_eq!(a1, b1);
        assert!(am.offered == 48);
    }

    #[test]
    fn continuous_backend_shards_conserve_and_keep_kv_floors() {
        let cfg = two_quant_config();
        let mut sd: ShardedDriver<(), ContinuousBackend> = ShardedDriver::new(
            cfg,
            ContinuousBackend::new,
            |_| Box::new(Dftsp::new()),
        )
        .unwrap();
        let mut b = RequestBuilder::new();
        for e in 0..4u64 {
            let now = e as f64 * 2.0;
            for i in 0..8 {
                sd.offer(b.build(now + 0.2 * i as f64, 256, 256, 1.9, 0.05), (), 0);
            }
            sd.offer(b.build(now, 128, 128, 1.9, 0.05), (), 1);
            sd.step_epoch(now);
            assert_eq!(sd.partition().iter().sum::<usize>(), 20);
        }
        sd.finish(8.0);
        let m = sd.merged_metrics();
        assert_eq!(m.offered, 36);
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped
        );
        for s in sd.shards() {
            assert_eq!(s.backend.in_flight(), 0, "finish drains every shard");
            assert_eq!(s.backend.ledger().in_use(), 0);
        }
    }

    #[test]
    fn apply_floors_preserves_total_and_raises_deficits() {
        assert_eq!(apply_floors(vec![8, 1, 1], &[1, 3, 1]), vec![6, 3, 1]);
        assert_eq!(apply_floors(vec![5, 5], &[1, 1]), vec![5, 5]);
        // Donor choice: largest surplus first, ties to the lowest index.
        assert_eq!(apply_floors(vec![4, 4, 0], &[1, 1, 2]), vec![3, 3, 2]);
        // Floors exactly exhaust the pool.
        assert_eq!(apply_floors(vec![3, 0, 0], &[1, 1, 1]), vec![1, 1, 1]);
    }

    #[test]
    fn shard_streams_split_deterministically() {
        assert_eq!(shard_stream(42, 0), 42, "shard 0 keeps the run stream");
        assert_ne!(shard_stream(42, 1), shard_stream(42, 2));
        assert_eq!(shard_stream(42, 1), shard_stream(42, 1));
        assert_ne!(shard_stream(42, 1), shard_stream(43, 1));
    }
}
