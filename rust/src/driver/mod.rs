//! The epoch-protocol core — the **single** implementation of the paper's
//! Fig. 2 loop, shared by the discrete-event simulator (`sim`) and the live
//! PJRT server (`serving`).
//!
//! Every epoch the driver runs the same pipeline:
//!
//! 1. apply the stale policy to the queue (simulator: best-case-infeasible;
//!    server: max-wait) and hand drops to the backend,
//! 2. freeze a [`ProblemInstance`] (padded prompt length per the s' policy,
//!    batch start time = the epoch boundary),
//! 3. draw this epoch's channel state and annotate the queue
//!    ([`EpochRequest`]s, constraint 1a/1b terms),
//! 4. reject accuracy-inadmissible requests (constraint 1e) so they cannot
//!    starve,
//! 5. ask the [`Scheduler`] for the batch and account the search effort,
//! 6. run the joint bandwidth allocation — the one `wireless::allocate`
//!    call site in the codebase,
//! 7. hand the batch to the [`ExecutionBackend`] (analytic cost model or
//!    the real engine) which records one outcome per scheduled request.
//!
//! What *varies* between the two worlds is injected: a [`Clock`] decides how
//! epoch boundaries are reached (jump vs sleep), an [`ExecutionBackend`]
//! decides how batches complete, and [`DriverPolicy`] captures the two
//! documented policy differences (stale rule, s' selection). Schedulers are
//! untouched — every policy (DFTSP, brute force, greedy, static, NoB,
//! multi-LLM) sees identical `ProblemInstance`/`EpochRequest` inputs in both
//! worlds.
//!
//! Three execution backends exist today:
//!
//! - [`AnalyticBackend`] — epoch-barrier completion from the cost model
//!   (the paper's protocol; the simulator default),
//! - `serving::EngineBackend` — real prefill/decode on the loaded engine,
//! - [`ContinuousBackend`] — **continuous batching**: decode-step admission
//!   into a persistent running batch gated by a [`KvLedger`], relaxing the
//!   epoch barrier for mid-epoch arrivals (see `continuous` module docs for
//!   the state machine and when to prefer each backend).
//!
//! Above the single-pool loop, [`ShardedDriver`] (module `sharded`) runs
//! one `EpochDriver` per GPU partition behind a dispatch layer — routing by
//! deployment affinity, KV-safe demand-driven re-partitioning, parallel
//! deterministic stepping.

pub mod backend;
pub mod chaos;
pub mod clock;
pub mod continuous;
pub mod sharded;

pub use backend::{AnalyticBackend, EpochContext, ExecutionBackend, QueuedRequest, RejectReason};
pub use chaos::{
    backoff_epochs, chaos_stream, restart_backoff_ms, ChaosBackend, ChaosConfig, Fault,
};
pub use clock::{Clock, SimClock, WallClock};
pub use continuous::{BatchingMode, ContinuousBackend, KvLedger};
pub use sharded::{
    pick_least_loaded, AutoscalePolicy, DriverBuilder, ElasticPolicy, EpochTunePolicy, Shard,
    ShardHealth, ShardedConfig, ShardedDriver, PARK_AFTER_QUICK_CRASHES,
};

use crate::cluster::ClusterSpec;
use crate::coordinator::{EpochParams, ProblemInstance, Scheduler};
use crate::metrics::Metrics;
use crate::model::CostModel;
use crate::quant::QuantSpec;
use crate::request::{EpochRequest, Request, RequestId};
use crate::util::rng::Rng;
use crate::wireless::{allocate, AllocationPolicy, ChannelParams, RadioParams};

/// Everything that stays constant across a run and is cloned into each
/// epoch's [`ProblemInstance`].
#[derive(Debug, Clone)]
pub struct InstanceTemplate {
    pub cost: CostModel,
    pub quant: QuantSpec,
    pub cluster: ClusterSpec,
    pub epoch: EpochParams,
}

impl InstanceTemplate {
    /// Best-case end-to-end service time of a solo request at full cluster
    /// speed: `T_U + β·flops/C + T_D`. The single source of the
    /// best-case-infeasible staleness formula, shared by the driver's
    /// [`StalePolicy::BestCaseInfeasible`] and the continuous backend's
    /// pending-gate screen.
    pub fn best_case_latency(&self, prompt_tokens: u32, output_tokens: u32) -> f64 {
        self.epoch.t_u
            + self.quant.beta * self.cost.total_flops_per_req(prompt_tokens, output_tokens)
                / self.cluster.total_flops()
            + self.epoch.t_d
    }
}

/// When is a queued request considered unservable and dropped?
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StalePolicy {
    /// Drop when even an immediate solo run at full cluster speed cannot
    /// meet the deadline (the simulator's rule — exact for the analytic
    /// backend).
    BestCaseInfeasible,
    /// Drop after waiting more than this many seconds (the serving rule —
    /// robust when compute time is measured, not modeled).
    MaxWait(f64),
}

/// How is the padded prompt length s' chosen each epoch?
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SPadPolicy {
    /// Always pad to a fixed length (the engine's compiled `max_prompt`).
    Fixed(u32),
    /// Pad to the longest queued prompt, or `fallback` when the queue is
    /// empty (the paper's evaluation setting).
    LongestQueued { fallback: u32 },
}

/// The per-deployment policy knobs of the epoch protocol.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriverPolicy {
    pub stale: StalePolicy,
    pub s_pad: SPadPolicy,
    /// Surplus-bandwidth distribution for the scheduled batch. `MinOnly`
    /// reproduces the paper's P1 accounting (transfers take exactly
    /// T_U/T_D); `Proportional`/`MaxMin` shorten effective transfer times.
    pub allocation: AllocationPolicy,
}

/// The shared epoch-protocol engine. Generic over the per-request payload
/// `P` the execution backend carries ( `()` for the simulator, prompt +
/// reply channel for the server).
pub struct EpochDriver<P> {
    template: InstanceTemplate,
    policy: DriverPolicy,
    radio: RadioParams,
    channel: ChannelParams,
    rng: Rng,
    queue: Vec<QueuedRequest<P>>,
    epoch_idx: u64,
    /// Consecutive-ish epoch-stall pressure (incremented on an overrun step,
    /// decremented on a healthy one) — drives the degradation ladder. Always
    /// 0 under the simulated clock, whose steps take microseconds of wall
    /// time against multi-millisecond epoch durations.
    stall_streak: u32,
    pub metrics: Metrics,
}

/// Degradation-ladder thresholds (see `step_epoch`): level 1 halves the
/// scheduler's candidate pool after this many net stalls...
const LADDER_CAP_STREAK: u32 = 2;
/// ...and level 2 additionally sheds the loosest-deadline quarter of the
/// queue after this many.
const LADDER_SHED_STREAK: u32 = 4;
/// Level 1 never shrinks the candidate pool below this.
const LADDER_MIN_POOL: usize = 8;

impl<P> EpochDriver<P> {
    pub fn new(
        template: InstanceTemplate,
        policy: DriverPolicy,
        radio: RadioParams,
        channel: ChannelParams,
        rng: Rng,
    ) -> Self {
        EpochDriver {
            template,
            policy,
            radio,
            channel,
            rng,
            queue: Vec::new(),
            epoch_idx: 0,
            stall_streak: 0,
            metrics: Metrics::new(),
        }
    }

    pub fn epoch_duration(&self) -> f64 {
        self.template.epoch.duration
    }

    pub fn epoch_idx(&self) -> u64 {
        self.epoch_idx
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn template(&self) -> &InstanceTemplate {
        &self.template
    }

    /// Replace the cluster slice this driver schedules against. Called by
    /// the sharded driver's between-epoch re-partitioning; takes effect at
    /// the next `step_epoch` (the new `ProblemInstance` is frozen then), so
    /// a batch never sees its cluster change mid-epoch.
    pub fn set_cluster(&mut self, cluster: ClusterSpec) {
        self.template.cluster = cluster;
    }

    /// Retarget the epoch length. Called by the sharded driver's
    /// epoch-duration auto-tuner between epochs; like `set_cluster`, the
    /// change is frozen into the next `ProblemInstance`, never a running one.
    pub fn set_epoch_duration(&mut self, duration: f64) {
        debug_assert!(duration.is_finite() && duration > 0.0);
        self.template.epoch.duration = duration;
    }

    /// The queued requests in queue order — the sharded driver's demand
    /// feedback signal for load-proportional re-partitioning.
    pub fn queued_requests(&self) -> impl Iterator<Item = &Request> + '_ {
        self.queue.iter().map(|e| &e.req)
    }

    /// Admit a request into the queue (schedulable from the next boundary
    /// onward — the Fig. 2 aggregation rule) and count it as offered.
    pub fn offer(&mut self, req: Request, payload: P) {
        self.metrics.record_offered(1);
        self.queue.push(QueuedRequest { req, payload });
    }

    pub fn into_metrics(self) -> Metrics {
        self.metrics
    }

    /// Pull every queued (not-yet-admitted) request out of the driver — the
    /// supervisor's redispatch hook after a crash. Queue entries hold no KV
    /// state, so they are the only work that may migrate to another shard
    /// (the sharded module's KV-safety rule); anything the backend had in
    /// flight is accounted by conservation instead.
    pub fn drain_queue(&mut self) -> Vec<QueuedRequest<P>> {
        std::mem::take(&mut self.queue)
    }

    /// Put previously drained entries back into the queue *without*
    /// re-counting them as offered — the supervisor's restart hook: a
    /// rebuilt shard inherits whatever queued on it while it was down (those
    /// arrivals were counted `offered` when first admitted).
    pub fn requeue(&mut self, entries: Vec<QueuedRequest<P>>) {
        self.queue.extend(entries);
    }

    /// The newest queued request, if any — what a steal would take. The
    /// elastic steal pass inspects this before committing so the thief's
    /// KV gate and the imbalance rule are checked against the actual entry.
    pub fn back_request(&self) -> Option<&Request> {
        self.queue.last().map(|e| &e.req)
    }

    /// Pop the most-recently queued entry — elastic work stealing's donor
    /// hook. Taking from the back preserves strict FCFS among the donor's
    /// remaining waiters and migrates the arrival with the most deadline
    /// slack left. Metrics are untouched here: the caller moves the
    /// `offered` count together with the request (decrement on the donor,
    /// re-count through the thief's `offer`), exactly the redispatch rule.
    pub fn steal_from_back(&mut self) -> Option<QueuedRequest<P>> {
        self.queue.pop()
    }

    fn is_stale(&self, r: &Request, now: f64) -> bool {
        match self.policy.stale {
            StalePolicy::BestCaseInfeasible => {
                let best_case = self
                    .template
                    .best_case_latency(r.prompt_tokens, r.output_tokens);
                r.waited(now) + best_case > r.latency_req
            }
            StalePolicy::MaxWait(max_wait) => r.waited(now) > max_wait,
        }
    }

    /// One full round of the Fig. 2 protocol at epoch boundary `now`.
    ///
    /// A wall-clock watchdog brackets the step: when the step's own work
    /// exceeds the configured epoch duration it counts an
    /// [`Metrics::epoch_stalls`] and raises the stall streak; under
    /// sustained pressure a two-level degradation ladder kicks in (shrink
    /// the scheduler's candidate pool, then shed the loosest-deadline
    /// arrivals with typed [`RejectReason::Overloaded`] rejections) so the
    /// shard degrades gracefully instead of falling behind unboundedly.
    /// Ladder behavior is wall-dependent by design and never fires under
    /// the simulated clock (steps take microseconds), so it is excluded
    /// from the bit-determinism contracts.
    pub fn step_epoch<B>(&mut self, scheduler: &mut dyn Scheduler, backend: &mut B, now: f64)
    where
        B: ExecutionBackend<Payload = P>,
    {
        let step_start = std::time::Instant::now();

        // 0. Degradation ladder, level 2: under sustained stalls, shed the
        //    loosest-deadline quarter of the queue (ties to the lowest id)
        //    with a typed overloaded rejection — the requests most likely to
        //    still make their SLO elsewhere, and the cheapest way to get the
        //    step back under its budget.
        if self.stall_streak >= LADDER_SHED_STREAK && !self.queue.is_empty() {
            let mut order: Vec<(f64, RequestId)> = self
                .queue
                .iter()
                .map(|e| (e.req.latency_req, e.req.id))
                .collect();
            order.sort_by(|a, b| {
                b.0.partial_cmp(&a.0)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.1.cmp(&b.1))
            });
            let shed: Vec<RequestId> = order[..(order.len() / 4).max(1)]
                .iter()
                .map(|&(_, id)| id)
                .collect();
            let queue = std::mem::take(&mut self.queue);
            for entry in queue {
                if shed.contains(&entry.req.id) {
                    self.metrics.shed_overloaded += 1;
                    backend.reject(entry, RejectReason::Overloaded, &mut self.metrics);
                } else {
                    self.queue.push(entry);
                }
            }
        }

        // 1. Stale policy: drop queued requests that can no longer be served.
        let queue = std::mem::take(&mut self.queue);
        for entry in queue {
            if self.is_stale(&entry.req, now) {
                backend.reject(entry, RejectReason::Stale, &mut self.metrics);
            } else {
                self.queue.push(entry);
            }
        }
        self.metrics.queue_depth.push(self.queue.len() as f64);

        // 2. Freeze this epoch's problem instance.
        let s_pad = match self.policy.s_pad {
            SPadPolicy::Fixed(s) => s,
            SPadPolicy::LongestQueued { fallback } => self
                .queue
                .iter()
                .map(|e| e.req.prompt_tokens)
                .max()
                .unwrap_or(fallback),
        };
        let (t_u, t_d) = (self.template.epoch.t_u, self.template.epoch.t_d);
        let inst = ProblemInstance::new(
            self.template.cost.clone(),
            self.template.quant.clone(),
            self.template.cluster.clone(),
            self.template.epoch.clone(),
            s_pad,
            now,
        );

        // 3. Annotate the queue with this epoch's channel state (one draw
        //    per queued request, in queue order — the determinism contract).
        let mut annotated: Vec<EpochRequest> = Vec::with_capacity(self.queue.len());
        for e in &self.queue {
            let h = self.channel.draw_h(&mut self.rng);
            annotated.push(EpochRequest::annotate(e.req.clone(), h, &self.radio, t_u, t_d));
        }

        // 4. Reject requests the deployed quantization can never satisfy
        //    (accuracy admission is workload-independent — they would
        //    otherwise sit in the queue forever).
        let inadmissible: Vec<RequestId> = annotated
            .iter()
            .filter(|r| !inst.admits(r))
            .map(|r| r.id())
            .collect();
        if !inadmissible.is_empty() {
            let queue = std::mem::take(&mut self.queue);
            for entry in queue {
                if inadmissible.contains(&entry.req.id) {
                    backend.reject(entry, RejectReason::Inadmissible, &mut self.metrics);
                } else {
                    self.queue.push(entry);
                }
            }
            annotated.retain(|r| !inadmissible.contains(&r.id()));
        }

        // 4b. Degradation ladder, level 1: under stall pressure, halve the
        //     scheduler's candidate pool to the earliest-deadline half (the
        //     DFTSP search is the dominant step cost and superlinear in the
        //     pool size). Excess requests simply stay queued for the next
        //     epoch — no outcome is recorded for them. The channel draws in
        //     step 3 already happened for the whole queue, so the RNG stream
        //     advances identically whether or not the ladder engages.
        if self.stall_streak >= LADDER_CAP_STREAK && annotated.len() > LADDER_MIN_POOL {
            let cap = (annotated.len() / 2).max(LADDER_MIN_POOL);
            if annotated.len() > cap {
                let mut order: Vec<(f64, RequestId)> = annotated
                    .iter()
                    .map(|r| (r.req.arrival + r.req.latency_req, r.id()))
                    .collect();
                order.sort_by(|a, b| {
                    a.0.partial_cmp(&b.0)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.1.cmp(&b.1))
                });
                let keep: Vec<RequestId> = order[..cap].iter().map(|&(_, id)| id).collect();
                annotated.retain(|r| keep.contains(&r.id()));
            }
        }

        // 5. Schedule and account the search effort, stamping wall time here
        //    so every Scheduler gets timed identically (the counters stay
        //    bit-deterministic; SearchStats::PartialEq ignores wall time).
        let sched_start = std::time::Instant::now();
        let mut schedule = scheduler.schedule(&inst, &annotated);
        schedule.stats.schedule_wall_s = sched_start.elapsed().as_secs_f64();
        self.metrics
            .record_schedule(schedule.batch_size(), &schedule.stats);

        // 6. Pull the scheduled entries out of the queue (order preserved).
        let mut batch: Vec<QueuedRequest<P>> = Vec::new();
        if !schedule.scheduled.is_empty() {
            let queue = std::mem::take(&mut self.queue);
            for entry in queue {
                if schedule.scheduled.contains(&entry.req.id) {
                    batch.push(entry);
                } else {
                    self.queue.push(entry);
                }
            }
        }

        // 7. Joint bandwidth allocation — the single allocator call site.
        let selected: Vec<&EpochRequest> = annotated
            .iter()
            .filter(|r| schedule.scheduled.contains(&r.id()))
            .collect();
        let allocations = allocate(&selected, &self.radio, t_u, t_d, self.policy.allocation);

        // 8. Execute: the backend records one outcome per scheduled request.
        let ctx = EpochContext {
            inst: &inst,
            annotated: &annotated,
            allocations: &allocations,
            now,
            epoch_idx: self.epoch_idx,
        };
        backend.execute(&ctx, &schedule, batch, &mut self.metrics);
        self.epoch_idx += 1;

        // 9. Epoch watchdog: charge a stall when this step's own work blew
        //    the epoch budget, and track net pressure for the ladder. The
        //    streak decays one level per healthy epoch so a transient blip
        //    never triggers degradation, but sustained overload does.
        if step_start.elapsed().as_secs_f64() > self.template.epoch.duration {
            self.metrics.epoch_stalls += 1;
            self.stall_streak += 1;
        } else {
            self.stall_streak = self.stall_streak.saturating_sub(1);
        }
    }

    /// Close the run: whatever still waits is unserved, then the backend
    /// drains anything it holds in flight (continuous batching keeps
    /// requests decoding across epoch boundaries); `horizon` is the
    /// simulated (or wall) time the run covered.
    pub fn finish<B>(&mut self, backend: &mut B, horizon: f64)
    where
        B: ExecutionBackend<Payload = P>,
    {
        for entry in std::mem::take(&mut self.queue) {
            backend.reject(entry, RejectReason::Shutdown, &mut self.metrics);
        }
        backend.finish(horizon, &mut self.metrics);
        self.metrics.horizon = horizon;
    }
}

/// Drive `epochs` rounds of the protocol against a clock: wait to each
/// boundary, ingest new arrivals (`ingest` is the adapter's intake — the
/// workload generator for the simulator, the mpsc drain for the server),
/// then step. Epochs whose own work exceeded the epoch duration are counted
/// in `Metrics::epoch_overruns` (the wall clock then starts the next epoch
/// immediately instead of sleeping backwards).
pub fn run_epochs<P, B, C, F>(
    driver: &mut EpochDriver<P>,
    scheduler: &mut dyn Scheduler,
    backend: &mut B,
    clock: &mut C,
    epochs: u64,
    mut ingest: F,
) where
    B: ExecutionBackend<Payload = P>,
    C: Clock + ?Sized,
    F: FnMut(&mut EpochDriver<P>, &mut B, f64),
{
    let duration = driver.epoch_duration();
    for e in 0..epochs {
        let boundary = e as f64 * duration;
        let now = clock.wait_until(boundary);
        ingest(&mut *driver, &mut *backend, now);
        driver.step_epoch(&mut *scheduler, &mut *backend, now);
        // Charge an overrun to an epoch whose *own* work exceeded the slot
        // (comparing against the absolute next boundary instead would also
        // count every epoch that merely started late after one stall).
        if clock.now() - now > duration {
            driver.metrics.epoch_overruns += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::coordinator::Dftsp;
    use crate::model::LlmSpec;
    use crate::quant;
    use crate::request::RequestBuilder;

    fn paper_template() -> InstanceTemplate {
        InstanceTemplate {
            cost: CostModel::new(LlmSpec::bloom_3b()),
            quant: quant::default_quant(),
            cluster: ClusterSpec::paper_default(),
            epoch: EpochParams::default(),
        }
    }

    fn driver(policy: DriverPolicy) -> EpochDriver<()> {
        EpochDriver::new(
            paper_template(),
            policy,
            RadioParams::default(),
            ChannelParams::default(),
            Rng::new(42),
        )
    }

    fn sim_policy() -> DriverPolicy {
        DriverPolicy {
            stale: StalePolicy::BestCaseInfeasible,
            s_pad: SPadPolicy::LongestQueued { fallback: 512 },
            allocation: AllocationPolicy::MinOnly,
        }
    }

    #[test]
    fn conservation_through_driver() {
        let mut d = driver(sim_policy());
        let mut sched = Dftsp::new();
        let mut backend = AnalyticBackend;
        let mut b = RequestBuilder::new();
        for e in 0..6u64 {
            let now = e as f64 * 2.0;
            for _ in 0..4 {
                d.offer(b.build(now, 128, 128, 1.8, 0.3), ());
            }
            d.step_epoch(&mut sched, &mut backend, now);
        }
        d.finish(&mut backend, 12.0);
        let m = d.into_metrics();
        assert_eq!(m.offered, 24);
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped,
            "conservation of requests"
        );
        assert!(m.completed_in_deadline > 0);
        assert!((m.horizon - 12.0).abs() < 1e-12);
    }

    #[test]
    fn max_wait_policy_drops_old_requests() {
        let mut d = driver(DriverPolicy {
            stale: StalePolicy::MaxWait(1.0),
            ..sim_policy()
        });
        let mut backend = AnalyticBackend;
        // A scheduler that never schedules, so the queue only drains by
        // staleness.
        struct Never;
        impl Scheduler for Never {
            fn name(&self) -> &'static str {
                "never"
            }
            fn schedule(
                &mut self,
                _inst: &ProblemInstance,
                _c: &[EpochRequest],
            ) -> crate::coordinator::Schedule {
                crate::coordinator::Schedule::empty()
            }
        }
        let mut sched = Never;
        let mut b = RequestBuilder::new();
        d.offer(b.build(0.0, 128, 128, 60.0, 0.0), ());
        d.step_epoch(&mut sched, &mut backend, 0.0);
        assert_eq!(d.queue_len(), 1, "fresh request stays queued");
        d.step_epoch(&mut sched, &mut backend, 2.0);
        assert_eq!(d.queue_len(), 0, "waited 2 s > max 1 s: dropped");
        assert_eq!(d.metrics.dropped, 1);
    }

    #[test]
    fn run_epochs_counts_overruns() {
        // A clock whose time leaps 10 s at every observation: every epoch
        // finishes past its boundary.
        struct Laggy {
            now: f64,
        }
        impl Clock for Laggy {
            fn now(&mut self) -> f64 {
                self.now += 10.0;
                self.now
            }
            fn wait_until(&mut self, t: f64) -> f64 {
                if t > self.now {
                    self.now = t;
                }
                self.now
            }
        }
        let mut d = driver(DriverPolicy {
            stale: StalePolicy::MaxWait(1e9),
            ..sim_policy()
        });
        let mut sched = Dftsp::new();
        let mut backend = AnalyticBackend;
        let mut clock = Laggy { now: 0.0 };
        run_epochs(&mut d, &mut sched, &mut backend, &mut clock, 4, |_, _, _| {});
        assert_eq!(d.metrics.epoch_overruns, 4);

        // The exact sim clock never overruns.
        let mut d2 = driver(sim_policy());
        let mut clock2 = SimClock::new();
        run_epochs(&mut d2, &mut sched, &mut backend, &mut clock2, 4, |_, _, _| {});
        assert_eq!(d2.metrics.epoch_overruns, 0);
    }

    /// Scheduler stub that records how many candidates it was shown and
    /// schedules nothing — isolates the ladder's pool capping.
    struct CountPool {
        seen: Vec<usize>,
    }
    impl Scheduler for CountPool {
        fn name(&self) -> &'static str {
            "count-pool"
        }
        fn schedule(
            &mut self,
            _inst: &ProblemInstance,
            c: &[EpochRequest],
        ) -> crate::coordinator::Schedule {
            self.seen.push(c.len());
            crate::coordinator::Schedule::empty()
        }
    }

    #[test]
    fn watchdog_counts_stalls_when_step_exceeds_epoch_budget() {
        let mut t = paper_template();
        t.epoch.duration = 0.0; // any step overruns a zero budget
        let mut d: EpochDriver<()> = EpochDriver::new(
            t,
            sim_policy(),
            RadioParams::default(),
            ChannelParams::default(),
            Rng::new(3),
        );
        let mut sched = Dftsp::new();
        let mut backend = AnalyticBackend;
        for e in 0..3 {
            d.step_epoch(&mut sched, &mut backend, e as f64);
        }
        assert_eq!(d.metrics.epoch_stalls, 3);
        assert_eq!(d.stall_streak, 3);

        // A sane budget: sim steps take microseconds, stalls never fire and
        // the streak decays back to zero.
        let mut d2 = driver(sim_policy());
        d2.stall_streak = 2;
        d2.step_epoch(&mut sched, &mut backend, 0.0);
        assert_eq!(d2.metrics.epoch_stalls, 0);
        assert_eq!(d2.stall_streak, 1);
    }

    #[test]
    fn ladder_level1_halves_the_candidate_pool() {
        let mut d = driver(sim_policy());
        let mut sched = CountPool { seen: Vec::new() };
        let mut backend = AnalyticBackend;
        let mut b = RequestBuilder::new();
        for _ in 0..20 {
            d.offer(b.build(0.0, 128, 128, 1000.0, 0.01), ());
        }
        d.stall_streak = LADDER_CAP_STREAK;
        d.step_epoch(&mut sched, &mut backend, 0.0);
        assert_eq!(sched.seen, vec![10], "pool halved under stall pressure");
        assert_eq!(d.queue_len(), 20, "excess candidates stay queued, not dropped");

        // No pressure: the full pool is offered.
        let mut d2 = driver(sim_policy());
        let mut b2 = RequestBuilder::new();
        for _ in 0..20 {
            d2.offer(b2.build(0.0, 128, 128, 1000.0, 0.01), ());
        }
        let mut sched2 = CountPool { seen: Vec::new() };
        d2.step_epoch(&mut sched2, &mut backend, 0.0);
        assert_eq!(sched2.seen, vec![20]);
    }

    #[test]
    fn ladder_level2_sheds_loosest_deadline_quarter() {
        let mut d = driver(sim_policy());
        let mut sched = CountPool { seen: Vec::new() };
        let mut backend = AnalyticBackend;
        let mut b = RequestBuilder::new();
        // Four tight deadlines, four loose: the loose ones are shed first.
        for i in 0..8u32 {
            let slack = if i % 2 == 0 { 1000.0 } else { 2000.0 };
            d.offer(b.build(0.0, 128, 128, slack, 0.01), ());
        }
        d.stall_streak = LADDER_SHED_STREAK;
        d.step_epoch(&mut sched, &mut backend, 0.0);
        assert_eq!(d.metrics.shed_overloaded, 2, "8/4 loosest shed");
        assert_eq!(d.metrics.dropped, 2, "sheds record a Dropped outcome");
        assert_eq!(d.queue_len(), 6);
        assert!(
            d.queued_requests().filter(|r| r.latency_req > 1500.0).count() == 2,
            "the loosest-deadline requests were preferred for shedding"
        );
        assert_eq!(
            d.metrics.offered,
            d.metrics.dropped + d.queue_len() as u64,
            "conservation through the shed"
        );
    }

    #[test]
    fn inadmissible_requests_rejected_not_starved() {
        let mut t = paper_template();
        // W4A16/ZQ-Local on BLOOM-3B admits only a <= 0.08.
        t.quant = quant::by_label(quant::Precision::W4A16, quant::QuantAlgo::ZqLocal).unwrap();
        let mut d: EpochDriver<()> = EpochDriver::new(
            t,
            sim_policy(),
            RadioParams::default(),
            ChannelParams::default(),
            Rng::new(1),
        );
        let mut sched = Dftsp::new();
        let mut backend = AnalyticBackend;
        let mut b = RequestBuilder::new();
        d.offer(b.build(0.0, 128, 128, 3600.0, 0.9), ()); // unservable accuracy
        d.offer(b.build(0.0, 128, 128, 2.0, 0.01), ()); // fine
        d.step_epoch(&mut sched, &mut backend, 0.0);
        assert_eq!(d.metrics.dropped, 1, "strict-accuracy request rejected");
        assert_eq!(d.metrics.completed_in_deadline, 1);
        assert_eq!(d.queue_len(), 0);
    }
}
