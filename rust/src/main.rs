//! `edgellm` — CLI launcher for the edge-LLM serving stack.
//!
//! Subcommands:
//!   simulate      run the discrete-event simulator (paper §IV testbed)
//!   compare       run all batching policies on one scenario and tabulate
//!   serve         serve the tiny real model through PJRT with DFTSP batching
//!   loadtest      loopback TCP load harness against synthetic engines
//!   elastic-bench sharded skewed-fleet benchmark, work stealing off vs on
//!   catalog       print the model and quantization catalogs
//!
//! Scenario files are TOML (see `config` module docs); every flag falls back
//! to the paper's §IV defaults.

use edgellm::config;
use edgellm::coordinator::{
    BruteForce, Dftsp, NoBatching, Scheduler, SchedulerConfig, StaticBatching,
};
use edgellm::model::LlmSpec;
use edgellm::quant;
use edgellm::runtime::Engine;
use edgellm::serving::{EpochServer, ServeRequest, ServerConfig};
use edgellm::sim;
use edgellm::util::cli::Args;
use edgellm::util::fmt::Table;
use std::path::Path;

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("compare") => cmd_compare(&args),
        Some("serve") => cmd_serve(&args),
        Some("loadtest") => cmd_loadtest(&args),
        Some("elastic-bench") => cmd_elastic_bench(&args),
        Some("catalog") => cmd_catalog(),
        _ => {
            eprintln!(
                "usage: edgellm <simulate|compare|serve|loadtest|elastic-bench|catalog> \
                 [--config FILE] \
                 [--scheduler dftsp|stb|nob|brute] [--batching epoch|continuous] [--rate R] \
                 [--epochs N] [--model NAME] [--quant LABEL] [--seed S] \
                 [--workers N] [--shards N] [--partition equal|load-proportional] \
                 [--steal] [--autoscale MIN:MAX] [--tune-epoch MIN:MAX] [--stats] \
                 [--listen ADDR] [--pending-cap N] [--clients N] [--quick] [--json] \
                 [--io-model threaded|evented] [--event-threads N] [--max-conns-per-peer N] \
                 [--chaos] [--chaos-seed S] [--chaos-panic P] [--chaos-stall P] \
                 [--chaos-stall-ms MS] [--chaos-error P] [--chaos-kv-fail P]\n\
                 (`--shards N` is the homogeneous shim for the `[[cluster.shard]]` \
                 topology tables; see the config module docs)"
            );
            2
        }
    };
    std::process::exit(code);
}

fn build_config(args: &Args) -> Result<sim::SimConfig, String> {
    let mut cfg = match args.get("config") {
        Some(path) => config::load_scenario(Path::new(path))?,
        None => sim::SimConfig::paper_default(),
    };
    if let Some(rate) = args.get("rate") {
        cfg.workload.arrival_rate = rate.parse().map_err(|_| "bad --rate")?;
    }
    if let Some(epochs) = args.get("epochs") {
        cfg.epochs = epochs.parse().map_err(|_| "bad --epochs")?;
    }
    if let Some(model) = args.get("model") {
        cfg.model = LlmSpec::by_name(model).ok_or_else(|| format!("unknown model `{model}`"))?;
    }
    if let Some(q) = args.get("quant") {
        cfg.quant = config::parse_quant_label(q)?;
    }
    if let Some(seed) = args.get("seed") {
        cfg.seed = seed.parse().map_err(|_| "bad --seed")?;
    }
    if let Some(mode) = args.get("batching") {
        cfg.batching = edgellm::driver::BatchingMode::parse(mode)?;
    }
    if let Some(workers) = args.get("workers") {
        cfg.scheduler.workers = workers.parse().map_err(|_| "bad --workers")?;
    }
    if let Some(shards) = args.get("shards") {
        cfg.shards = shards.parse().map_err(|_| "bad --shards")?;
        if cfg.shards == 0 {
            return Err("--shards must be >= 1".into());
        }
        if let Some(t) = &cfg.topology {
            // The scenario file pinned an explicit [[cluster.shard]] layout;
            // the homogeneous shim cannot override it, only agree with it.
            if cfg.shards != t.shard_count() {
                return Err(format!(
                    "--shards {} disagrees with the scenario's {}-shard topology",
                    cfg.shards,
                    t.shard_count()
                ));
            }
        } else if cfg.shards > cfg.cluster.num_gpus {
            return Err(format!(
                "--shards {} exceeds the {}-GPU cluster (every shard needs a GPU)",
                cfg.shards, cfg.cluster.num_gpus
            ));
        }
    }
    if let Some(p) = args.get("partition") {
        cfg.partition = edgellm::coordinator::PartitionPolicy::parse(p)?;
    }
    // Elastic flags mirror the `[elastic]` TOML section; CLI wins.
    fn parse_bounds(v: &str, flag: &str) -> Result<(f64, f64), String> {
        let (lo, hi) = v
            .split_once(':')
            .ok_or_else(|| format!("--{flag} wants MIN:MAX"))?;
        let lo: f64 = lo.parse().map_err(|_| format!("bad --{flag} MIN"))?;
        let hi: f64 = hi.parse().map_err(|_| format!("bad --{flag} MAX"))?;
        Ok((lo, hi))
    }
    if args.flag("steal") {
        cfg.elastic.stealing = true;
    }
    if let Some(v) = args.get("autoscale") {
        let (lo, hi) = parse_bounds(v, "autoscale")?;
        if !(lo >= 1.0 && hi >= lo && lo.fract() == 0.0 && hi.fract() == 0.0) {
            return Err("--autoscale wants integer bounds with 1 <= MIN <= MAX".into());
        }
        cfg.elastic.autoscale = Some(edgellm::driver::AutoscalePolicy::new(
            lo as usize,
            hi as usize,
        ));
    }
    if let Some(v) = args.get("tune-epoch") {
        let (lo, hi) = parse_bounds(v, "tune-epoch")?;
        if !(lo > 0.0 && hi >= lo) {
            return Err("--tune-epoch wants 0 < MIN <= MAX seconds".into());
        }
        cfg.elastic.tune_epoch = Some(edgellm::driver::EpochTunePolicy::new(lo, hi));
    }
    // Chaos flags mirror the `[chaos]` TOML section; CLI wins over the file.
    fn chaos_prob(args: &Args, flag: &str, current: f64) -> Result<f64, String> {
        let Some(v) = args.get(flag) else {
            return Ok(current);
        };
        let p: f64 = v.parse().map_err(|_| format!("bad --{flag}"))?;
        if !(0.0..=1.0).contains(&p) {
            return Err(format!("--{flag} must be within [0, 1]"));
        }
        Ok(p)
    }
    if let Some(v) = args.get("chaos-seed") {
        cfg.chaos.seed = v.parse().map_err(|_| "bad --chaos-seed")?;
    }
    if let Some(v) = args.get("chaos-stall-ms") {
        cfg.chaos.stall_ms = v.parse().map_err(|_| "bad --chaos-stall-ms")?;
    }
    cfg.chaos.panic_prob = chaos_prob(args, "chaos-panic", cfg.chaos.panic_prob)?;
    cfg.chaos.stall_prob = chaos_prob(args, "chaos-stall", cfg.chaos.stall_prob)?;
    cfg.chaos.error_prob = chaos_prob(args, "chaos-error", cfg.chaos.error_prob)?;
    cfg.chaos.kv_fail_prob = chaos_prob(args, "chaos-kv-fail", cfg.chaos.kv_fail_prob)?;
    // The supervised chaos path pins a fixed shard set; autoscaling moves
    // it. (The scenario loader rejects the TOML combination; this catches
    // the flag mix.)
    if cfg.chaos.enabled() && cfg.elastic.autoscale.is_some() {
        return Err("--autoscale and chaos fault injection are mutually exclusive \
                    (supervision needs a fixed shard set)"
            .into());
    }
    Ok(cfg)
}

/// Front-end knobs shared by `serve --listen` and `loadtest`.
fn net_config(args: &Args) -> Result<edgellm::serving::NetConfig, String> {
    let base = edgellm::serving::NetConfig::default();
    let io_model = match args.get("io-model") {
        Some(s) => edgellm::serving::IoModel::parse(s)?,
        None => base.io_model,
    };
    Ok(edgellm::serving::NetConfig {
        max_output_tokens: args.u64_or("max-output-tokens", base.max_output_tokens as u64) as u32,
        pending_cap: args.usize_or("pending-cap", base.pending_cap),
        idle_timeout: std::time::Duration::from_secs_f64(
            args.f64_or("idle-timeout-s", base.idle_timeout.as_secs_f64()),
        ),
        reply_timeout: std::time::Duration::from_secs_f64(
            args.f64_or("reply-timeout-s", base.reply_timeout.as_secs_f64()),
        ),
        max_line_bytes: base.max_line_bytes,
        io_model,
        event_threads: args.usize_or("event-threads", base.event_threads),
        max_conns_per_peer: args.usize_or("max-conns-per-peer", base.max_conns_per_peer),
    })
}

fn make_scheduler(name: &str, cfg: SchedulerConfig) -> Result<Box<dyn Scheduler + Send>, String> {
    match name.to_ascii_lowercase().as_str() {
        "dftsp" => Ok(Box::new(Dftsp::with_config(cfg))),
        "stb" => Ok(Box::new(StaticBatching::new())),
        "nob" => Ok(Box::new(NoBatching::new())),
        "brute" => Ok(Box::new(BruteForce::default())),
        other => Err(format!("unknown scheduler `{other}`")),
    }
}

/// Injected chaos panics are expected control flow — the shard supervisor
/// catches every one — so suppress their default stderr spew (payloads all
/// carry the "chaos: injected" marker) while forwarding real panics to the
/// original hook untouched.
fn silence_injected_panics() {
    let default_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        let payload = info.payload();
        let injected = payload
            .downcast_ref::<&str>()
            .map(|m| m.contains("chaos: injected"))
            .or_else(|| {
                payload
                    .downcast_ref::<String>()
                    .map(|m| m.contains("chaos: injected"))
            })
            .unwrap_or(false);
        if !injected {
            default_hook(info);
        }
    }));
}

fn cmd_simulate(args: &Args) -> i32 {
    let cfg = match build_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let sched_name = args.str_or("scheduler", "dftsp");
    let mut sched = match make_scheduler(&sched_name, cfg.scheduler) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let show_stats = args.flag("stats");
    println!(
        "model {}  quant {}  λ={} req/s  {} epochs × {} s  cluster {}×{}  batching {}{}",
        cfg.model.name,
        cfg.quant.label(),
        cfg.workload.arrival_rate,
        cfg.epochs,
        cfg.epoch.duration,
        cfg.cluster.num_gpus,
        cfg.cluster.gpu.name,
        cfg.batching,
        if cfg.shard_count() > 1 {
            format!("  shards {} ({})", cfg.shard_count(), cfg.partition)
        } else {
            String::new()
        }
    );
    if let Some(t) = &cfg.topology {
        let layout: Vec<String> = t
            .shards
            .iter()
            .map(|s| format!("{}×{}", s.num_gpus, s.gpu.name))
            .collect();
        println!("topology: {}", layout.join(" + "));
    }
    if cfg.elastic.stealing || cfg.elastic.autoscale.is_some() || cfg.elastic.tune_epoch.is_some()
    {
        println!(
            "elastic: stealing {}  autoscale {}  tune-epoch {}",
            if cfg.elastic.stealing { "on" } else { "off" },
            cfg.elastic
                .autoscale
                .map_or_else(|| "off".to_string(), |a| format!(
                    "[{}, {}]",
                    a.min_shards, a.max_shards
                )),
            cfg.elastic
                .tune_epoch
                .map_or_else(|| "off".to_string(), |t| format!(
                    "[{} s, {} s]",
                    t.min_duration, t.max_duration
                )),
        );
    }
    let m = if cfg.chaos.enabled() {
        println!(
            "chaos: seed {}  panic {}  stall {} ({} ms)  error {}  kv-fail {}",
            cfg.chaos.seed,
            cfg.chaos.panic_prob,
            cfg.chaos.stall_prob,
            cfg.chaos.stall_ms,
            cfg.chaos.error_prob,
            cfg.chaos.kv_fail_prob
        );
        silence_injected_panics();
        // Fault injection runs the supervised sharded path even at
        // --shards 1 (one supervised shard): crash isolation and restart
        // accounting need the supervisor in the loop.
        let sched_name = sched_name.clone();
        let sched_cfg = cfg.scheduler;
        sim::run_chaos(&cfg, move |_| {
            make_scheduler(&sched_name, sched_cfg).expect("scheduler name already validated")
        })
    } else if cfg.wants_sharded() {
        // One fresh scheduler per shard (validated above). The factory
        // takes 'static ownership — the autoscaler may keep it for spawns.
        let sched_name = sched_name.clone();
        let sched_cfg = cfg.scheduler;
        sim::run_sharded(&cfg, move |_| {
            make_scheduler(&sched_name, sched_cfg).expect("scheduler name already validated")
        })
    } else {
        sim::run(&cfg, sched.as_mut())
    };
    print!("{}", m.report(sched.name()));
    if show_stats {
        print!("{}", m.search_report());
    }
    0
}

fn cmd_compare(args: &Args) -> i32 {
    let cfg = match build_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let show_stats = args.flag("stats");
    let results = if cfg.wants_sharded() {
        // Sharded comparison: each policy gets one fresh scheduler per
        // shard, same seeded workload (run_sharded regenerates it).
        ["dftsp", "stb", "nob"]
            .iter()
            .map(|name| {
                // One construction up front supplies the display name; the
                // 'static closure then builds the real per-shard instances.
                let display = make_scheduler(name, cfg.scheduler)
                    .expect("known scheduler names")
                    .name()
                    .to_string();
                let name = *name;
                let sched_cfg = cfg.scheduler;
                let m = sim::run_sharded(&cfg, move |_| {
                    make_scheduler(name, sched_cfg).expect("known scheduler names")
                });
                (display, m)
            })
            .collect()
    } else {
        sim::compare(
            &cfg,
            vec![
                Box::new(Dftsp::with_config(cfg.scheduler)),
                Box::new(StaticBatching::new()),
                Box::new(NoBatching::new()),
            ],
        )
    };
    let mut t = Table::new(&[
        "scheduler",
        "throughput (req/s)",
        "goodput %",
        "mean batch",
        "p95 latency (s)",
    ]);
    for (name, m) in &results {
        t.row(&[
            name.clone(),
            format!("{:.2}", m.throughput()),
            format!("{:.1}", 100.0 * m.goodput_ratio()),
            format!("{:.1}", m.batch_sizes.mean()),
            format!("{:.3}", m.latency.quantile(0.95)),
        ]);
    }
    print!("{}", t.render());
    if show_stats {
        for (name, m) in &results {
            println!("-- {name} --");
            print!("{}", m.search_report());
        }
    }
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let artifacts = args.str_or("artifacts", "artifacts");
    let quant_label = args.str_or("quant", "W16A16");
    let epochs = args.u64_or("epochs", 10);
    let clients = args.u64_or("clients", 4);
    let rate = args.f64_or("rate", 4.0);
    let seed = args.u64_or("seed", 7);
    let net_cfg = match net_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };

    let engine = match Engine::load(Path::new(&artifacts), &quant_label) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("engine load failed: {e}\n(run `make artifacts` first)");
            return 1;
        }
    };
    println!(
        "engine up: {} on {} ({} batch variants, quant {})",
        engine.meta.model_name,
        engine.platform(),
        engine.meta.batch_variants.len(),
        quant_label
    );
    let mut server_cfg = ServerConfig::default();
    if let Some(mode) = args.get("batching") {
        match edgellm::driver::BatchingMode::parse(mode) {
            Ok(m) => server_cfg.batching = m,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    server_cfg.scheduler.workers = args.u64_or("workers", 0) as usize;
    let show_stats = args.flag("stats");
    let epoch_s = server_cfg.epoch.duration;
    println!("batching mode: {}", server_cfg.batching);

    // Sharded serving: N servers in this process, each on its own thread
    // with its own engine instance (disjoint KV arenas); clients round-robin
    // over the shard handles.
    let shards = args.u64_or("shards", 1) as usize;
    if shards == 0 {
        eprintln!("--shards must be >= 1");
        return 2;
    }
    if args.get("partition").is_some() {
        // Serving shards each own a whole engine; GPU re-partitioning is a
        // simulate/compare knob. Refuse rather than silently ignore.
        eprintln!("--partition applies to simulate/compare (serving shards each own their engine)");
        return 2;
    }
    if shards > 1 {
        drop(engine); // validated loadable; each shard loads its own copy
        let horizon = epochs as f64 * epoch_s;
        let base_cfg = server_cfg.clone();
        let artifacts_dir = artifacts.clone();
        let net_cfg = net_cfg.clone();
        // Net counters escape the drive closure so they merge into the
        // cross-shard report below.
        let mut net_metrics: Option<edgellm::metrics::Metrics> = None;
        let per_shard = edgellm::serving::serve_sharded(
            shards,
            epochs,
            |shard| {
                let engine = Engine::load(Path::new(&artifacts_dir), &quant_label)
                    .expect("engine loaded once already");
                let cfg = ServerConfig {
                    seed: base_cfg.seed ^ (shard as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    ..base_cfg.clone()
                };
                EpochServer::new(engine, cfg, Box::new(Dftsp::with_config(base_cfg.scheduler)))
            },
            |handles| {
                // Optional TCP front-end over every shard: the router
                // matches the wire `model` field against each shard's
                // deployment, least-loaded gate among candidates.
                let listener = args.get("listen").and_then(|addr| {
                    let bpe =
                        edgellm::tokenizer::Bpe::load(&Path::new(&artifacts_dir).join("bpe.json"))
                            .ok();
                    let router = edgellm::serving::Router::new(
                        handles
                            .iter()
                            .map(|h| (h.model.clone(), h.handle.clone()))
                            .collect(),
                        net_cfg.pending_cap,
                    );
                    match edgellm::serving::spawn_listener(addr, router, bpe, net_cfg.clone()) {
                        Ok(l) => {
                            println!(
                                "listening on {} ({} shards, model-name routing, io model {})",
                                l.addr(),
                                handles.len(),
                                l.io_model()
                            );
                            Some(l)
                        }
                        Err(e) => {
                            eprintln!("listen failed: {e}");
                            None
                        }
                    }
                });
                let joins: Vec<_> = (0..clients)
                    .map(|c| {
                        let tx = handles[(c as usize) % handles.len()].handle.clone();
                        std::thread::spawn(move || {
                            run_client(tx, c, seed, rate, clients, horizon)
                        })
                    })
                    .collect();
                if listener.is_some() && clients == 0 {
                    // No local traffic: keep the front-end up for the run.
                    std::thread::sleep(std::time::Duration::from_secs_f64(horizon));
                }
                let mut total_sent = 0u64;
                let mut total_ok = 0usize;
                for j in joins {
                    if let Ok((sent, ok)) = j.join() {
                        total_sent += sent;
                        total_ok += ok;
                    }
                }
                println!("clients: sent {total_sent}, completed-in-deadline {total_ok}");
                if let Some(l) = listener {
                    net_metrics = Some(l.net_metrics());
                    l.shutdown();
                }
            },
        );
        for (i, m) in per_shard.iter().enumerate() {
            print!("{}", m.report(&format!("shard {i} (DFTSP)")));
        }
        let mut merged = edgellm::serving::merge_shard_metrics(&per_shard);
        if let Some(net) = net_metrics {
            merged.merge(&net);
        }
        print!("{}", merged.report(&format!("merged × {shards} shards (DFTSP)")));
        if show_stats {
            print!("{}", merged.search_report());
        }
        return 0;
    }

    let scheduler = Box::new(Dftsp::with_config(server_cfg.scheduler));
    let mut server = EpochServer::new(engine, server_cfg, scheduler);
    let handle = server.handle();

    // Optional TCP JSON-line front-end: --listen 127.0.0.1:7070. The
    // single-shard path goes through the same Router (one shard, same
    // admission gate and typed replies) as `--shards N`.
    let listener = args.get("listen").and_then(|addr| {
        let bpe = edgellm::tokenizer::Bpe::load(&Path::new(&artifacts).join("bpe.json")).ok();
        let net_cfg = net_cfg.clone();
        let router =
            edgellm::serving::Router::single(server.model_name(), handle.clone(), net_cfg.pending_cap);
        match edgellm::serving::spawn_listener(addr, router, bpe, net_cfg) {
            Ok(l) => {
                println!(
                    "listening on {} (JSON lines; text prompts via BPE; io model {})",
                    l.addr(),
                    l.io_model()
                );
                Some(l)
            }
            Err(e) => {
                eprintln!("listen failed: {e}");
                None
            }
        }
    });

    // Client threads: Poisson-ish request submission.
    let horizon = epochs as f64 * epoch_s;
    let joins: Vec<_> = (0..clients)
        .map(|c| {
            let tx = handle.clone();
            std::thread::spawn(move || run_client(tx, c, seed, rate, clients, horizon))
        })
        .collect();

    server.run_for(epochs);
    let mut m = server.metrics().clone();
    if let Some(l) = listener {
        m.merge(&l.net_metrics());
        l.shutdown();
    }
    print!("{}", m.report("edge serving (DFTSP)"));
    if show_stats {
        print!("{}", m.search_report());
    }
    let mut total_sent = 0;
    let mut total_ok = 0;
    for j in joins {
        if let Ok((sent, ok)) = j.join() {
            total_sent += sent;
            total_ok += ok;
        }
    }
    println!("clients: sent {total_sent}, completed-in-deadline {total_ok}");
    0
}

/// One Poisson-ish client: submit requests through `tx` for 80% of the
/// horizon, then count in-deadline completions. Shared by the single-pool
/// and sharded serve paths (the latter hands each client one shard's
/// handle, round-robin).
fn run_client(
    tx: edgellm::serving::ServeHandle,
    c: u64,
    seed: u64,
    rate: f64,
    clients: u64,
    horizon: f64,
) -> (u64, usize) {
    let mut rng = edgellm::util::rng::Rng::new(seed ^ (c * 7919));
    let (rtx, rrx) = std::sync::mpsc::channel();
    let mut sent = 0u64;
    let t0 = std::time::Instant::now();
    while t0.elapsed().as_secs_f64() < horizon * 0.8 {
        let wait = rng.exponential(rate / clients.max(1) as f64);
        std::thread::sleep(std::time::Duration::from_secs_f64(wait.min(1.0)));
        let plen = rng.int_range(4, 48) as usize;
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(512) as i32).collect();
        let _ = tx.send(ServeRequest {
            prompt,
            output_tokens: rng.int_range(4, 32) as u32,
            latency_req: rng.uniform(1.0, 4.0),
            accuracy_req: rng.uniform(0.0, 0.6),
            respond: rtx.clone(),
            stream: None,
        });
        sent += 1;
    }
    drop(rtx);
    let ok = rrx
        .iter()
        .filter(|r| r.outcome == edgellm::serving::ServeOutcome::Completed)
        .count();
    (sent, ok)
}

/// Per-submit-thread tally for the load harness.
#[derive(Default)]
struct LoadTally {
    sent: u64,
    completed: u64,
    late: u64,
    shed: u64,
    other_rejected: u64,
    io_errors: u64,
    latencies: Vec<f64>,
}

impl LoadTally {
    fn replies(&self) -> u64 {
        self.completed + self.late + self.shed + self.other_rejected
    }

    fn absorb(&mut self, other: LoadTally) {
        self.sent += other.sent;
        self.completed += other.completed;
        self.late += other.late;
        self.shed += other.shed;
        self.other_rejected += other.other_rejected;
        self.io_errors += other.io_errors;
        self.latencies.extend(other.latencies);
    }
}

/// The load harness drives the synthetic host engine; the PJRT engine has
/// no in-memory synthetic constructor.
#[cfg(feature = "pjrt")]
fn cmd_loadtest(_args: &Args) -> i32 {
    eprintln!("loadtest uses the synthetic host engine; build without --features pjrt");
    2
}

/// Loopback TCP load harness: synthetic engines (no artifacts needed), a
/// real listener, and O(10k) concurrent client connections multiplexed over
/// a small pool of submit threads. Exercises the full hardened path —
/// model-name routing, bounded admission (typed `overloaded` sheds), reply
/// waits — then checks the accounting and leak invariants: every request
/// gets exactly one reply or one IO error, every handler thread drains, and
/// the accept loop is still alive at the end.
#[cfg(not(feature = "pjrt"))]
fn cmd_loadtest(args: &Args) -> i32 {
    use edgellm::coordinator::EpochParams;
    use edgellm::quant::Precision;
    use edgellm::runtime::SyntheticSpec;
    use edgellm::serving::IoModel;
    use edgellm::util::json::Json;
    use edgellm::util::stats::percentile;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::Barrier;
    use std::time::{Duration, Instant};

    let quick = args.flag("quick");
    let shards = args.usize_or("shards", 2).max(1);
    let clients = args.usize_or("clients", if quick { 200 } else { 10_000 });
    let pending_cap = args.usize_or("pending-cap", 64);
    let epochs = args.u64_or("epochs", if quick { 60 } else { 300 });
    let submit_threads = args.usize_or("client-threads", 32).clamp(1, clients.max(1));
    let write_json = args.flag("json");
    let io_model = match args.get("io-model").map(IoModel::parse) {
        Some(Ok(m)) => m,
        Some(Err(e)) => {
            eprintln!("{e}");
            return 2;
        }
        None => IoModel::Threaded,
    };

    /// Numeric field (`Threads:`, `VmHWM:`) from `/proc/self/status`.
    /// Linux-only introspection, `None` elsewhere; the columns it feeds are
    /// informational, never gated.
    fn proc_status_field(key: &str) -> Option<u64> {
        #[cfg(target_os = "linux")]
        {
            std::fs::read_to_string("/proc/self/status")
                .ok()?
                .lines()
                .find_map(|line| line.strip_prefix(key))
                .and_then(|rest| rest.split_whitespace().next()?.parse().ok())
        }
        #[cfg(not(target_os = "linux"))]
        {
            let _ = key;
            None
        }
    }
    // --chaos: panic-inject the shard schedulers so the run crosses real
    // crash/restart cycles, then hold the same accounting invariants the
    // clean run holds. The serving stack has no backend seam to wrap (the
    // engine is built inside `EpochServer`), so the scheduler — which runs
    // inside the supervisor's catch_unwind scope — is the injection point.
    let chaos_mode = args.flag("chaos");
    let chaos_seed = args.u64_or("chaos-seed", 1105);
    let chaos_panic = args.f64_or("chaos-panic", 0.03);
    if chaos_mode {
        silence_injected_panics();
    }
    let net_cfg = edgellm::serving::NetConfig {
        pending_cap,
        io_model,
        event_threads: args.usize_or("event-threads", 0),
        max_conns_per_peer: args.usize_or("max-conns-per-peer", 0),
        ..Default::default()
    };
    // Distinct model names across shards so the router's affinity path is
    // the one under load, not just the least-loaded fallback.
    let model_variants = shards.min(2);
    println!(
        "loadtest: {clients} connections over {submit_threads} threads → {shards} shards \
         (cap {pending_cap}/shard, {epochs} epochs, io model {io_model})"
    );

    /// DFTSP that panics pseudo-randomly at epoch boundaries. Seeded per
    /// (shard, incarnation) from the same `chaos_stream` the simulator's
    /// `ChaosBackend` uses, so a given incarnation's crash epoch is a pure
    /// function of `--chaos-seed`.
    struct ChaosScheduler {
        rng: edgellm::util::rng::Rng,
        panic_prob: f64,
        inner: Dftsp,
    }
    impl Scheduler for ChaosScheduler {
        fn name(&self) -> &'static str {
            "chaos-dftsp"
        }
        fn schedule(
            &mut self,
            inst: &edgellm::coordinator::ProblemInstance,
            c: &[edgellm::request::EpochRequest],
        ) -> edgellm::coordinator::Schedule {
            if self.rng.uniform(0.0, 1.0) < self.panic_prob {
                panic!("chaos: injected scheduler panic");
            }
            self.inner.schedule(inst, c)
        }
    }

    // Incarnation counter per shard: each rebuild advances the chaos stream
    // so a restarted shard does not replay its predecessor's crash epoch.
    let generations: Vec<std::sync::atomic::AtomicU64> =
        (0..shards).map(|_| Default::default()).collect();
    let mut outcome = None;
    let per_shard = edgellm::serving::serve_sharded(
        shards,
        epochs,
        |shard| {
            // Short epochs: the harness measures connection churn and
            // admission, not batch quality.
            let mut engine = Engine::synthetic(&SyntheticSpec::tiny(), Precision::W16A16);
            engine.meta.model_name = format!("synthetic-{}", shard % model_variants.max(1));
            let cfg = ServerConfig {
                epoch: EpochParams {
                    duration: 0.05,
                    t_u: 0.005,
                    t_d: 0.005,
                },
                seed: 7 + shard as u64,
                ..Default::default()
            };
            let scheduler: Box<dyn Scheduler> = if chaos_mode {
                let generation =
                    generations[shard].fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                Box::new(ChaosScheduler {
                    rng: edgellm::util::rng::Rng::new(edgellm::driver::chaos_stream(
                        chaos_seed,
                        shard as u64,
                        generation,
                    )),
                    panic_prob: chaos_panic,
                    inner: Dftsp::new(),
                })
            } else {
                Box::new(Dftsp::new())
            };
            EpochServer::new(engine, cfg, scheduler)
        },
        |handles| {
            let router = edgellm::serving::Router::new(
                handles
                    .iter()
                    .map(|h| (h.model.clone(), h.handle.clone()))
                    .collect(),
                net_cfg.pending_cap,
            );
            let listener =
                edgellm::serving::spawn_listener("127.0.0.1:0", router, None, net_cfg.clone())
                    .expect("bind loopback");
            let addr = listener.addr();
            // All submit threads connect + write, meet at the barrier (every
            // accepted connection is now simultaneously open), then read.
            let barrier = Barrier::new(submit_threads + 1);
            let (tally, peak_threads) = std::thread::scope(|scope| {
                let joins: Vec<_> = (0..submit_threads)
                    .map(|t| {
                        let barrier = &barrier;
                        scope.spawn(move || {
                            let lo = clients * t / submit_threads;
                            let hi = clients * (t + 1) / submit_threads;
                            let mut tally = LoadTally::default();
                            let mut conns = Vec::with_capacity(hi - lo);
                            for c in lo..hi {
                                let line = Json::obj(vec![
                                    ("ids", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])),
                                    ("output_tokens", Json::Num(4.0)),
                                    ("latency_req", Json::Num(60.0)),
                                    (
                                        "model",
                                        Json::Str(format!("synthetic-{}", c % model_variants)),
                                    ),
                                ])
                                .to_string();
                                match TcpStream::connect(addr) {
                                    Ok(mut s) => {
                                        let _ =
                                            s.set_read_timeout(Some(Duration::from_secs(30)));
                                        if writeln!(s, "{line}").is_ok() {
                                            tally.sent += 1;
                                            conns.push((Instant::now(), s));
                                        } else {
                                            tally.io_errors += 1;
                                        }
                                    }
                                    Err(_) => tally.io_errors += 1,
                                }
                            }
                            barrier.wait();
                            for (t0, s) in conns {
                                let mut reader = BufReader::new(s);
                                let mut reply = String::new();
                                match reader.read_line(&mut reply) {
                                    Ok(n) if n > 0 => match Json::parse(reply.trim()) {
                                        Ok(j) => {
                                            let wall = t0.elapsed().as_secs_f64();
                                            match j.req_str("outcome").unwrap_or("?") {
                                                "completed" => {
                                                    tally.completed += 1;
                                                    tally.latencies.push(wall);
                                                }
                                                "late" => {
                                                    tally.late += 1;
                                                    tally.latencies.push(wall);
                                                }
                                                "rejected" => {
                                                    if j.req_str("reason").unwrap_or("?")
                                                        == "overloaded"
                                                    {
                                                        tally.shed += 1;
                                                    } else {
                                                        tally.other_rejected += 1;
                                                    }
                                                }
                                                _ => tally.other_rejected += 1,
                                            }
                                        }
                                        Err(_) => tally.io_errors += 1,
                                    },
                                    _ => tally.io_errors += 1,
                                }
                            }
                            tally
                        })
                    })
                    .collect();
                barrier.wait();
                // Every write landed and nothing has been read back yet:
                // the fleet of connections is concurrently open right now.
                let peak_open = listener.open_connections();
                // Thread count at the same instant: the threaded model pays
                // one handler thread per open connection here; the evented
                // model stays at event-threads + pump + shards + harness.
                let peak_threads = proc_status_field("Threads:");
                let mut tally = LoadTally::default();
                for j in joins {
                    tally.absorb(j.join().expect("submit thread"));
                }
                println!(
                    "peak open connections at barrier: {peak_open} (accepted {})",
                    listener.accepted()
                );
                (tally, peak_threads)
            });
            // Liveness probe: the accept loop must still answer after the
            // storm (the pre-hardening loop died on its first accept error).
            let probe_alive = (|| {
                let mut s = TcpStream::connect(addr).ok()?;
                s.set_read_timeout(Some(Duration::from_secs(30))).ok()?;
                writeln!(s, r#"{{"ids": [1], "output_tokens": 1, "latency_req": 60.0}}"#).ok()?;
                let mut reply = String::new();
                BufReader::new(s).read_line(&mut reply).ok()?;
                Json::parse(reply.trim()).ok()
            })()
            .is_some();
            // Every client socket is closed; handlers must all exit.
            let drained = listener.wait_drained(Duration::from_secs(20));
            let leaked = if drained { 0 } else { listener.open_connections() };
            // Permits are RAII-scoped to handlers, so after a drain every
            // gate depth must be back at zero — even when handlers died
            // with crashed shards mid-reply.
            let leaked_permits: usize = listener.gate_depths().iter().sum();
            let net = listener.net_metrics();
            listener.shutdown();
            outcome = Some((tally, peak_threads, probe_alive, leaked, leaked_permits, net));
        },
    );
    let (tally, peak_threads, probe_alive, leaked, leaked_permits, net) =
        outcome.expect("drive ran");
    // VmHWM is the process-lifetime RSS peak, so sampling after shutdown
    // still captures the storm; dominated by per-thread stacks under the
    // threaded model.
    let vm_hwm_kb = proc_status_field("VmHWM:");
    // Every attempted connection must resolve to exactly one reply or one
    // IO error — a nonzero gap means a reply was lost in the stack.
    let accounting_gap = clients as i64 - tally.replies() as i64 - tally.io_errors as i64;
    let accept_loop_deaths = if probe_alive { 0 } else { 1 };
    let shed_rate = tally.shed as f64 / tally.sent.max(1) as f64;
    let (p50, p95, p99, p999) = if tally.latencies.is_empty() {
        (f64::NAN, f64::NAN, f64::NAN, f64::NAN)
    } else {
        (
            percentile(&tally.latencies, 50.0),
            percentile(&tally.latencies, 95.0),
            percentile(&tally.latencies, 99.0),
            percentile(&tally.latencies, 99.9),
        )
    };
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["sent".into(), tally.sent.to_string()]);
    t.row(&["completed".into(), tally.completed.to_string()]);
    t.row(&["late".into(), tally.late.to_string()]);
    t.row(&["shed (overloaded)".into(), tally.shed.to_string()]);
    t.row(&["other rejected".into(), tally.other_rejected.to_string()]);
    t.row(&["io errors".into(), tally.io_errors.to_string()]);
    t.row(&["shed rate".into(), format!("{:.3}", shed_rate)]);
    t.row(&["wire p50 (s)".into(), format!("{p50:.4}")]);
    t.row(&["wire p95 (s)".into(), format!("{p95:.4}")]);
    t.row(&["wire p99 (s)".into(), format!("{p99:.4}")]);
    t.row(&["wire p99.9 (s)".into(), format!("{p999:.4}")]);
    t.row(&["bad requests (server)".into(), net.bad_requests.to_string()]);
    t.row(&["accounting gap".into(), accounting_gap.to_string()]);
    t.row(&["leaked connections".into(), leaked.to_string()]);
    t.row(&["leaked permits".into(), leaked_permits.to_string()]);
    t.row(&["accept loop deaths".into(), accept_loop_deaths.to_string()]);
    t.row(&[
        "peak threads (barrier)".into(),
        peak_threads.map_or_else(|| "n/a".to_string(), |n| n.to_string()),
    ]);
    t.row(&[
        "peak RSS VmHWM (kB)".into(),
        vm_hwm_kb.map_or_else(|| "n/a".to_string(), |n| n.to_string()),
    ]);
    let merged = edgellm::serving::merge_shard_metrics(&per_shard);
    if chaos_mode {
        t.row(&["shard crashes".into(), merged.shard_crashes.to_string()]);
        t.row(&["shard restarts".into(), merged.shard_restarts.to_string()]);
        t.row(&["shards parked".into(), merged.shards_parked.to_string()]);
        t.row(&["shard failed (server)".into(), merged.shard_failed.to_string()]);
        t.row(&[
            "shard failed replies (net)".into(),
            net.net_shard_failures.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "server side: offered {} completed {}+{} dropped {} | wire histogram n={} p99={:.4}s",
        merged.offered,
        merged.completed_in_deadline,
        merged.completed_late,
        merged.dropped,
        net.wire_latency.count(),
        net.wire_latency.quantile(0.99),
    );
    println!(
        "io model {io_model}: peak threads {} at barrier, VmHWM {} kB \
         (evented bound: event-threads + pump + shards + harness)",
        peak_threads.map_or_else(|| "n/a".to_string(), |n| n.to_string()),
        vm_hwm_kb.map_or_else(|| "n/a".to_string(), |n| n.to_string()),
    );

    if write_json {
        let num_or_null = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        let count_or_null = |v: Option<u64>| match v {
            Some(n) => Json::Num(n as f64),
            None => Json::Null,
        };
        let mut scenario = match (chaos_mode, quick) {
            (true, true) => "chaos/quick",
            (true, false) => "chaos/full",
            (false, true) => "net/quick",
            (false, false) => "net/full",
        }
        .to_string();
        if io_model == IoModel::Evented {
            scenario.push_str("-evented");
        }
        let mut fields = vec![
            ("scenario", Json::Str(scenario.clone())),
            ("io_model", Json::Str(io_model.as_str().to_string())),
            ("sent", Json::Num(tally.sent as f64)),
            ("bad_requests", Json::Num(net.bad_requests as f64)),
            ("accounting_gap", Json::Num(accounting_gap as f64)),
            ("leaked_connections", Json::Num(leaked as f64)),
            ("accept_loop_deaths", Json::Num(accept_loop_deaths as f64)),
        ];
        if chaos_mode {
            // The invariant columns the chaos gate pins at zero, plus the
            // crash/restart counters (wall-dependent — how many faults fire
            // depends on how many epochs elapse — so informational only).
            fields.push(("leaked_permits", Json::Num(leaked_permits as f64)));
            fields.push(("parked", Json::Num(merged.shards_parked as f64)));
            fields.push(("crashes", num_or_null(merged.shard_crashes as f64)));
            fields.push(("restarts", num_or_null(merged.shard_restarts as f64)));
            fields.push((
                "shard_failed_replies",
                num_or_null(net.net_shard_failures as f64),
            ));
        }
        fields.extend([
            ("served", num_or_null((tally.completed + tally.late) as f64)),
            ("shed", num_or_null(tally.shed as f64)),
            ("shed_rate", num_or_null(shed_rate)),
            ("wall_p50_s", num_or_null(p50)),
            ("wall_p95_s", num_or_null(p95)),
            ("wall_p99_s", num_or_null(p99)),
            ("wall_p999_s", num_or_null(p999)),
            ("peak_threads", count_or_null(peak_threads)),
            ("vm_hwm_kb", count_or_null(vm_hwm_kb)),
        ]);
        let row = Json::obj(fields);
        let bench_name = if chaos_mode {
            "BENCH_chaos.json"
        } else {
            "BENCH_net.json"
        };
        let provenance = if chaos_mode {
            "cargo run --release -- loadtest --chaos --quick --json (one row per scenario; \
             --io-model evented adds the -evented rows)"
        } else {
            "cargo run --release -- loadtest --quick --json (one row per scenario; \
             --io-model evented adds the -evented rows)"
        };
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("..")
            .join(bench_name);
        // Merge by scenario rather than overwrite: CI regenerates this file
        // once per io model, and the second run must not clobber the first
        // run's row (the bench gate compares every baseline scenario).
        let mut rows: Vec<Json> = std::fs::read_to_string(&path)
            .ok()
            .and_then(|text| Json::parse(text.trim()).ok())
            .and_then(|doc| doc.get("rows").and_then(|r| r.as_arr().map(<[Json]>::to_vec)))
            .unwrap_or_default();
        if let Some(slot) = rows
            .iter_mut()
            .find(|r| r.get("scenario").and_then(Json::as_str) == Some(scenario.as_str()))
        {
            *slot = row;
        } else {
            rows.push(row);
        }
        let doc = Json::obj(vec![
            ("provenance", Json::Str(provenance.to_string())),
            ("rows", Json::Arr(rows)),
        ]);
        match std::fs::write(&path, format!("{doc}\n")) {
            Ok(()) => println!("wrote {} ({scenario} row merged)", path.display()),
            Err(e) => {
                eprintln!("write {bench_name} failed: {e}");
                return 1;
            }
        }
    }

    let ok = accounting_gap == 0
        && leaked == 0
        && leaked_permits == 0
        && merged.shards_parked == 0
        && accept_loop_deaths == 0
        && net.bad_requests == 0
        && tally.sent as usize == clients;
    if !ok {
        eprintln!("loadtest invariants FAILED");
        return 1;
    }
    println!("loadtest invariants hold");
    0
}

/// Deterministic skewed-fleet benchmark for the elastic sharding layer: the
/// paper deployment replicated over a fast and a slow migration group
/// (unequal silicon, so queue-depth routing alone leaves the slow replica
/// with a backlog the fast one could clear), run once with cross-shard work
/// stealing off and once with it on. With --json the rows merge into
/// BENCH_elastic.json (same merge-by-scenario writer as loadtest); CI's
/// bench-smoke job gates the invariant columns — request conservation, and
/// `steal_regression` (how many in-deadline completions stealing *lost*
/// versus routing alone, pinned at 0).
fn cmd_elastic_bench(args: &Args) -> i32 {
    use edgellm::cluster::{ClusterTopology, GpuSpec, ShardSpec};
    use edgellm::util::json::Json;

    let write_json = args.flag("json");
    let mut cfg = sim::SimConfig::paper_default();
    cfg.epochs = args.u64_or("epochs", 24) as usize;
    cfg.workload.arrival_rate = args.f64_or("rate", 50.0);
    cfg.seed = args.u64_or("seed", 11);
    // Half the paper fleet at full TX2 speed, half underclocked 4×: one
    // deployment, two single-member migration groups, so GPUs never migrate
    // between them and the only cross-shard remedy is stealing.
    let fast = GpuSpec::jetson_tx2();
    let slow = GpuSpec {
        name: format!("{}-underclocked", fast.name),
        flops: fast.flops / 4.0,
        mem_bytes: fast.mem_bytes,
    };
    cfg.topology = Some(ClusterTopology {
        shards: vec![
            ShardSpec {
                gpu: fast,
                num_gpus: 10,
            },
            ShardSpec {
                gpu: slow,
                num_gpus: 10,
            },
        ],
    });

    let mut runs = Vec::new();
    for stealing in [false, true] {
        cfg.elastic.stealing = stealing;
        let sched_cfg = cfg.scheduler;
        let m = sim::run_sharded(&cfg, move |_| Box::new(Dftsp::with_config(sched_cfg)));
        println!(
            "steal={}: offered {}  in-deadline {}  late {}  dropped {}  stolen {}",
            if stealing { "on" } else { "off" },
            m.offered,
            m.completed_in_deadline,
            m.completed_late,
            m.dropped,
            m.requests_stolen,
        );
        runs.push((stealing, m));
    }
    let off = &runs[0].1;
    let on = &runs[1].1;
    let steal_gain = on.completed_in_deadline as i64 - off.completed_in_deadline as i64;
    let steal_regression = (-steal_gain).max(0);
    println!(
        "stealing moved {} requests and changed in-deadline completions by {steal_gain:+}",
        on.requests_stolen
    );

    if write_json {
        let rows_new: Vec<Json> = runs
            .iter()
            .map(|(stealing, m)| {
                let conservation_gap = m.offered as i64
                    - (m.completed_in_deadline + m.completed_late + m.dropped) as i64;
                let mut fields = vec![
                    (
                        "scenario",
                        Json::Str(format!(
                            "sharded/elastic/steal={}",
                            if *stealing { "on" } else { "off" }
                        )),
                    ),
                    ("stealing", Json::Bool(*stealing)),
                    ("offered", Json::Num(m.offered as f64)),
                    (
                        "completed_in_deadline",
                        Json::Num(m.completed_in_deadline as f64),
                    ),
                    ("completed_late", Json::Num(m.completed_late as f64)),
                    ("dropped", Json::Num(m.dropped as f64)),
                    ("requests_stolen", Json::Num(m.requests_stolen as f64)),
                    ("conservation_gap", Json::Num(conservation_gap as f64)),
                ];
                if *stealing {
                    fields.push(("steal_gain", Json::Num(steal_gain as f64)));
                    fields.push(("steal_regression", Json::Num(steal_regression as f64)));
                }
                Json::obj(fields)
            })
            .collect();
        let provenance = "Baseline of the elastic sharding benchmark: the paper deployment \
             replicated over 10 full-speed and 10 4x-underclocked TX2s (two migration groups, \
             LoadProportional partitioning), 24 epochs at 50 req/s, DFTSP per shard, work \
             stealing off vs on. Regenerate with: cargo run --release -- elastic-bench --json \
             (the writer merges by scenario). Every counter is bit-deterministic. The gated \
             columns are invariants: conservation_gap (offered minus accounted outcomes) and \
             steal_regression (in-deadline completions stealing lost versus queue-depth \
             routing alone) are pinned at 0 — tests/sharded_e2e.rs asserts the strict version. \
             Null counters here because this baseline was authored in a container without a \
             Rust toolchain; the first CI run fills the regenerated artifact.";
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("..")
            .join("BENCH_elastic.json");
        let mut rows: Vec<Json> = std::fs::read_to_string(&path)
            .ok()
            .and_then(|text| Json::parse(text.trim()).ok())
            .and_then(|doc| doc.get("rows").and_then(|r| r.as_arr().map(<[Json]>::to_vec)))
            .unwrap_or_default();
        for row in rows_new {
            let scenario = row.get("scenario").and_then(Json::as_str).map(str::to_string);
            if let Some(slot) = rows.iter_mut().find(|r| {
                r.get("scenario").and_then(Json::as_str) == scenario.as_deref()
            }) {
                *slot = row;
            } else {
                rows.push(row);
            }
        }
        let doc = Json::obj(vec![
            ("provenance", Json::Str(provenance.to_string())),
            ("rows", Json::Arr(rows)),
        ]);
        match std::fs::write(&path, format!("{doc}\n")) {
            Ok(()) => println!("wrote {}", path.display()),
            Err(e) => {
                eprintln!("write BENCH_elastic.json failed: {e}");
                return 1;
            }
        }
    }
    if steal_regression > 0 {
        eprintln!("elastic-bench: stealing LOST {steal_regression} in-deadline completions");
        return 1;
    }
    0
}

fn cmd_catalog() -> i32 {
    let mut t = Table::new(&["model", "layers", "d_model", "heads", "d_head", "params"]);
    for m in LlmSpec::catalog() {
        t.row(&[
            m.name.clone(),
            m.layers.to_string(),
            m.d_model.to_string(),
            m.n_heads.to_string(),
            m.d_head.to_string(),
            format!("{:.1}B", m.param_count() as f64 / 1e9),
        ]);
    }
    print!("{}", t.render());
    println!();
    let mut q = Table::new(&[
        "quant",
        "alpha",
        "beta",
        "dPPL BLOOM-3B",
        "dPPL BLOOM-7.1B",
        "dPPL OPT-13B",
    ]);
    for spec in quant::catalog() {
        q.row(&[
            spec.label(),
            format!("{:.2}", spec.alpha),
            format!("{:.2}", spec.beta),
            format!("{:.2}", spec.dppl_for("BLOOM-3B")),
            format!("{:.2}", spec.dppl_for("BLOOM-7.1B")),
            format!("{:.2}", spec.dppl_for("OPT-13B")),
        ]);
    }
    print!("{}", q.render());
    0
}
