//! `edgellm` — CLI launcher for the edge-LLM serving stack.
//!
//! Subcommands:
//!   simulate   run the discrete-event simulator (paper §IV testbed)
//!   compare    run all batching policies on one scenario and tabulate
//!   serve      serve the tiny real model through PJRT with DFTSP batching
//!   catalog    print the model and quantization catalogs
//!
//! Scenario files are TOML (see `config` module docs); every flag falls back
//! to the paper's §IV defaults.

use edgellm::config;
use edgellm::coordinator::{
    BruteForce, Dftsp, NoBatching, Scheduler, SchedulerConfig, StaticBatching,
};
use edgellm::model::LlmSpec;
use edgellm::quant;
use edgellm::runtime::Engine;
use edgellm::serving::{EpochServer, ServeRequest, ServerConfig};
use edgellm::sim;
use edgellm::util::cli::Args;
use edgellm::util::fmt::Table;
use std::path::Path;

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("compare") => cmd_compare(&args),
        Some("serve") => cmd_serve(&args),
        Some("catalog") => cmd_catalog(),
        _ => {
            eprintln!(
                "usage: edgellm <simulate|compare|serve|catalog> [--config FILE] \
                 [--scheduler dftsp|stb|nob|brute] [--batching epoch|continuous] [--rate R] \
                 [--epochs N] [--model NAME] [--quant LABEL] [--seed S] \
                 [--workers N] [--shards N] [--partition equal|load-proportional] [--stats]"
            );
            2
        }
    };
    std::process::exit(code);
}

fn build_config(args: &Args) -> Result<sim::SimConfig, String> {
    let mut cfg = match args.get("config") {
        Some(path) => config::load_scenario(Path::new(path))?,
        None => sim::SimConfig::paper_default(),
    };
    if let Some(rate) = args.get("rate") {
        cfg.workload.arrival_rate = rate.parse().map_err(|_| "bad --rate")?;
    }
    if let Some(epochs) = args.get("epochs") {
        cfg.epochs = epochs.parse().map_err(|_| "bad --epochs")?;
    }
    if let Some(model) = args.get("model") {
        cfg.model = LlmSpec::by_name(model).ok_or_else(|| format!("unknown model `{model}`"))?;
    }
    if let Some(q) = args.get("quant") {
        cfg.quant = config::parse_quant_label(q)?;
    }
    if let Some(seed) = args.get("seed") {
        cfg.seed = seed.parse().map_err(|_| "bad --seed")?;
    }
    if let Some(mode) = args.get("batching") {
        cfg.batching = edgellm::driver::BatchingMode::parse(mode)?;
    }
    if let Some(workers) = args.get("workers") {
        cfg.scheduler.workers = workers.parse().map_err(|_| "bad --workers")?;
    }
    if let Some(shards) = args.get("shards") {
        cfg.shards = shards.parse().map_err(|_| "bad --shards")?;
        if cfg.shards == 0 {
            return Err("--shards must be >= 1".into());
        }
        if cfg.shards > cfg.cluster.num_gpus {
            return Err(format!(
                "--shards {} exceeds the {}-GPU cluster (every shard needs a GPU)",
                cfg.shards, cfg.cluster.num_gpus
            ));
        }
    }
    if let Some(p) = args.get("partition") {
        cfg.partition = edgellm::coordinator::PartitionPolicy::parse(p)?;
    }
    Ok(cfg)
}

fn make_scheduler(name: &str, cfg: SchedulerConfig) -> Result<Box<dyn Scheduler + Send>, String> {
    match name.to_ascii_lowercase().as_str() {
        "dftsp" => Ok(Box::new(Dftsp::with_config(cfg))),
        "stb" => Ok(Box::new(StaticBatching::new())),
        "nob" => Ok(Box::new(NoBatching::new())),
        "brute" => Ok(Box::new(BruteForce::default())),
        other => Err(format!("unknown scheduler `{other}`")),
    }
}

fn cmd_simulate(args: &Args) -> i32 {
    let cfg = match build_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let sched_name = args.str_or("scheduler", "dftsp");
    let mut sched = match make_scheduler(&sched_name, cfg.scheduler) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let show_stats = args.flag("stats");
    println!(
        "model {}  quant {}  λ={} req/s  {} epochs × {} s  cluster {}×{}  batching {}{}",
        cfg.model.name,
        cfg.quant.label(),
        cfg.workload.arrival_rate,
        cfg.epochs,
        cfg.epoch.duration,
        cfg.cluster.num_gpus,
        cfg.cluster.gpu.name,
        cfg.batching,
        if cfg.shards > 1 {
            format!("  shards {} ({})", cfg.shards, cfg.partition)
        } else {
            String::new()
        }
    );
    let m = if cfg.shards > 1 {
        // One fresh scheduler per shard (validated above).
        sim::run_sharded(&cfg, |_| {
            make_scheduler(&sched_name, cfg.scheduler).expect("scheduler name already validated")
        })
    } else {
        sim::run(&cfg, sched.as_mut())
    };
    print!("{}", m.report(sched.name()));
    if show_stats {
        print!("{}", m.search_report());
    }
    0
}

fn cmd_compare(args: &Args) -> i32 {
    let cfg = match build_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let show_stats = args.flag("stats");
    let results = if cfg.shards > 1 {
        // Sharded comparison: each policy gets one fresh scheduler per
        // shard, same seeded workload (run_sharded regenerates it).
        ["dftsp", "stb", "nob"]
            .iter()
            .map(|name| {
                // One construction up front supplies the display name; the
                // closure then builds the real per-shard instances.
                let display = make_scheduler(name, cfg.scheduler)
                    .expect("known scheduler names")
                    .name()
                    .to_string();
                let m = sim::run_sharded(&cfg, |_| {
                    make_scheduler(name, cfg.scheduler).expect("known scheduler names")
                });
                (display, m)
            })
            .collect()
    } else {
        sim::compare(
            &cfg,
            vec![
                Box::new(Dftsp::with_config(cfg.scheduler)),
                Box::new(StaticBatching::new()),
                Box::new(NoBatching::new()),
            ],
        )
    };
    let mut t = Table::new(&[
        "scheduler",
        "throughput (req/s)",
        "goodput %",
        "mean batch",
        "p95 latency (s)",
    ]);
    for (name, m) in &results {
        t.row(&[
            name.clone(),
            format!("{:.2}", m.throughput()),
            format!("{:.1}", 100.0 * m.goodput_ratio()),
            format!("{:.1}", m.batch_sizes.mean()),
            format!("{:.3}", m.latency.quantile(0.95)),
        ]);
    }
    print!("{}", t.render());
    if show_stats {
        for (name, m) in &results {
            println!("-- {name} --");
            print!("{}", m.search_report());
        }
    }
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let artifacts = args.str_or("artifacts", "artifacts");
    let quant_label = args.str_or("quant", "W16A16");
    let epochs = args.u64_or("epochs", 10);
    let clients = args.u64_or("clients", 4);
    let rate = args.f64_or("rate", 4.0);
    let seed = args.u64_or("seed", 7);

    let engine = match Engine::load(Path::new(&artifacts), &quant_label) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("engine load failed: {e}\n(run `make artifacts` first)");
            return 1;
        }
    };
    println!(
        "engine up: {} on {} ({} batch variants, quant {})",
        engine.meta.model_name,
        engine.platform(),
        engine.meta.batch_variants.len(),
        quant_label
    );
    let mut server_cfg = ServerConfig::default();
    if let Some(mode) = args.get("batching") {
        match edgellm::driver::BatchingMode::parse(mode) {
            Ok(m) => server_cfg.batching = m,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    server_cfg.scheduler.workers = args.u64_or("workers", 0) as usize;
    let show_stats = args.flag("stats");
    let epoch_s = server_cfg.epoch.duration;
    println!("batching mode: {}", server_cfg.batching);

    // Sharded serving: N servers in this process, each on its own thread
    // with its own engine instance (disjoint KV arenas); clients round-robin
    // over the shard handles.
    let shards = args.u64_or("shards", 1) as usize;
    if shards == 0 {
        eprintln!("--shards must be >= 1");
        return 2;
    }
    if args.get("partition").is_some() {
        // Serving shards each own a whole engine; GPU re-partitioning is a
        // simulate/compare knob. Refuse rather than silently ignore.
        eprintln!("--partition applies to simulate/compare (serving shards each own their engine)");
        return 2;
    }
    if shards > 1 {
        drop(engine); // validated loadable; each shard loads its own copy
        if args.get("listen").is_some() {
            eprintln!("--listen is not supported with --shards (route via the handles instead)");
            return 2;
        }
        let horizon = epochs as f64 * epoch_s;
        let base_cfg = server_cfg.clone();
        let artifacts_dir = artifacts.clone();
        let per_shard = edgellm::serving::serve_sharded(
            shards,
            epochs,
            |shard| {
                let engine = Engine::load(Path::new(&artifacts_dir), &quant_label)
                    .expect("engine loaded once already");
                let cfg = ServerConfig {
                    seed: base_cfg.seed ^ (shard as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    ..base_cfg.clone()
                };
                EpochServer::new(engine, cfg, Box::new(Dftsp::with_config(base_cfg.scheduler)))
            },
            |handles| {
                let joins: Vec<_> = (0..clients)
                    .map(|c| {
                        let tx = handles[(c as usize) % handles.len()].clone();
                        std::thread::spawn(move || {
                            run_client(tx, c, seed, rate, clients, horizon)
                        })
                    })
                    .collect();
                let mut total_sent = 0u64;
                let mut total_ok = 0usize;
                for j in joins {
                    if let Ok((sent, ok)) = j.join() {
                        total_sent += sent;
                        total_ok += ok;
                    }
                }
                println!("clients: sent {total_sent}, completed-in-deadline {total_ok}");
            },
        );
        for (i, m) in per_shard.iter().enumerate() {
            print!("{}", m.report(&format!("shard {i} (DFTSP)")));
        }
        let merged = edgellm::serving::merge_shard_metrics(&per_shard);
        print!("{}", merged.report(&format!("merged × {shards} shards (DFTSP)")));
        if show_stats {
            print!("{}", merged.search_report());
        }
        return 0;
    }

    let scheduler = Box::new(Dftsp::with_config(server_cfg.scheduler));
    let mut server = EpochServer::new(engine, server_cfg, scheduler);
    let handle = server.handle();

    // Optional TCP JSON-line front-end: --listen 127.0.0.1:7070
    if let Some(addr) = args.get("listen") {
        let bpe = edgellm::tokenizer::Bpe::load(&Path::new(&artifacts).join("bpe.json")).ok();
        match edgellm::serving::spawn_listener(addr, handle.clone(), bpe) {
            Ok(local) => println!("listening on {local} (JSON lines; text prompts via BPE)"),
            Err(e) => eprintln!("listen failed: {e}"),
        }
    }

    // Client threads: Poisson-ish request submission.
    let horizon = epochs as f64 * epoch_s;
    let joins: Vec<_> = (0..clients)
        .map(|c| {
            let tx = handle.clone();
            std::thread::spawn(move || run_client(tx, c, seed, rate, clients, horizon))
        })
        .collect();

    server.run_for(epochs);
    print!("{}", server.metrics().report("edge serving (DFTSP)"));
    if show_stats {
        print!("{}", server.metrics().search_report());
    }
    let mut total_sent = 0;
    let mut total_ok = 0;
    for j in joins {
        if let Ok((sent, ok)) = j.join() {
            total_sent += sent;
            total_ok += ok;
        }
    }
    println!("clients: sent {total_sent}, completed-in-deadline {total_ok}");
    0
}

/// One Poisson-ish client: submit requests through `tx` for 80% of the
/// horizon, then count in-deadline completions. Shared by the single-pool
/// and sharded serve paths (the latter hands each client one shard's
/// handle, round-robin).
fn run_client(
    tx: edgellm::serving::ServeHandle,
    c: u64,
    seed: u64,
    rate: f64,
    clients: u64,
    horizon: f64,
) -> (u64, usize) {
    let mut rng = edgellm::util::rng::Rng::new(seed ^ (c * 7919));
    let (rtx, rrx) = std::sync::mpsc::channel();
    let mut sent = 0u64;
    let t0 = std::time::Instant::now();
    while t0.elapsed().as_secs_f64() < horizon * 0.8 {
        let wait = rng.exponential(rate / clients.max(1) as f64);
        std::thread::sleep(std::time::Duration::from_secs_f64(wait.min(1.0)));
        let plen = rng.int_range(4, 48) as usize;
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(512) as i32).collect();
        let _ = tx.send(ServeRequest {
            prompt,
            output_tokens: rng.int_range(4, 32) as u32,
            latency_req: rng.uniform(1.0, 4.0),
            accuracy_req: rng.uniform(0.0, 0.6),
            respond: rtx.clone(),
        });
        sent += 1;
    }
    drop(rtx);
    let ok = rrx
        .iter()
        .filter(|r| r.outcome == edgellm::serving::ServeOutcome::Completed)
        .count();
    (sent, ok)
}

fn cmd_catalog() -> i32 {
    let mut t = Table::new(&["model", "layers", "d_model", "heads", "d_head", "params"]);
    for m in LlmSpec::catalog() {
        t.row(&[
            m.name.clone(),
            m.layers.to_string(),
            m.d_model.to_string(),
            m.n_heads.to_string(),
            m.d_head.to_string(),
            format!("{:.1}B", m.param_count() as f64 / 1e9),
        ]);
    }
    print!("{}", t.render());
    println!();
    let mut q = Table::new(&[
        "quant",
        "alpha",
        "beta",
        "dPPL BLOOM-3B",
        "dPPL BLOOM-7.1B",
        "dPPL OPT-13B",
    ]);
    for spec in quant::catalog() {
        q.row(&[
            spec.label(),
            format!("{:.2}", spec.alpha),
            format!("{:.2}", spec.beta),
            format!("{:.2}", spec.dppl_for("BLOOM-3B")),
            format!("{:.2}", spec.dppl_for("BLOOM-7.1B")),
            format!("{:.2}", spec.dppl_for("OPT-13B")),
        ]);
    }
    print!("{}", q.render());
    0
}
