//! Epoch-driven discrete-event simulator — the testbed stand-in that
//! regenerates the paper's §IV evaluation.
//!
//! Since PR 1 this module is a thin adapter: the Fig. 2 protocol itself
//! (aggregation, admission, scheduling, outcome accounting) lives once in
//! [`crate::driver::EpochDriver`]; the simulator contributes the *simulated*
//! ingredients — a [`SimClock`] that lands exactly on epoch boundaries, the
//! [`AnalyticBackend`] that resolves completions from the paper's cost
//! model, and a seeded Poisson workload. Requests arriving during epoch e
//! are aggregated and offered to the scheduler at the boundary of epoch
//! e+1; scheduled requests upload during T_U, compute during the
//! (overlapped) T_C and download during T_D. Completion within τ_i counts
//! toward throughput — the paper's headline metric.

use crate::cluster::ClusterSpec;
use crate::coordinator::{EpochParams, Scheduler};
use crate::driver::{
    run_epochs, AnalyticBackend, DriverPolicy, EpochDriver, InstanceTemplate, SPadPolicy,
    SimClock, StalePolicy,
};
use crate::metrics::Metrics;
use crate::model::{CostModel, LlmSpec};
use crate::quant::QuantSpec;
use crate::util::rng::Rng;
use crate::wireless::{AllocationPolicy, ChannelParams, RadioParams};
use crate::workload::{WorkloadGenerator, WorkloadParams};

/// Full simulation scenario.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub model: LlmSpec,
    pub quant: QuantSpec,
    pub cluster: ClusterSpec,
    pub epoch: EpochParams,
    pub radio: RadioParams,
    pub channel: ChannelParams,
    pub workload: WorkloadParams,
    /// Number of epochs to simulate.
    pub epochs: usize,
    pub seed: u64,
    /// Fixed padding length s'; `None` pads to the longest queued prompt.
    pub s_pad: Option<u32>,
}

impl SimConfig {
    /// Paper §IV defaults: BLOOM-3B, W8A16, 20×TX2, 2 s epochs, λ=50.
    pub fn paper_default() -> Self {
        SimConfig {
            model: LlmSpec::bloom_3b(),
            quant: crate::quant::default_quant(),
            cluster: ClusterSpec::paper_default(),
            epoch: EpochParams::default(),
            radio: RadioParams::default(),
            channel: ChannelParams::default(),
            workload: WorkloadParams::default(),
            epochs: 30,
            seed: 42,
            s_pad: None,
        }
    }
}

/// The driver configuration a scenario maps to (shared with the parity
/// tests; `sim::run` is exactly `EpochDriver` + `SimClock` +
/// `AnalyticBackend` under this policy).
pub fn driver_for(config: &SimConfig) -> EpochDriver<()> {
    EpochDriver::new(
        InstanceTemplate {
            cost: CostModel::new(config.model.clone()),
            quant: config.quant.clone(),
            cluster: config.cluster.clone(),
            epoch: config.epoch.clone(),
        },
        DriverPolicy {
            stale: StalePolicy::BestCaseInfeasible,
            s_pad: match config.s_pad {
                Some(s) => SPadPolicy::Fixed(s),
                None => SPadPolicy::LongestQueued { fallback: 512 },
            },
            allocation: AllocationPolicy::MinOnly,
        },
        config.radio.clone(),
        config.channel.clone(),
        Rng::new(config.seed ^ 0xC0FFEE),
    )
}

/// Run one scenario under one scheduling policy; returns aggregate metrics.
pub fn run(config: &SimConfig, scheduler: &mut dyn Scheduler) -> Metrics {
    let mut gen = WorkloadGenerator::new(config.workload.clone(), config.seed);
    let mut driver = driver_for(config);
    let mut backend = AnalyticBackend;
    let mut clock = SimClock::new();
    let duration = config.epoch.duration;

    // Arrivals during epoch e become schedulable at the boundary of epoch
    // e+1 (the Fig. 2 aggregation rule): ingest the *previous* window at
    // each boundary, and the final epoch's window before closing.
    let mut window_start = 0.0;
    run_epochs(
        &mut driver,
        scheduler,
        &mut backend,
        &mut clock,
        config.epochs as u64,
        |d, _backend, now| {
            for r in gen.arrivals_between(window_start, now) {
                d.offer(r, ());
            }
            window_start = now;
        },
    );
    if config.epochs > 0 {
        let last_boundary = (config.epochs - 1) as f64 * duration;
        for r in gen.arrivals_between(window_start, last_boundary + duration) {
            driver.offer(r, ());
        }
    }

    // Close accounting: whatever still waits at the horizon is unserved.
    driver.finish(&mut backend, config.epochs as f64 * duration);
    driver.into_metrics()
}

/// Convenience: run the same scenario under several schedulers (fresh
/// workload generator each time — identical arrivals thanks to the seed).
pub fn compare(
    config: &SimConfig,
    schedulers: Vec<Box<dyn Scheduler>>,
) -> Vec<(String, Metrics)> {
    schedulers
        .into_iter()
        .map(|mut s| {
            let m = run(config, s.as_mut());
            (s.name().to_string(), m)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Dftsp, NoBatching, StaticBatching};

    fn quick_config(rate: f64, epochs: usize) -> SimConfig {
        SimConfig {
            workload: WorkloadParams {
                arrival_rate: rate,
                ..Default::default()
            },
            epochs,
            ..SimConfig::paper_default()
        }
    }

    #[test]
    fn accounting_closes() {
        // offered == in-deadline + late + dropped (queue leftover included).
        let cfg = quick_config(20.0, 10);
        let m = run(&cfg, &mut Dftsp::new());
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped,
            "conservation of requests"
        );
        assert!(m.offered > 0);
        assert!(m.throughput() > 0.0);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = quick_config(30.0, 8);
        let a = run(&cfg, &mut Dftsp::new());
        let b = run(&cfg, &mut Dftsp::new());
        assert_eq!(a.completed_in_deadline, b.completed_in_deadline);
        assert_eq!(a.offered, b.offered);
        assert_eq!(a.search.nodes_visited, b.search.nodes_visited);
    }

    #[test]
    fn dftsp_beats_baselines_at_moderate_load() {
        let cfg = quick_config(40.0, 12);
        let d = run(&cfg, &mut Dftsp::new());
        let s = run(&cfg, &mut StaticBatching::new());
        let n = run(&cfg, &mut NoBatching::new());
        assert!(
            d.throughput() >= s.throughput(),
            "DFTSP {} vs StB {}",
            d.throughput(),
            s.throughput()
        );
        assert!(
            d.throughput() >= n.throughput(),
            "DFTSP {} vs NoB {}",
            d.throughput(),
            n.throughput()
        );
    }

    #[test]
    fn throughput_saturates_with_rate() {
        // Fig. 5(a) shape: throughput grows then flattens.
        let lo = run(&quick_config(5.0, 12), &mut Dftsp::new());
        let mid = run(&quick_config(60.0, 12), &mut Dftsp::new());
        let hi = run(&quick_config(200.0, 12), &mut Dftsp::new());
        assert!(mid.throughput() > lo.throughput());
        // saturation: the jump from mid to hi is much smaller than lo to mid
        let g1 = mid.throughput() - lo.throughput();
        let g2 = hi.throughput() - mid.throughput();
        assert!(g2 < g1, "g1={g1} g2={g2}");
    }

    #[test]
    fn larger_model_lower_throughput() {
        let mut cfg7 = quick_config(60.0, 10);
        cfg7.model = LlmSpec::bloom_7b();
        let m3 = run(&quick_config(60.0, 10), &mut Dftsp::new());
        let m7 = run(&cfg7, &mut Dftsp::new());
        assert!(
            m3.throughput() > m7.throughput(),
            "3B {} vs 7.1B {}",
            m3.throughput(),
            m7.throughput()
        );
    }

    #[test]
    fn nob_gpus_bound_throughput() {
        // NoB can never serve more than num_gpus per epoch.
        let cfg = quick_config(100.0, 10);
        let m = run(&cfg, &mut NoBatching::new());
        let max_per_epoch = cfg.cluster.num_gpus as f64 / cfg.epoch.duration;
        assert!(m.throughput() <= max_per_epoch + 1e-9);
    }
}
