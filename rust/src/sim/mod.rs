//! Epoch-driven discrete-event simulator — the testbed stand-in that
//! regenerates the paper's §IV evaluation.
//!
//! Implements the Fig. 2 protocol: time is divided into epochs; requests
//! arriving during epoch e are aggregated and offered to the scheduler at
//! the boundary of epoch e+1; scheduled requests upload during T_U, compute
//! during the (overlapped) T_C and download during T_D. Completion within
//! τ_i counts toward throughput — the paper's headline metric.

use crate::cluster::ClusterSpec;
use crate::coordinator::{EpochParams, ProblemInstance, Scheduler};
use crate::metrics::{Metrics, Outcome};
use crate::model::{CostModel, LlmSpec};
use crate::quant::QuantSpec;
use crate::request::{EpochRequest, Request};
use crate::util::rng::Rng;
use crate::wireless::{ChannelParams, RadioParams};
use crate::workload::{WorkloadGenerator, WorkloadParams};

/// Full simulation scenario.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub model: LlmSpec,
    pub quant: QuantSpec,
    pub cluster: ClusterSpec,
    pub epoch: EpochParams,
    pub radio: RadioParams,
    pub channel: ChannelParams,
    pub workload: WorkloadParams,
    /// Number of epochs to simulate.
    pub epochs: usize,
    pub seed: u64,
    /// Fixed padding length s'; `None` pads to the longest queued prompt.
    pub s_pad: Option<u32>,
}

impl SimConfig {
    /// Paper §IV defaults: BLOOM-3B, W8A16, 20×TX2, 2 s epochs, λ=50.
    pub fn paper_default() -> Self {
        SimConfig {
            model: LlmSpec::bloom_3b(),
            quant: crate::quant::default_quant(),
            cluster: ClusterSpec::paper_default(),
            epoch: EpochParams::default(),
            radio: RadioParams::default(),
            channel: ChannelParams::default(),
            workload: WorkloadParams::default(),
            epochs: 30,
            seed: 42,
            s_pad: None,
        }
    }
}

/// Run one scenario under one scheduling policy; returns aggregate metrics.
pub fn run(config: &SimConfig, scheduler: &mut dyn Scheduler) -> Metrics {
    let mut metrics = Metrics::new();
    let mut gen = WorkloadGenerator::new(config.workload.clone(), config.seed);
    let mut channel_rng = Rng::new(config.seed ^ 0xC0FFEE);
    let cost = CostModel::new(config.model.clone());
    let duration = config.epoch.duration;

    // Requests waiting to be scheduled (arrived in earlier epochs).
    let mut queue: Vec<Request> = Vec::new();

    for e in 0..config.epochs {
        let now = e as f64 * duration;

        // 1. Drop queued requests that can no longer make their deadline even
        //    if scheduled right now and run alone at full cluster speed.
        let mut survivors = Vec::with_capacity(queue.len());
        for r in queue.drain(..) {
            let best_case = config.epoch.t_u
                + config.quant.beta
                    * cost.total_flops_per_req(r.prompt_tokens, r.output_tokens)
                    / config.cluster.total_flops()
                + config.epoch.t_d;
            if r.waited(now) + best_case > r.latency_req {
                metrics.record_outcome(Outcome::Dropped, 0.0);
            } else {
                survivors.push(r);
            }
        }
        queue = survivors;
        metrics.queue_depth.push(queue.len() as f64);

        // 2. Annotate the queue with this epoch's channel state.
        let s_pad = config.s_pad.unwrap_or_else(|| {
            queue
                .iter()
                .map(|r| r.prompt_tokens)
                .max()
                .unwrap_or(512)
        });
        let inst = ProblemInstance::new(
            cost.clone(),
            config.quant.clone(),
            config.cluster.clone(),
            config.epoch.clone(),
            s_pad,
            now,
        );
        let annotated: Vec<EpochRequest> = queue
            .iter()
            .map(|r| {
                let h = config.channel.draw_h(&mut channel_rng);
                EpochRequest::annotate(r.clone(), h, &config.radio, config.epoch.t_u, config.epoch.t_d)
            })
            .collect();

        // 3. Drop requests the deployed quantization can never satisfy
        //    (accuracy admission is workload-independent).
        //    They'd otherwise sit in the queue forever.
        let inadmissible: Vec<u64> = annotated
            .iter()
            .filter(|r| !inst.admits(r))
            .map(|r| r.id())
            .collect();
        for _ in &inadmissible {
            metrics.record_outcome(Outcome::Dropped, 0.0);
        }
        queue.retain(|r| !inadmissible.contains(&r.id));
        let annotated: Vec<EpochRequest> = annotated
            .into_iter()
            .filter(|r| !inadmissible.contains(&r.id()))
            .collect();

        // 4. Schedule.
        let sched = scheduler.schedule(&inst, &annotated);
        metrics.record_schedule(sched.batch_size(), &sched.stats);

        // 5. Resolve completions.
        for &(id, t_compute) in &sched.per_request_compute {
            let req = annotated
                .iter()
                .find(|r| r.id() == id)
                .expect("scheduler returned unknown request id");
            let completion = now + config.epoch.t_u + t_compute + config.epoch.t_d;
            let latency = completion - req.req.arrival;
            let outcome = if latency <= req.req.latency_req + 1e-9 {
                Outcome::CompletedInDeadline
            } else {
                Outcome::CompletedLate
            };
            metrics.record_outcome(outcome, latency);
        }
        queue.retain(|r| !sched.scheduled.contains(&r.id));

        // 6. Admit the arrivals of this epoch (schedulable from the next
        //    boundary onward — the Fig. 2 aggregation rule).
        let arrivals = gen.arrivals_between(now, now + duration);
        metrics.record_offered(arrivals.len() as u64);
        queue.extend(arrivals);
    }

    // Close accounting: whatever still waits at the horizon is unserved.
    for _ in &queue {
        metrics.record_outcome(Outcome::Dropped, 0.0);
    }
    metrics.horizon = config.epochs as f64 * duration;
    metrics
}

/// Convenience: run the same scenario under several schedulers (fresh
/// workload generator each time — identical arrivals thanks to the seed).
pub fn compare(
    config: &SimConfig,
    schedulers: Vec<Box<dyn Scheduler>>,
) -> Vec<(String, Metrics)> {
    schedulers
        .into_iter()
        .map(|mut s| {
            let m = run(config, s.as_mut());
            (s.name().to_string(), m)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Dftsp, NoBatching, StaticBatching};

    fn quick_config(rate: f64, epochs: usize) -> SimConfig {
        SimConfig {
            workload: WorkloadParams {
                arrival_rate: rate,
                ..Default::default()
            },
            epochs,
            ..SimConfig::paper_default()
        }
    }

    #[test]
    fn accounting_closes() {
        // offered == in-deadline + late + dropped (queue leftover included).
        let cfg = quick_config(20.0, 10);
        let m = run(&cfg, &mut Dftsp::new());
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped,
            "conservation of requests"
        );
        assert!(m.offered > 0);
        assert!(m.throughput() > 0.0);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = quick_config(30.0, 8);
        let a = run(&cfg, &mut Dftsp::new());
        let b = run(&cfg, &mut Dftsp::new());
        assert_eq!(a.completed_in_deadline, b.completed_in_deadline);
        assert_eq!(a.offered, b.offered);
        assert_eq!(a.search.nodes_visited, b.search.nodes_visited);
    }

    #[test]
    fn dftsp_beats_baselines_at_moderate_load() {
        let cfg = quick_config(40.0, 12);
        let d = run(&cfg, &mut Dftsp::new());
        let s = run(&cfg, &mut StaticBatching::new());
        let n = run(&cfg, &mut NoBatching::new());
        assert!(
            d.throughput() >= s.throughput(),
            "DFTSP {} vs StB {}",
            d.throughput(),
            s.throughput()
        );
        assert!(
            d.throughput() >= n.throughput(),
            "DFTSP {} vs NoB {}",
            d.throughput(),
            n.throughput()
        );
    }

    #[test]
    fn throughput_saturates_with_rate() {
        // Fig. 5(a) shape: throughput grows then flattens.
        let lo = run(&quick_config(5.0, 12), &mut Dftsp::new());
        let mid = run(&quick_config(60.0, 12), &mut Dftsp::new());
        let hi = run(&quick_config(200.0, 12), &mut Dftsp::new());
        assert!(mid.throughput() > lo.throughput());
        // saturation: the jump from mid to hi is much smaller than lo to mid
        let g1 = mid.throughput() - lo.throughput();
        let g2 = hi.throughput() - mid.throughput();
        assert!(g2 < g1, "g1={g1} g2={g2}");
    }

    #[test]
    fn larger_model_lower_throughput() {
        let mut cfg7 = quick_config(60.0, 10);
        cfg7.model = LlmSpec::bloom_7b();
        let m3 = run(&quick_config(60.0, 10), &mut Dftsp::new());
        let m7 = run(&cfg7, &mut Dftsp::new());
        assert!(
            m3.throughput() > m7.throughput(),
            "3B {} vs 7.1B {}",
            m3.throughput(),
            m7.throughput()
        );
    }

    #[test]
    fn nob_gpus_bound_throughput() {
        // NoB can never serve more than num_gpus per epoch.
        let cfg = quick_config(100.0, 10);
        let m = run(&cfg, &mut NoBatching::new());
        let max_per_epoch = cfg.cluster.num_gpus as f64 / cfg.epoch.duration;
        assert!(m.throughput() <= max_per_epoch + 1e-9);
    }
}
