//! Epoch-driven discrete-event simulator — the testbed stand-in that
//! regenerates the paper's §IV evaluation.
//!
//! Since PR 1 this module is a thin adapter: the Fig. 2 protocol itself
//! (aggregation, admission, scheduling, outcome accounting) lives once in
//! [`crate::driver::EpochDriver`]; the simulator contributes the *simulated*
//! ingredients — a [`SimClock`] that lands exactly on epoch boundaries, the
//! [`AnalyticBackend`] that resolves completions from the paper's cost
//! model, and a seeded Poisson workload. Requests arriving during epoch e
//! are aggregated and offered to the scheduler at the boundary of epoch
//! e+1; scheduled requests upload during T_U, compute during the
//! (overlapped) T_C and download during T_D. Completion within τ_i counts
//! toward throughput — the paper's headline metric.

use crate::cluster::{ClusterSpec, ClusterTopology};
use crate::coordinator::{Deployment, EpochParams, PartitionPolicy, Scheduler, SchedulerConfig};
use crate::driver::{
    run_epochs, AnalyticBackend, BatchingMode, ChaosBackend, ChaosConfig, ContinuousBackend,
    DriverBuilder, DriverPolicy, ElasticPolicy, EpochDriver, ExecutionBackend, InstanceTemplate,
    SPadPolicy, ShardedDriver, SimClock, StalePolicy,
};
use crate::metrics::Metrics;
use crate::model::{CostModel, LlmSpec};
use crate::quant::QuantSpec;
use crate::util::rng::Rng;
use crate::wireless::{AllocationPolicy, ChannelParams, RadioParams};
use crate::workload::{WorkloadGenerator, WorkloadParams};

/// Full simulation scenario.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub model: LlmSpec,
    pub quant: QuantSpec,
    pub cluster: ClusterSpec,
    pub epoch: EpochParams,
    pub radio: RadioParams,
    pub channel: ChannelParams,
    pub workload: WorkloadParams,
    /// Number of epochs to simulate.
    pub epochs: usize,
    pub seed: u64,
    /// Fixed padding length s'; `None` pads to the longest queued prompt.
    pub s_pad: Option<u32>,
    /// Execution mode: the paper's epoch barrier, or continuous batching
    /// with decode-step admission (`ContinuousBackend`).
    pub batching: BatchingMode,
    /// Scheduler-level knobs (scenario TOML `[scheduler]`, CLI `--workers`):
    /// the simulator itself is scheduler-agnostic, but the CLI uses this to
    /// construct the policy it passes in (e.g. DFTSP's parallel search).
    pub scheduler: SchedulerConfig,
    /// GPU-pool shards (scenario TOML `[cluster] shards`, CLI `--shards`):
    /// 1 = the paper's single pool (`run`); N > 1 = one `EpochDriver` per
    /// GPU partition behind the sharded dispatch layer (`run_sharded`).
    pub shards: usize,
    /// How the sharded dispatch layer re-partitions GPUs between epochs
    /// (`[cluster] partition_policy`, CLI `--partition`). Ignored at
    /// `shards = 1`.
    pub partition: PartitionPolicy,
    /// Explicit heterogeneous shard layout (`[[cluster.shard]]` TOML
    /// tables). `None` — the common case — expands the `shards` shim into
    /// `shards` near-equal slices of `cluster`
    /// ([`ClusterTopology::homogeneous`]); `Some` overrides both `cluster`
    /// and `shards` for the sharded paths, giving each shard its own GPU
    /// model and pool size.
    pub topology: Option<ClusterTopology>,
    /// Elastic behaviour for the sharded paths (`[elastic]` TOML,
    /// `--steal`/`--autoscale` CLI): cross-shard work stealing, shard
    /// autoscaling and epoch-duration tuning. All off by default, which is
    /// what keeps fixed-shard runs bit-identical to earlier revisions.
    pub elastic: ElasticPolicy,
    /// Deterministic fault injection (`[chaos]` TOML, `--chaos-*` CLI).
    /// Disabled by default; when any fault probability is non-zero the CLI
    /// routes the run through [`run_chaos`] — the supervised sharded driver
    /// with [`ChaosBackend`]-wrapped backends. The chaos stream is seeded
    /// independently of the run seed, so enabling it never perturbs
    /// workload or channel randomness.
    pub chaos: ChaosConfig,
}

impl SimConfig {
    /// Paper §IV defaults: BLOOM-3B, W8A16, 20×TX2, 2 s epochs, λ=50.
    pub fn paper_default() -> Self {
        SimConfig {
            model: LlmSpec::bloom_3b(),
            quant: crate::quant::default_quant(),
            cluster: ClusterSpec::paper_default(),
            epoch: EpochParams::default(),
            radio: RadioParams::default(),
            channel: ChannelParams::default(),
            workload: WorkloadParams::default(),
            epochs: 30,
            seed: 42,
            s_pad: None,
            batching: BatchingMode::Epoch,
            scheduler: SchedulerConfig::default(),
            shards: 1,
            partition: PartitionPolicy::LoadProportional,
            topology: None,
            elastic: ElasticPolicy::default(),
            chaos: ChaosConfig::default(),
        }
    }

    /// The number of shards the sharded paths start with: the explicit
    /// topology's entry count when one is given, else the `shards` shim
    /// (floored at 1). Autoscaling may move the *live* count afterwards.
    pub fn shard_count(&self) -> usize {
        match &self.topology {
            Some(t) => t.shard_count(),
            None => self.shards.max(1),
        }
    }

    /// Does this scenario need the sharded dispatch layer? More than one
    /// shard, an explicit topology, or any elastic behaviour (stealing and
    /// autoscaling only exist across shards; tuning rides the same path).
    pub fn wants_sharded(&self) -> bool {
        self.shard_count() > 1
            || self.topology.is_some()
            || self.elastic.stealing
            || self.elastic.autoscale.is_some()
            || self.elastic.tune_epoch.is_some()
    }
}

/// The driver configuration a scenario maps to (shared with the parity
/// tests; `sim::run` is exactly `EpochDriver` + `SimClock` +
/// `AnalyticBackend` under this policy).
pub fn driver_for(config: &SimConfig) -> EpochDriver<()> {
    EpochDriver::new(
        InstanceTemplate {
            cost: CostModel::new(config.model.clone()),
            quant: config.quant.clone(),
            cluster: config.cluster.clone(),
            epoch: config.epoch.clone(),
        },
        DriverPolicy {
            stale: StalePolicy::BestCaseInfeasible,
            s_pad: match config.s_pad {
                Some(s) => SPadPolicy::Fixed(s),
                None => SPadPolicy::LongestQueued { fallback: 512 },
            },
            allocation: AllocationPolicy::MinOnly,
        },
        config.radio.clone(),
        config.channel.clone(),
        Rng::new(config.seed ^ 0xC0FFEE),
    )
}

/// Run one scenario under one scheduling policy; returns aggregate metrics.
/// Dispatches on `config.batching` — both modes share the driver, the
/// scheduler, the cost model and the seeded workload, so their metrics are
/// directly comparable.
pub fn run(config: &SimConfig, scheduler: &mut dyn Scheduler) -> Metrics {
    match config.batching {
        BatchingMode::Epoch => run_epoch_mode(config, scheduler),
        BatchingMode::Continuous => run_continuous(config, scheduler),
    }
}

/// The paper's Fig. 2 protocol: arrivals during epoch e are offered at the
/// boundary of epoch e+1 and the scheduled batch starts/finishes together.
fn run_epoch_mode(config: &SimConfig, scheduler: &mut dyn Scheduler) -> Metrics {
    let mut gen = WorkloadGenerator::new(config.workload.clone(), config.seed);
    let mut driver = driver_for(config);
    let mut backend = AnalyticBackend;
    let mut clock = SimClock::new();
    let duration = config.epoch.duration;

    // Arrivals during epoch e become schedulable at the boundary of epoch
    // e+1 (the Fig. 2 aggregation rule): ingest the *previous* window at
    // each boundary, and the final epoch's window before closing.
    let mut window_start = 0.0;
    run_epochs(
        &mut driver,
        scheduler,
        &mut backend,
        &mut clock,
        config.epochs as u64,
        |d, _backend, now| {
            for r in gen.arrivals_between(window_start, now) {
                d.offer(r, ());
            }
            window_start = now;
        },
    );
    if config.epochs > 0 {
        let last_boundary = (config.epochs - 1) as f64 * duration;
        for r in gen.arrivals_between(window_start, last_boundary + duration) {
            driver.offer(r, ());
        }
    }

    // Close accounting: whatever still waits at the horizon is unserved.
    driver.finish(&mut backend, config.epochs as f64 * duration);
    driver.into_metrics()
}

/// Continuous batching over the same scenario: each window's arrivals are
/// offered at the window's *start* boundary carrying their true mid-epoch
/// timestamps; the scheduler still picks the feasible set per epoch, but the
/// [`ContinuousBackend`] admits each request at the first decode step after
/// its arrival (KV headroom permitting) instead of the barrier. At the
/// horizon, `finish` decodes the already-running batch to completion and
/// shutdown-rejects whatever still waits at the admission gate (mirroring
/// the epoch path's queue rejection), so the accounting identity
/// `offered = completed + dropped` holds in both modes.
///
/// **Modeling approximation**: offering a window's arrivals at its start
/// gives the *scheduler* (selection + channel annotation) up to one epoch of
/// preview over a causal server — the analytic stand-in for the live path,
/// where mid-epoch arrivals are admitted by the backend's ingress poll
/// without a scheduler pass at all. Admission itself stays causal: the
/// backend never starts a request before its arrival timestamp. Keep this in
/// mind when reading continuous-vs-epoch deltas; the bursty-trace e2e test's
/// margin comes from admission timing, which both intake rules share.
pub fn run_continuous(config: &SimConfig, scheduler: &mut dyn Scheduler) -> Metrics {
    let mut gen = WorkloadGenerator::new(config.workload.clone(), config.seed);
    let mut driver = driver_for(config);
    let mut backend = ContinuousBackend::new(driver.template());
    let mut clock = SimClock::new();
    let duration = config.epoch.duration;

    run_epochs(
        &mut driver,
        scheduler,
        &mut backend,
        &mut clock,
        config.epochs as u64,
        |d, _backend, now| {
            for r in gen.arrivals_between(now, now + duration) {
                d.offer(r, ());
            }
        },
    );

    driver.finish(&mut backend, config.epochs as f64 * duration);
    driver.into_metrics()
}

/// The shard layout a scenario maps to: one deployment per shard, all
/// hosting the scenario's (model, quant) pair — pure data-parallel
/// scale-out of the paper's single deployment over either the homogeneous
/// `shards` shim or the scenario's explicit [`ClusterTopology`].
/// (Heterogeneous multi-*model* layouts construct [`DriverBuilder`]
/// directly; see `tests/sharded_e2e.rs`.)
fn sharded_builder_for(config: &SimConfig) -> DriverBuilder {
    let shards = config.shard_count();
    let deployments = (0..shards)
        .map(|_| Deployment {
            model: config.model.clone(),
            quant: config.quant.clone(),
        })
        .collect();
    let topology = match &config.topology {
        Some(t) => t.clone(),
        None => ClusterTopology::homogeneous(config.cluster.clone(), shards),
    };
    DriverBuilder::new(deployments, topology)
        .partition(config.partition)
        .policy(DriverPolicy {
            stale: StalePolicy::BestCaseInfeasible,
            s_pad: match config.s_pad {
                Some(s) => SPadPolicy::Fixed(s),
                None => SPadPolicy::LongestQueued { fallback: 512 },
            },
            allocation: AllocationPolicy::MinOnly,
        })
        .epoch(config.epoch.clone())
        .radio(config.radio.clone())
        .channel(config.channel.clone())
        // The same stream `driver_for` seeds: shard 0 inherits it verbatim,
        // which is what makes `shards = 1` bit-identical to `run`.
        .seed(config.seed ^ 0xC0FFEE)
        .elastic(config.elastic.clone())
}

/// Run one scenario through the sharded dispatch layer
/// ([`SimConfig::shard_count`] partitions, `config.partition` policy,
/// `config.elastic` behaviours), one fresh scheduler per shard from
/// `make_scheduler`. Intake mirrors [`run`] exactly — same seeded workload,
/// same per-mode aggregation rule — and requests carry a deployment affinity
/// of `id % shards` (deployments are identical here, so routing balances by
/// queue depth regardless). With `shards = 1` the result is bit-identical to
/// [`run`] (`tests/sharded_e2e.rs` pins this; `tests/proptest_sharded.rs`
/// fuzzes it). Construction goes through [`DriverBuilder`], so the factory
/// takes `'static` ownership (the autoscaler keeps it for spawns).
pub fn run_sharded(
    config: &SimConfig,
    make_scheduler: impl FnMut(usize) -> Box<dyn Scheduler + Send> + 'static,
) -> Metrics {
    let builder = sharded_builder_for(config);
    match config.batching {
        BatchingMode::Epoch => {
            let mut sd: ShardedDriver<(), AnalyticBackend> = builder
                .build(|_| AnalyticBackend, make_scheduler)
                .expect("shards <= GPUs (validated by the scenario loader)");
            drive_sharded_epoch_mode(config, &mut sd)
        }
        BatchingMode::Continuous => {
            let mut sd: ShardedDriver<(), ContinuousBackend> = builder
                .build(ContinuousBackend::new, make_scheduler)
                .expect("shards <= GPUs (validated by the scenario loader)");
            drive_sharded_continuous(config, &mut sd)
        }
    }
}

/// Run one scenario through the *supervised* sharded dispatch layer with
/// [`ChaosBackend`]-wrapped backends injecting `config.chaos`'s fault mix.
/// Intake is byte-for-byte [`run_sharded`]'s (the shared drive helpers), so
/// every delta against a chaos-free run is attributable to injected faults
/// and the supervisor's response — and two runs with the same seeds produce
/// the same fault schedule and the same metrics (wall-dependent
/// `epoch_stalls` excepted when stall faults are enabled).
///
/// The factories take `'static` ownership because the supervisor keeps them
/// for crash-time rebuilds (fresh backend and scheduler, next chaos
/// generation).
pub fn run_chaos(
    config: &SimConfig,
    make_scheduler: impl FnMut(usize) -> Box<dyn Scheduler + Send> + 'static,
) -> Metrics {
    let builder = sharded_builder_for(config);
    let chaos = config.chaos;
    match config.batching {
        BatchingMode::Epoch => {
            let mut sd: ShardedDriver<(), ChaosBackend<AnalyticBackend>> = builder
                .build_supervised(
                    move |_t: &InstanceTemplate, shard, generation| {
                        ChaosBackend::new(AnalyticBackend, chaos, shard as u64, generation)
                    },
                    make_scheduler,
                )
                .expect("shards <= GPUs (validated by the scenario loader)");
            drive_sharded_epoch_mode(config, &mut sd)
        }
        BatchingMode::Continuous => {
            let mut sd: ShardedDriver<(), ChaosBackend<ContinuousBackend>> = builder
                .build_supervised(
                    move |t: &InstanceTemplate, shard, generation| {
                        ChaosBackend::new(ContinuousBackend::new(t), chaos, shard as u64, generation)
                    },
                    make_scheduler,
                )
                .expect("shards <= GPUs (validated by the scenario loader)");
            drive_sharded_continuous(config, &mut sd)
        }
    }
}

/// Fig. 2 intake over a sharded driver: epoch e's arrival window is offered
/// at the boundary of e+1 with a deployment affinity of `id % shards`.
/// Shared verbatim by [`run_sharded`] and [`run_chaos`], so the two paths
/// cannot drift.
fn drive_sharded_epoch_mode<B>(config: &SimConfig, sd: &mut ShardedDriver<(), B>) -> Metrics
where
    B: ExecutionBackend<Payload = ()> + Send,
{
    let shards = config.shard_count();
    let duration = config.epoch.duration;
    // With the epoch tuner armed, boundaries follow the tuner's per-epoch
    // durations (read back from the driver each tick). Without it, keep the
    // exact `e * duration` arithmetic of earlier revisions — accumulation
    // rounds differently for non-dyadic durations, and the fixed-count
    // parity contract is bit-level.
    let tuned = config.elastic.tune_epoch.is_some();
    let mut gen = WorkloadGenerator::new(config.workload.clone(), config.seed);
    let affinity = |id: u64| (id % shards as u64) as usize;
    // Fig. 2 aggregation: epoch e's window is offered at e+1.
    let mut window_start = 0.0;
    let mut now = 0.0;
    for e in 0..config.epochs as u64 {
        if !tuned {
            now = e as f64 * duration;
        }
        for r in gen.arrivals_between(window_start, now) {
            let aff = affinity(r.id);
            sd.offer(r, (), aff);
        }
        window_start = now;
        // The duration governing this epoch: the tuner adjusts at the *end*
        // of a step, so read before stepping.
        let d = if tuned { sd.epoch_duration() } else { duration };
        sd.step_epoch(now);
        now += d;
    }
    if config.epochs > 0 {
        // Untuned, this is `last_boundary + duration` — the exact expression
        // (and rounding) the unsharded path uses, not `epochs * duration`.
        let window_end = if tuned {
            now
        } else {
            (config.epochs - 1) as f64 * duration + duration
        };
        for r in gen.arrivals_between(window_start, window_end) {
            let aff = affinity(r.id);
            sd.offer(r, (), aff);
        }
    }
    let horizon = if tuned {
        now
    } else {
        config.epochs as f64 * duration
    };
    sd.finish(horizon);
    sd.merged_metrics()
}

/// Continuous-mode intake over a sharded driver (window offered at its own
/// start; see [`run_continuous`]'s modeling note). Shared by [`run_sharded`]
/// and [`run_chaos`].
fn drive_sharded_continuous<B>(config: &SimConfig, sd: &mut ShardedDriver<(), B>) -> Metrics
where
    B: ExecutionBackend<Payload = ()> + Send,
{
    let shards = config.shard_count();
    let duration = config.epoch.duration;
    // See drive_sharded_epoch_mode: tuner-driven boundaries accumulate, the
    // fixed schedule keeps the historical `e * duration` arithmetic.
    let tuned = config.elastic.tune_epoch.is_some();
    let mut gen = WorkloadGenerator::new(config.workload.clone(), config.seed);
    let affinity = |id: u64| (id % shards as u64) as usize;
    let mut now = 0.0;
    for e in 0..config.epochs as u64 {
        if !tuned {
            now = e as f64 * duration;
        }
        let d = if tuned { sd.epoch_duration() } else { duration };
        for r in gen.arrivals_between(now, now + d) {
            let aff = affinity(r.id);
            sd.offer(r, (), aff);
        }
        sd.step_epoch(now);
        now += d;
    }
    let horizon = if tuned {
        now
    } else {
        config.epochs as f64 * duration
    };
    sd.finish(horizon);
    sd.merged_metrics()
}

/// Convenience: run the same scenario under several schedulers (fresh
/// workload generator each time — identical arrivals thanks to the seed).
pub fn compare(
    config: &SimConfig,
    schedulers: Vec<Box<dyn Scheduler>>,
) -> Vec<(String, Metrics)> {
    schedulers
        .into_iter()
        .map(|mut s| {
            let m = run(config, s.as_mut());
            (s.name().to_string(), m)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Dftsp, NoBatching, StaticBatching};

    fn quick_config(rate: f64, epochs: usize) -> SimConfig {
        SimConfig {
            workload: WorkloadParams {
                arrival_rate: rate,
                ..Default::default()
            },
            epochs,
            ..SimConfig::paper_default()
        }
    }

    #[test]
    fn accounting_closes() {
        // offered == in-deadline + late + dropped (queue leftover included).
        let cfg = quick_config(20.0, 10);
        let m = run(&cfg, &mut Dftsp::new());
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped,
            "conservation of requests"
        );
        assert!(m.offered > 0);
        assert!(m.throughput() > 0.0);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = quick_config(30.0, 8);
        let a = run(&cfg, &mut Dftsp::new());
        let b = run(&cfg, &mut Dftsp::new());
        assert_eq!(a.completed_in_deadline, b.completed_in_deadline);
        assert_eq!(a.offered, b.offered);
        assert_eq!(a.search.nodes_visited, b.search.nodes_visited);
    }

    #[test]
    fn dftsp_beats_baselines_at_moderate_load() {
        let cfg = quick_config(40.0, 12);
        let d = run(&cfg, &mut Dftsp::new());
        let s = run(&cfg, &mut StaticBatching::new());
        let n = run(&cfg, &mut NoBatching::new());
        assert!(
            d.throughput() >= s.throughput(),
            "DFTSP {} vs StB {}",
            d.throughput(),
            s.throughput()
        );
        assert!(
            d.throughput() >= n.throughput(),
            "DFTSP {} vs NoB {}",
            d.throughput(),
            n.throughput()
        );
    }

    #[test]
    fn throughput_saturates_with_rate() {
        // Fig. 5(a) shape: throughput grows then flattens.
        let lo = run(&quick_config(5.0, 12), &mut Dftsp::new());
        let mid = run(&quick_config(60.0, 12), &mut Dftsp::new());
        let hi = run(&quick_config(200.0, 12), &mut Dftsp::new());
        assert!(mid.throughput() > lo.throughput());
        // saturation: the jump from mid to hi is much smaller than lo to mid
        let g1 = mid.throughput() - lo.throughput();
        let g2 = hi.throughput() - mid.throughput();
        assert!(g2 < g1, "g1={g1} g2={g2}");
    }

    #[test]
    fn larger_model_lower_throughput() {
        let mut cfg7 = quick_config(60.0, 10);
        cfg7.model = LlmSpec::bloom_7b();
        let m3 = run(&quick_config(60.0, 10), &mut Dftsp::new());
        let m7 = run(&cfg7, &mut Dftsp::new());
        assert!(
            m3.throughput() > m7.throughput(),
            "3B {} vs 7.1B {}",
            m3.throughput(),
            m7.throughput()
        );
    }

    #[test]
    fn continuous_mode_conserves_requests() {
        let mut cfg = quick_config(30.0, 10);
        cfg.batching = BatchingMode::Continuous;
        let m = run(&cfg, &mut Dftsp::new());
        assert_eq!(
            m.offered,
            m.completed_in_deadline + m.completed_late + m.dropped,
            "conservation of requests (continuous)"
        );
        assert!(m.offered > 0);
        assert!(m.completed_in_deadline > 0);
        assert!(m.admission_latency.count() > 0, "admissions recorded");
        assert!(m.inflight_occupancy.count() > 0, "occupancy recorded");
    }

    #[test]
    fn continuous_mode_deterministic() {
        let mut cfg = quick_config(40.0, 8);
        cfg.batching = BatchingMode::Continuous;
        let a = run(&cfg, &mut Dftsp::new());
        let b = run(&cfg, &mut Dftsp::new());
        assert_eq!(a, b);
    }

    #[test]
    fn continuous_admission_beats_the_barrier_on_waiting() {
        // Same scenario, same scheduler, same arrivals: decode-step
        // admission must not wait longer than the epoch barrier does on
        // average. (The strict throughput comparison under a bursty trace
        // lives in tests/continuous_e2e.rs.)
        let cfg_epoch = quick_config(30.0, 12);
        let mut cfg_cont = quick_config(30.0, 12);
        cfg_cont.batching = BatchingMode::Continuous;
        let e = run(&cfg_epoch, &mut Dftsp::new());
        let c = run(&cfg_cont, &mut Dftsp::new());
        assert!(c.completed_in_deadline + c.completed_late > 0);
        // Continuous admission latency is bounded by the epoch duration for
        // a lightly-loaded system (a barrier admission averages ~half an
        // epoch of queueing before T_U even starts).
        assert!(c.mean_admission_latency() < cfg_epoch.epoch.duration);
        assert_eq!(e.offered, c.offered, "identical seeded workloads");
    }

    #[test]
    fn sharded_one_shard_matches_unsharded_bit_exactly() {
        // The headline parity contract, in both batching modes: shards = 1
        // through the dispatch layer is the unsharded driver, bit for bit.
        for batching in [BatchingMode::Epoch, BatchingMode::Continuous] {
            let mut cfg = quick_config(35.0, 10);
            cfg.batching = batching;
            cfg.shards = 1;
            let unsharded = run(&cfg, &mut Dftsp::new());
            let sharded = run_sharded(&cfg, |_| Box::new(Dftsp::new()));
            assert_eq!(unsharded, sharded, "{batching:?}");
        }
    }

    #[test]
    fn sharded_runs_conserve_and_stay_deterministic() {
        for batching in [BatchingMode::Epoch, BatchingMode::Continuous] {
            let mut cfg = quick_config(40.0, 8);
            cfg.batching = batching;
            cfg.shards = 4;
            let a = run_sharded(&cfg, |_| Box::new(Dftsp::new()));
            let b = run_sharded(&cfg, |_| Box::new(Dftsp::new()));
            assert_eq!(a, b, "{batching:?}: sharded runs are deterministic");
            assert!(a.offered > 0);
            assert_eq!(
                a.offered,
                a.completed_in_deadline + a.completed_late + a.dropped,
                "{batching:?}: conservation through the dispatch layer"
            );
            // Same seeded workload as the unsharded run.
            cfg.shards = 1;
            let solo = run(&cfg, &mut Dftsp::new());
            assert_eq!(solo.offered, a.offered, "{batching:?}: identical arrivals");
        }
    }

    #[test]
    fn chaos_disabled_supervised_run_matches_run_sharded_bit_exactly() {
        // Acceptance gate: with every fault probability at zero, the
        // supervised chaos path (catch_unwind, health bookkeeping,
        // passthrough ChaosBackend) is bit-identical to the plain sharded
        // run — at shards = 1 this chains with
        // `sharded_one_shard_matches_unsharded_bit_exactly` to pin the full
        // tower sim == sharded == supervised.
        for shards in [1usize, 3] {
            let mut cfg = quick_config(35.0, 8);
            cfg.shards = shards;
            let plain = run_sharded(&cfg, |_| Box::new(Dftsp::new()));
            let chaos = run_chaos(&cfg, |_| Box::new(Dftsp::new()));
            assert_eq!(plain, chaos, "shards={shards}");
        }
    }

    #[test]
    fn seeded_chaos_run_is_reproducible_and_conserves() {
        let mut cfg = quick_config(40.0, 12);
        cfg.shards = 3;
        // Panic/error/kv-fail only: stall faults are wall-dependent
        // (epoch_stalls), which would break the bit-equality assertion.
        cfg.chaos = crate::driver::ChaosConfig {
            seed: 77,
            panic_prob: 0.2,
            error_prob: 0.15,
            kv_fail_prob: 0.15,
            ..Default::default()
        };
        let a = run_chaos(&cfg, |_| Box::new(Dftsp::new()));
        let b = run_chaos(&cfg, |_| Box::new(Dftsp::new()));
        assert_eq!(a, b, "same seeds, same fault schedule, same metrics");
        assert!(a.shard_crashes > 0, "the fault mix actually fired");
        assert_eq!(
            a.offered,
            a.completed_in_deadline + a.completed_late + a.dropped + a.shard_failed,
            "conservation holds through injected crashes"
        );
        // A different chaos seed yields a different schedule without
        // touching the workload.
        let mut cfg2 = cfg.clone();
        cfg2.chaos.seed = 78;
        let c = run_chaos(&cfg2, |_| Box::new(Dftsp::new()));
        assert_eq!(a.offered, c.offered, "workload stream untouched by chaos seed");
    }

    #[test]
    fn nob_gpus_bound_throughput() {
        // NoB can never serve more than num_gpus per epoch.
        let cfg = quick_config(100.0, 10);
        let m = run(&cfg, &mut NoBatching::new());
        let max_per_epoch = cfg.cluster.num_gpus as f64 / cfg.epoch.duration;
        assert!(m.throughput() <= max_per_epoch + 1e-9);
    }
}
