//! StB — static batching baseline (paper §IV benchmark 1).
//!
//! "The edge node has a set batch size based on epoch duration and LLM
//! parameters to avoid GPU overflow." The batch size is fixed *offline* from
//! worst-case assumptions (every request at the maximum output length), and
//! requests are admitted FCFS up to that size; per-request deadlines play no
//! role in selection — the defining weakness the paper's Fig. 5 exposes.

use crate::coordinator::problem::ProblemInstance;
use crate::coordinator::scheduler::{Schedule, Scheduler, SearchStats};
use crate::request::EpochRequest;
use crate::wireless::BandwidthLedger;

/// Static batching with an offline-fixed batch size.
#[derive(Debug, Clone, Default)]
pub struct StaticBatching {
    /// Optional manual override of the computed batch size.
    pub fixed_batch: Option<usize>,
}

impl StaticBatching {
    pub fn new() -> Self {
        StaticBatching::default()
    }

    /// The offline batch-size rule: the largest batch that can neither
    /// overflow memory nor overrun its share of the epoch even if *every*
    /// request demands the maximum output length. The compute budget is half
    /// the usable slot (T_C − T_U − T_D): the conservative static
    /// provisioning headroom an operator would configure so a worst-case
    /// batch still leaves time for queueing jitter — without it, StB batches
    /// always consume the whole epoch and never meet a sub-epoch deadline.
    pub fn static_batch_size(inst: &ProblemInstance, n_max: u32) -> usize {
        let kv_worst = inst.kv_bytes(n_max);
        let by_mem = inst
            .cluster
            .max_batch_by_memory(&inst.cost, &inst.quant, kv_worst);
        // Compute: B · β(F_prefill + F_decode_worst)/C_total ≤ budget.
        let budget = 0.5 * (inst.epoch.t_c() - inst.epoch.t_u - inst.epoch.t_d).max(0.0);
        let per_req = inst.quant.beta
            * (inst.cost.prefill_flops_per_req(inst.s_pad)
                + inst.cost.decode_flops_per_req(inst.s_pad, n_max))
            / inst.cluster.total_flops();
        let by_compute = if per_req <= 0.0 {
            usize::MAX
        } else {
            (budget / per_req).floor() as usize
        };
        by_mem.min(by_compute)
    }
}

impl Scheduler for StaticBatching {
    fn name(&self) -> &'static str {
        "StB"
    }

    fn schedule(&mut self, inst: &ProblemInstance, candidates: &[EpochRequest]) -> Schedule {
        // Accuracy admission still applies (it is a property of the deployed
        // model, not of the batching policy). Latency is deliberately NOT
        // consulted.
        let mut adm: Vec<&EpochRequest> = candidates
            .iter()
            .filter(|r| inst.admits(r))
            .filter(|r| r.rho_min_u <= 1.0 && r.rho_min_d <= 1.0)
            .collect();
        if adm.is_empty() {
            return Schedule::empty();
        }
        // FCFS: earliest arrival first.
        adm.sort_by(|a, b| {
            a.req
                .arrival
                .total_cmp(&b.req.arrival)
                .then(a.id().cmp(&b.id()))
        });

        let n_max = candidates
            .iter()
            .map(|r| r.req.output_tokens)
            .max()
            .unwrap_or(512)
            .max(512);
        let batch_cap = self
            .fixed_batch
            .unwrap_or_else(|| Self::static_batch_size(inst, n_max));

        let mut ledger = BandwidthLedger::new();
        let mut selected: Vec<&EpochRequest> = Vec::new();
        for r in adm {
            if selected.len() >= batch_cap {
                break;
            }
            if ledger.alloc(r.rho_min_u, r.rho_min_d) {
                selected.push(r);
            }
        }
        if selected.is_empty() {
            return Schedule::empty();
        }
        let decode_flops: f64 = selected
            .iter()
            .map(|r| {
                inst.cost
                    .decode_flops_per_req(inst.s_pad, r.req.output_tokens)
            })
            .sum();
        let t = inst.compute_time(selected.len(), decode_flops);
        Schedule::from_subset(&selected, t, SearchStats::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, GpuSpec};
    use crate::coordinator::problem::EpochParams;
    use crate::model::{CostModel, LlmSpec};
    use crate::quant;
    use crate::request::RequestBuilder;
    use crate::wireless::RadioParams;

    fn inst(gpus: usize) -> ProblemInstance {
        ProblemInstance::new(
            CostModel::new(LlmSpec::bloom_3b()),
            quant::default_quant(),
            ClusterSpec::new(GpuSpec::jetson_tx2(), gpus),
            EpochParams::default(),
            512,
            0.0,
        )
    }

    fn gen(specs: &[(f64, u32, u32, f64, f64)]) -> Vec<EpochRequest> {
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        specs
            .iter()
            .map(|&(at, s, n, tau, a)| {
                EpochRequest::annotate(
                    b.build(at, s, n, tau, a),
                    (1e-3f64).sqrt(),
                    &radio,
                    0.25,
                    0.25,
                )
            })
            .collect()
    }

    #[test]
    fn batch_size_is_worst_case_conservative() {
        let i = inst(20);
        let b = StaticBatching::static_batch_size(&i, 512);
        assert!(b > 0);
        // Worst-case sizing must not exceed what the epoch can compute at
        // max output length.
        let per_req = i.quant.beta
            * (i.cost.prefill_flops_per_req(512) + i.cost.decode_flops_per_req(512, 512))
            / i.cluster.total_flops();
        assert!(b as f64 * per_req <= i.epoch.t_c() + 1e-9);
    }

    #[test]
    fn fcfs_selection() {
        let i = inst(20);
        let reqs = gen(&[
            (2.0, 128, 128, 2.0, 0.2),
            (0.5, 128, 128, 2.0, 0.2),
            (1.0, 128, 128, 2.0, 0.2),
        ]);
        let mut stb = StaticBatching {
            fixed_batch: Some(2),
        };
        let s = stb.schedule(&i, &reqs);
        assert_eq!(s.batch_size(), 2);
        // picks the two earliest arrivals (ids 1 and 2)
        assert!(s.scheduled.contains(&reqs[1].id()));
        assert!(s.scheduled.contains(&reqs[2].id()));
    }

    #[test]
    fn ignores_deadlines() {
        // A request whose deadline is hopeless still gets batched — StB's
        // defining flaw.
        let i = inst(20);
        let reqs = gen(&[(0.0, 512, 512, 0.51, 0.2); 4]);
        let s = StaticBatching::new().schedule(&i, &reqs);
        assert!(s.batch_size() >= 1);
    }

    #[test]
    fn respects_bandwidth() {
        let i = inst(20);
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        // Horrible channel: each request needs ~36% of uplink.
        let reqs: Vec<EpochRequest> = (0..6)
            .map(|k| {
                EpochRequest::annotate(
                    b.build(k as f64 * 0.01, 512, 128, 5.0, 0.2),
                    5e-8,
                    &radio,
                    0.25,
                    0.25,
                )
            })
            .collect();
        let s = StaticBatching::new().schedule(&i, &reqs);
        assert!(s.rho_u_total <= 1.0 + 1e-9);
        assert!(s.batch_size() < 6);
    }

    #[test]
    fn smaller_cluster_smaller_batch() {
        let big = StaticBatching::static_batch_size(&inst(20), 512);
        let small = StaticBatching::static_batch_size(&inst(2), 512);
        assert!(big > small);
    }
}
