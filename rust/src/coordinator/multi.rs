//! Multi-LLM deployments — the paper's §II note that "while Fig. 1 focuses
//! on one LLM, our approach is adaptable for multiple LLMs", made concrete:
//! the edge node hosts several (model, quantization) deployments, the GPU
//! pool is partitioned between them, and each partition runs its own DFTSP
//! epoch schedule over the requests routed to it.

use crate::cluster::ClusterSpec;
use crate::coordinator::problem::{EpochParams, ProblemInstance};
use crate::coordinator::scheduler::{Schedule, Scheduler};
use crate::model::{CostModel, LlmSpec};
use crate::quant::QuantSpec;
use crate::request::EpochRequest;

/// One hosted (model, quantization) pair.
#[derive(Debug, Clone)]
pub struct Deployment {
    pub model: LlmSpec,
    pub quant: QuantSpec,
}

impl Deployment {
    /// Peak FLOPs one "typical" request costs on this deployment — used as
    /// the load weight for GPU partitioning (here and by the sharded
    /// driver's between-epoch re-partitioning).
    pub fn req_weight(&self, s_pad: u32, n_typ: u32) -> f64 {
        let cost = CostModel::new(self.model.clone());
        self.quant.beta * cost.total_flops_per_req(s_pad, n_typ)
    }

    /// Do two deployments serve the same (model, quantization) pair? The
    /// sharded driver's routing treats same-deployment shards as mutual
    /// spill-over targets.
    pub fn same_as(&self, other: &Deployment) -> bool {
        self.model.name == other.model.name && self.quant.label() == other.quant.label()
    }
}

/// GPU-partitioning policy across deployments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionPolicy {
    /// Equal GPU counts (remainder to the earliest deployments).
    Equal,
    /// GPUs ∝ offered load (queued requests × per-request FLOPs).
    LoadProportional,
}

impl PartitionPolicy {
    /// Parse the `partition_policy = "equal" | "load-proportional"` knob
    /// (scenario TOML `[cluster]`, CLI `--partition`).
    pub fn parse(s: &str) -> Result<PartitionPolicy, String> {
        match s.to_ascii_lowercase().as_str() {
            "equal" => Ok(PartitionPolicy::Equal),
            "load" | "load-proportional" | "load_proportional" | "loadproportional" => {
                Ok(PartitionPolicy::LoadProportional)
            }
            other => Err(format!(
                "unknown partition policy `{other}` (expected `equal` or `load-proportional`)"
            )),
        }
    }
}

impl std::fmt::Display for PartitionPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PartitionPolicy::Equal => write!(f, "equal"),
            PartitionPolicy::LoadProportional => write!(f, "load-proportional"),
        }
    }
}

/// Why a GPU partition could not be formed. Before this error existed, a
/// request for more deployments than GPUs died on an `assert!` deep inside
/// the apportionment — callers (the sharded driver, scenario validation)
/// now get a typed, recoverable verdict instead.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionError {
    /// No deployments were given — there is nothing to partition over.
    NoDeployments,
    /// Fewer GPUs than active deployments: the min-1-GPU-per-deployment
    /// guarantee (a deployment with zero GPUs can never serve anything,
    /// silently blackholing every request routed to it) is unsatisfiable.
    InsufficientGpus {
        deployments: usize,
        total_gpus: usize,
    },
}

impl std::fmt::Display for PartitionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PartitionError::NoDeployments => write!(f, "no deployments to partition GPUs over"),
            PartitionError::InsufficientGpus {
                deployments,
                total_gpus,
            } => write!(
                f,
                "{total_gpus} GPUs cannot give {deployments} deployments one GPU each \
                 (min-1 guarantee)"
            ),
        }
    }
}

impl std::error::Error for PartitionError {}

/// Core apportionment over precomputed per-deployment load weights (FLOPs of
/// queued demand; any non-negative scale works — only ratios matter). Every
/// deployment is guaranteed at least one GPU and the result always sums to
/// `total_gpus`; when that guarantee cannot hold, a typed [`PartitionError`]
/// is returned instead of a zero-GPU partition.
pub fn partition_gpus_by_load(
    loads: &[f64],
    total_gpus: usize,
    policy: PartitionPolicy,
) -> Result<Vec<usize>, PartitionError> {
    let k = loads.len();
    if k == 0 {
        return Err(PartitionError::NoDeployments);
    }
    if total_gpus < k {
        return Err(PartitionError::InsufficientGpus {
            deployments: k,
            total_gpus,
        });
    }
    match policy {
        PartitionPolicy::Equal => {
            let base = total_gpus / k;
            let extra = total_gpus % k;
            Ok((0..k).map(|i| base + usize::from(i < extra)).collect())
        }
        PartitionPolicy::LoadProportional => {
            // Idle deployments keep a floor weight so the quota ratios stay
            // finite; NaN/negative loads (poisoned cost inputs) clamp there
            // too rather than corrupting the apportionment.
            let weights: Vec<f64> = loads
                .iter()
                .map(|&w| if w.is_finite() && w > 1.0 { w } else { 1.0 })
                .collect();
            let total_w: f64 = weights.iter().sum();
            // one guaranteed GPU each, remainder largest-remainder apportioned
            let spare = total_gpus - k;
            let quotas: Vec<f64> = weights.iter().map(|w| spare as f64 * w / total_w).collect();
            let mut alloc: Vec<usize> = quotas.iter().map(|q| 1 + q.floor() as usize).collect();
            let mut assigned: usize = alloc.iter().sum();
            let mut rema: Vec<(usize, f64)> = quotas
                .iter()
                .enumerate()
                .map(|(i, q)| (i, q - q.floor()))
                .collect();
            rema.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
            let mut ri = 0;
            while assigned < total_gpus {
                alloc[rema[ri % k].0] += 1;
                assigned += 1;
                ri += 1;
            }
            Ok(alloc)
        }
    }
}

/// Partition `total_gpus` across deployments given their queued demand.
/// Every deployment gets at least one GPU (a model with zero GPUs serves
/// nothing — it would silently blackhole its queue); the result always sums
/// to `total_gpus`. More deployments than GPUs is a typed error, not a
/// panic or a zero-GPU partition.
pub fn partition_gpus(
    deployments: &[Deployment],
    demand: &[Vec<EpochRequest>],
    total_gpus: usize,
    s_pad: u32,
    policy: PartitionPolicy,
) -> Result<Vec<usize>, PartitionError> {
    assert_eq!(deployments.len(), demand.len());
    let loads: Vec<f64> = deployments
        .iter()
        .zip(demand.iter())
        .map(|(d, q)| {
            q.iter()
                .map(|r| d.req_weight(s_pad, r.req.output_tokens))
                .sum()
        })
        .collect();
    partition_gpus_by_load(&loads, total_gpus, policy)
}

/// The multi-LLM coordinator: routes per-deployment request queues onto GPU
/// partitions and schedules each partition independently.
pub struct MultiLlm {
    pub deployments: Vec<Deployment>,
    pub policy: PartitionPolicy,
    schedulers: Vec<Box<dyn Scheduler>>,
}

impl MultiLlm {
    /// Build with one scheduler instance per deployment (DFTSP by default
    /// via `with_dftsp`).
    pub fn new(
        deployments: Vec<Deployment>,
        policy: PartitionPolicy,
        schedulers: Vec<Box<dyn Scheduler>>,
    ) -> Self {
        assert_eq!(deployments.len(), schedulers.len());
        MultiLlm {
            deployments,
            policy,
            schedulers,
        }
    }

    pub fn with_dftsp(deployments: Vec<Deployment>, policy: PartitionPolicy) -> Self {
        let schedulers = deployments
            .iter()
            .map(|_| Box::new(crate::coordinator::Dftsp::new()) as Box<dyn Scheduler>)
            .collect();
        Self::new(deployments, policy, schedulers)
    }

    /// One epoch across every deployment. `demand[i]` are the requests
    /// routed to deployment i (the application API names the target model).
    /// Returns (per-deployment schedule, per-deployment GPU count), or the
    /// typed partition error when the cluster cannot give every deployment
    /// its guaranteed GPU.
    pub fn schedule_epoch(
        &mut self,
        cluster: &ClusterSpec,
        epoch: &EpochParams,
        s_pad: u32,
        now: f64,
        demand: &[Vec<EpochRequest>],
    ) -> Result<(Vec<Schedule>, Vec<usize>), PartitionError> {
        let gpus = partition_gpus(
            &self.deployments,
            demand,
            cluster.num_gpus,
            s_pad,
            self.policy,
        )?;
        let mut out = Vec::with_capacity(self.deployments.len());
        for ((dep, sched), (&g, reqs)) in self
            .deployments
            .iter()
            .zip(self.schedulers.iter_mut())
            .zip(gpus.iter().zip(demand.iter()))
        {
            let inst = ProblemInstance::new(
                CostModel::new(dep.model.clone()),
                dep.quant.clone(),
                ClusterSpec::new(cluster.gpu.clone(), g),
                epoch.clone(),
                s_pad,
                now,
            );
            out.push(sched.schedule(&inst, reqs));
        }
        Ok((out, gpus))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant;
    use crate::request::RequestBuilder;
    use crate::wireless::RadioParams;

    fn deployments() -> Vec<Deployment> {
        vec![
            Deployment {
                model: LlmSpec::bloom_3b(),
                quant: quant::default_quant(),
            },
            Deployment {
                model: LlmSpec::bloom_7b(),
                quant: quant::default_quant(),
            },
        ]
    }

    fn reqs(n: usize, n_out: u32) -> Vec<EpochRequest> {
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        (0..n)
            .map(|_| {
                EpochRequest::annotate(
                    b.build(0.0, 128, n_out, 2.0, 0.2),
                    (1e-3f64).sqrt(),
                    &radio,
                    0.25,
                    0.25,
                )
            })
            .collect()
    }

    #[test]
    fn partitions_sum_to_total() {
        let deps = deployments();
        let demand = vec![reqs(10, 128), reqs(3, 512)];
        for policy in [PartitionPolicy::Equal, PartitionPolicy::LoadProportional] {
            for total in [2usize, 7, 20, 21] {
                let p = partition_gpus(&deps, &demand, total, 512, policy).unwrap();
                assert_eq!(p.iter().sum::<usize>(), total, "{policy:?} total {total}");
                assert!(p.iter().all(|&g| g >= 1), "{policy:?}: everyone gets a GPU");
            }
        }
    }

    /// Regression (issue satellite): at the boundary `total_gpus ==
    /// deployments` both policies must hand out exactly one GPU each, and
    /// *below* it they must return the typed error — never a partition with
    /// a zero-GPU deployment, and never a panic.
    #[test]
    fn boundary_min_one_gpu_or_typed_error() {
        let deps = deployments();
        let demand = vec![reqs(40, 512), reqs(0, 128)];
        for policy in [PartitionPolicy::Equal, PartitionPolicy::LoadProportional] {
            // Exactly one GPU per deployment: the guarantee binds everywhere.
            let p = partition_gpus(&deps, &demand, 2, 512, policy).unwrap();
            assert_eq!(p, vec![1, 1], "{policy:?} at the boundary");
            // One GPU short: typed error carrying both sides of the deficit.
            let err = partition_gpus(&deps, &demand, 1, 512, policy).unwrap_err();
            assert_eq!(
                err,
                PartitionError::InsufficientGpus {
                    deployments: 2,
                    total_gpus: 1
                },
                "{policy:?} below the boundary"
            );
            assert!(err.to_string().contains("min-1"));
        }
        // Zero deployments is its own typed case.
        assert_eq!(
            partition_gpus_by_load(&[], 4, PartitionPolicy::Equal).unwrap_err(),
            PartitionError::NoDeployments
        );
    }

    #[test]
    fn load_weights_clamp_non_finite() {
        // NaN / negative loads must clamp to the floor weight, not poison
        // the quotas: the partition stays total-preserving and min-1.
        let p = partition_gpus_by_load(
            &[f64::NAN, 10.0, -3.0],
            9,
            PartitionPolicy::LoadProportional,
        )
        .unwrap();
        assert_eq!(p.iter().sum::<usize>(), 9);
        assert!(p.iter().all(|&g| g >= 1), "{p:?}");
        assert!(p[1] > p[0] && p[1] > p[2], "{p:?}: real load dominates");
    }

    #[test]
    fn load_proportional_favors_loaded_deployment() {
        let deps = deployments();
        // deployment 0 heavily loaded, deployment 1 nearly idle
        let demand = vec![reqs(40, 512), reqs(1, 128)];
        let p =
            partition_gpus(&deps, &demand, 20, 512, PartitionPolicy::LoadProportional).unwrap();
        assert!(p[0] > p[1], "loaded deployment gets more GPUs: {p:?}");
        let eq = partition_gpus(&deps, &demand, 20, 512, PartitionPolicy::Equal).unwrap();
        assert_eq!(eq, vec![10, 10]);
    }

    #[test]
    fn bigger_model_weighs_more() {
        let deps = deployments();
        // identical queue sizes: 7.1B requests cost more FLOPs, so the 7.1B
        // deployment should receive at least as many GPUs.
        let demand = vec![reqs(10, 256), reqs(10, 256)];
        let p =
            partition_gpus(&deps, &demand, 20, 512, PartitionPolicy::LoadProportional).unwrap();
        assert!(p[1] >= p[0], "{p:?}");
    }

    #[test]
    fn partition_policy_parses() {
        assert_eq!(PartitionPolicy::parse("equal").unwrap(), PartitionPolicy::Equal);
        assert_eq!(
            PartitionPolicy::parse("Load-Proportional").unwrap(),
            PartitionPolicy::LoadProportional
        );
        assert_eq!(
            PartitionPolicy::parse("load").unwrap(),
            PartitionPolicy::LoadProportional
        );
        assert!(PartitionPolicy::parse("fair").is_err());
        assert_eq!(PartitionPolicy::LoadProportional.to_string(), "load-proportional");
        assert_eq!(PartitionPolicy::Equal.to_string(), "equal");
    }

    #[test]
    fn schedule_epoch_runs_both_deployments() {
        let mut multi =
            MultiLlm::with_dftsp(deployments(), PartitionPolicy::LoadProportional);
        let cluster = ClusterSpec::paper_default();
        let demand = vec![reqs(8, 128), reqs(8, 128)];
        let (schedules, gpus) = multi
            .schedule_epoch(&cluster, &EpochParams::default(), 512, 0.0, &demand)
            .unwrap();
        assert_eq!(schedules.len(), 2);
        assert_eq!(gpus.iter().sum::<usize>(), 20);
        // both deployments serve something under light load
        assert!(schedules[0].batch_size() > 0);
        assert!(schedules[1].batch_size() > 0);
        // scheduled ids come from the right queue
        for (s, q) in schedules.iter().zip(demand.iter()) {
            for id in &s.scheduled {
                assert!(q.iter().any(|r| r.id() == *id));
            }
        }
    }

    #[test]
    fn proportional_beats_equal_under_skew() {
        // All the load on the 3B deployment: proportional partitioning must
        // serve at least as many requests as the equal split.
        let deps = deployments();
        let demand = vec![reqs(30, 256), reqs(0, 128)];
        let cluster = ClusterSpec::paper_default();
        let total = |policy| {
            let mut m = MultiLlm::with_dftsp(deps.clone(), policy);
            let (s, _) = m
                .schedule_epoch(&cluster, &EpochParams::default(), 512, 0.0, &demand)
                .unwrap();
            s.iter().map(|x| x.batch_size()).sum::<usize>()
        };
        assert!(total(PartitionPolicy::LoadProportional) >= total(PartitionPolicy::Equal));
    }
}
