//! Multi-LLM deployments — the paper's §II note that "while Fig. 1 focuses
//! on one LLM, our approach is adaptable for multiple LLMs", made concrete:
//! the edge node hosts several (model, quantization) deployments, the GPU
//! pool is partitioned between them, and each partition runs its own DFTSP
//! epoch schedule over the requests routed to it.

use crate::cluster::ClusterSpec;
use crate::coordinator::problem::{EpochParams, ProblemInstance};
use crate::coordinator::scheduler::{Schedule, Scheduler};
use crate::model::{CostModel, LlmSpec};
use crate::quant::QuantSpec;
use crate::request::EpochRequest;

/// One hosted (model, quantization) pair.
#[derive(Debug, Clone)]
pub struct Deployment {
    pub model: LlmSpec,
    pub quant: QuantSpec,
}

impl Deployment {
    /// Peak FLOPs one "typical" request costs on this deployment — used as
    /// the load weight for GPU partitioning.
    fn req_weight(&self, s_pad: u32, n_typ: u32) -> f64 {
        let cost = CostModel::new(self.model.clone());
        self.quant.beta * cost.total_flops_per_req(s_pad, n_typ)
    }
}

/// GPU-partitioning policy across deployments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionPolicy {
    /// Equal GPU counts (remainder to the earliest deployments).
    Equal,
    /// GPUs ∝ offered load (queued requests × per-request FLOPs).
    LoadProportional,
}

/// Partition `total_gpus` across deployments given their queued demand.
/// Every deployment with demand gets at least one GPU (a model that cannot
/// run serves nothing); the result always sums to `total_gpus`.
pub fn partition_gpus(
    deployments: &[Deployment],
    demand: &[Vec<EpochRequest>],
    total_gpus: usize,
    s_pad: u32,
    policy: PartitionPolicy,
) -> Vec<usize> {
    assert_eq!(deployments.len(), demand.len());
    let k = deployments.len();
    assert!(k > 0 && total_gpus >= k, "need at least one GPU per deployment");
    match policy {
        PartitionPolicy::Equal => {
            let base = total_gpus / k;
            let extra = total_gpus % k;
            (0..k).map(|i| base + usize::from(i < extra)).collect()
        }
        PartitionPolicy::LoadProportional => {
            let weights: Vec<f64> = deployments
                .iter()
                .zip(demand.iter())
                .map(|(d, q)| {
                    let load: f64 = q
                        .iter()
                        .map(|r| d.req_weight(s_pad, r.req.output_tokens))
                        .sum();
                    load.max(1.0) // idle deployments keep a floor weight
                })
                .collect();
            let total_w: f64 = weights.iter().sum();
            // one guaranteed GPU each, remainder largest-remainder apportioned
            let spare = total_gpus - k;
            let quotas: Vec<f64> = weights.iter().map(|w| spare as f64 * w / total_w).collect();
            let mut alloc: Vec<usize> = quotas.iter().map(|q| 1 + q.floor() as usize).collect();
            let mut assigned: usize = alloc.iter().sum();
            let mut rema: Vec<(usize, f64)> = quotas
                .iter()
                .enumerate()
                .map(|(i, q)| (i, q - q.floor()))
                .collect();
            rema.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
            let mut ri = 0;
            while assigned < total_gpus {
                alloc[rema[ri % k].0] += 1;
                assigned += 1;
                ri += 1;
            }
            alloc
        }
    }
}

/// The multi-LLM coordinator: routes per-deployment request queues onto GPU
/// partitions and schedules each partition independently.
pub struct MultiLlm {
    pub deployments: Vec<Deployment>,
    pub policy: PartitionPolicy,
    schedulers: Vec<Box<dyn Scheduler>>,
}

impl MultiLlm {
    /// Build with one scheduler instance per deployment (DFTSP by default
    /// via `with_dftsp`).
    pub fn new(
        deployments: Vec<Deployment>,
        policy: PartitionPolicy,
        schedulers: Vec<Box<dyn Scheduler>>,
    ) -> Self {
        assert_eq!(deployments.len(), schedulers.len());
        MultiLlm {
            deployments,
            policy,
            schedulers,
        }
    }

    pub fn with_dftsp(deployments: Vec<Deployment>, policy: PartitionPolicy) -> Self {
        let schedulers = deployments
            .iter()
            .map(|_| Box::new(crate::coordinator::Dftsp::new()) as Box<dyn Scheduler>)
            .collect();
        Self::new(deployments, policy, schedulers)
    }

    /// One epoch across every deployment. `demand[i]` are the requests
    /// routed to deployment i (the application API names the target model).
    /// Returns (per-deployment schedule, per-deployment GPU count).
    pub fn schedule_epoch(
        &mut self,
        cluster: &ClusterSpec,
        epoch: &EpochParams,
        s_pad: u32,
        now: f64,
        demand: &[Vec<EpochRequest>],
    ) -> (Vec<Schedule>, Vec<usize>) {
        let gpus = partition_gpus(
            &self.deployments,
            demand,
            cluster.num_gpus,
            s_pad,
            self.policy,
        );
        let mut out = Vec::with_capacity(self.deployments.len());
        for ((dep, sched), (&g, reqs)) in self
            .deployments
            .iter()
            .zip(self.schedulers.iter_mut())
            .zip(gpus.iter().zip(demand.iter()))
        {
            let inst = ProblemInstance::new(
                CostModel::new(dep.model.clone()),
                dep.quant.clone(),
                ClusterSpec::new(cluster.gpu.clone(), g),
                epoch.clone(),
                s_pad,
                now,
            );
            out.push(sched.schedule(&inst, reqs));
        }
        (out, gpus)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant;
    use crate::request::RequestBuilder;
    use crate::wireless::RadioParams;

    fn deployments() -> Vec<Deployment> {
        vec![
            Deployment {
                model: LlmSpec::bloom_3b(),
                quant: quant::default_quant(),
            },
            Deployment {
                model: LlmSpec::bloom_7b(),
                quant: quant::default_quant(),
            },
        ]
    }

    fn reqs(n: usize, n_out: u32) -> Vec<EpochRequest> {
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        (0..n)
            .map(|_| {
                EpochRequest::annotate(
                    b.build(0.0, 128, n_out, 2.0, 0.2),
                    (1e-3f64).sqrt(),
                    &radio,
                    0.25,
                    0.25,
                )
            })
            .collect()
    }

    #[test]
    fn partitions_sum_to_total() {
        let deps = deployments();
        let demand = vec![reqs(10, 128), reqs(3, 512)];
        for policy in [PartitionPolicy::Equal, PartitionPolicy::LoadProportional] {
            for total in [2usize, 7, 20, 21] {
                let p = partition_gpus(&deps, &demand, total, 512, policy);
                assert_eq!(p.iter().sum::<usize>(), total, "{policy:?} total {total}");
                assert!(p.iter().all(|&g| g >= 1), "{policy:?}: everyone gets a GPU");
            }
        }
    }

    #[test]
    fn load_proportional_favors_loaded_deployment() {
        let deps = deployments();
        // deployment 0 heavily loaded, deployment 1 nearly idle
        let demand = vec![reqs(40, 512), reqs(1, 128)];
        let p = partition_gpus(&deps, &demand, 20, 512, PartitionPolicy::LoadProportional);
        assert!(p[0] > p[1], "loaded deployment gets more GPUs: {p:?}");
        let eq = partition_gpus(&deps, &demand, 20, 512, PartitionPolicy::Equal);
        assert_eq!(eq, vec![10, 10]);
    }

    #[test]
    fn bigger_model_weighs_more() {
        let deps = deployments();
        // identical queue sizes: 7.1B requests cost more FLOPs, so the 7.1B
        // deployment should receive at least as many GPUs.
        let demand = vec![reqs(10, 256), reqs(10, 256)];
        let p = partition_gpus(&deps, &demand, 20, 512, PartitionPolicy::LoadProportional);
        assert!(p[1] >= p[0], "{p:?}");
    }

    #[test]
    fn schedule_epoch_runs_both_deployments() {
        let mut multi =
            MultiLlm::with_dftsp(deployments(), PartitionPolicy::LoadProportional);
        let cluster = ClusterSpec::paper_default();
        let demand = vec![reqs(8, 128), reqs(8, 128)];
        let (schedules, gpus) =
            multi.schedule_epoch(&cluster, &EpochParams::default(), 512, 0.0, &demand);
        assert_eq!(schedules.len(), 2);
        assert_eq!(gpus.iter().sum::<usize>(), 20);
        // both deployments serve something under light load
        assert!(schedules[0].batch_size() > 0);
        assert!(schedules[1].batch_size() > 0);
        // scheduled ids come from the right queue
        for (s, q) in schedules.iter().zip(demand.iter()) {
            for id in &s.scheduled {
                assert!(q.iter().any(|r| r.id() == *id));
            }
        }
    }

    #[test]
    fn proportional_beats_equal_under_skew() {
        // All the load on the 3B deployment: proportional partitioning must
        // serve at least as many requests as the equal split.
        let deps = deployments();
        let demand = vec![reqs(30, 256), reqs(0, 128)];
        let cluster = ClusterSpec::paper_default();
        let total = |policy| {
            let mut m = MultiLlm::with_dftsp(deps.clone(), policy);
            let (s, _) =
                m.schedule_epoch(&cluster, &EpochParams::default(), 512, 0.0, &demand);
            s.iter().map(|x| x.batch_size()).sum::<usize>()
        };
        assert!(total(PartitionPolicy::LoadProportional) >= total(PartitionPolicy::Equal));
    }
}
