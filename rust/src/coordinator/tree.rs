//! Search-tree construction shared by DFTSP and the brute-force baseline —
//! paper §III-B.
//!
//! The candidate pool F_d is partitioned by output-length level
//! (F_{N_1} ∪ ... ∪ F_{N_N}); a tree node at depth k fixes the number of
//! requests c_k taken from level k, and within a level requests are ranked
//! by uplink bandwidth demand so that "take the c_k cheapest" is the only
//! selection the search must consider (optimal under the paper's
//! geographically-concentrated-users assumption of §III-A).

use crate::coordinator::problem::ProblemInstance;
use crate::request::EpochRequest;

/// One output-length level of the candidate pool, with prefix aggregates so
/// the DFS can add a whole block `c_k` in O(1).
#[derive(Debug, Clone)]
pub struct LevelGroup<'a> {
    /// The level's output length N_k.
    pub n_out: u32,
    /// Members sorted by ρ_min^U ascending (cheapest uplink first).
    pub members: Vec<&'a EpochRequest>,
    /// prefix_rho_u[c] = Σ ρ_min^U of the first c members (len = members+1).
    pub prefix_rho_u: Vec<f64>,
    /// prefix_rho_d[c] = Σ ρ_min^D of the first c members.
    pub prefix_rho_d: Vec<f64>,
    /// prefix_min_slack[c] = min compute slack among the first c members
    /// (+∞ at c = 0).
    pub prefix_min_slack: Vec<f64>,
    /// Peak KV bytes per request at this level (identical within a level).
    pub kv_per_req: u64,
    /// Decode FLOPs per request at this level (identical within a level).
    pub decode_flops_per_req: f64,
}

impl<'a> LevelGroup<'a> {
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }
}

/// Build the per-level groups for a candidate pool. Levels are ordered by
/// ascending output length (N_1 shortest first), matching Fig. 4.
pub fn build_levels<'a>(
    inst: &ProblemInstance,
    pool: &[&'a EpochRequest],
) -> Vec<LevelGroup<'a>> {
    let mut ns: Vec<u32> = pool.iter().map(|r| r.req.output_tokens).collect();
    ns.sort_unstable();
    ns.dedup();

    ns.into_iter()
        .map(|n| {
            let mut members: Vec<&EpochRequest> = pool
                .iter()
                .copied()
                .filter(|r| r.req.output_tokens == n)
                .collect();
            // Uplink-cheapest first; id tiebreak for determinism.
            members.sort_by(|a, b| {
                a.rho_min_u
                    .total_cmp(&b.rho_min_u)
                    .then(a.id().cmp(&b.id()))
            });
            let mut prefix_rho_u = Vec::with_capacity(members.len() + 1);
            let mut prefix_rho_d = Vec::with_capacity(members.len() + 1);
            let mut prefix_min_slack = Vec::with_capacity(members.len() + 1);
            prefix_rho_u.push(0.0);
            prefix_rho_d.push(0.0);
            prefix_min_slack.push(f64::INFINITY);
            for (i, m) in members.iter().enumerate() {
                prefix_rho_u.push(prefix_rho_u[i] + m.rho_min_u);
                prefix_rho_d.push(prefix_rho_d[i] + m.rho_min_d);
                prefix_min_slack.push(prefix_min_slack[i].min(inst.compute_slack(m)));
            }
            LevelGroup {
                n_out: n,
                kv_per_req: inst.kv_bytes(n),
                decode_flops_per_req: inst.cost.decode_flops_per_req(inst.s_pad, n),
                members,
                prefix_rho_u,
                prefix_rho_d,
                prefix_min_slack,
            }
        })
        .collect()
}

/// suffix_capacity[k] = Σ_{j ≥ k} |F_{N_j}| — how many candidates remain at
/// or below depth k; the quantity the paper's pruning rule compares against
/// the outstanding demand z − Σ v.
pub fn suffix_capacity(levels: &[LevelGroup]) -> Vec<usize> {
    let mut cap = vec![0usize; levels.len() + 1];
    for k in (0..levels.len()).rev() {
        cap[k] = cap[k + 1] + levels[k].len();
    }
    cap
}

/// Locate a request inside level groups: `(depth, rank)` where `rank` is its
/// position in the level's uplink-cheapest order. DFTSP's cross-pool reuse
/// rule floors the level's count at `rank + 1` once every selection without
/// the request has been proven infeasible in the previous pool.
pub fn member_rank(levels: &[LevelGroup], req: &EpochRequest) -> Option<(usize, usize)> {
    levels.iter().enumerate().find_map(|(depth, g)| {
        if g.n_out != req.req.output_tokens {
            return None;
        }
        g.members
            .iter()
            .position(|m| m.id() == req.id())
            .map(|rank| (depth, rank))
    })
}

/// Materialize the request set selected by a count vector (first c_k members
/// of each level).
pub fn materialize<'a>(levels: &[LevelGroup<'a>], counts: &[usize]) -> Vec<&'a EpochRequest> {
    let mut out = Vec::with_capacity(counts.iter().sum());
    for (g, &c) in levels.iter().zip(counts.iter()) {
        out.extend_from_slice(&g.members[..c]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::coordinator::problem::EpochParams;
    use crate::model::{CostModel, LlmSpec};
    use crate::quant;
    use crate::request::{EpochRequest, RequestBuilder};
    use crate::wireless::RadioParams;

    fn inst() -> ProblemInstance {
        ProblemInstance::new(
            CostModel::new(LlmSpec::bloom_3b()),
            quant::default_quant(),
            ClusterSpec::paper_default(),
            EpochParams::default(),
            512,
            0.0,
        )
    }

    fn reqs() -> Vec<EpochRequest> {
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        let mut out = Vec::new();
        for (s, n) in [
            (128, 512),
            (256, 128),
            (512, 128),
            (128, 256),
            (64, 128),
            (256, 512),
        ] {
            out.push(EpochRequest::annotate(
                b.build(0.0, s, n, 2.0, 0.3),
                0.03,
                &radio,
                0.25,
                0.25,
            ));
        }
        out
    }

    #[test]
    fn levels_sorted_and_grouped() {
        let i = inst();
        let rs = reqs();
        let pool: Vec<&EpochRequest> = rs.iter().collect();
        let levels = build_levels(&i, &pool);
        assert_eq!(levels.len(), 3);
        assert_eq!(levels[0].n_out, 128);
        assert_eq!(levels[1].n_out, 256);
        assert_eq!(levels[2].n_out, 512);
        assert_eq!(levels[0].len(), 3);
        assert_eq!(levels[1].len(), 1);
        assert_eq!(levels[2].len(), 2);
        // within level 128, cheapest uplink first = smallest prompt (equal h)
        let prompts: Vec<u32> = levels[0].members.iter().map(|r| r.req.prompt_tokens).collect();
        assert_eq!(prompts, vec![64, 256, 512]);
    }

    #[test]
    fn prefix_sums_consistent() {
        let i = inst();
        let rs = reqs();
        let pool: Vec<&EpochRequest> = rs.iter().collect();
        let levels = build_levels(&i, &pool);
        for g in &levels {
            assert_eq!(g.prefix_rho_u.len(), g.len() + 1);
            for c in 1..=g.len() {
                let manual: f64 = g.members[..c].iter().map(|m| m.rho_min_u).sum();
                assert!((g.prefix_rho_u[c] - manual).abs() < 1e-15);
                assert!(g.prefix_rho_u[c] >= g.prefix_rho_u[c - 1]);
                assert!(g.prefix_min_slack[c] <= g.prefix_min_slack[c - 1]);
            }
        }
    }

    #[test]
    fn suffix_capacity_sums() {
        let i = inst();
        let rs = reqs();
        let pool: Vec<&EpochRequest> = rs.iter().collect();
        let levels = build_levels(&i, &pool);
        let cap = suffix_capacity(&levels);
        assert_eq!(cap[0], 6);
        assert_eq!(cap[1], 3);
        assert_eq!(cap[2], 2);
        assert_eq!(cap[3], 0);
    }

    #[test]
    fn member_rank_finds_every_pool_member() {
        let i = inst();
        let rs = reqs();
        let pool: Vec<&EpochRequest> = rs.iter().collect();
        let levels = build_levels(&i, &pool);
        for r in &rs {
            let (depth, rank) = member_rank(&levels, r).expect("member present");
            assert_eq!(levels[depth].members[rank].id(), r.id());
        }
        // A request outside the pool is not found.
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        let outsider = EpochRequest::annotate(
            b.build(0.0, 128, 1024, 2.0, 0.3),
            0.03,
            &radio,
            0.25,
            0.25,
        );
        assert_eq!(member_rank(&levels, &outsider), None);
    }

    #[test]
    fn materialize_takes_prefixes() {
        let i = inst();
        let rs = reqs();
        let pool: Vec<&EpochRequest> = rs.iter().collect();
        let levels = build_levels(&i, &pool);
        let sel = materialize(&levels, &[2, 0, 1]);
        assert_eq!(sel.len(), 3);
        assert_eq!(sel[0].req.prompt_tokens, 64);
        assert_eq!(sel[1].req.prompt_tokens, 256);
        assert_eq!(sel[2].req.output_tokens, 512);
    }
}
