//! Greedy-by-slack heuristic scheduler — a fast non-optimal reference point
//! between the paper's baselines and DFTSP, used in ablations: it respects
//! every constraint (unlike StB/NoB) but commits to a single insertion
//! order, so DFTSP's advantage over it isolates the value of *searching*.

use crate::coordinator::problem::{FeasibilityChecker, ProblemInstance};
use crate::coordinator::scheduler::{Schedule, Scheduler, SearchStats};
use crate::request::EpochRequest;

/// Insertion order for the greedy pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GreedyOrder {
    /// Most latency-tolerant first (DFTSP's outer ranking).
    #[default]
    SlackDescending,
    /// Shortest output first (cheapest decode).
    OutputAscending,
    /// First come, first served.
    Fcfs,
}

/// Feasibility-preserving greedy insertion.
#[derive(Debug, Clone, Default)]
pub struct Greedy {
    pub order: GreedyOrder,
}

impl Greedy {
    pub fn new(order: GreedyOrder) -> Self {
        Greedy { order }
    }
}

impl Scheduler for Greedy {
    fn name(&self) -> &'static str {
        match self.order {
            GreedyOrder::SlackDescending => "Greedy-slack",
            GreedyOrder::OutputAscending => "Greedy-output",
            GreedyOrder::Fcfs => "Greedy-fcfs",
        }
    }

    fn schedule(&mut self, inst: &ProblemInstance, candidates: &[EpochRequest]) -> Schedule {
        let mut stats = SearchStats::default();
        let mut adm = inst.admissible(candidates);
        if adm.is_empty() {
            return Schedule::empty();
        }
        match self.order {
            GreedyOrder::SlackDescending => adm.sort_by(|a, b| {
                inst.compute_slack(b)
                    .total_cmp(&inst.compute_slack(a))
                    .then(a.id().cmp(&b.id()))
            }),
            GreedyOrder::OutputAscending => adm.sort_by(|a, b| {
                a.req
                    .output_tokens
                    .cmp(&b.req.output_tokens)
                    .then(a.rho_min_u.total_cmp(&b.rho_min_u))
                    .then(a.id().cmp(&b.id()))
            }),
            GreedyOrder::Fcfs => adm.sort_by(|a, b| {
                a.req
                    .arrival
                    .total_cmp(&b.req.arrival)
                    .then(a.id().cmp(&b.id()))
            }),
        }
        let checker = FeasibilityChecker::new(inst);
        let mut chosen: Vec<&EpochRequest> = Vec::new();
        for r in adm {
            chosen.push(r);
            stats.solutions_checked += 1;
            if checker.check(&chosen).is_err() {
                chosen.pop();
            }
        }
        if chosen.is_empty() {
            return Schedule {
                stats,
                ..Schedule::empty()
            };
        }
        let t = checker.check(&chosen).expect("greedy kept a feasible set");
        Schedule::from_subset(&chosen, t, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, GpuSpec};
    use crate::coordinator::problem::EpochParams;
    use crate::coordinator::Dftsp;
    use crate::model::{CostModel, LlmSpec};
    use crate::quant;
    use crate::request::RequestBuilder;
    use crate::wireless::RadioParams;

    fn inst(gpus: usize) -> ProblemInstance {
        ProblemInstance::new(
            CostModel::new(LlmSpec::bloom_3b()),
            quant::default_quant(),
            ClusterSpec::new(GpuSpec::jetson_tx2(), gpus),
            EpochParams::default(),
            512,
            0.0,
        )
    }

    fn gen(specs: &[(u32, u32, f64)]) -> Vec<EpochRequest> {
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        specs
            .iter()
            .map(|&(s, n, tau)| {
                EpochRequest::annotate(
                    b.build(0.0, s, n, tau, 0.2),
                    (1e-3f64).sqrt(),
                    &radio,
                    0.25,
                    0.25,
                )
            })
            .collect()
    }

    #[test]
    fn greedy_schedules_are_feasible() {
        let i = inst(2);
        let reqs = gen(&[
            (128, 128, 1.2),
            (512, 512, 2.0),
            (256, 128, 1.8),
            (128, 256, 1.5),
            (512, 128, 0.9),
        ]);
        for order in [
            GreedyOrder::SlackDescending,
            GreedyOrder::OutputAscending,
            GreedyOrder::Fcfs,
        ] {
            let sched = Greedy::new(order).schedule(&i, &reqs);
            let subset: Vec<&EpochRequest> = reqs
                .iter()
                .filter(|r| sched.scheduled.contains(&r.id()))
                .collect();
            assert!(FeasibilityChecker::new(&i).check(&subset).is_ok());
        }
    }

    #[test]
    fn dftsp_at_least_greedy_every_order() {
        let i = inst(1);
        let reqs = gen(&[
            (128, 512, 1.9),
            (128, 128, 1.1),
            (256, 256, 1.6),
            (512, 128, 1.4),
            (128, 128, 1.9),
            (256, 512, 2.2),
        ]);
        let d = Dftsp::new().schedule(&i, &reqs).batch_size();
        for order in [
            GreedyOrder::SlackDescending,
            GreedyOrder::OutputAscending,
            GreedyOrder::Fcfs,
        ] {
            let g = Greedy::new(order).schedule(&i, &reqs).batch_size();
            assert!(d >= g, "{order:?}: DFTSP {d} < greedy {g}");
        }
    }

    #[test]
    fn orders_can_differ() {
        // A scenario where insertion order matters: one long-output request
        // with huge slack blocks shorter ones if inserted first.
        let i = inst(1);
        let reqs = gen(&[
            (128, 512, 30.0), // huge slack, expensive
            (128, 128, 1.5),
            (128, 128, 1.5),
            (128, 128, 1.5),
        ]);
        let slack = Greedy::new(GreedyOrder::SlackDescending)
            .schedule(&i, &reqs)
            .batch_size();
        let out = Greedy::new(GreedyOrder::OutputAscending)
            .schedule(&i, &reqs)
            .batch_size();
        assert!(out >= slack);
    }
}
