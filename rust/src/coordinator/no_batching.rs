//! NoB — no-batching baseline (paper §IV benchmark 2).
//!
//! "Each GPU accepts a request once idle." Requests run solo on a single
//! GPU at its native speed; there is no batching parallelism, so per-request
//! latency is low but aggregate throughput is bounded by the GPU count. The
//! scheduler is stateful: a long generation occupies its GPU across epochs.

use crate::cluster::GpuPool;
use crate::coordinator::problem::ProblemInstance;
use crate::coordinator::scheduler::{Schedule, Scheduler, SearchStats};
use crate::request::EpochRequest;
use crate::wireless::BandwidthLedger;

/// One-request-per-GPU scheduling.
#[derive(Debug, Clone)]
pub struct NoBatching {
    pool: Option<GpuPool>,
}

impl Default for NoBatching {
    fn default() -> Self {
        Self::new()
    }
}

impl NoBatching {
    pub fn new() -> Self {
        NoBatching { pool: None }
    }

    /// Solo run time of a request on one GPU (no padding: the lone prompt is
    /// its own maximum).
    pub fn solo_compute_time(inst: &ProblemInstance, r: &EpochRequest) -> f64 {
        let flops = inst
            .cost
            .total_flops_per_req(r.req.prompt_tokens, r.req.output_tokens);
        inst.quant.beta * flops / inst.cluster.gpu.flops
    }
}

impl Scheduler for NoBatching {
    fn name(&self) -> &'static str {
        "NoB"
    }

    fn schedule(&mut self, inst: &ProblemInstance, candidates: &[EpochRequest]) -> Schedule {
        let pool = self
            .pool
            .get_or_insert_with(|| GpuPool::new(inst.cluster.num_gpus));

        // Accuracy admission + per-GPU memory screen (the model replica plus
        // one request's KV must fit a single GPU).
        let mut adm: Vec<&EpochRequest> = candidates
            .iter()
            .filter(|r| inst.admits(r))
            .filter(|r| r.rho_min_u <= 1.0 && r.rho_min_d <= 1.0)
            .filter(|r| {
                let kv = inst
                    .cost
                    .kv_peak_bytes_per_req(r.req.prompt_tokens, r.req.output_tokens);
                inst.quant.alpha * (inst.cost.weight_bytes() + kv) as f64
                    <= inst.cluster.gpu.mem_bytes as f64
            })
            .collect();
        if adm.is_empty() {
            return Schedule::empty();
        }
        // FCFS.
        adm.sort_by(|a, b| {
            a.req
                .arrival
                .total_cmp(&b.req.arrival)
                .then(a.id().cmp(&b.id()))
        });

        let mut ledger = BandwidthLedger::new();
        let mut scheduled = Vec::new();
        let mut per_request_compute = Vec::new();
        let mut rho_u_total = 0.0;
        let mut rho_d_total = 0.0;
        let mut max_t = 0.0f64;
        for r in adm {
            let Some(gpu) = pool.idle_gpu(inst.now) else {
                break; // all GPUs busy
            };
            if !ledger.alloc(r.rho_min_u, r.rho_min_d) {
                continue; // bandwidth exhausted for this epoch
            }
            let t = Self::solo_compute_time(inst, r);
            pool.occupy(gpu, inst.now + inst.epoch.t_u + t);
            scheduled.push(r.id());
            per_request_compute.push((r.id(), t));
            rho_u_total += r.rho_min_u;
            rho_d_total += r.rho_min_d;
            max_t = max_t.max(t);
        }
        Schedule {
            scheduled,
            compute_time: max_t,
            per_request_compute,
            rho_u_total,
            rho_d_total,
            stats: SearchStats::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, GpuSpec};
    use crate::coordinator::problem::EpochParams;
    use crate::model::{CostModel, LlmSpec};
    use crate::quant;
    use crate::request::RequestBuilder;
    use crate::wireless::RadioParams;

    fn inst(gpus: usize, now: f64) -> ProblemInstance {
        ProblemInstance::new(
            CostModel::new(LlmSpec::bloom_3b()),
            quant::default_quant(),
            ClusterSpec::new(GpuSpec::jetson_tx2(), gpus),
            EpochParams::default(),
            512,
            now,
        )
    }

    fn gen_sized(n: usize, prompt: u32, out: u32) -> Vec<EpochRequest> {
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        (0..n)
            .map(|k| {
                EpochRequest::annotate(
                    b.build(k as f64 * 1e-3, prompt, out, 30.0, 0.2),
                    (1e-3f64).sqrt(),
                    &radio,
                    0.25,
                    0.25,
                )
            })
            .collect()
    }

    fn gen(n: usize) -> Vec<EpochRequest> {
        gen_sized(n, 128, 128)
    }

    #[test]
    fn capped_by_gpu_count() {
        let mut s = NoBatching::new();
        let sched = s.schedule(&inst(3, 0.0), &gen(10));
        assert_eq!(sched.batch_size(), 3);
        assert_eq!(sched.per_request_compute.len(), 3);
    }

    #[test]
    fn gpus_stay_busy_across_epochs() {
        let mut s = NoBatching::new();
        // 512-prompt/512-output solo runs take ≈3 s on one TX2 — longer than
        // the 2 s epoch.
        let first = s.schedule(&inst(2, 0.0), &gen_sized(4, 512, 512));
        assert_eq!(first.batch_size(), 2);
        // At the next epoch boundary both GPUs are still busy.
        let second = s.schedule(&inst(2, 2.0), &gen_sized(4, 512, 512));
        assert_eq!(second.batch_size(), 0);
    }

    #[test]
    fn solo_time_faster_than_batched_share() {
        // A single request alone is quicker than the same request inside a
        // 20-deep batch on aggregate hardware — the NoB latency advantage.
        let i = inst(20, 0.0);
        let reqs = gen(1);
        let solo = NoBatching::solo_compute_time(&i, &reqs[0]);
        assert!(solo > 0.0);
        let batched_per_req = i.quant.beta
            * (i.cost.prefill_flops_per_req(512) + i.cost.decode_flops_per_req(512, 128))
            / i.cluster.total_flops();
        // padded batched request costs more FLOPs than the unpadded solo run
        assert!(batched_per_req * 20.0 > solo * 0.9);
    }

    #[test]
    fn per_request_times_vary() {
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        let short = EpochRequest::annotate(
            b.build(0.0, 128, 128, 30.0, 0.2),
            (1e-3f64).sqrt(),
            &radio,
            0.25,
            0.25,
        );
        let long = EpochRequest::annotate(
            b.build(0.0, 128, 512, 30.0, 0.2),
            (1e-3f64).sqrt(),
            &radio,
            0.25,
            0.25,
        );
        let i = inst(2, 0.0);
        let mut s = NoBatching::new();
        let sched = s.schedule(&i, &[short.clone(), long.clone()]);
        assert_eq!(sched.batch_size(), 2);
        let t_short = sched
            .per_request_compute
            .iter()
            .find(|(id, _)| *id == short.id())
            .unwrap()
            .1;
        let t_long = sched
            .per_request_compute
            .iter()
            .find(|(id, _)| *id == long.id())
            .unwrap()
            .1;
        assert!(t_long > t_short);
    }
}
