//! Brute-force tree search — the paper's Table III comparison baseline:
//! identical tree construction and outer (z, d) loops as DFTSP, but **no
//! online pruning**: partial-constraint violations do not cut subtrees and
//! the remaining-capacity rule is not applied, so the search walks every
//! count vector of every subproblem until a feasible leaf appears.
//!
//! A node budget guards against the exponential node count at high arrival
//! rates (the very effect Table III quantifies); when the budget trips, the
//! searcher falls back to DFTSP's answer for the *schedule* (so simulations
//! stay comparable) while `stats.budget_exhausted` records that the node
//! count is a lower bound.

use crate::coordinator::dftsp::Dftsp;
use crate::coordinator::problem::{FeasibilityChecker, ProblemInstance};
use crate::coordinator::scheduler::{Schedule, Scheduler, SearchStats};
use crate::coordinator::tree::{build_levels, materialize, suffix_capacity, LevelGroup};
use crate::request::EpochRequest;

/// Unpruned depth-first tree search.
#[derive(Debug, Clone)]
pub struct BruteForce {
    /// Maximum tree nodes to visit across the whole scheduling call.
    pub node_budget: u64,
}

impl Default for BruteForce {
    fn default() -> Self {
        BruteForce {
            node_budget: 50_000_000,
        }
    }
}

impl BruteForce {
    pub fn with_budget(node_budget: u64) -> Self {
        BruteForce { node_budget }
    }

    fn dfs(
        &self,
        inst: &ProblemInstance,
        levels: &[LevelGroup],
        depth: usize,
        count_sum: usize,
        counts: &mut Vec<usize>,
        z: usize,
        stats: &mut SearchStats,
    ) -> Option<bool> {
        // Option<bool>: None = budget exhausted, Some(found) otherwise.
        if count_sum == z {
            stats.solutions_checked += 1;
            // The exact check walks every member: O(z) leaf-check work — the
            // Table III / §Perf comparison axis against DFTSP's O(1)
            // incremental leaf test.
            stats.leaf_check_work += z as u64;
            let subset = materialize_partial(levels, counts);
            return Some(FeasibilityChecker::new(inst).check(&subset).is_ok());
        }
        if depth == levels.len() {
            return Some(false); // dead leaf: max depth, Σ < z
        }
        let need = z - count_sum;
        let g = &levels[depth];
        let cmax = need.min(g.len());
        for c in (0..=cmax).rev() {
            stats.nodes_visited += 1;
            if stats.nodes_visited > self.node_budget {
                stats.budget_exhausted = true;
                return None;
            }
            counts.push(c);
            match self.dfs(inst, levels, depth + 1, count_sum + c, counts, z, stats) {
                None => {
                    counts.pop();
                    return None;
                }
                Some(true) => return Some(true),
                Some(false) => {}
            }
            counts.pop();
        }
        Some(false)
    }
}

/// Materialize when `counts` may be shorter than `levels` (deep leaves cut
/// the vector early once Σ = z).
fn materialize_partial<'a>(
    levels: &[LevelGroup<'a>],
    counts: &[usize],
) -> Vec<&'a EpochRequest> {
    let mut padded: Vec<usize> = counts.to_vec();
    padded.resize(levels.len(), 0);
    materialize(levels, &padded)
}

impl Scheduler for BruteForce {
    fn name(&self) -> &'static str {
        "BruteForce"
    }

    fn schedule(&mut self, inst: &ProblemInstance, candidates: &[EpochRequest]) -> Schedule {
        let mut stats = SearchStats::default();
        let mut adm = inst.admissible(candidates);
        if adm.is_empty() {
            return Schedule::empty();
        }
        adm.sort_by(|a, b| {
            inst.compute_slack(b)
                .total_cmp(&inst.compute_slack(a))
                .then(a.id().cmp(&b.id()))
        });

        for z in (1..=adm.len()).rev() {
            for d in z..=adm.len() {
                stats.subproblems += 1;
                let pool = &adm[..d];
                let levels = build_levels(inst, pool);
                // Capacity is still a *tree construction* fact (children are
                // capped at min{z', |F_k|}); the quick skip below only avoids
                // trees that cannot even contain a Σ=z path.
                let cap = suffix_capacity(&levels);
                if cap[0] < z {
                    continue;
                }
                let mut counts = Vec::with_capacity(levels.len());
                match self.dfs(inst, &levels, 0, 0, &mut counts, z, &mut stats) {
                    None => {
                        // Budget exhausted: delegate the decision to DFTSP so
                        // downstream simulation remains meaningful; keep our
                        // (lower bound) node count.
                        let mut fallback = Dftsp::new();
                        let mut sched = fallback.schedule(inst, candidates);
                        stats.nodes_visited += sched.stats.nodes_visited;
                        sched.stats = stats;
                        return sched;
                    }
                    Some(true) => {
                        let subset = materialize_partial(&levels, &counts);
                        let t = FeasibilityChecker::new(inst)
                            .check(&subset)
                            .expect("checked feasible");
                        return Schedule::from_subset(&subset, t, stats);
                    }
                    Some(false) => {}
                }
            }
        }
        Schedule {
            stats,
            ..Schedule::empty()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, GpuSpec};
    use crate::coordinator::problem::EpochParams;
    use crate::model::{CostModel, LlmSpec};
    use crate::quant;
    use crate::request::RequestBuilder;
    use crate::wireless::RadioParams;

    fn inst(gpus: usize) -> ProblemInstance {
        ProblemInstance::new(
            CostModel::new(LlmSpec::bloom_3b()),
            quant::default_quant(),
            ClusterSpec::new(GpuSpec::jetson_tx2(), gpus),
            EpochParams::default(),
            512,
            0.0,
        )
    }

    fn gen_reqs(specs: &[(u32, u32, f64, f64)]) -> Vec<crate::request::EpochRequest> {
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        specs
            .iter()
            .map(|&(s, n, tau, a)| {
                crate::request::EpochRequest::annotate(
                    b.build(0.0, s, n, tau, a),
                    (1e-3f64).sqrt(),
                    &radio,
                    0.25,
                    0.25,
                )
            })
            .collect()
    }

    #[test]
    fn same_cardinality_as_dftsp() {
        // Both are exact searches: cardinality must agree even if the chosen
        // sets differ.
        for gpus in [1, 2, 20] {
            let i = inst(gpus);
            let reqs = gen_reqs(&[
                (128, 128, 1.6, 0.2),
                (256, 128, 1.9, 0.2),
                (128, 256, 1.7, 0.2),
                (512, 512, 2.0, 0.2),
                (128, 128, 0.9, 0.2),
                (256, 256, 1.4, 0.2),
                (128, 512, 1.9, 0.2),
            ]);
            let bf = BruteForce::default().schedule(&i, &reqs);
            let df = Dftsp::new().schedule(&i, &reqs);
            assert!(!bf.stats.budget_exhausted);
            assert_eq!(bf.batch_size(), df.batch_size(), "gpus={gpus}");
        }
    }

    #[test]
    fn visits_at_least_as_many_nodes_as_dftsp() {
        let i = inst(2);
        let reqs = gen_reqs(&[
            (128, 128, 1.2, 0.2),
            (256, 128, 1.3, 0.2),
            (128, 256, 1.5, 0.2),
            (512, 512, 1.8, 0.2),
            (128, 512, 1.9, 0.2),
            (256, 256, 1.1, 0.2),
            (128, 128, 1.0, 0.2),
            (64, 256, 1.6, 0.2),
            (96, 512, 1.7, 0.2),
            (200, 128, 1.4, 0.2),
        ]);
        let bf = BruteForce::default().schedule(&i, &reqs);
        let df = Dftsp::new().schedule(&i, &reqs);
        assert!(
            bf.stats.nodes_visited >= df.stats.nodes_visited,
            "bf={} df={}",
            bf.stats.nodes_visited,
            df.stats.nodes_visited
        );
    }

    #[test]
    fn budget_guard_falls_back() {
        let i = inst(1);
        // Many requests, all infeasible at high z: brute force must grind.
        let reqs = gen_reqs(&[(512, 512, 1.1, 0.2); 24]);
        let mut bf = BruteForce::with_budget(2_000);
        let sched = bf.schedule(&i, &reqs);
        assert!(sched.stats.budget_exhausted);
        // Fallback still produces a feasible (possibly empty) schedule.
        let df = Dftsp::new().schedule(&i, &reqs);
        assert_eq!(sched.batch_size(), df.batch_size());
    }
}
