//! The paper's L3 coordination contribution: per-epoch batch scheduling of
//! LLM inference requests under joint communication/computation/memory
//! constraints (Problem P1), solved by DFTSP (Algorithm 1) and compared
//! against the paper's baselines.

pub mod brute_force;
pub mod dftsp;
pub mod greedy;
pub mod multi;
pub mod no_batching;
pub mod problem;
pub mod reformulation;
pub mod scheduler;
pub mod static_batching;
pub mod tree;

pub use brute_force::BruteForce;
pub use dftsp::Dftsp;
pub use greedy::{Greedy, GreedyOrder};
pub use multi::{
    partition_gpus, partition_gpus_by_load, Deployment, MultiLlm, PartitionError, PartitionPolicy,
};
pub use no_batching::NoBatching;
pub use problem::{EpochParams, FeasibilityChecker, PartialState, ProblemInstance, Violation};
pub use reformulation::P2Coefficients;
pub use scheduler::{Schedule, Scheduler, SchedulerConfig, SearchStats};
pub use static_batching::StaticBatching;
