//! DFTSP — optimal Depth-First Tree-Searching with online tree-Pruning
//! (paper Algorithm 1, §III), scaled for decode-step invocation.
//!
//! Outer structure: for z = |Ĩ| … 1 (largest batch first), for d = z … |Ĩ|,
//! form the pool F_d of the d most latency-tolerant admissible requests and
//! search the per-level count tree for a feasible selection of exactly z
//! requests. The first feasible solution is optimal in cardinality because z
//! decreases only after every d has failed.
//!
//! Tree search (§III-C): depth k chooses c_k = |S'_k| requests from level k
//! (the c_k with smallest uplink demand). Children are explored largest
//! count first (favoring short-output requests), depth before breadth.
//! Pruning: (a) the paper's capacity rule — skip a node when the remaining
//! levels cannot supply the outstanding demand; (b) monotone constraint
//! violation — uplink/downlink/memory/latency partial sums only grow, so a
//! violated partial proves its whole subtree infeasible.
//!
//! On top of the paper's algorithm, three search-space reductions keep the
//! scheduler on budget at 1k–4k candidates (PR 2 put it on the serving
//! critical path at decode-step granularity). All three preserve exactness —
//! the DFTSP == brute-force == exhaustive-oracle proptests are the contract:
//!
//! 1. **Incremental leaf feasibility** — a leaf (Σ v_k = z) is tested with
//!    [`PartialState::violation`], whose partial sums already hold the whole
//!    batch: O(1), no allocation, no `materialize`. Blockwise summation can
//!    drift an ulp against the checker's flat sums, so a leaf whose binding
//!    quantity sits inside [`PartialState::near_boundary`]'s band is
//!    arbitrated by the exact checker (measure-zero case); outside the band
//!    the forms cannot disagree (`debug_assert`-checked per leaf in debug
//!    builds). One exact `FeasibilityChecker::check` still validates the
//!    final accepted subset, with an exact-leaf re-search of that (z, d) as
//!    a last-resort fallback.
//! 2. **Subproblem reuse across the (z, d) loop** —
//!    *Full-pool probe*: each z level first searches the full pool F_|Ĩ|.
//!    If that fails and the latency constraint was never the lone binding
//!    violation, no smaller pool can succeed either (smaller pools only
//!    shrink the per-level cheap prefixes, which worsens the monotone
//!    bandwidth/memory constraints), so the whole z level is skipped after
//!    one search instead of |Ĩ|−z+1.
//!    *Chained floors*: going d → d+1 adds exactly one request; any
//!    F_{d+1} selection that avoids it is an F_d selection, already proven
//!    infeasible — so the search at d+1 floors the newcomer's level count
//!    at its uplink rank, never revisiting the failed subtree.
//! 3. **Combined z upper bound** — the per-constraint relaxations are
//!    scanned jointly and the latency bound pairs z·(cheapest per-request
//!    compute) against the z-th largest slack (pigeonhole) instead of the
//!    maximum slack, so fewer hopeless z levels are visited at all.
//!
//! An opt-in parallel mode (`SchedulerConfig::workers` ≥ 2, std-only
//! `std::thread::scope`) fans the d pools of one z level out across worker
//! waves; the winner is the smallest feasible d, which makes the returned
//! schedule byte-identical to the sequential search (property-tested).

use crate::coordinator::problem::{
    FeasibilityChecker, PartialState, ProblemInstance, Violation,
};
use crate::coordinator::scheduler::{Schedule, Scheduler, SchedulerConfig, SearchStats};
use crate::coordinator::tree::{
    build_levels, materialize, member_rank, suffix_capacity, LevelGroup,
};
use crate::request::EpochRequest;

/// DFTSP scheduler. Stateless between epochs.
#[derive(Debug, Clone, Default)]
pub struct Dftsp {
    /// Disable the constraint-based subtree pruning (the capacity rule stays,
    /// it is part of tree construction). Used for ablations. The monotone
    /// violation is still *evaluated* per node so the probe's latency flag —
    /// and therefore the z-skip decisions and visited subproblems — are
    /// identical with and without pruning.
    pub disable_constraint_pruning: bool,
    /// Worker threads for the parallel d-pool search; 0 or 1 = sequential.
    pub workers: usize,
}

/// Immutable per-subproblem search context threaded through the DFS.
#[derive(Clone, Copy)]
struct DfsCtx<'a, 'r> {
    inst: &'a ProblemInstance,
    levels: &'a [LevelGroup<'r>],
    suffix_cap: &'a [usize],
    z: usize,
    /// Depth whose count is floored by the cross-pool reuse rule
    /// (`usize::MAX` = no floor).
    floor_depth: usize,
    floor_count: usize,
    /// Leaf test: `false` = incremental `PartialState` (the fast path),
    /// `true` = materialize + exact checker (the boundary-disagreement
    /// fallback; also what the pre-PR implementation did at every leaf).
    exact_leaves: bool,
}

/// The cached (levels, suffix capacity) pair for each pool prefix length d.
type PoolCache<'r> = Vec<Option<(Vec<LevelGroup<'r>>, Vec<usize>)>>;

/// Level groups depend only on d (the pool is always the first d requests);
/// cache them so the (z, d) loops do not rebuild and re-sort the same pools
/// (§Perf: ~40% of schedule time at 512 candidates before caching).
fn pool<'s, 'r>(
    cache: &'s mut PoolCache<'r>,
    inst: &ProblemInstance,
    adm: &[&'r EpochRequest],
    d: usize,
) -> &'s (Vec<LevelGroup<'r>>, Vec<usize>) {
    if cache[d].is_none() {
        let levels = build_levels(inst, &adm[..d]);
        let cap = suffix_capacity(&levels);
        cache[d] = Some((levels, cap));
    }
    cache[d].as_ref().unwrap()
}

/// The reuse floor for the pool that just gained `req`: selections taking
/// fewer than rank+1 from its level exclude it and were proven infeasible
/// at the previous d.
fn reuse_floor(levels: &[LevelGroup], req: &EpochRequest) -> (usize, usize) {
    let (depth, rank) =
        member_rank(levels, req).expect("pool request missing from its own level groups");
    (depth, rank + 1)
}

impl Dftsp {
    /// Default-configured DFTSP — routes through [`SchedulerConfig::default`]
    /// so the `SCHED_WORKERS` env override (CI's worker matrix) reaches every
    /// default-constructed scheduler in the test suite. Schedules are
    /// byte-identical across worker counts; tests that freeze search-effort
    /// counters (golden fixtures) construct `with_config` explicitly.
    pub fn new() -> Self {
        Dftsp::with_config(SchedulerConfig::default())
    }

    /// Build with deployment knobs (scenario TOML / CLI / `ServerConfig`).
    pub fn with_config(cfg: SchedulerConfig) -> Self {
        Dftsp {
            workers: cfg.workers,
            ..Dftsp::default()
        }
    }

    /// Cheap sound upper bound on the achievable batch size, as one monotone
    /// scan over z. `adm` must be admission-filtered and sorted by compute
    /// slack descending (the caller's invariant). Cardinality z survives
    /// only while
    ///
    /// - the z cheapest uplink / downlink fractions fit their bands,
    /// - the z smallest KV footprints fit the aggregate budget,
    /// - z·(prefill + cheapest decode)·β/C — a lower bound on any z-batch's
    ///   compute time — fits both T_C and the z-th *largest* individual
    ///   slack (any z-subset's min slack is at most that, by pigeonhole;
    ///   combining the cardinality and latency constraints tightens the
    ///   former `max_slack / per_req` bound).
    ///
    /// Each test is monotone in z, so stopping at the first failure is
    /// sound. The scan replaces the former `(max_slack / per_req).floor()
    /// as usize`, whose NaN input saturated to 0 through `as` and silently
    /// emptied the schedule; there is no float→int cast left, and NaN terms
    /// fail no comparison — they relax the bound, never tighten it.
    fn z_upper_bound(inst: &ProblemInstance, adm: &[&EpochRequest]) -> usize {
        if adm.is_empty() {
            return 0;
        }
        debug_assert!(
            adm.windows(2)
                .all(|w| inst.compute_slack(w[0]) >= inst.compute_slack(w[1])
                    || inst.compute_slack(w[0]).is_nan()
                    || inst.compute_slack(w[1]).is_nan()),
            "z_upper_bound requires slack-descending order"
        );
        // total_cmp sorts: adversarial request inputs (NaN channel gains)
        // must degrade the bound, not panic the scheduler.
        let mut us: Vec<f64> = adm.iter().map(|r| r.rho_min_u).collect();
        let mut ds: Vec<f64> = adm.iter().map(|r| r.rho_min_d).collect();
        us.sort_by(f64::total_cmp);
        ds.sort_by(f64::total_cmp);
        let mut kvs: Vec<u64> = adm
            .iter()
            .map(|r| inst.kv_bytes(r.req.output_tokens))
            .collect();
        kvs.sort_unstable();

        let budget_per_gpu = inst.cluster.kv_budget_per_gpu(&inst.cost, &inst.quant);
        let total_budget = budget_per_gpu * inst.cluster.num_gpus as f64;
        let min_decode = adm
            .iter()
            .map(|r| inst.cost.decode_flops_per_req(inst.s_pad, r.req.output_tokens))
            .fold(f64::INFINITY, f64::min);
        let per_req =
            inst.quant.beta * (inst.cost.prefill_flops_per_req(inst.s_pad) + min_decode)
                / inst.cluster.total_flops();
        let t_c = inst.epoch.t_c();

        let (mut acc_u, mut acc_d, mut acc_kv) = (0.0f64, 0.0f64, 0.0f64);
        let mut z = 0usize;
        for k in 0..adm.len() {
            acc_u += us[k];
            acc_d += ds[k];
            acc_kv += kvs[k] as f64;
            if acc_u > 1.0 + 1e-12 || acc_d > 1.0 + 1e-12 {
                break;
            }
            if budget_per_gpu <= 0.0 || acc_kv > total_budget {
                break;
            }
            if per_req > 0.0 && per_req.is_finite() {
                let t_lb = (k + 1) as f64 * per_req;
                if t_lb > inst.compute_slack(adm[k]) || t_lb > t_c {
                    break;
                }
            }
            z = k + 1;
        }
        z
    }

    /// Depth-first search over level counts. On success `counts` holds the
    /// per-level counts of the first feasible exact-z selection (levels past
    /// the found leaf's depth implicitly contribute 0).
    ///
    /// `latency_seen` records whether any rejected node's *first* violated
    /// constraint was latency — the probe's soundness flag for skipping a z
    /// level: below a node whose first violation is uplink/downlink/memory,
    /// that same monotone violation persists, so latency-first rejections
    /// cannot hide under pruned subtrees and the flag is identical whether
    /// or not pruning is enabled.
    fn dfs(
        &self,
        ctx: &DfsCtx,
        depth: usize,
        partial: &PartialState,
        counts: &mut Vec<usize>,
        stats: &mut SearchStats,
        latency_seen: &mut bool,
    ) -> bool {
        if partial.count == ctx.z {
            // Leaf: Σ v_k = z (Algorithm 1 lines 13–16).
            stats.solutions_checked += 1;
            if ctx.exact_leaves {
                stats.leaf_check_work += ctx.z as u64;
                let subset = materialize(ctx.levels, counts);
                return FeasibilityChecker::new(ctx.inst).check(&subset).is_ok();
            }
            stats.leaf_check_work += 1;
            let v = partial.violation(ctx.inst);
            if v == Some(Violation::Latency) {
                *latency_seen = true;
            }
            if partial.near_boundary(ctx.inst) {
                // An ulp of blockwise-vs-flat association drift could flip
                // this leaf either way: arbitrate with the exact checker
                // (measure-zero case) so the (z, d) verdict — and every
                // z-skip and reuse floor chained off it — stays exact. The
                // latency flag must then come from the *exact* verdict: an
                // incrementally-accepted leaf the checker rejects on
                // latency alone must still block the z-skip.
                stats.leaf_check_work += ctx.z as u64;
                let subset = materialize(ctx.levels, counts);
                return match FeasibilityChecker::new(ctx.inst).check(&subset) {
                    Ok(_) => true,
                    Err(e) => {
                        if e == Violation::Latency {
                            *latency_seen = true;
                        }
                        false
                    }
                };
            }
            // Outside the boundary band the two forms cannot disagree.
            debug_assert_eq!(
                v.is_none(),
                FeasibilityChecker::new(ctx.inst)
                    .check(&materialize(ctx.levels, counts))
                    .is_ok(),
                "incremental leaf feasibility diverged from the exact checker"
            );
            return v.is_none();
        }
        if depth == ctx.levels.len() {
            return false; // max depth without reaching z
        }
        let need = ctx.z - partial.count;
        // Paper's pruning rule: remaining levels cannot supply the demand.
        if ctx.suffix_cap[depth] < need {
            stats.pruned_capacity += 1;
            return false;
        }
        let g = &ctx.levels[depth];
        let cmax = need.min(g.len());
        let lo = if depth == ctx.floor_depth {
            ctx.floor_count
        } else {
            0
        };
        if cmax < lo {
            stats.pruned_reuse += 1;
            return false;
        }
        // Largest index first: prefer taking many short-output requests.
        for c in (lo..=cmax).rev() {
            stats.nodes_visited += 1;
            let child = partial.add_block(
                c,
                g.prefix_rho_u[c],
                g.prefix_rho_d[c],
                g.kv_per_req,
                g.decode_flops_per_req * c as f64,
                g.prefix_min_slack[c],
            );
            // Evaluated even with pruning disabled so `latency_seen` — and
            // with it every probe skip — is ablation-invariant.
            let v = child.violation(ctx.inst);
            if v == Some(Violation::Latency) {
                *latency_seen = true;
            }
            if !self.disable_constraint_pruning && v.is_some() {
                stats.pruned_constraint += 1;
                continue;
            }
            counts.push(c);
            if self.dfs(ctx, depth + 1, &child, counts, stats, latency_seen) {
                return true;
            }
            counts.pop();
        }
        false
    }

    /// Materialize a found count vector and run the one exact feasibility
    /// check of the fast path. `None` only on an ulp-level disagreement
    /// between the incremental and exact forms (the caller then re-searches
    /// with exact leaves).
    fn accept_counts(
        &self,
        inst: &ProblemInstance,
        levels: &[LevelGroup],
        counts: &[usize],
        stats: &mut SearchStats,
    ) -> Option<Schedule> {
        let subset = materialize(levels, counts);
        match FeasibilityChecker::new(inst).check(&subset) {
            Ok(t) => Some(Schedule::from_subset(&subset, t, std::mem::take(stats))),
            Err(_) => None,
        }
    }

    /// Exact-leaf fallback for one (z, d) subproblem, keeping the verdict —
    /// and the reuse floors chained off it — exact when the incremental leaf
    /// test disagreed with the checker on a constraint-boundary leaf.
    fn exact_rerun(
        &self,
        inst: &ProblemInstance,
        levels: &[LevelGroup],
        suffix_cap: &[usize],
        z: usize,
        floor: (usize, usize),
        stats: &mut SearchStats,
    ) -> Option<Schedule> {
        let ctx = DfsCtx {
            inst,
            levels,
            suffix_cap,
            z,
            floor_depth: floor.0,
            floor_count: floor.1,
            exact_leaves: true,
        };
        let mut counts = Vec::with_capacity(levels.len());
        let mut latency_seen = false;
        if self.dfs(&ctx, 0, &PartialState::empty(), &mut counts, stats, &mut latency_seen) {
            return self.accept_counts(inst, levels, &counts, stats);
        }
        None
    }

    /// Search one z level: probe the full pool, skip the level when the
    /// probe proves it hopeless, otherwise walk the d pools (sequentially
    /// with chained reuse floors, or in parallel waves).
    fn search_z<'r>(
        &self,
        inst: &ProblemInstance,
        adm: &[&'r EpochRequest],
        z: usize,
        cache: &mut PoolCache<'r>,
        stats: &mut SearchStats,
    ) -> Option<Schedule> {
        let n = adm.len();
        let mut latency_seen = false;

        // Full-pool probe: one search of F_n decides the whole level when it
        // fails on monotone-in-pool-growth constraints alone.
        stats.subproblems += 1;
        let (probe_found, probe_counts) = {
            let (levels, cap) = pool(cache, inst, adm, n);
            let ctx = DfsCtx {
                inst,
                levels,
                suffix_cap: cap,
                z,
                floor_depth: usize::MAX,
                floor_count: 0,
                exact_leaves: false,
            };
            let mut counts = Vec::with_capacity(levels.len());
            let found = self.dfs(
                &ctx,
                0,
                &PartialState::empty(),
                &mut counts,
                stats,
                &mut latency_seen,
            );
            (found, counts)
        };
        if !probe_found && !latency_seen {
            stats.z_levels_skipped += 1;
            return None;
        }
        // Probe failed on a latency-involved path (smaller pools keep more
        // slack — must try them), or succeeded (smallest feasible d still to
        // be found). Either way the full pool needs no second search: the d
        // loops stop at n − 1 and a successful probe's solution is reused
        // below.
        let found = if self.workers >= 2 {
            self.d_loop_parallel(inst, adm, z, n - 1, cache, stats)
        } else {
            self.d_loop_sequential(inst, adm, z, n - 1, cache, stats, &mut latency_seen)
        };
        if found.is_some() {
            return found;
        }
        if probe_found {
            // Every pool below n failed, so each feasible F_n selection
            // includes the pool's newest request — the probe's first-found
            // leaf is exactly what the floored d = n search would return.
            let (levels, cap) = cache[n].as_ref().unwrap();
            if let Some(s) = self.accept_counts(inst, levels, &probe_counts, stats) {
                return Some(s);
            }
            let floor = if n > z {
                reuse_floor(levels, adm[n - 1])
            } else {
                (usize::MAX, 0)
            };
            return self.exact_rerun(inst, levels, cap, z, floor, stats);
        }
        None
    }

    /// Ascending-d scan with chained reuse floors: pool d > z only searches
    /// selections that include its newest request (everything else failed at
    /// d − 1).
    fn d_loop_sequential<'r>(
        &self,
        inst: &ProblemInstance,
        adm: &[&'r EpochRequest],
        z: usize,
        d_max: usize,
        cache: &mut PoolCache<'r>,
        stats: &mut SearchStats,
        latency_seen: &mut bool,
    ) -> Option<Schedule> {
        for d in z..=d_max {
            stats.subproblems += 1;
            let (levels, cap) = pool(cache, inst, adm, d);
            let floor = if d > z {
                reuse_floor(levels, adm[d - 1])
            } else {
                (usize::MAX, 0)
            };
            let ctx = DfsCtx {
                inst,
                levels,
                suffix_cap: cap,
                z,
                floor_depth: floor.0,
                floor_count: floor.1,
                exact_leaves: false,
            };
            let mut counts = Vec::with_capacity(levels.len());
            if self.dfs(&ctx, 0, &PartialState::empty(), &mut counts, stats, latency_seen) {
                if let Some(s) = self.accept_counts(inst, levels, &counts, stats) {
                    return Some(s);
                }
                if let Some(s) = self.exact_rerun(inst, levels, cap, z, floor, stats) {
                    return Some(s);
                }
                // Exact verdict: infeasible after all — keep chaining.
            }
        }
        None
    }

    /// Parallel d-pool search: waves of `workers` consecutive pools, each
    /// searched unrestricted on its own thread; the deterministic winner is
    /// the smallest feasible d. At that d every feasible leaf includes the
    /// pool's newest request (all smaller pools failed), so the first leaf
    /// the unrestricted DFS finds is exactly the one the floored sequential
    /// search returns — schedules are byte-identical across modes
    /// (`tests/proptest_coordinator.rs`). Per-worker `SearchStats` merge in
    /// ascending d order, so parallel runs are deterministic too (their
    /// effort counters legitimately exceed the sequential ones: a wave may
    /// search pools past the winner).
    fn d_loop_parallel<'r>(
        &self,
        inst: &ProblemInstance,
        adm: &[&'r EpochRequest],
        z: usize,
        d_max: usize,
        cache: &mut PoolCache<'r>,
        stats: &mut SearchStats,
    ) -> Option<Schedule> {
        let mut d_lo = z;
        while d_lo <= d_max {
            let d_hi = d_max.min(d_lo + self.workers - 1);
            let results: Vec<(bool, Vec<usize>, SearchStats)> = std::thread::scope(|scope| {
                let handles: Vec<_> = (d_lo..=d_hi)
                    .map(|d| {
                        let pool_slice = &adm[..d];
                        scope.spawn(move || {
                            let levels = build_levels(inst, pool_slice);
                            let cap = suffix_capacity(&levels);
                            let ctx = DfsCtx {
                                inst,
                                levels: &levels,
                                suffix_cap: &cap,
                                z,
                                floor_depth: usize::MAX,
                                floor_count: 0,
                                exact_leaves: false,
                            };
                            let mut st = SearchStats {
                                subproblems: 1,
                                ..SearchStats::default()
                            };
                            let mut counts = Vec::with_capacity(levels.len());
                            let mut latency_seen = false;
                            let found = self.dfs(
                                &ctx,
                                0,
                                &PartialState::empty(),
                                &mut counts,
                                &mut st,
                                &mut latency_seen,
                            );
                            (found, counts, st)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("DFTSP search worker panicked"))
                    .collect()
            });

            let mut winner: Option<(usize, Vec<usize>)> = None;
            for (i, (found, counts, st)) in results.into_iter().enumerate() {
                stats.merge(&st);
                if found && winner.is_none() {
                    winner = Some((d_lo + i, counts));
                }
            }
            if let Some((d, counts)) = winner {
                let (levels, cap) = pool(cache, inst, adm, d);
                if let Some(s) = self.accept_counts(inst, levels, &counts, stats) {
                    return Some(s);
                }
                if let Some(s) =
                    self.exact_rerun(inst, levels, cap, z, (usize::MAX, 0), stats)
                {
                    return Some(s);
                }
                // Exact verdict overruled the boundary leaf: resume past d.
                d_lo = d + 1;
                continue;
            }
            d_lo = d_hi + 1;
        }
        None
    }
}

impl Scheduler for Dftsp {
    fn name(&self) -> &'static str {
        "DFTSP"
    }

    fn schedule(&mut self, inst: &ProblemInstance, candidates: &[EpochRequest]) -> Schedule {
        let mut stats = SearchStats::default();
        // Admission filter Ĩ (constraint 1e + individually-infeasible screens).
        let mut adm = inst.admissible(candidates);
        if adm.is_empty() {
            return Schedule::empty();
        }
        // Rank by latency tolerance (descending compute slack), id tiebreak.
        adm.sort_by(|a, b| {
            inst.compute_slack(b)
                .total_cmp(&inst.compute_slack(a))
                .then(a.id().cmp(&b.id()))
        });

        let z_ub = Self::z_upper_bound(inst, &adm);
        let mut cache: PoolCache<'_> = vec![None; adm.len() + 1];
        for z in (1..=z_ub).rev() {
            if let Some(schedule) = self.search_z(inst, &adm, z, &mut cache, &mut stats) {
                return schedule;
            }
        }
        Schedule {
            stats,
            ..Schedule::empty()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, GpuSpec};
    use crate::coordinator::problem::EpochParams;
    use crate::model::{CostModel, LlmSpec};
    use crate::quant;
    use crate::request::{EpochRequest, RequestBuilder};
    use crate::wireless::RadioParams;

    fn inst_with(cluster: ClusterSpec, quant: quant::QuantSpec) -> ProblemInstance {
        ProblemInstance::new(
            CostModel::new(LlmSpec::bloom_3b()),
            quant,
            cluster,
            EpochParams::default(),
            512,
            0.0,
        )
    }

    fn inst() -> ProblemInstance {
        inst_with(ClusterSpec::paper_default(), quant::default_quant())
    }

    /// Uniform h (paper's concentration assumption) request generator.
    fn gen_reqs(specs: &[(u32, u32, f64, f64)]) -> Vec<EpochRequest> {
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        specs
            .iter()
            .map(|&(s, n, tau, a)| {
                EpochRequest::annotate(
                    b.build(0.0, s, n, tau, a),
                    (1e-3f64).sqrt(),
                    &radio,
                    0.25,
                    0.25,
                )
            })
            .collect()
    }

    /// Exhaustive subset optimum for small instances (reference oracle).
    fn exhaustive_opt(inst: &ProblemInstance, reqs: &[EpochRequest]) -> usize {
        let n = reqs.len();
        assert!(n <= 20);
        let checker = FeasibilityChecker::new(inst);
        let mut best = 0;
        for mask in 0u32..(1 << n) {
            let subset: Vec<&EpochRequest> = (0..n)
                .filter(|i| mask & (1 << i) != 0)
                .map(|i| &reqs[i])
                .collect();
            if subset.len() > best && checker.check(&subset).is_ok() {
                best = subset.len();
            }
        }
        best
    }

    #[test]
    fn schedules_everything_when_unconstrained() {
        let i = inst();
        let reqs = gen_reqs(&[(128, 128, 2.0, 0.5); 8]);
        let mut s = Dftsp::new();
        let sched = s.schedule(&i, &reqs);
        assert_eq!(sched.batch_size(), 8);
        assert!(sched.compute_time > 0.0);
    }

    #[test]
    fn empty_candidates_empty_schedule() {
        let mut s = Dftsp::new();
        assert_eq!(s.schedule(&inst(), &[]).batch_size(), 0);
    }

    #[test]
    fn drops_inadmissible_requests() {
        let i = inst_with(
            ClusterSpec::paper_default(),
            quant::by_label(quant::Precision::W4A16, quant::QuantAlgo::ZqLocal).unwrap(),
        );
        // BLOOM-3B + W4A16/ZQ-Local: f = 0.08.
        let reqs = gen_reqs(&[
            (128, 128, 2.0, 0.05), // admissible
            (128, 128, 2.0, 0.50), // not
            (128, 128, 2.0, 0.02), // admissible
        ]);
        let sched = Dftsp::new().schedule(&i, &reqs);
        assert_eq!(sched.batch_size(), 2);
        assert!(!sched.scheduled.contains(&reqs[1].id()));
    }

    #[test]
    fn respects_latency_under_compute_pressure() {
        // Two weak GPUs: a 512-padded prefill costs ≈0.75 s of the ≈1.3 s
        // compute slack, so only one request fits the deadline.
        let i = inst_with(
            ClusterSpec::new(
                GpuSpec {
                    name: "two-tx2".into(),
                    flops: 1.33e12,
                    mem_bytes: 32 * (1 << 30),
                },
                2,
            ),
            quant::default_quant(),
        );
        let reqs = gen_reqs(&[(128, 128, 1.8, 0.2); 10]);
        let sched = Dftsp::new().schedule(&i, &reqs);
        assert!(sched.batch_size() < 10, "compute-bound must reject some");
        assert!(sched.batch_size() >= 1);
        // Returned schedule is feasible.
        let sel: Vec<&EpochRequest> = reqs
            .iter()
            .filter(|r| sched.scheduled.contains(&r.id()))
            .collect();
        assert!(FeasibilityChecker::new(&i).check(&sel).is_ok());
    }

    #[test]
    fn matches_exhaustive_optimum_small() {
        // Mixed levels + tight compute; uniform h per the paper's P2
        // assumption, under which DFTSP is optimal.
        let i = inst_with(
            ClusterSpec::new(
                GpuSpec {
                    name: "duo".into(),
                    flops: 1.33e12,
                    mem_bytes: 32 * (1 << 30),
                },
                2,
            ),
            quant::default_quant(),
        );
        let reqs = gen_reqs(&[
            (128, 128, 1.6, 0.2),
            (256, 128, 1.9, 0.2),
            (128, 256, 1.7, 0.2),
            (512, 512, 2.0, 0.2),
            (128, 128, 0.9, 0.2),
            (256, 256, 1.4, 0.2),
            (128, 512, 1.9, 0.2),
            (64, 128, 1.2, 0.2),
        ]);
        let opt = exhaustive_opt(&i, &reqs);
        let got = Dftsp::new().schedule(&i, &reqs).batch_size();
        assert_eq!(got, opt, "DFTSP must match the exhaustive optimum");
        assert!(opt >= 1);
    }

    #[test]
    fn matches_exhaustive_optimum_bandwidth_bound() {
        // Terrible channels: uplink is the binding constraint.
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        let h = 5e-8; // rho_min_u for 512 tokens ≈ 0.36
        let reqs: Vec<EpochRequest> = [
            (512u32, 128u32),
            (512, 128),
            (512, 256),
            (256, 128),
            (512, 512),
            (384, 128),
        ]
        .iter()
        .map(|&(s, n)| {
            EpochRequest::annotate(b.build(0.0, s, n, 30.0, 0.1), h, &radio, 0.25, 0.25)
        })
        .collect();
        let mut i = inst();
        i.epoch.duration = 40.0; // plenty of compute slot; bandwidth binds
        let opt = exhaustive_opt(&i, &reqs);
        let got = Dftsp::new().schedule(&i, &reqs).batch_size();
        assert_eq!(got, opt);
        assert!(opt < reqs.len(), "bandwidth must actually bind");
    }

    #[test]
    fn prefers_short_outputs_under_memory_pressure() {
        let i = inst_with(
            ClusterSpec::new(
                GpuSpec {
                    name: "small-mem".into(),
                    flops: 1.33e13,
                    mem_bytes: 4 * (1 << 30),
                },
                1,
            ),
            quant::default_quant(),
        );
        let reqs = gen_reqs(&[
            (128, 512, 8.0, 0.2),
            (128, 512, 8.0, 0.2),
            (128, 128, 8.0, 0.2),
            (128, 128, 8.0, 0.2),
            (128, 128, 8.0, 0.2),
        ]);
        let mut i2 = i;
        i2.epoch.duration = 10.0;
        let sched = Dftsp::new().schedule(&i2, &reqs);
        // With KV budget tight, scheduling the three short requests beats two
        // long ones; DFTSP must find a max-cardinality set.
        let opt = exhaustive_opt(&i2, &reqs);
        assert_eq!(sched.batch_size(), opt);
    }

    #[test]
    fn stats_populated() {
        let i = inst();
        let reqs = gen_reqs(&[(128, 128, 2.0, 0.5); 6]);
        let sched = Dftsp::new().schedule(&i, &reqs);
        assert!(sched.stats.nodes_visited > 0);
        assert!(sched.stats.subproblems >= 1);
        assert!(sched.stats.solutions_checked >= 1);
        assert!(sched.stats.leaf_check_work >= 1);
    }

    #[test]
    fn adversarial_nan_inputs_do_not_panic() {
        // NaN channel gains / deadlines produce NaN ρ_min and slack; the
        // admission screens drop them and the total_cmp sorts tolerate any
        // survivors — scheduling must never panic.
        let i = inst();
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        let good_h = (1e-3f64).sqrt();
        let reqs = vec![
            EpochRequest::annotate(b.build(0.0, 128, 128, 2.0, 0.2), good_h, &radio, 0.25, 0.25),
            EpochRequest::annotate(b.build(0.0, 256, 128, 1.8, 0.2), good_h, &radio, 0.25, 0.25),
            EpochRequest::annotate(b.build(0.0, 128, 128, 2.0, 0.2), f64::NAN, &radio, 0.25, 0.25),
            EpochRequest::annotate(
                b.build(0.0, 128, 128, f64::NAN, 0.2),
                good_h,
                &radio,
                0.25,
                0.25,
            ),
        ];
        let sched = Dftsp::new().schedule(&i, &reqs);
        assert_eq!(sched.batch_size(), 2, "only the two sane requests run");
        assert!(!sched.scheduled.contains(&reqs[2].id()));
        assert!(!sched.scheduled.contains(&reqs[3].id()));
    }

    #[test]
    fn deterministic() {
        let i = inst();
        let reqs = gen_reqs(&[
            (128, 128, 1.6, 0.2),
            (256, 256, 1.2, 0.2),
            (512, 512, 1.9, 0.2),
            (128, 256, 1.4, 0.2),
        ]);
        let a = Dftsp::new().schedule(&i, &reqs);
        let b = Dftsp::new().schedule(&i, &reqs);
        assert_eq!(a.scheduled, b.scheduled);
        assert_eq!(a.stats, b.stats);
    }

    #[test]
    fn parallel_matches_sequential_schedule() {
        // The parallel d-pool search must pick the same batch as the chained
        // sequential scan (deterministic winner = smallest feasible d).
        let i = inst_with(
            ClusterSpec::new(
                GpuSpec {
                    name: "duo".into(),
                    flops: 1.33e12,
                    mem_bytes: 32 * (1 << 30),
                },
                2,
            ),
            quant::default_quant(),
        );
        let reqs = gen_reqs(&[
            (128, 128, 1.6, 0.2),
            (256, 128, 1.9, 0.2),
            (128, 256, 1.7, 0.2),
            (512, 512, 2.0, 0.2),
            (128, 128, 0.9, 0.2),
            (256, 256, 1.4, 0.2),
            (128, 512, 1.9, 0.2),
            (64, 128, 1.2, 0.2),
            (96, 256, 1.5, 0.2),
            (200, 128, 1.3, 0.2),
        ]);
        let seq = Dftsp::new().schedule(&i, &reqs);
        let par = Dftsp::with_config(SchedulerConfig { workers: 3 }).schedule(&i, &reqs);
        assert_eq!(seq.scheduled, par.scheduled);
        assert_eq!(seq.compute_time, par.compute_time);
        assert_eq!(seq.per_request_compute, par.per_request_compute);
        // Parallel runs are themselves deterministic, stats included.
        let par2 = Dftsp::with_config(SchedulerConfig { workers: 3 }).schedule(&i, &reqs);
        assert_eq!(par.scheduled, par2.scheduled);
        assert_eq!(par.stats, par2.stats);
    }

    #[test]
    fn z_upper_bound_adversarial_inputs() {
        // Regression for the former `(max_slack / per_req).floor() as usize`
        // cast: huge/NaN inputs must neither panic nor zero the bound.
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        let good_h = (1e-3f64).sqrt();
        let mk = |b: &mut RequestBuilder, tau: f64| {
            EpochRequest::annotate(b.build(0.0, 128, 128, tau, 0.2), good_h, &radio, 0.25, 0.25)
        };

        // Huge slack (τ = 1e300): the old code divided it by per_req and
        // cast; the scan must simply cap at the pool size.
        let i = inst();
        let huge: Vec<EpochRequest> = (0..4).map(|_| mk(&mut b, 1e300)).collect();
        let refs: Vec<&EpochRequest> = huge.iter().collect();
        let zb = Dftsp::z_upper_bound(&i, &refs);
        assert!(zb <= refs.len());
        assert!(zb >= 1, "huge slack must not zero the bound");

        // β = NaN poisons per_req: the latency dimension must drop out
        // (sound relaxation), not propagate NaN through a cast to 0.
        let mut i_nan = inst();
        i_nan.quant.beta = f64::NAN;
        let zb = Dftsp::z_upper_bound(&i_nan, &refs);
        assert_eq!(zb, refs.len(), "NaN per_req relaxes the latency bound");

        // β = 0 keeps the old `per_req <= 0` escape hatch.
        let mut i_zero = inst();
        i_zero.quant.beta = 0.0;
        assert_eq!(Dftsp::z_upper_bound(&i_zero, &refs), refs.len());

        // End-to-end: scheduling the adversarial pool must not panic and
        // must still return a feasible batch.
        let sched = Dftsp::new().schedule(&i, &huge);
        assert!(sched.batch_size() >= 1);
    }

    #[test]
    fn z_upper_bound_combined_latency_tighter_than_max_slack() {
        // One very tolerant request plus nine tight ones on the paper
        // cluster: per-request compute ≈ 0.094 s, tight slack = 0.9 s, so 10
        // requests need ≈ 0.94 s > 0.9 s while 9 need ≈ 0.84 s. The old
        // bound (max slack, capped at T_C = 2 s, over per_req) allowed all
        // 10; the pigeonhole bound (z-th largest slack) must stop at 9 —
        // exactly the optimum.
        let i = inst();
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        let good_h = (1e-3f64).sqrt();
        let mut reqs = vec![EpochRequest::annotate(
            b.build(0.0, 128, 128, 1e6, 0.2),
            good_h,
            &radio,
            0.25,
            0.25,
        )];
        for _ in 0..9 {
            reqs.push(EpochRequest::annotate(
                b.build(0.0, 128, 128, 1.4, 0.2),
                good_h,
                &radio,
                0.25,
                0.25,
            ));
        }
        let mut adm: Vec<&EpochRequest> = reqs.iter().collect();
        adm.sort_by(|a, b| {
            i.compute_slack(b)
                .total_cmp(&i.compute_slack(a))
                .then(a.id().cmp(&b.id()))
        });
        let zb = Dftsp::z_upper_bound(&i, &adm);
        assert_eq!(zb, 9, "combined bound strictly tighter than max-slack's 10");
        // And it stays sound: the true optimum is reached, not cut off.
        let opt = exhaustive_opt(&i, &reqs);
        assert_eq!(opt, 9);
        assert_eq!(Dftsp::new().schedule(&i, &reqs).batch_size(), opt);
    }

    #[test]
    fn probe_skips_hopeless_z_levels() {
        // The z upper bound relaxes memory to the *aggregate* budget, which
        // admits z = 4 here; but the worst-GPU packing bound (total/G + max)
        // caps any actual selection at 2. That gap is exactly what the
        // full-pool probe closes: z = 4 and z = 3 fail on memory everywhere
        // (never latency), so each z level costs one probed subproblem
        // instead of a full d scan. Budget per GPU = 2.2 KV footprints:
        // packing needs z/2 + 1 ≤ 2.2 ⇒ z ≤ 2; aggregate allows 4.4 ⇒ 4.
        let cost = CostModel::new(LlmSpec::bloom_3b());
        let kv = cost.kv_peak_bytes_per_req(512, 512);
        let w = cost.weight_bytes();
        let mem = (0.55 * (2.2 * kv as f64 + w as f64)) as u64 + 1;
        let mut i = inst_with(
            ClusterSpec::new(
                GpuSpec {
                    name: "packing-gap".into(),
                    flops: 1.33e12,
                    mem_bytes: mem,
                },
                2,
            ),
            quant::default_quant(),
        );
        i.epoch.duration = 60.0; // latency never binds
        let reqs = gen_reqs(&[(128, 512, 50.0, 0.2); 4]);
        let sched = Dftsp::new().schedule(&i, &reqs);
        assert_eq!(sched.batch_size(), 2);
        assert_eq!(
            sched.stats.z_levels_skipped, 2,
            "z = 4 and z = 3 must be probe-skipped"
        );
        assert_eq!(sched.batch_size(), exhaustive_opt(&i, &reqs));
    }
}
