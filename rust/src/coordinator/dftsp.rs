//! DFTSP — optimal Depth-First Tree-Searching with online tree-Pruning
//! (paper Algorithm 1, §III).
//!
//! Outer structure: for z = |Ĩ| … 1 (largest batch first), for d = z … |Ĩ|,
//! form the pool F_d of the d most latency-tolerant admissible requests and
//! search the per-level count tree for a feasible selection of exactly z
//! requests. The first feasible solution is optimal in cardinality because z
//! decreases only after every d has failed.
//!
//! Tree search (§III-C): depth k chooses c_k = |S'_k| requests from level k
//! (the c_k with smallest uplink demand). Children are explored largest
//! count first (favoring short-output requests), depth before breadth.
//! Pruning: (a) the paper's capacity rule — skip a node when the remaining
//! levels cannot supply the outstanding demand; (b) monotone constraint
//! violation — uplink/downlink/memory/latency partial sums only grow, so a
//! violated partial proves its whole subtree infeasible.

use crate::coordinator::problem::{FeasibilityChecker, PartialState, ProblemInstance};
use crate::coordinator::scheduler::{Schedule, Scheduler, SearchStats};
use crate::coordinator::tree::{build_levels, materialize, suffix_capacity, LevelGroup};
use crate::request::EpochRequest;

/// DFTSP scheduler. Stateless between epochs.
#[derive(Debug, Clone, Default)]
pub struct Dftsp {
    /// Disable the constraint-based subtree pruning (the capacity rule stays,
    /// it is part of tree construction). Used for ablations.
    pub disable_constraint_pruning: bool,
}

impl Dftsp {
    pub fn new() -> Self {
        Dftsp::default()
    }

    /// Cheap sound upper bound on the achievable batch size: each constraint
    /// is relaxed independently (take the globally cheapest requests per
    /// dimension); the true optimum cannot exceed the minimum over
    /// dimensions. Skipping z above this bound preserves optimality.
    fn z_upper_bound(inst: &ProblemInstance, adm: &[&EpochRequest]) -> usize {
        if adm.is_empty() {
            return 0;
        }
        // Uplink / downlink: prefix of the cheapest fractions. total_cmp:
        // adversarial request inputs (NaN channel gains) must degrade the
        // bound, not panic the scheduler.
        let bound_by = |vals: &mut Vec<f64>, cap: f64| -> usize {
            vals.sort_by(f64::total_cmp);
            let mut acc = 0.0;
            let mut z = 0;
            for v in vals.iter() {
                acc += v;
                if acc > cap + 1e-12 {
                    break;
                }
                z += 1;
            }
            z
        };
        let mut us: Vec<f64> = adm.iter().map(|r| r.rho_min_u).collect();
        let mut ds: Vec<f64> = adm.iter().map(|r| r.rho_min_d).collect();
        let z_u = bound_by(&mut us, 1.0);
        let z_d = bound_by(&mut ds, 1.0);

        // Memory: cheapest-KV prefix against the aggregate budget.
        let budget_per_gpu = inst.cluster.kv_budget_per_gpu(&inst.cost, &inst.quant);
        let z_m = if budget_per_gpu <= 0.0 {
            0
        } else {
            let mut kvs: Vec<u64> = adm
                .iter()
                .map(|r| inst.kv_bytes(r.req.output_tokens))
                .collect();
            kvs.sort_unstable();
            let total_budget = budget_per_gpu * inst.cluster.num_gpus as f64;
            let mut acc = 0.0;
            let mut z = 0;
            for kv in kvs {
                acc += kv as f64;
                if acc > total_budget {
                    break;
                }
                z += 1;
            }
            z
        };

        // Latency: z requests cost at least z·(prefill + cheapest decode);
        // the most slack any batch can have is the max individual slack.
        let max_slack = adm
            .iter()
            .map(|r| inst.compute_slack(r))
            .fold(0.0f64, f64::max)
            .min(inst.epoch.t_c());
        let min_decode = adm
            .iter()
            .map(|r| inst.cost.decode_flops_per_req(inst.s_pad, r.req.output_tokens))
            .fold(f64::INFINITY, f64::min);
        let per_req =
            inst.quant.beta * (inst.cost.prefill_flops_per_req(inst.s_pad) + min_decode)
                / inst.cluster.total_flops();
        let z_t = if per_req <= 0.0 {
            adm.len()
        } else {
            (max_slack / per_req).floor() as usize
        };

        z_u.min(z_d).min(z_m).min(z_t).min(adm.len())
    }

    /// Depth-first search over level counts. Returns the per-level counts of
    /// the first feasible exact-z selection.
    #[allow(clippy::too_many_arguments)]
    fn dfs(
        &self,
        inst: &ProblemInstance,
        levels: &[LevelGroup],
        suffix_cap: &[usize],
        depth: usize,
        partial: &PartialState,
        counts: &mut Vec<usize>,
        z: usize,
        stats: &mut SearchStats,
    ) -> bool {
        if partial.count == z {
            // Leaf: Σ v_k = z — recover S' and run the exact check
            // (Algorithm 1 lines 13–16).
            stats.solutions_checked += 1;
            let subset = materialize(levels, counts);
            return FeasibilityChecker::new(inst).check(&subset).is_ok();
        }
        if depth == levels.len() {
            return false; // max depth without reaching z
        }
        let need = z - partial.count;
        // Paper's pruning rule: remaining levels cannot supply the demand.
        if suffix_cap[depth] < need {
            stats.pruned_capacity += 1;
            return false;
        }
        let g = &levels[depth];
        let cmax = need.min(g.len());
        // Largest index first: prefer taking many short-output requests.
        for c in (0..=cmax).rev() {
            stats.nodes_visited += 1;
            let child = partial.add_block(
                c,
                g.prefix_rho_u[c],
                g.prefix_rho_d[c],
                g.kv_per_req,
                g.decode_flops_per_req * c as f64,
                g.prefix_min_slack[c],
            );
            if !self.disable_constraint_pruning && !child.feasible(inst) {
                stats.pruned_constraint += 1;
                continue;
            }
            counts.push(c);
            if self.dfs(inst, levels, suffix_cap, depth + 1, &child, counts, z, stats) {
                return true;
            }
            counts.pop();
        }
        false
    }
}

impl Scheduler for Dftsp {
    fn name(&self) -> &'static str {
        "DFTSP"
    }

    fn schedule(&mut self, inst: &ProblemInstance, candidates: &[EpochRequest]) -> Schedule {
        let mut stats = SearchStats::default();
        // Admission filter Ĩ (constraint 1e + individually-infeasible screens).
        let mut adm = inst.admissible(candidates);
        if adm.is_empty() {
            return Schedule::empty();
        }
        // Rank by latency tolerance (descending compute slack), id tiebreak.
        adm.sort_by(|a, b| {
            inst.compute_slack(b)
                .total_cmp(&inst.compute_slack(a))
                .then(a.id().cmp(&b.id()))
        });

        let z_ub = Self::z_upper_bound(inst, &adm);
        // Level groups depend only on d (the pool is always the first d
        // requests); cache them so the z-loop does not rebuild and re-sort
        // the same pools (§Perf: ~40% of schedule time at 512 candidates).
        let mut levels_by_d: Vec<Option<(Vec<LevelGroup>, Vec<usize>)>> =
            vec![None; adm.len() + 1];
        for z in (1..=z_ub).rev() {
            for d in z..=adm.len() {
                stats.subproblems += 1;
                if levels_by_d[d].is_none() {
                    let pool = &adm[..d];
                    let levels = build_levels(inst, pool);
                    let cap = suffix_capacity(&levels);
                    levels_by_d[d] = Some((levels, cap));
                }
                let (levels, suffix_cap) = levels_by_d[d].as_ref().unwrap();
                let mut counts = Vec::with_capacity(levels.len());
                let found = self.dfs(
                    inst,
                    levels,
                    suffix_cap,
                    0,
                    &PartialState::empty(),
                    &mut counts,
                    z,
                    &mut stats,
                );
                if found {
                    let subset = materialize(levels, &counts);
                    let t = FeasibilityChecker::new(inst)
                        .check(&subset)
                        .expect("dfs returned a checked-feasible subset");
                    return Schedule::from_subset(&subset, t, stats);
                }
            }
        }
        Schedule {
            stats,
            ..Schedule::empty()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, GpuSpec};
    use crate::coordinator::problem::EpochParams;
    use crate::model::{CostModel, LlmSpec};
    use crate::quant;
    use crate::request::{EpochRequest, RequestBuilder};
    use crate::wireless::RadioParams;

    fn inst_with(cluster: ClusterSpec, quant: quant::QuantSpec) -> ProblemInstance {
        ProblemInstance::new(
            CostModel::new(LlmSpec::bloom_3b()),
            quant,
            cluster,
            EpochParams::default(),
            512,
            0.0,
        )
    }

    fn inst() -> ProblemInstance {
        inst_with(ClusterSpec::paper_default(), quant::default_quant())
    }

    /// Uniform h (paper's concentration assumption) request generator.
    fn gen_reqs(specs: &[(u32, u32, f64, f64)]) -> Vec<EpochRequest> {
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        specs
            .iter()
            .map(|&(s, n, tau, a)| {
                EpochRequest::annotate(
                    b.build(0.0, s, n, tau, a),
                    (1e-3f64).sqrt(),
                    &radio,
                    0.25,
                    0.25,
                )
            })
            .collect()
    }

    /// Exhaustive subset optimum for small instances (reference oracle).
    fn exhaustive_opt(inst: &ProblemInstance, reqs: &[EpochRequest]) -> usize {
        let n = reqs.len();
        assert!(n <= 20);
        let checker = FeasibilityChecker::new(inst);
        let mut best = 0;
        for mask in 0u32..(1 << n) {
            let subset: Vec<&EpochRequest> = (0..n)
                .filter(|i| mask & (1 << i) != 0)
                .map(|i| &reqs[i])
                .collect();
            if subset.len() > best && checker.check(&subset).is_ok() {
                best = subset.len();
            }
        }
        best
    }

    #[test]
    fn schedules_everything_when_unconstrained() {
        let i = inst();
        let reqs = gen_reqs(&[(128, 128, 2.0, 0.5); 8]);
        let mut s = Dftsp::new();
        let sched = s.schedule(&i, &reqs);
        assert_eq!(sched.batch_size(), 8);
        assert!(sched.compute_time > 0.0);
    }

    #[test]
    fn empty_candidates_empty_schedule() {
        let mut s = Dftsp::new();
        assert_eq!(s.schedule(&inst(), &[]).batch_size(), 0);
    }

    #[test]
    fn drops_inadmissible_requests() {
        let i = inst_with(
            ClusterSpec::paper_default(),
            quant::by_label(quant::Precision::W4A16, quant::QuantAlgo::ZqLocal).unwrap(),
        );
        // BLOOM-3B + W4A16/ZQ-Local: f = 0.08.
        let reqs = gen_reqs(&[
            (128, 128, 2.0, 0.05), // admissible
            (128, 128, 2.0, 0.50), // not
            (128, 128, 2.0, 0.02), // admissible
        ]);
        let sched = Dftsp::new().schedule(&i, &reqs);
        assert_eq!(sched.batch_size(), 2);
        assert!(!sched.scheduled.contains(&reqs[1].id()));
    }

    #[test]
    fn respects_latency_under_compute_pressure() {
        // Two weak GPUs: a 512-padded prefill costs ≈0.75 s of the ≈1.3 s
        // compute slack, so only one request fits the deadline.
        let i = inst_with(
            ClusterSpec::new(
                GpuSpec {
                    name: "two-tx2".into(),
                    flops: 1.33e12,
                    mem_bytes: 32 * (1 << 30),
                },
                2,
            ),
            quant::default_quant(),
        );
        let reqs = gen_reqs(&[(128, 128, 1.8, 0.2); 10]);
        let sched = Dftsp::new().schedule(&i, &reqs);
        assert!(sched.batch_size() < 10, "compute-bound must reject some");
        assert!(sched.batch_size() >= 1);
        // Returned schedule is feasible.
        let sel: Vec<&EpochRequest> = reqs
            .iter()
            .filter(|r| sched.scheduled.contains(&r.id()))
            .collect();
        assert!(FeasibilityChecker::new(&i).check(&sel).is_ok());
    }

    #[test]
    fn matches_exhaustive_optimum_small() {
        // Mixed levels + tight compute; uniform h per the paper's P2
        // assumption, under which DFTSP is optimal.
        let i = inst_with(
            ClusterSpec::new(
                GpuSpec {
                    name: "duo".into(),
                    flops: 1.33e12,
                    mem_bytes: 32 * (1 << 30),
                },
                2,
            ),
            quant::default_quant(),
        );
        let reqs = gen_reqs(&[
            (128, 128, 1.6, 0.2),
            (256, 128, 1.9, 0.2),
            (128, 256, 1.7, 0.2),
            (512, 512, 2.0, 0.2),
            (128, 128, 0.9, 0.2),
            (256, 256, 1.4, 0.2),
            (128, 512, 1.9, 0.2),
            (64, 128, 1.2, 0.2),
        ]);
        let opt = exhaustive_opt(&i, &reqs);
        let got = Dftsp::new().schedule(&i, &reqs).batch_size();
        assert_eq!(got, opt, "DFTSP must match the exhaustive optimum");
        assert!(opt >= 1);
    }

    #[test]
    fn matches_exhaustive_optimum_bandwidth_bound() {
        // Terrible channels: uplink is the binding constraint.
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        let h = 5e-8; // rho_min_u for 512 tokens ≈ 0.36
        let reqs: Vec<EpochRequest> = [
            (512u32, 128u32),
            (512, 128),
            (512, 256),
            (256, 128),
            (512, 512),
            (384, 128),
        ]
        .iter()
        .map(|&(s, n)| {
            EpochRequest::annotate(b.build(0.0, s, n, 30.0, 0.1), h, &radio, 0.25, 0.25)
        })
        .collect();
        let mut i = inst();
        i.epoch.duration = 40.0; // plenty of compute slot; bandwidth binds
        let opt = exhaustive_opt(&i, &reqs);
        let got = Dftsp::new().schedule(&i, &reqs).batch_size();
        assert_eq!(got, opt);
        assert!(opt < reqs.len(), "bandwidth must actually bind");
    }

    #[test]
    fn prefers_short_outputs_under_memory_pressure() {
        let i = inst_with(
            ClusterSpec::new(
                GpuSpec {
                    name: "small-mem".into(),
                    flops: 1.33e13,
                    mem_bytes: 4 * (1 << 30),
                },
                1,
            ),
            quant::default_quant(),
        );
        let reqs = gen_reqs(&[
            (128, 512, 8.0, 0.2),
            (128, 512, 8.0, 0.2),
            (128, 128, 8.0, 0.2),
            (128, 128, 8.0, 0.2),
            (128, 128, 8.0, 0.2),
        ]);
        let mut i2 = i;
        i2.epoch.duration = 10.0;
        let sched = Dftsp::new().schedule(&i2, &reqs);
        // With KV budget tight, scheduling the three short requests beats two
        // long ones; DFTSP must find a max-cardinality set.
        let opt = exhaustive_opt(&i2, &reqs);
        assert_eq!(sched.batch_size(), opt);
    }

    #[test]
    fn stats_populated() {
        let i = inst();
        let reqs = gen_reqs(&[(128, 128, 2.0, 0.5); 6]);
        let sched = Dftsp::new().schedule(&i, &reqs);
        assert!(sched.stats.nodes_visited > 0);
        assert!(sched.stats.subproblems >= 1);
        assert!(sched.stats.solutions_checked >= 1);
    }

    #[test]
    fn adversarial_nan_inputs_do_not_panic() {
        // NaN channel gains / deadlines produce NaN ρ_min and slack; the
        // admission screens drop them and the total_cmp sorts tolerate any
        // survivors — scheduling must never panic.
        let i = inst();
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        let good_h = (1e-3f64).sqrt();
        let reqs = vec![
            EpochRequest::annotate(b.build(0.0, 128, 128, 2.0, 0.2), good_h, &radio, 0.25, 0.25),
            EpochRequest::annotate(b.build(0.0, 256, 128, 1.8, 0.2), good_h, &radio, 0.25, 0.25),
            EpochRequest::annotate(b.build(0.0, 128, 128, 2.0, 0.2), f64::NAN, &radio, 0.25, 0.25),
            EpochRequest::annotate(
                b.build(0.0, 128, 128, f64::NAN, 0.2),
                good_h,
                &radio,
                0.25,
                0.25,
            ),
        ];
        let sched = Dftsp::new().schedule(&i, &reqs);
        assert_eq!(sched.batch_size(), 2, "only the two sane requests run");
        assert!(!sched.scheduled.contains(&reqs[2].id()));
        assert!(!sched.scheduled.contains(&reqs[3].id()));
    }

    #[test]
    fn deterministic() {
        let i = inst();
        let reqs = gen_reqs(&[
            (128, 128, 1.6, 0.2),
            (256, 256, 1.2, 0.2),
            (512, 512, 1.9, 0.2),
            (128, 256, 1.4, 0.2),
        ]);
        let a = Dftsp::new().schedule(&i, &reqs);
        let b = Dftsp::new().schedule(&i, &reqs);
        assert_eq!(a.scheduled, b.scheduled);
        assert_eq!(a.stats, b.stats);
    }
}
