//! The `Scheduler` interface every batching policy implements, and the
//! `Schedule` decision it returns.

use crate::coordinator::problem::ProblemInstance;
use crate::request::{EpochRequest, RequestId};

/// Search-effort accounting (Table III compares these between DFTSP and the
/// brute-force tree search).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SearchStats {
    /// Tree nodes visited across all (z, d) subproblems.
    pub nodes_visited: u64,
    /// Complete candidate solutions submitted to the exact checker.
    pub solutions_checked: u64,
    /// Nodes skipped by the capacity rule Σ_{k≥N(v)}|F_k| < z − Σ v.
    pub pruned_capacity: u64,
    /// Subtrees cut because a monotone partial constraint was violated.
    pub pruned_constraint: u64,
    /// (z, d) subproblems attempted.
    pub subproblems: u64,
    /// True if a node budget stopped the search early (brute force guard).
    pub budget_exhausted: bool,
}

/// A scheduling decision for one epoch.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    /// The scheduled requests (paper: S, the set with x_i = 1).
    pub scheduled: Vec<RequestId>,
    /// β-scaled batch compute time t = β(tᴵ + tᴬ) in seconds.
    pub compute_time: f64,
    /// Per-request compute seconds. For synchronous batch policies this is
    /// `compute_time` for every member (the batch finishes together); for
    /// NoB it is each request's solo run time on its GPU.
    pub per_request_compute: Vec<(RequestId, f64)>,
    /// Σ ρ_min^U and Σ ρ_min^D actually committed.
    pub rho_u_total: f64,
    pub rho_d_total: f64,
    /// Search-effort statistics.
    pub stats: SearchStats,
}

impl Schedule {
    pub fn empty() -> Schedule {
        Schedule::default()
    }

    pub fn batch_size(&self) -> usize {
        self.scheduled.len()
    }

    /// Build a schedule from a validated subset (synchronous batch: every
    /// member completes after `compute_time`).
    pub fn from_subset(subset: &[&EpochRequest], compute_time: f64, stats: SearchStats) -> Self {
        Schedule {
            scheduled: subset.iter().map(|r| r.id()).collect(),
            compute_time,
            per_request_compute: subset.iter().map(|r| (r.id(), compute_time)).collect(),
            rho_u_total: subset.iter().map(|r| r.rho_min_u).sum(),
            rho_d_total: subset.iter().map(|r| r.rho_min_d).sum(),
            stats,
        }
    }
}

/// A per-epoch batch scheduling policy.
pub trait Scheduler {
    /// Human-readable policy name ("DFTSP", "StB", "NoB", "BruteForce").
    fn name(&self) -> &'static str;

    /// Decide which of `candidates` to run in the epoch described by `inst`.
    ///
    /// Implementations must only return subsets that satisfy constraints
    /// (1a)–(1f) — except deliberately deadline-oblivious baselines (StB),
    /// which document the deviation.
    fn schedule(&mut self, inst: &ProblemInstance, candidates: &[EpochRequest]) -> Schedule;
}
