//! The `Scheduler` interface every batching policy implements, and the
//! `Schedule` decision it returns.

use crate::coordinator::problem::ProblemInstance;
use crate::request::{EpochRequest, RequestId};

/// Search-effort accounting (Table III compares these between DFTSP and the
/// brute-force tree search).
#[derive(Debug, Clone, Default)]
pub struct SearchStats {
    /// Tree nodes visited across all (z, d) subproblems.
    pub nodes_visited: u64,
    /// Complete candidate solutions (leaves) submitted to a feasibility test.
    pub solutions_checked: u64,
    /// Per-request units of work spent in leaf feasibility tests: the exact
    /// `FeasibilityChecker::check` costs |S| units per leaf, the incremental
    /// `PartialState` leaf test costs 1 — the "leaf-check FLOPs" axis of the
    /// §Perf benchmarks.
    pub leaf_check_work: u64,
    /// Nodes skipped by the capacity rule Σ_{k≥N(v)}|F_k| < z − Σ v.
    pub pruned_capacity: u64,
    /// Subtrees cut because a monotone partial constraint was violated.
    pub pruned_constraint: u64,
    /// Subtrees cut by the cross-pool reuse floor: selections that avoid the
    /// pool's newest request were already proven infeasible at the previous
    /// d, so the new request's level count is floored at its uplink rank.
    pub pruned_reuse: u64,
    /// Whole z levels skipped because the full-pool probe failed without the
    /// latency constraint ever being the lone binding violation (no smaller
    /// pool can then succeed — smaller pools only worsen the monotone
    /// bandwidth/memory constraints).
    pub z_levels_skipped: u64,
    /// (z, d) subproblems attempted (the full-pool probe counts as one).
    pub subproblems: u64,
    /// True if a node budget stopped the search early (brute force guard).
    pub budget_exhausted: bool,
    /// Wall-clock seconds spent inside `Scheduler::schedule`, stamped by the
    /// epoch driver. Excluded from `PartialEq`: wall time varies run-to-run
    /// while every counter above is bit-deterministic (the determinism and
    /// driver-parity suites compare `SearchStats` directly).
    pub schedule_wall_s: f64,
}

impl PartialEq for SearchStats {
    fn eq(&self, other: &Self) -> bool {
        // Everything except `schedule_wall_s` (see field doc).
        self.nodes_visited == other.nodes_visited
            && self.solutions_checked == other.solutions_checked
            && self.leaf_check_work == other.leaf_check_work
            && self.pruned_capacity == other.pruned_capacity
            && self.pruned_constraint == other.pruned_constraint
            && self.pruned_reuse == other.pruned_reuse
            && self.z_levels_skipped == other.z_levels_skipped
            && self.subproblems == other.subproblems
            && self.budget_exhausted == other.budget_exhausted
    }
}

impl SearchStats {
    /// Accumulate another run's counters into this one (wall time included).
    pub fn merge(&mut self, other: &SearchStats) {
        self.nodes_visited += other.nodes_visited;
        self.solutions_checked += other.solutions_checked;
        self.leaf_check_work += other.leaf_check_work;
        self.pruned_capacity += other.pruned_capacity;
        self.pruned_constraint += other.pruned_constraint;
        self.pruned_reuse += other.pruned_reuse;
        self.z_levels_skipped += other.z_levels_skipped;
        self.subproblems += other.subproblems;
        self.budget_exhausted |= other.budget_exhausted;
        self.schedule_wall_s += other.schedule_wall_s;
    }
}

/// Deployment-level scheduler knobs, threaded from scenario TOML
/// (`[scheduler]`), the CLI (`--workers`) and `ServerConfig` into the
/// policy constructors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedulerConfig {
    /// Worker threads for DFTSP's opt-in parallel d-pool search; 0 or 1
    /// keeps the sequential chained search (the default).
    pub workers: usize,
}

impl Default for SchedulerConfig {
    /// Sequential search, unless the `SCHED_WORKERS` environment variable
    /// overrides it. The override exists so CI can run the whole test suite
    /// over a worker matrix (schedules are byte-identical across modes —
    /// property-tested — so every behavioral assertion holds under both;
    /// only search-*effort* counters may differ, which is why effort-
    /// sensitive fixtures pin `workers` explicitly). Explicit scenario TOML
    /// and CLI values are parsed with their own fallbacks and are not
    /// affected.
    fn default() -> Self {
        SchedulerConfig {
            workers: std::env::var("SCHED_WORKERS")
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or(0),
        }
    }
}

/// A scheduling decision for one epoch.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    /// The scheduled requests (paper: S, the set with x_i = 1).
    pub scheduled: Vec<RequestId>,
    /// β-scaled batch compute time t = β(tᴵ + tᴬ) in seconds.
    pub compute_time: f64,
    /// Per-request compute seconds. For synchronous batch policies this is
    /// `compute_time` for every member (the batch finishes together); for
    /// NoB it is each request's solo run time on its GPU.
    pub per_request_compute: Vec<(RequestId, f64)>,
    /// Σ ρ_min^U and Σ ρ_min^D actually committed.
    pub rho_u_total: f64,
    pub rho_d_total: f64,
    /// Search-effort statistics.
    pub stats: SearchStats,
}

impl Schedule {
    pub fn empty() -> Schedule {
        Schedule::default()
    }

    pub fn batch_size(&self) -> usize {
        self.scheduled.len()
    }

    /// Build a schedule from a validated subset (synchronous batch: every
    /// member completes after `compute_time`).
    pub fn from_subset(subset: &[&EpochRequest], compute_time: f64, stats: SearchStats) -> Self {
        Schedule {
            scheduled: subset.iter().map(|r| r.id()).collect(),
            compute_time,
            per_request_compute: subset.iter().map(|r| (r.id(), compute_time)).collect(),
            rho_u_total: subset.iter().map(|r| r.rho_min_u).sum(),
            rho_d_total: subset.iter().map(|r| r.rho_min_d).sum(),
            stats,
        }
    }
}

/// A per-epoch batch scheduling policy.
pub trait Scheduler {
    /// Human-readable policy name ("DFTSP", "StB", "NoB", "BruteForce").
    fn name(&self) -> &'static str;

    /// Decide which of `candidates` to run in the epoch described by `inst`.
    ///
    /// Implementations must only return subsets that satisfy constraints
    /// (1a)–(1f) — except deliberately deadline-oblivious baselines (StB),
    /// which document the deviation.
    fn schedule(&mut self, inst: &ProblemInstance, candidates: &[EpochRequest]) -> Schedule;
}
