//! Problem reformulation P1 → P2 (paper §III-A).
//!
//! Under the geographically-concentrated-users assumption (identical h, so
//! identical spectral efficiency for everyone), P1's constraints collapse to
//! the scalar-coefficient forms (2b)–(2e):
//!
//!   (2b) Σ k_i s_i ≤ 1          k_i = bits/(T_U·B^U·log₂(1+SNR_U))·(1/s_i)·s_i
//!   (2c) Σ k_1 n_i ≤ 1          k_1 = bits_per_token/(T_D·B^D·log₂(1+SNR_D))
//!   (2d) Σ n_i ≤ M̃             M̃ = k_2 − s'·z
//!   (2e) Σ k_4 n_i + k_5 n_i² ≤ τ̃_i   τ̃_i = (τ_i − t_w − T_U − T_D)·C/β − k_3·z
//!
//! This module computes k₁…k₅, M̃ and τ̃ explicitly and is cross-validated in
//! tests against the direct constraint checker — it documents that the
//! implementation and the paper's algebra agree.

use crate::coordinator::problem::ProblemInstance;
use crate::request::EpochRequest;
use crate::wireless::RadioParams;

/// The scalar coefficients of P2.
#[derive(Debug, Clone)]
pub struct P2Coefficients {
    /// Uplink cost per prompt token (constraint 2b): ρ_min^U = k_u · s_i.
    pub k_u: f64,
    /// k₁ — downlink cost per output token (constraint 2c).
    pub k1: f64,
    /// k₂ — total KV-token capacity: Σ(s' + n_i) ≤ k₂, i.e. M̃ = k₂ − s'z.
    pub k2: f64,
    /// k₃ — prefill FLOPs per request (the z-dependent part of 2e).
    pub k3: f64,
    /// k₄ — decode FLOPs coefficient linear in n_i.
    pub k4: f64,
    /// k₅ — decode FLOPs coefficient quadratic in n_i.
    pub k5: f64,
}

impl P2Coefficients {
    /// Derive the coefficients for an instance with common channel gain `h`.
    pub fn derive(inst: &ProblemInstance, radio: &RadioParams, h: f64) -> P2Coefficients {
        let spec = &inst.cost.spec;
        let l = spec.layers as f64;
        let dm = spec.d_model as f64;
        let df = spec.d_ff as f64;
        let s = inst.s_pad as f64;

        // (2b)/(2c): per-token bandwidth fractions.
        let k_u = radio.bits_per_token
            / (inst.epoch.t_u * radio.uplink_hz * radio.uplink_se(h));
        let k1 = radio.bits_per_token
            / (inst.epoch.t_d * radio.downlink_hz * radio.downlink_se(h));

        // (2d): α·(m1 + 4·L·d_m·Σ(s' + n_i)) ≤ M_total
        //  ⇒ Σ(s' + n_i) ≤ (M/α − m1_total)/(4·L·d_m) = k₂.
        // m1 is paid once per GPU replica.
        let m_total = inst.cluster.total_mem_bytes() as f64;
        let m1_total = inst.cluster.num_gpus as f64 * inst.cost.weight_bytes() as f64;
        let k2 = (m_total / inst.quant.alpha - m1_total) / (4.0 * l * dm);

        // (2e): per-request decode FLOPs
        //   L(n−1)(8d_m² + 4(s'+n/2)d_m + 4 d_m d_f)
        // ≈ k₄·n + k₅·n² with the −1 folded in exactly below; prefill adds
        // k₃ per scheduled request (the z-dependent term).
        let k3 = l * (8.0 * s * dm * dm + 4.0 * s * s * dm + 4.0 * s * dm * df);
        let a_const = 8.0 * dm * dm + 4.0 * s * dm + 4.0 * dm * df;
        // L(n−1)(A + 2·n·d_m) = L(A·n + 2n²d_m − A − 2n·d_m)
        //                     = L((A − 2d_m)·n + 2d_m·n² − A)
        // We keep the exact quadratic-in-n form: k₄·n + k₅·n² − L·A.
        let k4 = l * (a_const - 2.0 * dm);
        let k5 = l * 2.0 * dm;
        P2Coefficients {
            k_u,
            k1,
            k2,
            k3,
            k4,
            k5,
        }
    }

    /// Exact per-request decode FLOPs via the quadratic form (matches
    /// `CostModel::decode_flops_per_req` for the same s').
    pub fn decode_flops(&self, inst: &ProblemInstance, n: u32) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let l = inst.cost.spec.layers as f64;
        let dm = inst.cost.spec.d_model as f64;
        let df = inst.cost.spec.d_ff as f64;
        let s = inst.s_pad as f64;
        let a_const = 8.0 * dm * dm + 4.0 * s * dm + 4.0 * dm * df;
        self.k4 * n as f64 + self.k5 * (n as f64) * (n as f64) - l * a_const
    }

    /// M̃ for batch size z (constraint 2d right-hand side).
    pub fn m_tilde(&self, inst: &ProblemInstance, z: usize) -> f64 {
        self.k2 - inst.s_pad as f64 * z as f64
    }

    /// τ̃_i for a request at batch size z (constraint 2e right-hand side),
    /// in FLOP units.
    pub fn tau_tilde(&self, inst: &ProblemInstance, r: &EpochRequest, z: usize) -> f64 {
        let slack = inst.compute_slack(r);
        slack * inst.cluster.total_flops() / inst.quant.beta - self.k3 * z as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::coordinator::problem::EpochParams;
    use crate::model::{CostModel, LlmSpec};
    use crate::quant;
    use crate::request::RequestBuilder;

    fn inst() -> ProblemInstance {
        ProblemInstance::new(
            CostModel::new(LlmSpec::bloom_3b()),
            quant::default_quant(),
            ClusterSpec::paper_default(),
            EpochParams::default(),
            512,
            0.0,
        )
    }

    #[test]
    fn k_u_matches_rho_min() {
        let i = inst();
        let radio = RadioParams::default();
        let h = (1e-3f64).sqrt();
        let k = P2Coefficients::derive(&i, &radio, h);
        // ρ_min^U = k_u · s_i exactly, for any s.
        for s in [64u32, 128, 511] {
            let direct = radio.rho_min_uplink(s, h, i.epoch.t_u);
            assert!((k.k_u * s as f64 - direct).abs() < 1e-15, "s={s}");
        }
    }

    #[test]
    fn k1_matches_rho_min_downlink() {
        let i = inst();
        let radio = RadioParams::default();
        let h = 0.02;
        let k = P2Coefficients::derive(&i, &radio, h);
        for n in [128u32, 256, 512] {
            let direct = radio.rho_min_downlink(n, h, i.epoch.t_d);
            assert!((k.k1 * n as f64 - direct).abs() < 1e-15, "n={n}");
        }
    }

    #[test]
    fn quadratic_decode_matches_cost_model() {
        let i = inst();
        let radio = RadioParams::default();
        let k = P2Coefficients::derive(&i, &radio, 0.03);
        for n in [2u32, 100, 128, 256, 512] {
            let via_quadratic = k.decode_flops(&i, n);
            let via_cost = i.cost.decode_flops_per_req(i.s_pad, n);
            assert!(
                (via_quadratic - via_cost).abs() / via_cost.max(1.0) < 1e-12,
                "n={n}: {via_quadratic} vs {via_cost}"
            );
        }
    }

    #[test]
    fn k3_is_prefill_flops() {
        let i = inst();
        let k = P2Coefficients::derive(&i, &RadioParams::default(), 0.03);
        let direct = i.cost.prefill_flops_per_req(i.s_pad);
        assert!((k.k3 - direct).abs() / direct < 1e-12);
    }

    #[test]
    fn m_tilde_equals_memory_constraint() {
        // Σ n_i ≤ M̃(z) must match the aggregate form of constraint (1c).
        let i = inst();
        let k = P2Coefficients::derive(&i, &RadioParams::default(), 0.03);
        let z = 10usize;
        let m_tilde = k.m_tilde(&i, z);
        // Reconstruct: α(m1_total + 4Ld_m(s'z + Σn)) ≤ M_total at Σn = M̃
        let l = i.cost.spec.layers as f64;
        let dm = i.cost.spec.d_model as f64;
        let lhs = i.quant.alpha
            * (i.cluster.num_gpus as f64 * i.cost.weight_bytes() as f64
                + 4.0 * l * dm * (i.s_pad as f64 * z as f64 + m_tilde));
        let rhs = i.cluster.total_mem_bytes() as f64;
        assert!((lhs - rhs).abs() / rhs < 1e-12);
    }

    #[test]
    fn tau_tilde_decreases_with_z_and_waiting() {
        let i = inst();
        let k = P2Coefficients::derive(&i, &RadioParams::default(), 0.03);
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        let r = crate::request::EpochRequest::annotate(
            b.build(0.0, 128, 128, 2.0, 0.2),
            0.03,
            &radio,
            0.25,
            0.25,
        );
        assert!(k.tau_tilde(&i, &r, 5) > k.tau_tilde(&i, &r, 10));
        let mut i_late = inst();
        i_late.now = 0.5; // r waited 0.5 s
        assert!(k.tau_tilde(&i_late, &r, 5) < k.tau_tilde(&i, &r, 5));
    }
}
