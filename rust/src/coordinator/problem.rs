//! Problem instance and feasibility checking — paper P1, constraints
//! (1a)–(1f).
//!
//! `ProblemInstance` freezes everything that is constant within one epoch
//! (model, quantization, cluster, radio slots, padded prompt length, batch
//! start time). `FeasibilityChecker` evaluates a candidate subset against the
//! exact published constraints; `PartialState` is its incremental, monotone
//! form used for online tree pruning inside DFTSP.

use crate::cluster::ClusterSpec;
use crate::model::CostModel;
use crate::quant::QuantSpec;
use crate::request::EpochRequest;

/// Epoch timing protocol (paper Fig. 2). Defaults = §IV: 2 s epochs with
/// T_U = T_D = 250 ms; T_C spans the full epoch thanks to the overlap of
/// adjacent epochs' T_D/T_U slots.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochParams {
    pub duration: f64,
    pub t_u: f64,
    pub t_d: f64,
}

impl Default for EpochParams {
    fn default() -> Self {
        EpochParams {
            duration: 2.0,
            t_u: 0.25,
            t_d: 0.25,
        }
    }
}

impl EpochParams {
    /// The computation slot available to a batch — with the paper's
    /// overlapped timeline, a full epoch.
    pub fn t_c(&self) -> f64 {
        self.duration
    }
}

/// Everything constant during one scheduling decision.
///
/// `cluster` is the *partition* this decision schedules against, not the
/// whole edge fleet: under heterogeneous sharding each shard's
/// `ProblemInstance` carries its own per-GPU FLOPs/memory
/// (`cluster.gpu`), so constraints (1b)–(1d) — compute-time feasibility
/// and the KV memory bound — are evaluated against the shard's real
/// capacity, never a fleet-wide average.
#[derive(Debug, Clone)]
pub struct ProblemInstance {
    pub cost: CostModel,
    pub quant: QuantSpec,
    pub cluster: ClusterSpec,
    pub epoch: EpochParams,
    /// s' — the padded prompt length for the Initial Stage (all prompts in a
    /// batch are extended to this length for parallel execution).
    pub s_pad: u32,
    /// Batch start time (the epoch boundary at which T_U begins).
    pub now: f64,
}

impl ProblemInstance {
    pub fn new(
        cost: CostModel,
        quant: QuantSpec,
        cluster: ClusterSpec,
        epoch: EpochParams,
        s_pad: u32,
        now: f64,
    ) -> Self {
        ProblemInstance {
            cost,
            quant,
            cluster,
            epoch,
            s_pad,
            now,
        }
    }

    /// Per-request compute slack in seconds available for β(tᴵ+tᴬ):
    /// τᵢ − t_{w,i} − T_U − T_D (constraint 1d rearranged).
    pub fn compute_slack(&self, r: &EpochRequest) -> f64 {
        r.req.latency_req - r.req.waited(self.now) - self.epoch.t_u - self.epoch.t_d
    }

    /// Peak KV bytes a request occupies (unscaled; α applied at check time).
    pub fn kv_bytes(&self, n_out: u32) -> u64 {
        self.cost.kv_peak_bytes_per_req(self.s_pad, n_out)
    }

    /// β-scaled compute seconds for a batch described by (count, total decode
    /// FLOPs) on the aggregate cluster.
    pub fn compute_time(&self, batch: usize, decode_flops: f64) -> f64 {
        let prefill = batch as f64 * self.cost.prefill_flops_per_req(self.s_pad);
        self.quant.beta * (prefill + decode_flops) / self.cluster.total_flops()
    }

    /// Accuracy admission (constraint 1e): is this request servable at all by
    /// the deployed quantization?
    pub fn admits(&self, r: &EpochRequest) -> bool {
        self.quant
            .satisfies_accuracy(&self.cost.spec.name, r.req.accuracy_req)
    }

    /// The admission filter Ĩ — requests satisfying (1e) plus the trivial
    /// individual-feasibility screens (a request that alone violates a
    /// constraint can never appear in any feasible batch).
    pub fn admissible<'a>(&self, reqs: &'a [EpochRequest]) -> Vec<&'a EpochRequest> {
        reqs.iter()
            .filter(|r| self.admits(r))
            .filter(|r| r.rho_min_u <= 1.0 && r.rho_min_d <= 1.0)
            .filter(|r| self.compute_slack(r) > 0.0)
            .filter(|r| {
                self.cluster.batch_fits_memory(
                    &self.cost,
                    &self.quant,
                    &[self.kv_bytes(r.req.output_tokens)],
                )
            })
            .collect()
    }
}

/// Which constraint a subset violates (for diagnostics and tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Violation {
    /// (1a) Σ ρ_min^U > 1
    Uplink,
    /// (1b) Σ ρ_min^D > 1
    Downlink,
    /// (1c) α(m1 + m2^I + m2^A) > M
    Memory,
    /// (1d) some scheduled request misses its deadline
    Latency,
    /// (1e) some scheduled request's accuracy requirement unmet
    Accuracy,
}

/// Exact feasibility evaluation of a complete subset.
pub struct FeasibilityChecker<'a> {
    pub inst: &'a ProblemInstance,
}

impl<'a> FeasibilityChecker<'a> {
    pub fn new(inst: &'a ProblemInstance) -> Self {
        FeasibilityChecker { inst }
    }

    /// Check constraints (1a)–(1e) for subset `s`. `Ok(batch_compute_time)`
    /// on success.
    pub fn check(&self, s: &[&EpochRequest]) -> Result<f64, Violation> {
        let inst = self.inst;
        if s.is_empty() {
            return Ok(0.0);
        }
        // (1e)
        if s.iter().any(|r| !inst.admits(r)) {
            return Err(Violation::Accuracy);
        }
        // (1a), (1b)
        let rho_u: f64 = s.iter().map(|r| r.rho_min_u).sum();
        if rho_u > 1.0 + 1e-12 {
            return Err(Violation::Uplink);
        }
        let rho_d: f64 = s.iter().map(|r| r.rho_min_d).sum();
        if rho_d > 1.0 + 1e-12 {
            return Err(Violation::Downlink);
        }
        // (1c)
        let kv: Vec<u64> = s
            .iter()
            .map(|r| inst.kv_bytes(r.req.output_tokens))
            .collect();
        if !inst
            .cluster
            .batch_fits_memory(&inst.cost, &inst.quant, &kv)
        {
            return Err(Violation::Memory);
        }
        // (1d): the whole batch finishes together; every member must meet its
        // own deadline.
        let decode_flops: f64 = s
            .iter()
            .map(|r| {
                inst.cost
                    .decode_flops_per_req(inst.s_pad, r.req.output_tokens)
            })
            .sum();
        let t_compute = inst.compute_time(s.len(), decode_flops);
        let min_slack = s
            .iter()
            .map(|r| inst.compute_slack(r))
            .fold(f64::INFINITY, f64::min);
        if t_compute > min_slack {
            return Err(Violation::Latency);
        }
        // The batch must also fit the computation slot itself.
        if t_compute > inst.epoch.t_c() {
            return Err(Violation::Latency);
        }
        Ok(t_compute)
    }
}

/// Monotone partial-batch state for DFS pruning: every `add` makes all
/// tracked quantities weakly worse, so a violated partial can never become
/// feasible again — the soundness condition for online tree pruning.
#[derive(Debug, Clone)]
pub struct PartialState {
    pub count: usize,
    pub rho_u: f64,
    pub rho_d: f64,
    pub kv_total: u64,
    pub kv_max: u64,
    pub decode_flops: f64,
    pub min_slack: f64,
}

impl PartialState {
    pub fn empty() -> Self {
        PartialState {
            count: 0,
            rho_u: 0.0,
            rho_d: 0.0,
            kv_total: 0,
            kv_max: 0,
            decode_flops: 0.0,
            min_slack: f64::INFINITY,
        }
    }

    /// Add a block of `count` requests with aggregate uplink/downlink
    /// fractions, identical per-request KV bytes, aggregate decode FLOPs and
    /// the block's minimum compute slack.
    pub fn add_block(
        &self,
        count: usize,
        rho_u: f64,
        rho_d: f64,
        kv_per_req: u64,
        decode_flops: f64,
        block_min_slack: f64,
    ) -> PartialState {
        PartialState {
            count: self.count + count,
            rho_u: self.rho_u + rho_u,
            rho_d: self.rho_d + rho_d,
            kv_total: self.kv_total + kv_per_req * count as u64,
            kv_max: self.kv_max.max(if count > 0 { kv_per_req } else { 0 }),
            decode_flops: self.decode_flops + decode_flops,
            min_slack: self.min_slack.min(block_min_slack),
        }
    }

    /// Can this partial still be part of a feasible batch? (Monotone bound —
    /// `false` is a proof that every extension is infeasible.)
    pub fn feasible(&self, inst: &ProblemInstance) -> bool {
        self.violation(inst).is_none()
    }

    /// The first violated constraint of this partial batch, in the exact
    /// checker's order (uplink, downlink, memory, latency), or `None`.
    ///
    /// Two contracts hang off this method:
    ///
    /// - **Monotone bound** (any partial): every tracked quantity only
    ///   worsens under `add_block`, so `Some(_)` proves the whole subtree
    ///   infeasible — the online-pruning rule.
    /// - **Exact leaf test** (complete batch of admissible requests): the
    ///   formulas and comparisons mirror `FeasibilityChecker::check`
    ///   term-for-term — same ρ sums, the same worst-GPU packing bound as
    ///   `ClusterSpec::batch_fits_memory`, the same `t > slack` / `t > T_C`
    ///   tests — so at a DFS leaf (Σ v_k = z) this *is* the (1a)–(1d) check,
    ///   in O(1) with no allocation. (1e) is handled upstream by the
    ///   admission filter. The only divergence from the checker is
    ///   floating-point association: block sums group additions by level,
    ///   which can drift by an ulp against the checker's flat sums — why
    ///   DFTSP re-runs the exact checker once on the final accepted subset.
    ///
    /// NaN inputs follow the checker's convention (`NaN > cap` is false, so
    /// a NaN term never *triggers* a violation) — required so the
    /// incremental and exact forms agree on adversarial inputs, and sound
    /// for pruning (a NaN partial is simply never pruned).
    pub fn violation(&self, inst: &ProblemInstance) -> Option<Violation> {
        if self.count == 0 {
            return None;
        }
        if self.rho_u > 1.0 + 1e-12 {
            return Some(Violation::Uplink);
        }
        if self.rho_d > 1.0 + 1e-12 {
            return Some(Violation::Downlink);
        }
        // Memory: same worst-GPU bound as ClusterSpec::batch_fits_memory.
        let budget = inst.cluster.kv_budget_per_gpu(&inst.cost, &inst.quant);
        if budget <= 0.0 {
            return Some(Violation::Memory);
        }
        let per_gpu_kv = if self.count <= inst.cluster.num_gpus {
            self.kv_max as f64
        } else {
            self.kv_total as f64 / inst.cluster.num_gpus as f64 + self.kv_max as f64
        };
        if per_gpu_kv > budget {
            return Some(Violation::Memory);
        }
        // Latency lower bound: even with no further additions the batch costs
        // compute_time(count, decode_flops); min_slack only shrinks later.
        let t = inst.compute_time(self.count, self.decode_flops);
        if t > self.min_slack || t > inst.epoch.t_c() {
            return Some(Violation::Latency);
        }
        None
    }

    /// Is any drift-prone constraint quantity within an ulp-scale band of
    /// its threshold? The incremental sums group additions by level while
    /// the exact checker sums flat; the two can differ by ~n·ε ≈ 1e-12
    /// relative — far inside this 1e-9 band. Outside the band the two forms
    /// *cannot* disagree, so DFTSP's O(1) leaf test is exact there and
    /// arbitrates with the full checker only on (measure-zero) boundary
    /// leaves. Memory is excluded: its sums are integer u64 on both paths,
    /// bit-identical by construction.
    pub fn near_boundary(&self, inst: &ProblemInstance) -> bool {
        if self.count == 0 {
            return false;
        }
        fn close(a: f64, b: f64) -> bool {
            (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0)
        }
        if close(self.rho_u, 1.0 + 1e-12) || close(self.rho_d, 1.0 + 1e-12) {
            return true;
        }
        let t = inst.compute_time(self.count, self.decode_flops);
        close(t, self.min_slack) || close(t, inst.epoch.t_c())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::model::LlmSpec;
    use crate::quant;
    use crate::request::{Request, RequestBuilder};
    use crate::wireless::RadioParams;

    fn inst() -> ProblemInstance {
        ProblemInstance::new(
            CostModel::new(LlmSpec::bloom_3b()),
            quant::default_quant(),
            ClusterSpec::paper_default(),
            EpochParams::default(),
            512,
            0.0,
        )
    }

    fn er(req: Request) -> EpochRequest {
        EpochRequest::annotate(req, (1e-3f64).sqrt(), &RadioParams::default(), 0.25, 0.25)
    }

    fn mk(b: &mut RequestBuilder, n: u32, tau: f64, a: f64) -> EpochRequest {
        er(b.build(0.0, 128, n, tau, a))
    }

    #[test]
    fn empty_batch_feasible() {
        let i = inst();
        assert_eq!(FeasibilityChecker::new(&i).check(&[]), Ok(0.0));
    }

    #[test]
    fn single_modest_request_feasible() {
        let i = inst();
        let mut b = RequestBuilder::new();
        let r = mk(&mut b, 128, 2.0, 0.5);
        let t = FeasibilityChecker::new(&i).check(&[&r]).unwrap();
        assert!(t > 0.0 && t < 2.0, "compute time {t}");
    }

    #[test]
    fn accuracy_violation_detected() {
        let mut i = inst();
        // Deploy W4A16/ZQ-Local on BLOOM-3B: dPPL 0.92 → f = 0.08.
        i.quant = quant::by_label(quant::Precision::W4A16, quant::QuantAlgo::ZqLocal).unwrap();
        let mut b = RequestBuilder::new();
        let strict = mk(&mut b, 128, 2.0, 0.9);
        assert_eq!(
            FeasibilityChecker::new(&i).check(&[&strict]),
            Err(Violation::Accuracy)
        );
        let lax = mk(&mut b, 128, 2.0, 0.05);
        assert!(FeasibilityChecker::new(&i).check(&[&lax]).is_ok());
    }

    #[test]
    fn latency_violation_detected() {
        let i = inst();
        let mut b = RequestBuilder::new();
        // τ = 0.55 s leaves only 50 ms of compute slack after T_U + T_D —
        // far below one 512-token prefill+decode on the cluster.
        let tight = mk(&mut b, 512, 0.55, 0.5);
        assert_eq!(
            FeasibilityChecker::new(&i).check(&[&tight]),
            Err(Violation::Latency)
        );
    }

    #[test]
    fn uplink_violation_detected() {
        let i = inst();
        let mut b = RequestBuilder::new();
        // Terrible channel makes rho_min huge (h ≈ 5e-8 ⇒ SNR ≈ 3e-3,
        // spectral efficiency ≈ 4.5e-3 bit/s/Hz ⇒ ρ_min ≈ 0.36 for 512 tok).
        let radio = RadioParams::default();
        let reqs: Vec<EpochRequest> = (0..3)
            .map(|_| {
                EpochRequest::annotate(b.build(0.0, 512, 128, 60.0, 0.0), 5e-8, &radio, 0.25, 0.25)
            })
            .collect();
        assert!(reqs[0].rho_min_u > 0.34 && reqs[0].rho_min_u <= 1.0);
        let refs: Vec<&EpochRequest> = reqs.iter().collect();
        assert_eq!(
            FeasibilityChecker::new(&i).check(&refs),
            Err(Violation::Uplink)
        );
    }

    #[test]
    fn memory_violation_detected() {
        // Small-memory cluster: a few 512-out requests overflow the KV budget.
        let mut i = inst();
        i.cluster = ClusterSpec::new(
            crate::cluster::GpuSpec {
                name: "small".into(),
                flops: 1.33e12,
                mem_bytes: 7 * (1 << 30) / 2, // 3.5 GiB; weights*α ≈ 3.3 GiB
            },
            1,
        );
        let mut b = RequestBuilder::new();
        let reqs: Vec<EpochRequest> = (0..6).map(|_| mk(&mut b, 512, 3600.0, 0.0)).collect();
        let refs: Vec<&EpochRequest> = reqs.iter().collect();
        assert_eq!(
            FeasibilityChecker::new(&i).check(&refs),
            Err(Violation::Memory)
        );
    }

    #[test]
    fn partial_state_matches_full_checker() {
        // Building the same batch through PartialState must agree with the
        // exact checker on feasibility for same-slack, same-level batches.
        let i = inst();
        let mut b = RequestBuilder::new();
        let reqs: Vec<EpochRequest> = (0..8).map(|_| mk(&mut b, 256, 2.0, 0.5)).collect();
        let refs: Vec<&EpochRequest> = reqs.iter().collect();
        let full = FeasibilityChecker::new(&i).check(&refs).is_ok();

        let mut p = PartialState::empty();
        for r in &reqs {
            p = p.add_block(
                1,
                r.rho_min_u,
                r.rho_min_d,
                i.kv_bytes(r.req.output_tokens),
                i.cost.decode_flops_per_req(i.s_pad, r.req.output_tokens),
                i.compute_slack(r),
            );
        }
        assert_eq!(p.feasible(&i), full);
        assert_eq!(p.count, 8);
    }

    #[test]
    fn partial_state_monotone() {
        // Once infeasible, adding more blocks never restores feasibility.
        let i = inst();
        let mut b = RequestBuilder::new();
        let mut p = PartialState::empty();
        let mut was_infeasible = false;
        for _ in 0..2000 {
            let r = mk(&mut b, 512, 1.2, 0.5);
            p = p.add_block(
                1,
                r.rho_min_u,
                r.rho_min_d,
                i.kv_bytes(512),
                i.cost.decode_flops_per_req(i.s_pad, 512),
                i.compute_slack(&r),
            );
            if was_infeasible {
                assert!(!p.feasible(&i));
            }
            if !p.feasible(&i) {
                was_infeasible = true;
            }
        }
        assert!(was_infeasible);
    }

    #[test]
    fn admissible_filters() {
        let mut i = inst();
        i.quant = quant::by_label(quant::Precision::W4A16, quant::QuantAlgo::Gptq).unwrap();
        // BLOOM-3B dPPL 0.75 → f = 0.25.
        let mut b = RequestBuilder::new();
        let ok = mk(&mut b, 128, 2.0, 0.2);
        let too_strict = mk(&mut b, 128, 2.0, 0.3);
        let too_late = mk(&mut b, 128, 0.4, 0.1); // slack < 0 after T_U+T_D
        let reqs = vec![ok.clone(), too_strict, too_late];
        let adm = i.admissible(&reqs);
        assert_eq!(adm.len(), 1);
        assert_eq!(adm[0].id(), ok.id());
    }

    #[test]
    fn compute_slack_accounts_waiting() {
        let mut i = inst();
        i.now = 1.0;
        let mut b = RequestBuilder::new();
        let r = er(b.build(0.5, 128, 128, 2.0, 0.5));
        // waited 0.5, slack = 2.0 - 0.5 - 0.25 - 0.25 = 1.0
        assert!((i.compute_slack(&r) - 1.0).abs() < 1e-12);
    }
}
