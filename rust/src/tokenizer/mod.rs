//! Byte-level BPE tokenizer — the request-path twin of
//! `python/compile/tokenizer.py` (paper §IV: BPE tokens as 2-byte indices).
//!
//! Loads the rank-ordered merge table from `artifacts/bpe.json` and
//! implements encode (lowest-rank merge first, exactly like the Python
//! trainer) and decode. Golden text↔ids pairs embedded in the artifact
//! prove cross-language agreement.

use crate::util::json::Json;
use std::collections::HashMap;
use std::path::Path;

/// A loaded BPE vocabulary.
#[derive(Debug, Clone)]
pub struct Bpe {
    /// merges[r] = (left, right) merged into token 256 + r.
    merges: Vec<(u32, u32)>,
    /// (left, right) -> rank.
    rank: HashMap<(u32, u32), u32>,
    /// token id -> bytes.
    vocab: Vec<Vec<u8>>,
}

impl Bpe {
    /// Build from a merge table.
    pub fn from_merges(merges: Vec<(u32, u32)>) -> Bpe {
        let mut vocab: Vec<Vec<u8>> = (0..=255u8).map(|b| vec![b]).collect();
        for &(a, b) in &merges {
            let mut bytes = vocab[a as usize].clone();
            bytes.extend_from_slice(&vocab[b as usize]);
            vocab.push(bytes);
        }
        let rank = merges
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, i as u32))
            .collect();
        Bpe {
            merges,
            rank,
            vocab,
        }
    }

    /// Load `bpe.json` produced by the Python trainer.
    pub fn load(path: &Path) -> Result<Bpe, String> {
        let src = std::fs::read_to_string(path).map_err(|e| format!("read {path:?}: {e}"))?;
        let j = Json::parse(&src).map_err(|e| e.to_string())?;
        let merges = j
            .get("merges")
            .and_then(|m| m.as_arr())
            .ok_or("missing `merges`")?
            .iter()
            .map(|pair| {
                let p = pair.as_arr().ok_or("merge entry not a pair")?;
                if p.len() != 2 {
                    return Err("merge entry not a pair".to_string());
                }
                Ok((
                    p[0].as_u64().ok_or("bad merge id")? as u32,
                    p[1].as_u64().ok_or("bad merge id")? as u32,
                ))
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(Bpe::from_merges(merges))
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    /// Encode text to token ids (reference-identical greedy lowest-rank
    /// merging).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        while ids.len() >= 2 {
            // find the lowest-rank adjacent pair
            let mut best: Option<(usize, u32)> = None; // (position, rank)
            for i in 0..ids.len() - 1 {
                if let Some(&r) = self.rank.get(&(ids[i], ids[i + 1])) {
                    if best.map(|(_, br)| r < br).unwrap_or(true) {
                        best = Some((i, r));
                    }
                }
            }
            let Some((_, r)) = best else { break };
            let (a, b) = self.merges[r as usize];
            let merged = 256 + r;
            // merge every occurrence of (a, b), as the trainer does
            let mut out = Vec::with_capacity(ids.len());
            let mut i = 0;
            while i < ids.len() {
                if i + 1 < ids.len() && ids[i] == a && ids[i + 1] == b {
                    out.push(merged);
                    i += 2;
                } else {
                    out.push(ids[i]);
                    i += 1;
                }
            }
            ids = out;
        }
        ids
    }

    /// Decode ids back to text (invalid UTF-8 becomes U+FFFD, invalid ids are
    /// skipped).
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if let Some(b) = self.vocab.get(id as usize) {
                bytes.extend_from_slice(b);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Validate against the golden pairs embedded in bpe.json; returns the
    /// number of goldens checked.
    pub fn check_goldens(&self, json: &Json) -> Result<usize, String> {
        let goldens = json
            .get("goldens")
            .and_then(|g| g.as_arr())
            .ok_or("missing `goldens`")?;
        for g in goldens {
            let text = g.req_str("text")?;
            let want: Vec<u32> = g
                .get("ids")
                .and_then(|i| i.as_arr())
                .ok_or("missing ids")?
                .iter()
                .filter_map(|x| x.as_u64().map(|u| u as u32))
                .collect();
            let got = self.encode(text);
            if got != want {
                return Err(format!(
                    "golden mismatch for `{text}`: rust {got:?} vs python {want:?}"
                ));
            }
            if self.decode(&got) != text {
                return Err(format!("decode(encode) != id for `{text}`"));
            }
        }
        Ok(goldens.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A small hand-built merge table: "ab" -> 256, then (256, 'c') -> 257.
    fn toy() -> Bpe {
        Bpe::from_merges(vec![(b'a' as u32, b'b' as u32), (256, b'c' as u32)])
    }

    #[test]
    fn encodes_with_rank_priority() {
        let bpe = toy();
        assert_eq!(bpe.encode("abc"), vec![257]);
        assert_eq!(bpe.encode("ab"), vec![256]);
        assert_eq!(bpe.encode("ba"), vec![b'b' as u32, b'a' as u32]);
        assert_eq!(bpe.encode("abab"), vec![256, 256]);
    }

    #[test]
    fn decode_inverts_encode() {
        let bpe = toy();
        for text in ["abcabcab", "xyz", "aabbcc", ""] {
            assert_eq!(bpe.decode(&bpe.encode(text)), text);
        }
    }

    #[test]
    fn roundtrips_unicode() {
        let bpe = toy();
        let text = "héllo wörld — ab";
        assert_eq!(bpe.decode(&bpe.encode(text)), text);
    }

    #[test]
    fn vocab_size_counts_merges() {
        assert_eq!(toy().vocab_size(), 258);
    }

    #[test]
    fn invalid_ids_skipped_in_decode() {
        let bpe = toy();
        assert_eq!(bpe.decode(&[b'h' as u32, 9999, b'i' as u32]), "hi");
    }

    #[test]
    fn matches_python_goldens_when_built() {
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/bpe.json");
        if !path.exists() {
            eprintln!("skipping: artifacts/bpe.json not built");
            return;
        }
        let bpe = Bpe::load(&path).unwrap();
        let json = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let n = bpe.check_goldens(&json).unwrap();
        assert!(n >= 3, "expected several goldens, got {n}");
        assert!(bpe.vocab_size() > 256);
        // arbitrary text roundtrips
        let text = "the scheduler batches requests across the wireless edge.";
        assert_eq!(bpe.decode(&bpe.encode(text)), text);
    }
}
