//! Edge GPU-cluster substrate — the simulated stand-in for the paper's
//! testbed of 20 NVIDIA Jetson TX2 GPUs (1.33 TFLOPs, 32 GB each).
//!
//! DFTSP and StB schedule one batch per epoch across the cluster in data
//! parallel: every GPU holds a (quantized) model replica, the batch is split
//! evenly, and the aggregate computing speed is G·C_gpu. NoB instead binds
//! one request to one GPU (paper §IV). The memory ledger performs *per-GPU*
//! accounting: each GPU pays the weight footprint once plus the KV cache of
//! the requests routed to it.

use crate::model::CostModel;
use crate::quant::QuantSpec;

/// A single accelerator (defaults = Jetson TX2 per paper §IV).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    /// Peak computing speed C in FLOP/s (TX2: 1.33 TFLOPs).
    pub flops: f64,
    /// Memory capacity M in bytes (TX2 config in paper: 32 GB).
    pub mem_bytes: u64,
}

impl GpuSpec {
    pub fn jetson_tx2() -> Self {
        GpuSpec {
            name: "Jetson-TX2".to_string(),
            flops: 1.33e12,
            mem_bytes: 32 * (1 << 30),
        }
    }
}

/// The edge node's accelerator pool.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub gpu: GpuSpec,
    pub num_gpus: usize,
}

impl ClusterSpec {
    /// Paper §IV: 20 Jetson TX2 GPUs.
    pub fn paper_default() -> Self {
        ClusterSpec {
            gpu: GpuSpec::jetson_tx2(),
            num_gpus: 20,
        }
    }

    pub fn new(gpu: GpuSpec, num_gpus: usize) -> Self {
        assert!(num_gpus > 0);
        ClusterSpec { gpu, num_gpus }
    }

    /// Aggregate computing speed C = G · C_gpu (FLOP/s).
    pub fn total_flops(&self) -> f64 {
        self.num_gpus as f64 * self.gpu.flops
    }

    /// Aggregate memory M = G · M_gpu (bytes).
    pub fn total_mem_bytes(&self) -> u64 {
        self.num_gpus as u64 * self.gpu.mem_bytes
    }

    /// Per-GPU unscaled-KV budget after the α-scaled weight footprint:
    /// `(M_gpu / α − m1) / kv_bytes_factor` (negative when the weights alone
    /// do not fit). The budget is denominated in *unscaled* (baseline-width)
    /// KV bytes, so when the deployment stores its KV cache at a narrower
    /// width (kv_bytes_factor < 1, e.g. int8 KV = 0.5) the same physical
    /// headroom holds proportionally more unscaled bytes — ~2× batch
    /// capacity under KV-int8. The single source of the memory-budget
    /// formula shared by the feasibility checker, DFTSP's memory bound and
    /// the continuous-batching KV ledger.
    pub fn kv_budget_per_gpu(&self, cost: &CostModel, quant: &QuantSpec) -> f64 {
        (self.gpu.mem_bytes as f64 / quant.alpha - cost.weight_bytes() as f64)
            / quant.kv_bytes_factor()
    }

    /// Largest batch the cluster can hold in memory for a model+quant when
    /// every request carries `kv_bytes_per_req` of (unscaled) KV cache —
    /// the inverse of constraint (1c) used by static batching to pick its
    /// overflow-safe batch size.
    pub fn max_batch_by_memory(
        &self,
        cost: &CostModel,
        quant: &QuantSpec,
        kv_bytes_per_req: u64,
    ) -> usize {
        // Per GPU: α(m1 + per_gpu_batch · kv) ≤ M_gpu
        let kv = kv_bytes_per_req as f64;
        let per_gpu_budget = self.kv_budget_per_gpu(cost, quant);
        if per_gpu_budget <= 0.0 {
            return 0;
        }
        let per_gpu = (per_gpu_budget / kv).floor() as usize;
        per_gpu * self.num_gpus
    }

    /// Does a batch with total unscaled KV bytes `kv_total` fit? Batch is
    /// spread evenly over GPUs (ceil division for the worst-loaded GPU).
    pub fn batch_fits_memory(
        &self,
        cost: &CostModel,
        quant: &QuantSpec,
        kv_bytes_each: &[u64],
    ) -> bool {
        if kv_bytes_each.is_empty() {
            return true;
        }
        // Worst-case GPU holds ceil(batch/G) largest requests; with even
        // round-robin of sorted requests this bound is tight enough and
        // monotone (adding a request never makes it fit better).
        let per_gpu_budget = self.kv_budget_per_gpu(cost, quant);
        if per_gpu_budget <= 0.0 {
            return false;
        }
        let total_kv: u64 = kv_bytes_each.iter().sum();
        let max_kv: u64 = *kv_bytes_each.iter().max().unwrap();
        // Worst-loaded-GPU bound under greedy balanced placement: when the
        // batch fits one-per-GPU the worst GPU holds exactly max_kv; beyond
        // that we use the classic LPT makespan bound total/G + max, which is
        // conservative AND monotone in batch growth (required for pruning).
        let per_gpu_kv = if kv_bytes_each.len() <= self.num_gpus {
            max_kv as f64
        } else {
            total_kv as f64 / self.num_gpus as f64 + max_kv as f64
        };
        per_gpu_kv <= per_gpu_budget
    }
}

/// One shard's slice of a (possibly heterogeneous) edge fleet: the GPU
/// model its partition is built from and how many of those GPUs the shard's
/// *group* contributes to the pool. Shards sharing an identical [`GpuSpec`]
/// form a migration group — the sharded driver's between-epoch
/// re-partitioning moves headroom freely inside a group (the devices are
/// interchangeable) and never across groups (a TX2 cannot become an Orin).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardSpec {
    pub gpu: GpuSpec,
    /// GPUs this shard contributes to its migration group's pool. Explicit
    /// (TOML/builder) topologies require ≥ 1 ([`ClusterTopology::validate`]);
    /// the homogeneous shim may emit 0 for a shard when the pool is smaller
    /// than the shard count — the driver then reports `InsufficientGpus`.
    pub num_gpus: usize,
}

/// The typed shard-configuration surface: one [`ShardSpec`] per shard, in
/// shard order. This is the single source the CLI (`--shards`, `--topology`
/// via scenario files), scenario TOML (`[[cluster.shard]]` tables) and
/// [`DriverBuilder`](crate::driver::DriverBuilder) all reduce to — the
/// legacy `--shards N` / `[cluster] shards` knobs are documented shims that
/// expand to [`ClusterTopology::homogeneous`].
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterTopology {
    pub shards: Vec<ShardSpec>,
}

impl ClusterTopology {
    /// The legacy shim: `shards` identical partitions carved out of one
    /// homogeneous [`ClusterSpec`] pool. Per-shard counts are only group
    /// bookkeeping here (all shards share one migration group whose pool is
    /// `cluster.num_gpus`), so the near-equal split below is cosmetic — the
    /// driver's apportionment over the group total decides actual counts,
    /// exactly as the pre-topology code did. The split is *exact*: totals
    /// are never rounded up, so an undersized pool (fewer GPUs than shards)
    /// still surfaces as the driver's typed `InsufficientGpus` error rather
    /// than silently growing.
    pub fn homogeneous(cluster: ClusterSpec, shards: usize) -> Self {
        assert!(shards >= 1, "a topology needs at least one shard");
        let base = cluster.num_gpus / shards;
        let extra = cluster.num_gpus % shards;
        ClusterTopology {
            shards: (0..shards)
                .map(|i| ShardSpec {
                    gpu: cluster.gpu.clone(),
                    num_gpus: base + usize::from(i < extra),
                })
                .collect(),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Total GPUs across every shard (the whole pool).
    pub fn total_gpus(&self) -> usize {
        self.shards.iter().map(|s| s.num_gpus).sum()
    }

    /// Migration groups: shard indices partitioned by [`GpuSpec`] equality,
    /// in first-occurrence order, members in shard-index order. One group
    /// for a homogeneous topology — where group-wise apportionment reduces
    /// bit-for-bit to the single global apportionment.
    pub fn groups(&self) -> Vec<Vec<usize>> {
        let mut groups: Vec<(GpuSpec, Vec<usize>)> = Vec::new();
        for (i, s) in self.shards.iter().enumerate() {
            match groups.iter_mut().find(|(g, _)| *g == s.gpu) {
                Some((_, members)) => members.push(i),
                None => groups.push((s.gpu.clone(), vec![i])),
            }
        }
        groups.into_iter().map(|(_, m)| m).collect()
    }

    /// Structural validation shared by every entry point: at least one
    /// shard, and at least one GPU per shard entry.
    pub fn validate(&self) -> Result<(), String> {
        if self.shards.is_empty() {
            return Err("topology has no shards".into());
        }
        for (i, s) in self.shards.iter().enumerate() {
            if s.num_gpus == 0 {
                return Err(format!("topology shard {i} has zero GPUs"));
            }
            if !(s.gpu.flops.is_finite() && s.gpu.flops > 0.0) {
                return Err(format!("topology shard {i} has non-positive FLOPs"));
            }
            if s.gpu.mem_bytes == 0 {
                return Err(format!("topology shard {i} has zero memory"));
            }
        }
        Ok(())
    }
}

/// Per-GPU execution state for the NoB (no-batching) baseline: each GPU
/// accepts one request when idle.
#[derive(Debug, Clone)]
pub struct GpuPool {
    /// Completion time of the request each GPU is running (0 = idle).
    busy_until: Vec<f64>,
}

impl GpuPool {
    pub fn new(num_gpus: usize) -> Self {
        GpuPool {
            busy_until: vec![0.0; num_gpus],
        }
    }

    /// Index of an idle GPU at time `now`, if any.
    pub fn idle_gpu(&self, now: f64) -> Option<usize> {
        self.busy_until
            .iter()
            .position(|&t| t <= now + 1e-12)
    }

    /// Count of idle GPUs at `now`.
    pub fn idle_count(&self, now: f64) -> usize {
        self.busy_until.iter().filter(|&&t| t <= now + 1e-12).count()
    }

    /// Occupy a GPU until `until`.
    pub fn occupy(&mut self, gpu: usize, until: f64) {
        self.busy_until[gpu] = until;
    }

    /// Earliest time any GPU becomes idle.
    pub fn next_idle_at(&self) -> f64 {
        self.busy_until.iter().cloned().fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LlmSpec;
    use crate::quant;

    fn cluster() -> ClusterSpec {
        ClusterSpec::paper_default()
    }

    #[test]
    fn paper_cluster_aggregates() {
        let c = cluster();
        assert!((c.total_flops() - 20.0 * 1.33e12).abs() < 1.0);
        assert_eq!(c.total_mem_bytes(), 20 * 32 * (1 << 30));
    }

    #[test]
    fn max_batch_shrinks_with_model_size() {
        let c = cluster();
        let q = quant::default_quant();
        let small = CostModel::new(LlmSpec::bloom_3b());
        let big = CostModel::new(LlmSpec::opt_13b());
        let kv_small = small.kv_peak_bytes_per_req(512, 512);
        let kv_big = big.kv_peak_bytes_per_req(512, 512);
        assert!(
            c.max_batch_by_memory(&small, &q, kv_small)
                > c.max_batch_by_memory(&big, &q, kv_big)
        );
    }

    #[test]
    fn max_batch_grows_with_lower_precision() {
        let c = cluster();
        let cost = CostModel::new(LlmSpec::bloom_7b());
        let kv = cost.kv_peak_bytes_per_req(512, 512);
        let w8 = quant::by_label(quant::Precision::W8A16, quant::QuantAlgo::Gptq).unwrap();
        let w4 = quant::by_label(quant::Precision::W4A16, quant::QuantAlgo::Gptq).unwrap();
        assert!(c.max_batch_by_memory(&cost, &w4, kv) > c.max_batch_by_memory(&cost, &w8, kv));
    }

    #[test]
    fn kv_int8_doubles_memory_capacity() {
        // W8A8 vs W8A8KV8 share α, so the KV-bytes factor alone must double
        // the per-GPU KV budget and (floor effects aside) the batch bound.
        let c = cluster();
        let cost = CostModel::new(LlmSpec::bloom_7b());
        let kv = cost.kv_peak_bytes_per_req(512, 512);
        let base = quant::spec_for_label("W8A8/RTN").unwrap();
        let kv8 = quant::spec_for_label("W8A8KV8/RTN").unwrap();
        let b_base = c.kv_budget_per_gpu(&cost, &base);
        let b_kv8 = c.kv_budget_per_gpu(&cost, &kv8);
        assert!((b_kv8 - 2.0 * b_base).abs() < 1.0, "{b_kv8} vs 2×{b_base}");
        let m_base = c.max_batch_by_memory(&cost, &base, kv);
        let m_kv8 = c.max_batch_by_memory(&cost, &kv8, kv);
        assert!(m_kv8 > m_base, "{m_kv8} must beat {m_base}");
        assert!(m_kv8 >= 2 * m_base - c.num_gpus, "~2× up to per-GPU floors");
        // A uniform batch sized to just overflow the base worst-GPU bound
        // (total/G + max > budget) still fits under KV8's doubled budget.
        let g = c.num_gpus as f64;
        let n_over = (g * (b_base / kv as f64 - 1.0)).ceil() as usize + 1;
        let batch: Vec<u64> = vec![kv; n_over];
        assert!(!c.batch_fits_memory(&cost, &base, &batch));
        assert!(c.batch_fits_memory(&cost, &kv8, &batch));
    }

    #[test]
    fn model_too_big_for_gpu_gives_zero_batch() {
        // A model whose fp16 weights exceed per-GPU memory can't run at fp16.
        let c = ClusterSpec::new(
            GpuSpec {
                name: "tiny-gpu".into(),
                flops: 1e12,
                mem_bytes: 1 << 30, // 1 GiB
            },
            4,
        );
        let cost = CostModel::new(LlmSpec::opt_13b()); // ~26 GB fp16
        let q = quant::QuantSpec::fp16();
        assert_eq!(c.max_batch_by_memory(&cost, &q, 1 << 20), 0);
        assert!(!c.batch_fits_memory(&cost, &q, &[1 << 20]));
    }

    #[test]
    fn batch_fits_monotone() {
        let c = cluster();
        let cost = CostModel::new(LlmSpec::bloom_3b());
        let q = quant::default_quant();
        let kv = cost.kv_peak_bytes_per_req(512, 512);
        let mut batch = Vec::new();
        let mut prev_fit = true;
        for _ in 0..10_000 {
            batch.push(kv);
            let fit = c.batch_fits_memory(&cost, &q, &batch);
            // once it stops fitting it never fits again
            assert!(prev_fit || !fit);
            prev_fit = fit;
            if !fit {
                break;
            }
        }
        assert!(!prev_fit, "10k huge requests must eventually overflow");
    }

    #[test]
    fn homogeneous_topology_expands_the_shards_shim() {
        let t = ClusterTopology::homogeneous(ClusterSpec::paper_default(), 3);
        assert_eq!(t.shard_count(), 3);
        assert_eq!(t.total_gpus(), 20);
        assert_eq!(t.groups(), vec![vec![0, 1, 2]], "one migration group");
        assert!(t.validate().is_ok());
        // One shard = the unsharded pool.
        let one = ClusterTopology::homogeneous(ClusterSpec::paper_default(), 1);
        assert_eq!(one.shards[0].num_gpus, 20);
    }

    #[test]
    fn heterogeneous_topology_groups_by_gpu_spec() {
        let fast = GpuSpec {
            name: "orin".into(),
            flops: 5.32e12,
            mem_bytes: 64 * (1 << 30),
        };
        let t = ClusterTopology {
            shards: vec![
                ShardSpec {
                    gpu: fast.clone(),
                    num_gpus: 4,
                },
                ShardSpec {
                    gpu: GpuSpec::jetson_tx2(),
                    num_gpus: 10,
                },
                ShardSpec {
                    gpu: fast.clone(),
                    num_gpus: 2,
                },
            ],
        };
        assert_eq!(t.total_gpus(), 16);
        assert_eq!(t.groups(), vec![vec![0, 2], vec![1]]);
        assert!(t.validate().is_ok());
        // Zero-GPU and degenerate-spec entries are typed config errors.
        let mut bad = t.clone();
        bad.shards[1].num_gpus = 0;
        assert!(bad.validate().is_err());
        let mut bad = t.clone();
        bad.shards[0].gpu.flops = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = t;
        bad.shards[2].gpu.mem_bytes = 0;
        assert!(bad.validate().is_err());
        assert!(ClusterTopology { shards: vec![] }.validate().is_err());
    }

    #[test]
    fn gpu_pool_idle_tracking() {
        let mut p = GpuPool::new(2);
        assert_eq!(p.idle_count(0.0), 2);
        let g = p.idle_gpu(0.0).unwrap();
        p.occupy(g, 5.0);
        assert_eq!(p.idle_count(1.0), 1);
        p.occupy(p.idle_gpu(1.0).unwrap(), 3.0);
        assert_eq!(p.idle_count(1.0), 0);
        assert_eq!(p.next_idle_at(), 3.0);
        assert_eq!(p.idle_count(3.0), 1);
    }
}
