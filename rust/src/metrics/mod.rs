//! Serving metrics: counters, throughput accounting, latency distribution,
//! and the per-run report consumed by the simulator, the serving loop and
//! the benchmark harness.

use crate::coordinator::SearchStats;
use crate::util::fmt;
use crate::util::stats::{LatencyHistogram, OnlineStats};

/// Why a request left the system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Completed with end-to-end latency within τ_i.
    CompletedInDeadline,
    /// Completed but after its deadline (counts as a miss in Fig. 5 terms).
    CompletedLate,
    /// Dropped: could never meet its deadline (queue pressure) or was
    /// inadmissible under the deployed quantization.
    Dropped,
}

/// Aggregated run metrics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Metrics {
    pub offered: u64,
    pub scheduled: u64,
    pub completed_in_deadline: u64,
    pub completed_late: u64,
    pub dropped: u64,
    /// End-to-end latency of in-deadline completions.
    pub latency: LatencyHistogram,
    /// Batch sizes of non-empty schedules.
    pub batch_sizes: OnlineStats,
    /// Queue length observed at each epoch boundary.
    pub queue_depth: OnlineStats,
    /// Accumulated search-effort statistics, including total scheduler wall
    /// time (`SearchStats::schedule_wall_s`, stamped by the epoch driver).
    pub search: SearchStats,
    /// Number of `Scheduler::schedule` invocations (including ones that
    /// returned an empty batch) — the denominator for per-call wall time.
    pub schedule_calls: u64,
    /// Epochs whose own work (scheduling + execution) exceeded the epoch
    /// duration, forcing the wall clock to start the next epoch late instead
    /// of sleeping. Always 0 under the simulated clock.
    pub epoch_overruns: u64,
    /// Simulated (or wall) time covered by this run, in seconds.
    pub horizon: f64,
    /// Arrival → admission-into-the-running-batch latency. Only continuous
    /// backends record this (epoch-mode admission *is* the schedule barrier,
    /// and the epoch analytic path stays bit-identical to the frozen
    /// pre-refactor loop in `tests/driver_parity.rs`).
    pub admission_latency: OnlineStats,
    /// In-flight batch size observed at each decode step (continuous
    /// backends only).
    pub inflight_occupancy: OnlineStats,
    /// Requests shed with a typed `overloaded` rejection: at the TCP
    /// ingress gate before reaching the server (never counted `offered`),
    /// or by the driver's degradation ladder under sustained epoch stalls
    /// (already `offered`; the shed also records a `Dropped` outcome, so
    /// conservation closes either way).
    pub shed_overloaded: u64,
    /// Connections rejected at accept with a typed `per_peer_limit` reply
    /// because their remote IP was already at `--max-conns-per-peer`.
    /// Counted per connection (the request line is never read), unlike
    /// `shed_overloaded`, which counts requests.
    pub shed_per_peer: u64,
    /// Malformed wire requests answered with a typed `bad_request` reply.
    pub bad_requests: u64,
    /// Transient accept-loop errors survived by backoff-and-retry (the
    /// pre-hardening loop died on the first of these).
    pub accept_errors: u64,
    /// Requests whose reply wait expired at the front-end (typed `timeout`
    /// replies; the server may still finish them, but the client is gone).
    pub net_timeouts: u64,
    /// Requests whose reply channel dropped unanswered — the shard crashed
    /// with the request in flight; the client got a typed `shard_failed`
    /// reply. The client-visible twin of the servers' `shard_failed`
    /// (which already counts the lost request via the conservation
    /// subtraction), so the two are never summed into one number.
    pub net_shard_failures: u64,
    /// TCP connections accepted by the front-end.
    pub net_connections: u64,
    /// Front-end wire latency: request line parsed → reply line written,
    /// recorded for every completed (in-deadline or late) request. Distinct
    /// from `latency`, which the driver records for in-deadline completions
    /// only; mergeable across shards/listeners like every histogram here.
    pub wire_latency: LatencyHistogram,
    /// Shard panics caught by a supervisor (`ShardedDriver` supervision or
    /// `serve_sharded`'s per-shard restart loop).
    pub shard_crashes: u64,
    /// Successful shard restarts (fresh driver/backend after a caught
    /// panic; a parked shard never counts another restart).
    pub shard_restarts: u64,
    /// Queued-but-not-admitted requests moved off a crashed shard onto a
    /// surviving same-deployment shard. Redispatched requests are counted in
    /// `offered` exactly once (the crashed shard's count is decremented when
    /// the survivor's is incremented).
    pub requests_redispatched: u64,
    /// Requests that lost their shard mid-flight: offered but terminated by
    /// a crash instead of an outcome. Closes the conservation identity
    /// `offered == completed_in_deadline + completed_late + dropped +
    /// shard_failed` through crashes.
    pub shard_failed: u64,
    /// `step_epoch` invocations whose wall time exceeded the configured
    /// epoch duration (the epoch watchdog; feeds the degradation ladder).
    /// Wall-dependent, so excluded from bit-determinism claims — always 0
    /// under the simulated clock.
    pub epoch_stalls: u64,
    /// Shards parked by the crash-loop circuit breaker (crashed again
    /// immediately after too many consecutive restarts).
    pub shards_parked: u64,
    /// Queued-but-not-admitted requests pulled by an underloaded shard from
    /// an overloaded same-deployment shard (elastic work stealing). Stolen
    /// requests are counted in `offered` exactly once — the donor's count is
    /// decremented when the thief's is incremented — so the conservation
    /// identity is untouched.
    pub requests_stolen: u64,
    /// Shards spun up by the between-epoch autoscaler.
    pub shards_spawned: u64,
    /// Empty shards drained and retired by the autoscaler (KV-safe: a shard
    /// only retires with an empty queue and an idle backend).
    pub shards_retired: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            latency: LatencyHistogram::new(),
            wire_latency: LatencyHistogram::new(),
            ..Default::default()
        }
    }

    pub fn record_offered(&mut self, n: u64) {
        self.offered += n;
    }

    /// A request joined the running batch `latency` seconds after arriving.
    pub fn record_admission(&mut self, latency: f64) {
        self.admission_latency.push(latency.max(0.0));
    }

    /// One decode step ran with `n` requests in flight.
    pub fn record_step_occupancy(&mut self, n: usize) {
        self.inflight_occupancy.push(n as f64);
    }

    /// Mean arrival → service-start waiting time (NaN when nothing was
    /// admitted through a continuous backend).
    pub fn mean_admission_latency(&self) -> f64 {
        self.admission_latency.mean()
    }

    pub fn record_outcome(&mut self, outcome: Outcome, latency: f64) {
        match outcome {
            Outcome::CompletedInDeadline => {
                self.completed_in_deadline += 1;
                self.latency.record(latency);
            }
            Outcome::CompletedLate => self.completed_late += 1,
            Outcome::Dropped => self.dropped += 1,
        }
    }

    pub fn record_schedule(&mut self, batch_size: usize, stats: &SearchStats) {
        if batch_size > 0 {
            self.scheduled += batch_size as u64;
            self.batch_sizes.push(batch_size as f64);
        }
        self.schedule_calls += 1;
        self.search.merge(stats);
    }

    /// Fold another run's (or shard's) metrics into this one — the
    /// cross-shard aggregation the sharded driver reports merged results
    /// through. Every counter is an exact integer (or an exactly-mergeable
    /// accumulator: Welford moments, histogram buckets), so merging N
    /// per-shard metrics in shard-index order is deterministic and the
    /// merged totals equal the per-shard sums bit-exactly
    /// (`tests/proptest_sharded.rs`). `horizon` takes the max, not the sum:
    /// shards cover the same wall span concurrently, so summing would
    /// deflate merged throughput by the shard count.
    pub fn merge(&mut self, other: &Metrics) {
        self.offered += other.offered;
        self.scheduled += other.scheduled;
        self.completed_in_deadline += other.completed_in_deadline;
        self.completed_late += other.completed_late;
        self.dropped += other.dropped;
        self.latency.merge(&other.latency);
        self.batch_sizes.merge(&other.batch_sizes);
        self.queue_depth.merge(&other.queue_depth);
        self.search.merge(&other.search);
        self.schedule_calls += other.schedule_calls;
        self.epoch_overruns += other.epoch_overruns;
        self.horizon = self.horizon.max(other.horizon);
        self.admission_latency.merge(&other.admission_latency);
        self.inflight_occupancy.merge(&other.inflight_occupancy);
        self.shed_overloaded += other.shed_overloaded;
        self.shed_per_peer += other.shed_per_peer;
        self.bad_requests += other.bad_requests;
        self.accept_errors += other.accept_errors;
        self.net_timeouts += other.net_timeouts;
        self.net_shard_failures += other.net_shard_failures;
        self.net_connections += other.net_connections;
        self.wire_latency.merge(&other.wire_latency);
        self.shard_crashes += other.shard_crashes;
        self.shard_restarts += other.shard_restarts;
        self.requests_redispatched += other.requests_redispatched;
        self.shard_failed += other.shard_failed;
        self.epoch_stalls += other.epoch_stalls;
        self.shards_parked += other.shards_parked;
        self.requests_stolen += other.requests_stolen;
        self.shards_spawned += other.shards_spawned;
        self.shards_retired += other.shards_retired;
    }

    /// Mean scheduler wall time per `schedule` call in seconds (0 when the
    /// driver never invoked a scheduler).
    pub fn mean_schedule_wall_s(&self) -> f64 {
        if self.schedule_calls == 0 {
            return 0.0;
        }
        self.search.schedule_wall_s / self.schedule_calls as f64
    }

    /// The paper's headline metric: successfully served requests per second.
    pub fn throughput(&self) -> f64 {
        if self.horizon <= 0.0 {
            return 0.0;
        }
        self.completed_in_deadline as f64 / self.horizon
    }

    /// Fraction of offered requests served within deadline.
    pub fn goodput_ratio(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        self.completed_in_deadline as f64 / self.offered as f64
    }

    /// Flat JSON view of the run — the golden-test serialization
    /// (`rust/tests/golden/`). Every field is a number so fixtures can be
    /// compared field-by-field with a tolerance.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let num = Json::Num;
        let finite = |x: f64| if x.is_finite() { x } else { 0.0 };
        Json::obj(vec![
            ("offered", num(self.offered as f64)),
            ("scheduled", num(self.scheduled as f64)),
            ("completed_in_deadline", num(self.completed_in_deadline as f64)),
            ("completed_late", num(self.completed_late as f64)),
            ("dropped", num(self.dropped as f64)),
            ("throughput", num(finite(self.throughput()))),
            ("goodput_ratio", num(finite(self.goodput_ratio()))),
            ("latency_count", num(self.latency.count() as f64)),
            ("latency_mean", num(finite(self.latency.mean()))),
            ("latency_p50", num(finite(self.latency.quantile(0.50)))),
            ("latency_p95", num(finite(self.latency.quantile(0.95)))),
            ("latency_p99", num(finite(self.latency.quantile(0.99)))),
            ("latency_p999", num(finite(self.latency.quantile(0.999)))),
            ("latency_max", num(finite(self.latency.max()))),
            ("shed_overloaded", num(self.shed_overloaded as f64)),
            ("shed_per_peer", num(self.shed_per_peer as f64)),
            ("bad_requests", num(self.bad_requests as f64)),
            ("accept_errors", num(self.accept_errors as f64)),
            ("net_timeouts", num(self.net_timeouts as f64)),
            ("net_shard_failures", num(self.net_shard_failures as f64)),
            ("net_connections", num(self.net_connections as f64)),
            ("wire_latency_count", num(self.wire_latency.count() as f64)),
            ("wire_latency_p50", num(finite(self.wire_latency.quantile(0.50)))),
            ("wire_latency_p95", num(finite(self.wire_latency.quantile(0.95)))),
            ("wire_latency_p99", num(finite(self.wire_latency.quantile(0.99)))),
            ("wire_latency_p999", num(finite(self.wire_latency.quantile(0.999)))),
            ("batch_size_mean", num(finite(self.batch_sizes.mean()))),
            ("queue_depth_mean", num(finite(self.queue_depth.mean()))),
            ("admission_count", num(self.admission_latency.count() as f64)),
            ("admission_mean", num(finite(self.admission_latency.mean()))),
            ("occupancy_mean", num(finite(self.inflight_occupancy.mean()))),
            ("nodes_visited", num(self.search.nodes_visited as f64)),
            ("solutions_checked", num(self.search.solutions_checked as f64)),
            ("leaf_check_work", num(self.search.leaf_check_work as f64)),
            ("pruned_capacity", num(self.search.pruned_capacity as f64)),
            ("pruned_constraint", num(self.search.pruned_constraint as f64)),
            ("pruned_reuse", num(self.search.pruned_reuse as f64)),
            ("z_levels_skipped", num(self.search.z_levels_skipped as f64)),
            ("subproblems", num(self.search.subproblems as f64)),
            ("schedule_calls", num(self.schedule_calls as f64)),
            // Wall-clock, not bit-deterministic: the golden-fixture compare
            // (tests/golden_metrics.rs) skips this key.
            ("schedule_wall_s", num(finite(self.search.schedule_wall_s))),
            ("epoch_overruns", num(self.epoch_overruns as f64)),
            ("shard_crashes", num(self.shard_crashes as f64)),
            ("shard_restarts", num(self.shard_restarts as f64)),
            ("requests_redispatched", num(self.requests_redispatched as f64)),
            ("shard_failed", num(self.shard_failed as f64)),
            // Wall-dependent like schedule_wall_s: the watchdog compares
            // real elapsed time against the epoch duration.
            ("epoch_stalls", num(self.epoch_stalls as f64)),
            ("shards_parked", num(self.shards_parked as f64)),
            ("requests_stolen", num(self.requests_stolen as f64)),
            ("shards_spawned", num(self.shards_spawned as f64)),
            ("shards_retired", num(self.shards_retired as f64)),
            ("horizon", num(self.horizon)),
        ])
    }

    /// Multi-line human-readable report.
    pub fn report(&self, label: &str) -> String {
        let mut s = String::new();
        s.push_str(&format!("== {label} ==\n"));
        s.push_str(&format!(
            "offered {}  scheduled {}  in-deadline {}  late {}  dropped {}\n",
            self.offered, self.scheduled, self.completed_in_deadline, self.completed_late, self.dropped
        ));
        s.push_str(&format!(
            "throughput {:.2} req/s  goodput {:.1}%  mean batch {:.1}  mean queue {:.1}\n",
            self.throughput(),
            100.0 * self.goodput_ratio(),
            self.batch_sizes.mean(),
            self.queue_depth.mean(),
        ));
        if self.admission_latency.count() > 0 {
            s.push_str(&format!(
                "admission latency mean {:.3} s  in-flight occupancy mean {:.1}\n",
                self.admission_latency.mean(),
                self.inflight_occupancy.mean(),
            ));
        }
        if self.epoch_overruns > 0 {
            s.push_str(&format!(
                "epoch overruns {} (epochs whose work exceeded the epoch duration)\n",
                self.epoch_overruns
            ));
        }
        if self.net_connections > 0 || self.shed_overloaded > 0 || self.bad_requests > 0 {
            s.push_str(&format!(
                "net: {} connections  shed {}  per-peer shed {}  bad requests {}  timeouts {}  shard failures {}  accept retries {}\n",
                self.net_connections,
                self.shed_overloaded,
                self.shed_per_peer,
                self.bad_requests,
                self.net_timeouts,
                self.net_shard_failures,
                self.accept_errors,
            ));
        }
        if self.shard_crashes > 0 || self.shards_parked > 0 || self.epoch_stalls > 0 {
            s.push_str(&format!(
                "faults: {} crashes  {} restarts  {} redispatched  {} shard-failed  {} stalls  {} parked\n",
                self.shard_crashes,
                self.shard_restarts,
                self.requests_redispatched,
                self.shard_failed,
                self.epoch_stalls,
                self.shards_parked,
            ));
        }
        if self.requests_stolen > 0 || self.shards_spawned > 0 || self.shards_retired > 0 {
            s.push_str(&format!(
                "elastic: {} stolen  {} shards spawned  {} shards retired\n",
                self.requests_stolen, self.shards_spawned, self.shards_retired,
            ));
        }
        if self.wire_latency.count() > 0 {
            s.push_str(&format!(
                "wire latency p50 {}  p95 {}  p99 {}  p999 {}  max {}\n",
                fmt::duration(self.wire_latency.quantile(0.50)),
                fmt::duration(self.wire_latency.quantile(0.95)),
                fmt::duration(self.wire_latency.quantile(0.99)),
                fmt::duration(self.wire_latency.quantile(0.999)),
                fmt::duration(self.wire_latency.max()),
            ));
        }
        if self.latency.count() > 0 {
            s.push_str(&format!(
                "latency p50 {}  p95 {}  p99 {}  p999 {}  max {}\n",
                fmt::duration(self.latency.quantile(0.50)),
                fmt::duration(self.latency.quantile(0.95)),
                fmt::duration(self.latency.quantile(0.99)),
                fmt::duration(self.latency.quantile(0.999)),
                fmt::duration(self.latency.max()),
            ));
        }
        if self.search.nodes_visited > 0 {
            s.push_str(&format!(
                "search: {} nodes, {} solutions checked, {} capacity-pruned, {} constraint-pruned{}, schedule wall {}\n",
                self.search.nodes_visited,
                self.search.solutions_checked,
                self.search.pruned_capacity,
                self.search.pruned_constraint,
                if self.search.budget_exhausted {
                    " (budget exhausted)"
                } else {
                    ""
                },
                fmt::duration(self.search.schedule_wall_s),
            ));
        }
        s
    }

    /// Detailed scheduler-observability block (the CLI's `--stats` view):
    /// every search-effort counter plus total and per-call schedule wall
    /// time, so perf work on the DFTSP hot path is measurable straight from
    /// the binary.
    pub fn search_report(&self) -> String {
        let s = &self.search;
        let mut out = String::from("== scheduler search stats ==\n");
        out.push_str(&format!(
            "schedule calls {}  wall total {}  wall mean/call {}\n",
            self.schedule_calls,
            fmt::duration(s.schedule_wall_s),
            fmt::duration(self.mean_schedule_wall_s()),
        ));
        out.push_str(&format!(
            "nodes {}  leaves checked {}  leaf-check work {}  subproblems {}\n",
            s.nodes_visited, s.solutions_checked, s.leaf_check_work, s.subproblems,
        ));
        out.push_str(&format!(
            "pruned: capacity {}  constraint {}  reuse {}  z-levels skipped {}{}\n",
            s.pruned_capacity,
            s.pruned_constraint,
            s.pruned_reuse,
            s.z_levels_skipped,
            if s.budget_exhausted {
                "  (budget exhausted)"
            } else {
                ""
            },
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcomes_accumulate() {
        let mut m = Metrics::new();
        m.record_offered(10);
        m.record_outcome(Outcome::CompletedInDeadline, 0.8);
        m.record_outcome(Outcome::CompletedInDeadline, 1.2);
        m.record_outcome(Outcome::CompletedLate, 2.5);
        m.record_outcome(Outcome::Dropped, 0.0);
        m.horizon = 2.0;
        assert_eq!(m.completed_in_deadline, 2);
        assert_eq!(m.completed_late, 1);
        assert_eq!(m.dropped, 1);
        assert!((m.throughput() - 1.0).abs() < 1e-12);
        assert!((m.goodput_ratio() - 0.2).abs() < 1e-12);
        assert_eq!(m.latency.count(), 2);
    }

    #[test]
    fn schedule_stats_merge() {
        let mut m = Metrics::new();
        let s1 = SearchStats {
            nodes_visited: 10,
            subproblems: 2,
            ..Default::default()
        };
        let s2 = SearchStats {
            nodes_visited: 5,
            budget_exhausted: true,
            ..Default::default()
        };
        m.record_schedule(4, &s1);
        m.record_schedule(0, &s2);
        assert_eq!(m.scheduled, 4);
        assert_eq!(m.search.nodes_visited, 15);
        assert!(m.search.budget_exhausted);
        assert_eq!(m.batch_sizes.count(), 1); // empty schedule not counted
        assert_eq!(m.schedule_calls, 2); // but it still counts as a call
    }

    #[test]
    fn schedule_wall_time_accumulates() {
        let mut m = Metrics::new();
        let mut s = SearchStats {
            nodes_visited: 3,
            schedule_wall_s: 0.25,
            ..Default::default()
        };
        m.record_schedule(2, &s);
        s.schedule_wall_s = 0.75;
        m.record_schedule(1, &s);
        assert!((m.search.schedule_wall_s - 1.0).abs() < 1e-12);
        assert!((m.mean_schedule_wall_s() - 0.5).abs() < 1e-12);
        let r = m.search_report();
        assert!(r.contains("schedule calls 2"));
        assert!(r.contains("wall"));
        assert!(r.contains("pruned"));
        // Wall time is diagnostics, not identity: two runs differing only in
        // wall time compare equal (driver-parity / determinism contract).
        let mut a = SearchStats::default();
        let b = SearchStats {
            schedule_wall_s: 123.0,
            ..Default::default()
        };
        a.schedule_wall_s = 4.0;
        assert_eq!(a, b);
    }

    #[test]
    fn report_contains_key_fields() {
        let mut m = Metrics::new();
        m.record_offered(3);
        m.record_outcome(Outcome::CompletedInDeadline, 1.0);
        m.horizon = 1.0;
        let r = m.report("unit");
        assert!(r.contains("unit"));
        assert!(r.contains("throughput"));
        assert!(r.contains("p95"));
    }

    #[test]
    fn merge_sums_counters_and_maxes_horizon() {
        let mut a = Metrics::new();
        a.record_offered(3);
        a.record_outcome(Outcome::CompletedInDeadline, 1.0);
        a.record_outcome(Outcome::Dropped, 0.0);
        a.record_schedule(2, &SearchStats { nodes_visited: 7, ..Default::default() });
        a.horizon = 10.0;
        let mut b = Metrics::new();
        b.record_offered(2);
        b.record_outcome(Outcome::CompletedLate, 3.0);
        b.record_schedule(1, &SearchStats { nodes_visited: 5, ..Default::default() });
        b.record_admission(0.5);
        b.horizon = 10.0;
        a.merge(&b);
        assert_eq!(a.offered, 5);
        assert_eq!(a.scheduled, 3);
        assert_eq!(a.completed_in_deadline, 1);
        assert_eq!(a.completed_late, 1);
        assert_eq!(a.dropped, 1);
        assert_eq!(a.search.nodes_visited, 12);
        assert_eq!(a.schedule_calls, 2);
        assert_eq!(a.latency.count(), 1);
        assert_eq!(a.admission_latency.count(), 1);
        // Concurrent shards cover the same span: horizon is the max.
        assert!((a.horizon - 10.0).abs() < 1e-12);
        assert!((a.throughput() - 0.1).abs() < 1e-12);
        // Merging an empty Metrics is the identity.
        let snapshot = a.clone();
        a.merge(&Metrics::new());
        assert_eq!(a, snapshot);
    }

    #[test]
    fn net_counters_merge_and_serialize() {
        let mut a = Metrics::new();
        a.shed_overloaded = 3;
        a.shed_per_peer = 2;
        a.bad_requests = 2;
        a.net_connections = 10;
        a.wire_latency.record(0.010);
        let mut b = Metrics::new();
        b.shed_overloaded = 1;
        b.shed_per_peer = 1;
        b.accept_errors = 4;
        b.net_timeouts = 2;
        b.net_connections = 5;
        b.wire_latency.record(0.020);
        a.merge(&b);
        assert_eq!(a.shed_overloaded, 4);
        assert_eq!(a.shed_per_peer, 3);
        assert_eq!(a.bad_requests, 2);
        assert_eq!(a.accept_errors, 4);
        assert_eq!(a.net_timeouts, 2);
        assert_eq!(a.net_connections, 15);
        assert_eq!(a.wire_latency.count(), 2);
        let j = a.to_json();
        assert_eq!(j.req_f64("shed_overloaded").unwrap(), 4.0);
        assert_eq!(j.req_f64("shed_per_peer").unwrap(), 3.0);
        assert_eq!(j.req_f64("net_connections").unwrap(), 15.0);
        assert_eq!(j.req_f64("wire_latency_count").unwrap(), 2.0);
        assert!(j.req_f64("wire_latency_p99").unwrap() > 0.0);
        // The tail quantile is monotone in the quantile level.
        assert!(
            j.req_f64("wire_latency_p999").unwrap() >= j.req_f64("wire_latency_p99").unwrap()
        );
        assert!(j.req_f64("latency_p99").unwrap() == 0.0, "no driver latency recorded");
        assert!(j.req_f64("latency_p999").unwrap() == 0.0);
        let r = a.report("net");
        assert!(r.contains("shed 4"));
        assert!(r.contains("per-peer shed 3"));
        assert!(r.contains("p999"));
        assert!(r.contains("wire latency"));
        // Merging an empty Metrics stays the identity with net counters too.
        let snapshot = a.clone();
        a.merge(&Metrics::new());
        assert_eq!(a, snapshot);
    }

    #[test]
    fn fault_counters_merge_and_serialize() {
        let mut a = Metrics::new();
        a.shard_crashes = 2;
        a.shard_restarts = 2;
        a.requests_redispatched = 5;
        a.shard_failed = 3;
        let mut b = Metrics::new();
        b.shard_crashes = 1;
        b.epoch_stalls = 4;
        b.shards_parked = 1;
        a.merge(&b);
        assert_eq!(a.shard_crashes, 3);
        assert_eq!(a.shard_restarts, 2);
        assert_eq!(a.requests_redispatched, 5);
        assert_eq!(a.shard_failed, 3);
        assert_eq!(a.epoch_stalls, 4);
        assert_eq!(a.shards_parked, 1);
        let j = a.to_json();
        assert_eq!(j.req_f64("shard_crashes").unwrap(), 3.0);
        assert_eq!(j.req_f64("shard_restarts").unwrap(), 2.0);
        assert_eq!(j.req_f64("requests_redispatched").unwrap(), 5.0);
        assert_eq!(j.req_f64("shard_failed").unwrap(), 3.0);
        assert_eq!(j.req_f64("epoch_stalls").unwrap(), 4.0);
        assert_eq!(j.req_f64("shards_parked").unwrap(), 1.0);
        let r = a.report("faulty");
        assert!(r.contains("3 crashes"));
        assert!(r.contains("1 parked"));
        // A clean run prints no fault line at all.
        assert!(!Metrics::new().report("clean").contains("faults:"));
        // Merging an empty Metrics stays the identity with fault counters.
        let snapshot = a.clone();
        a.merge(&Metrics::new());
        assert_eq!(a, snapshot);
    }

    #[test]
    fn elastic_counters_merge_and_serialize() {
        let mut a = Metrics::new();
        a.requests_stolen = 4;
        a.shards_spawned = 2;
        let mut b = Metrics::new();
        b.requests_stolen = 3;
        b.shards_retired = 1;
        a.merge(&b);
        assert_eq!(a.requests_stolen, 7);
        assert_eq!(a.shards_spawned, 2);
        assert_eq!(a.shards_retired, 1);
        let j = a.to_json();
        assert_eq!(j.req_f64("requests_stolen").unwrap(), 7.0);
        assert_eq!(j.req_f64("shards_spawned").unwrap(), 2.0);
        assert_eq!(j.req_f64("shards_retired").unwrap(), 1.0);
        let r = a.report("elastic");
        assert!(r.contains("7 stolen"));
        assert!(r.contains("2 shards spawned"));
        // A static run prints no elastic line at all.
        assert!(!Metrics::new().report("static").contains("elastic:"));
        let snapshot = a.clone();
        a.merge(&Metrics::new());
        assert_eq!(a, snapshot);
    }

    #[test]
    fn zero_division_safe() {
        let m = Metrics::new();
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.goodput_ratio(), 0.0);
    }

    #[test]
    fn admission_and_occupancy_accumulate() {
        let mut m = Metrics::new();
        m.record_admission(0.5);
        m.record_admission(-0.1); // clock skew clamps to 0
        m.record_step_occupancy(3);
        m.record_step_occupancy(5);
        assert_eq!(m.admission_latency.count(), 2);
        assert!((m.mean_admission_latency() - 0.25).abs() < 1e-12);
        assert!((m.inflight_occupancy.mean() - 4.0).abs() < 1e-12);
        let r = m.report("cont");
        assert!(r.contains("admission latency"));
    }

    #[test]
    fn json_export_covers_counters() {
        let mut m = Metrics::new();
        m.record_offered(4);
        m.record_outcome(Outcome::CompletedInDeadline, 1.0);
        m.record_outcome(Outcome::Dropped, 0.0);
        m.horizon = 2.0;
        let j = m.to_json();
        assert_eq!(j.req_f64("offered").unwrap(), 4.0);
        assert_eq!(j.req_f64("completed_in_deadline").unwrap(), 1.0);
        assert_eq!(j.req_f64("dropped").unwrap(), 1.0);
        assert!((j.req_f64("throughput").unwrap() - 0.5).abs() < 1e-12);
        // NaN-producing empty stats serialize as finite zeros.
        assert_eq!(j.req_f64("admission_mean").unwrap(), 0.0);
        // The string round-trips through the parser (fixture format).
        let back = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.req_f64("horizon").unwrap(), 2.0);
    }
}
