//! Serving metrics: counters, throughput accounting, latency distribution,
//! and the per-run report consumed by the simulator, the serving loop and
//! the benchmark harness.

use crate::coordinator::SearchStats;
use crate::util::fmt;
use crate::util::stats::{LatencyHistogram, OnlineStats};

/// Why a request left the system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Completed with end-to-end latency within τ_i.
    CompletedInDeadline,
    /// Completed but after its deadline (counts as a miss in Fig. 5 terms).
    CompletedLate,
    /// Dropped: could never meet its deadline (queue pressure) or was
    /// inadmissible under the deployed quantization.
    Dropped,
}

/// Aggregated run metrics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Metrics {
    pub offered: u64,
    pub scheduled: u64,
    pub completed_in_deadline: u64,
    pub completed_late: u64,
    pub dropped: u64,
    /// End-to-end latency of in-deadline completions.
    pub latency: LatencyHistogram,
    /// Batch sizes of non-empty schedules.
    pub batch_sizes: OnlineStats,
    /// Queue length observed at each epoch boundary.
    pub queue_depth: OnlineStats,
    /// Accumulated search-effort statistics.
    pub search: SearchStats,
    /// Epochs whose own work (scheduling + execution) exceeded the epoch
    /// duration, forcing the wall clock to start the next epoch late instead
    /// of sleeping. Always 0 under the simulated clock.
    pub epoch_overruns: u64,
    /// Simulated (or wall) time covered by this run, in seconds.
    pub horizon: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            latency: LatencyHistogram::new(),
            ..Default::default()
        }
    }

    pub fn record_offered(&mut self, n: u64) {
        self.offered += n;
    }

    pub fn record_outcome(&mut self, outcome: Outcome, latency: f64) {
        match outcome {
            Outcome::CompletedInDeadline => {
                self.completed_in_deadline += 1;
                self.latency.record(latency);
            }
            Outcome::CompletedLate => self.completed_late += 1,
            Outcome::Dropped => self.dropped += 1,
        }
    }

    pub fn record_schedule(&mut self, batch_size: usize, stats: &SearchStats) {
        if batch_size > 0 {
            self.scheduled += batch_size as u64;
            self.batch_sizes.push(batch_size as f64);
        }
        self.search.nodes_visited += stats.nodes_visited;
        self.search.solutions_checked += stats.solutions_checked;
        self.search.pruned_capacity += stats.pruned_capacity;
        self.search.pruned_constraint += stats.pruned_constraint;
        self.search.subproblems += stats.subproblems;
        self.search.budget_exhausted |= stats.budget_exhausted;
    }

    /// The paper's headline metric: successfully served requests per second.
    pub fn throughput(&self) -> f64 {
        if self.horizon <= 0.0 {
            return 0.0;
        }
        self.completed_in_deadline as f64 / self.horizon
    }

    /// Fraction of offered requests served within deadline.
    pub fn goodput_ratio(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        self.completed_in_deadline as f64 / self.offered as f64
    }

    /// Multi-line human-readable report.
    pub fn report(&self, label: &str) -> String {
        let mut s = String::new();
        s.push_str(&format!("== {label} ==\n"));
        s.push_str(&format!(
            "offered {}  scheduled {}  in-deadline {}  late {}  dropped {}\n",
            self.offered, self.scheduled, self.completed_in_deadline, self.completed_late, self.dropped
        ));
        s.push_str(&format!(
            "throughput {:.2} req/s  goodput {:.1}%  mean batch {:.1}  mean queue {:.1}\n",
            self.throughput(),
            100.0 * self.goodput_ratio(),
            self.batch_sizes.mean(),
            self.queue_depth.mean(),
        ));
        if self.epoch_overruns > 0 {
            s.push_str(&format!(
                "epoch overruns {} (epochs whose work exceeded the epoch duration)\n",
                self.epoch_overruns
            ));
        }
        if self.latency.count() > 0 {
            s.push_str(&format!(
                "latency p50 {}  p95 {}  p99 {}  max {}\n",
                fmt::duration(self.latency.quantile(0.50)),
                fmt::duration(self.latency.quantile(0.95)),
                fmt::duration(self.latency.quantile(0.99)),
                fmt::duration(self.latency.max()),
            ));
        }
        if self.search.nodes_visited > 0 {
            s.push_str(&format!(
                "search: {} nodes, {} solutions checked, {} capacity-pruned, {} constraint-pruned{}\n",
                self.search.nodes_visited,
                self.search.solutions_checked,
                self.search.pruned_capacity,
                self.search.pruned_constraint,
                if self.search.budget_exhausted {
                    " (budget exhausted)"
                } else {
                    ""
                }
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcomes_accumulate() {
        let mut m = Metrics::new();
        m.record_offered(10);
        m.record_outcome(Outcome::CompletedInDeadline, 0.8);
        m.record_outcome(Outcome::CompletedInDeadline, 1.2);
        m.record_outcome(Outcome::CompletedLate, 2.5);
        m.record_outcome(Outcome::Dropped, 0.0);
        m.horizon = 2.0;
        assert_eq!(m.completed_in_deadline, 2);
        assert_eq!(m.completed_late, 1);
        assert_eq!(m.dropped, 1);
        assert!((m.throughput() - 1.0).abs() < 1e-12);
        assert!((m.goodput_ratio() - 0.2).abs() < 1e-12);
        assert_eq!(m.latency.count(), 2);
    }

    #[test]
    fn schedule_stats_merge() {
        let mut m = Metrics::new();
        let s1 = SearchStats {
            nodes_visited: 10,
            subproblems: 2,
            ..Default::default()
        };
        let s2 = SearchStats {
            nodes_visited: 5,
            budget_exhausted: true,
            ..Default::default()
        };
        m.record_schedule(4, &s1);
        m.record_schedule(0, &s2);
        assert_eq!(m.scheduled, 4);
        assert_eq!(m.search.nodes_visited, 15);
        assert!(m.search.budget_exhausted);
        assert_eq!(m.batch_sizes.count(), 1); // empty schedule not counted
    }

    #[test]
    fn report_contains_key_fields() {
        let mut m = Metrics::new();
        m.record_offered(3);
        m.record_outcome(Outcome::CompletedInDeadline, 1.0);
        m.horizon = 1.0;
        let r = m.report("unit");
        assert!(r.contains("unit"));
        assert!(r.contains("throughput"));
        assert!(r.contains("p95"));
    }

    #[test]
    fn zero_division_safe() {
        let m = Metrics::new();
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.goodput_ratio(), 0.0);
    }
}
