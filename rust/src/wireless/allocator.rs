//! Joint bandwidth allocation — the "resource allocation" half of the
//! paper's contribution (P1 allocates ρᵢ ≥ ρ_min; the objective only needs
//! ρ_min, but an operator should hand the *surplus* back to users).
//!
//! After the batch is selected, the unallocated fraction of each band is
//! distributed to the scheduled users. Two policies:
//!
//! - `Proportional`: surplus split ∝ ρ_min (equalizes relative headroom, so
//!   every user's transfer finishes at the same fraction of the slot);
//! - `MaxMin`: water-filling toward equal absolute fractions (helps the
//!   worst-channel users most).
//!
//! Shorter actual upload times translate into extra compute slack; the
//! simulator and serving loop use the effective upload time to tighten
//! constraint (1d) beyond the conservative T_U bound.

use crate::request::{EpochRequest, RequestId};
use crate::wireless::RadioParams;

/// Surplus-distribution policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocationPolicy {
    /// Everyone keeps exactly ρ_min (the P1 baseline).
    MinOnly,
    /// Surplus ∝ ρ_min.
    Proportional,
    /// Water-filling toward equal absolute fractions.
    MaxMin,
}

/// Final per-request allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub id: RequestId,
    pub rho_u: f64,
    pub rho_d: f64,
    /// Seconds to push the prompt at the allocated uplink rate.
    pub upload_time: f64,
    /// Seconds to push the output at the allocated downlink rate.
    pub download_time: f64,
}

/// Allocate both bands for a scheduled batch. Requires Σρ_min ≤ 1 per band
/// (the scheduler guarantees it); returns one `Allocation` per request in
/// input order.
pub fn allocate(
    batch: &[&EpochRequest],
    radio: &RadioParams,
    t_u: f64,
    t_d: f64,
    policy: AllocationPolicy,
) -> Vec<Allocation> {
    if batch.is_empty() {
        return Vec::new();
    }
    let rho_u = distribute(
        &batch.iter().map(|r| r.rho_min_u).collect::<Vec<_>>(),
        policy,
    );
    let rho_d = distribute(
        &batch.iter().map(|r| r.rho_min_d).collect::<Vec<_>>(),
        policy,
    );
    // At exactly ρ_min a transfer fills its slot by the definition of ρ_min;
    // report the slot time verbatim so the P1-baseline accounting is
    // bit-stable (floating round-trip through rate/bits would differ in the
    // last ulp).
    let exact_min = policy == AllocationPolicy::MinOnly;
    batch
        .iter()
        .zip(rho_u.iter().zip(rho_d.iter()))
        .map(|(r, (&u, &d))| {
            let up_rate = radio.uplink_rate(u, r.h); // bit/s
            let down_rate = radio.downlink_rate(d, r.h);
            let up_bits = r.req.prompt_tokens as f64 * radio.bits_per_token;
            let down_bits = r.req.output_tokens as f64 * radio.bits_per_token;
            Allocation {
                id: r.id(),
                rho_u: u,
                rho_d: d,
                // Positive-rate test so a NaN rate (NaN channel gain) falls
                // back to the slot time instead of propagating NaN.
                upload_time: if !exact_min && up_rate > 0.0 {
                    up_bits / up_rate
                } else {
                    t_u
                },
                download_time: if !exact_min && down_rate > 0.0 {
                    down_bits / down_rate
                } else {
                    t_d
                },
            }
        })
        .collect()
}

/// Distribute a unit band over users with minimum fractions `mins`.
fn distribute(mins: &[f64], policy: AllocationPolicy) -> Vec<f64> {
    let total_min: f64 = mins.iter().sum();
    let surplus = (1.0 - total_min).max(0.0);
    match policy {
        AllocationPolicy::MinOnly => mins.to_vec(),
        AllocationPolicy::Proportional => {
            if total_min <= 0.0 {
                return vec![1.0 / mins.len() as f64; mins.len()];
            }
            mins.iter()
                .map(|&m| m + surplus * m / total_min)
                .collect()
        }
        AllocationPolicy::MaxMin => water_fill(mins, surplus),
    }
}

/// Classic water-filling: raise the lowest allocations first until the
/// surplus is exhausted or all are equal (then split the remainder evenly).
fn water_fill(mins: &[f64], mut surplus: f64) -> Vec<f64> {
    let n = mins.len();
    let mut alloc = mins.to_vec();
    // Process levels in ascending order of current allocation.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| mins[a].total_cmp(&mins[b]));
    let mut i = 0;
    while surplus > 1e-15 && i < n {
        // Raise members order[0..=i] up to the next level (order[i+1]) or
        // spend the surplus evenly among them.
        let active = i + 1;
        let cur = alloc[order[i]];
        let next = if i + 1 < n { mins[order[i + 1]] } else { f64::INFINITY };
        let lift = (next - cur).min(surplus / active as f64);
        if lift <= 0.0 {
            i += 1;
            continue;
        }
        for &j in &order[..active] {
            alloc[j] += lift;
        }
        surplus -= lift * active as f64;
        if cur + lift >= next {
            i += 1;
        }
    }
    alloc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::RequestBuilder;

    fn batch(hs: &[f64], prompts: &[u32]) -> Vec<EpochRequest> {
        let mut b = RequestBuilder::new();
        let radio = RadioParams::default();
        hs.iter()
            .zip(prompts.iter())
            .map(|(&h, &s)| {
                EpochRequest::annotate(b.build(0.0, s, 128, 2.0, 0.2), h, &radio, 0.25, 0.25)
            })
            .collect()
    }

    fn total(allocs: &[Allocation], f: impl Fn(&Allocation) -> f64) -> f64 {
        allocs.iter().map(f).sum()
    }

    #[test]
    fn min_only_matches_rho_min() {
        let reqs = batch(&[1e-2, 1e-3], &[128, 512]);
        let refs: Vec<&EpochRequest> = reqs.iter().collect();
        let a = allocate(&refs, &RadioParams::default(), 0.25, 0.25, AllocationPolicy::MinOnly);
        for (al, r) in a.iter().zip(reqs.iter()) {
            assert_eq!(al.rho_u, r.rho_min_u);
            assert_eq!(al.rho_d, r.rho_min_d);
            // at rho_min the upload takes exactly T_U
            assert!((al.upload_time - 0.25).abs() < 1e-9);
        }
    }

    #[test]
    fn proportional_uses_whole_band_and_speeds_everyone() {
        let reqs = batch(&[1e-2, 1e-3, 5e-3], &[128, 512, 256]);
        let refs: Vec<&EpochRequest> = reqs.iter().collect();
        let a = allocate(
            &refs,
            &RadioParams::default(),
            0.25,
            0.25,
            AllocationPolicy::Proportional,
        );
        assert!((total(&a, |x| x.rho_u) - 1.0).abs() < 1e-9, "full band used");
        for (al, r) in a.iter().zip(reqs.iter()) {
            assert!(al.rho_u >= r.rho_min_u - 1e-12);
            assert!(al.upload_time <= 0.25 + 1e-12, "never slower than T_U");
        }
        // equal relative headroom => identical upload times
        for w in a.windows(2) {
            assert!((w[0].upload_time - w[1].upload_time).abs() < 1e-9);
        }
    }

    #[test]
    fn max_min_equalizes_fractions() {
        // user 0 has the better channel (smaller rho_min); water-filling
        // raises the lower allocations first, so with ample surplus both
        // end at the same absolute fraction and the lower-min user received
        // the larger lift.
        let reqs = batch(&[1e-2, 2e-4], &[256, 256]);
        assert!(reqs[0].rho_min_u < reqs[1].rho_min_u);
        let refs: Vec<&EpochRequest> = reqs.iter().collect();
        let a = allocate(
            &refs,
            &RadioParams::default(),
            0.25,
            0.25,
            AllocationPolicy::MaxMin,
        );
        assert!((total(&a, |x| x.rho_u) - 1.0).abs() < 1e-6);
        // water-filling equalizes absolute fractions when surplus is large
        assert!((a[0].rho_u - a[1].rho_u).abs() < 1e-6);
        let boost0 = a[0].rho_u - reqs[0].rho_min_u;
        let boost1 = a[1].rho_u - reqs[1].rho_min_u;
        assert!(boost0 > boost1);
        // the worse-channel user still uploads faster than T_U
        assert!(a[1].upload_time < 0.25);
    }

    #[test]
    fn water_fill_respects_surplus_budget() {
        let mins = [0.1, 0.2, 0.3];
        let out = water_fill(&mins, 0.15);
        let spent: f64 = out.iter().sum::<f64>() - mins.iter().sum::<f64>();
        assert!((spent - 0.15).abs() < 1e-12);
        // mins preserved
        for (o, m) in out.iter().zip(mins.iter()) {
            assert!(o >= m);
        }
        // lowest got raised first
        assert!(out[0] > mins[0] && (out[2] - mins[2]).abs() < 1e-12);
    }

    #[test]
    fn empty_batch() {
        let a = allocate(&[], &RadioParams::default(), 0.25, 0.25, AllocationPolicy::MaxMin);
        assert!(a.is_empty());
    }

    #[test]
    fn oversubscribed_mins_degrade_gracefully() {
        // If somehow rho_min sums above 1 (scheduler bug), surplus is 0 and
        // allocations equal mins.
        let mins = [0.7, 0.8];
        let out = distribute(&mins, AllocationPolicy::Proportional);
        assert_eq!(out, mins.to_vec());
    }
}
