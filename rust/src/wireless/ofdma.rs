//! OFDMA rate model and bandwidth accounting — paper §II-A.
//!
//! With thousands of sub-carriers, bandwidth splitting is treated as
//! continuous: user i receives fractions ρᵢᵁ, ρᵢᴰ of the uplink/downlink
//! bands. The transmission rate is
//!
//!   rᵢ = ρᵢ · B · log₂(1 + p·h² / N₀)
//!
//! with N₀ the total white-noise power over the band (paper's convention:
//! SNR independent of the allocated fraction).

use super::channel::{dbm_per_hz_to_w_per_hz, dbm_to_watts};

/// Static radio parameters of the edge node (defaults = paper §IV).
#[derive(Debug, Clone)]
pub struct RadioParams {
    /// Uplink band B^U in Hz (paper: 20 MHz).
    pub uplink_hz: f64,
    /// Downlink band B^D in Hz (paper: 20 MHz).
    pub downlink_hz: f64,
    /// User transmit power p_i^U in watts (paper: 20 dBm).
    pub uplink_tx_w: f64,
    /// EN transmit power p^D in watts (paper: 43 dBm).
    pub downlink_tx_w: f64,
    /// Noise density in W/Hz (paper: −174 dBm/Hz).
    pub noise_w_per_hz: f64,
    /// Bits used to encode one token over the air (2-byte BPE index).
    pub bits_per_token: f64,
}

impl Default for RadioParams {
    fn default() -> Self {
        RadioParams {
            uplink_hz: 20e6,
            downlink_hz: 20e6,
            uplink_tx_w: dbm_to_watts(20.0),
            downlink_tx_w: dbm_to_watts(43.0),
            noise_w_per_hz: dbm_per_hz_to_w_per_hz(-174.0),
            bits_per_token: 16.0,
        }
    }
}

impl RadioParams {
    /// Total noise power over a band of `band_hz`.
    fn noise_power(&self, band_hz: f64) -> f64 {
        self.noise_w_per_hz * band_hz
    }

    /// Uplink spectral efficiency log₂(1 + SNR) for channel amplitude h.
    pub fn uplink_se(&self, h: f64) -> f64 {
        (1.0 + self.uplink_tx_w * h * h / self.noise_power(self.uplink_hz)).log2()
    }

    /// Downlink spectral efficiency log₂(1 + SNR) for channel amplitude h.
    pub fn downlink_se(&self, h: f64) -> f64 {
        (1.0 + self.downlink_tx_w * h * h / self.noise_power(self.downlink_hz)).log2()
    }

    /// Uplink rate in bit/s for bandwidth fraction rho.
    pub fn uplink_rate(&self, rho: f64, h: f64) -> f64 {
        rho * self.uplink_hz * self.uplink_se(h)
    }

    /// Downlink rate in bit/s for bandwidth fraction rho.
    pub fn downlink_rate(&self, rho: f64, h: f64) -> f64 {
        rho * self.downlink_hz * self.downlink_se(h)
    }

    /// ρ_{i,min}^U — minimum uplink fraction to push `s_tokens` prompt tokens
    /// within the uplink slot T_U: ρ ≥ s_bits / (T_U · B^U · log₂(1+SNR)).
    pub fn rho_min_uplink(&self, s_tokens: u32, h: f64, t_u: f64) -> f64 {
        let bits = s_tokens as f64 * self.bits_per_token;
        bits / (t_u * self.uplink_hz * self.uplink_se(h))
    }

    /// ρ_{i,min}^D — minimum downlink fraction to push `n_tokens` output
    /// tokens within the downlink slot T_D.
    pub fn rho_min_downlink(&self, n_tokens: u32, h: f64, t_d: f64) -> f64 {
        let bits = n_tokens as f64 * self.bits_per_token;
        bits / (t_d * self.downlink_hz * self.downlink_se(h))
    }
}

/// Tracks cumulative bandwidth-fraction commitments within one epoch and
/// enforces Σρ ≤ 1 on each band — constraints (1a)/(1b).
#[derive(Debug, Clone, Default)]
pub struct BandwidthLedger {
    uplink_used: f64,
    downlink_used: f64,
}

impl BandwidthLedger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn uplink_used(&self) -> f64 {
        self.uplink_used
    }

    pub fn downlink_used(&self) -> f64 {
        self.downlink_used
    }

    /// Can both fractions still fit?
    pub fn fits(&self, rho_u: f64, rho_d: f64) -> bool {
        self.uplink_used + rho_u <= 1.0 + 1e-12 && self.downlink_used + rho_d <= 1.0 + 1e-12
    }

    /// Commit an allocation; returns false (and commits nothing) on overflow.
    pub fn alloc(&mut self, rho_u: f64, rho_d: f64) -> bool {
        if !self.fits(rho_u, rho_d) {
            return false;
        }
        self.uplink_used += rho_u;
        self.downlink_used += rho_d;
        true
    }

    /// Release an allocation (end of epoch).
    pub fn free(&mut self, rho_u: f64, rho_d: f64) {
        self.uplink_used = (self.uplink_used - rho_u).max(0.0);
        self.downlink_used = (self.downlink_used - rho_d).max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> RadioParams {
        RadioParams::default()
    }

    #[test]
    fn snr_magnitude_sane() {
        // h² = 1e-3, p=0.1W, N = 3.98e-21*20e6 = 7.96e-14 W
        // SNR = 0.1*1e-3/7.96e-14 ≈ 1.26e9 → SE ≈ 30 bit/s/Hz
        let se = params().uplink_se((1e-3f64).sqrt());
        assert!((25.0..35.0).contains(&se), "uplink SE {se}");
        let sed = params().downlink_se((1e-3f64).sqrt());
        assert!(sed > se, "downlink more powerful");
    }

    #[test]
    fn rate_linear_in_rho() {
        let p = params();
        let h = 0.03;
        let r1 = p.uplink_rate(0.1, h);
        let r2 = p.uplink_rate(0.2, h);
        assert!((r2 - 2.0 * r1).abs() < 1e-6);
    }

    #[test]
    fn rho_min_inverts_rate() {
        // Sending exactly s tokens at rho_min for T_U seconds delivers s bits.
        let p = params();
        let h = 0.02;
        let t_u = 0.25;
        let s = 512;
        let rho = p.rho_min_uplink(s, h, t_u);
        let delivered_bits = p.uplink_rate(rho, h) * t_u;
        assert!((delivered_bits - s as f64 * 16.0).abs() < 1e-6);
    }

    #[test]
    fn rho_min_monotonicity() {
        let p = params();
        let t = 0.25;
        // more tokens => more bandwidth
        assert!(p.rho_min_uplink(512, 0.02, t) > p.rho_min_uplink(128, 0.02, t));
        // better channel => less bandwidth
        assert!(p.rho_min_uplink(256, 0.01, t) > p.rho_min_uplink(256, 0.05, t));
        // longer slot => less bandwidth
        assert!(p.rho_min_uplink(256, 0.02, 0.1) > p.rho_min_uplink(256, 0.02, 0.5));
    }

    #[test]
    fn typical_rho_min_small() {
        // Paper-scale: 512 tokens, mean channel, 250 ms slot => tiny fraction,
        // so tens-to-hundreds of users can share the band.
        let p = params();
        let rho = p.rho_min_uplink(512, (1e-3f64).sqrt(), 0.25);
        assert!(rho < 1e-4, "rho_min {rho}");
    }

    #[test]
    fn ledger_enforces_unit_capacity() {
        let mut l = BandwidthLedger::new();
        assert!(l.alloc(0.6, 0.2));
        assert!(l.alloc(0.4, 0.2));
        assert!(!l.alloc(0.01, 0.0), "uplink exhausted");
        assert!(l.fits(0.0, 0.6));
        l.free(0.4, 0.2);
        assert!(l.alloc(0.2, 0.1));
    }

    #[test]
    fn ledger_free_clamps_at_zero() {
        let mut l = BandwidthLedger::new();
        l.alloc(0.1, 0.1);
        l.free(0.5, 0.5);
        assert_eq!(l.uplink_used(), 0.0);
        assert_eq!(l.downlink_used(), 0.0);
    }
}
