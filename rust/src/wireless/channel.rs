//! Wireless channel model — paper §II-A and §IV settings.
//!
//! Frequency non-selective channel whose gain h_i is constant within an
//! epoch (re-drawn each epoch, as the EN would re-measure via CSI-RS).
//! Small-scale fading is Rayleigh; large-scale attenuation is the paper's
//! flat 10⁻³ path loss.

use crate::util::rng::Rng;

/// Convert dBm to linear watts.
pub fn dbm_to_watts(dbm: f64) -> f64 {
    10f64.powf((dbm - 30.0) / 10.0)
}

/// Convert dBm/Hz noise density to watts/Hz.
pub fn dbm_per_hz_to_w_per_hz(dbm_hz: f64) -> f64 {
    dbm_to_watts(dbm_hz)
}

/// Channel parameters (defaults = paper §IV).
#[derive(Debug, Clone)]
pub struct ChannelParams {
    /// Large-scale path loss (power ratio). Paper: 1e-3.
    pub path_loss: f64,
    /// Rayleigh scale σ of the complex gain's magnitude; σ = 1/√2 gives a
    /// unit-mean-power (E[|g|²]=1) normalized fading coefficient.
    pub rayleigh_sigma: f64,
}

impl Default for ChannelParams {
    fn default() -> Self {
        ChannelParams {
            path_loss: 1e-3,
            rayleigh_sigma: std::f64::consts::FRAC_1_SQRT_2,
        }
    }
}

impl ChannelParams {
    /// Draw a channel amplitude h for one user for one epoch.
    ///
    /// h² (the power gain used in the SNR) equals path_loss · |g|² with
    /// |g| ~ Rayleigh(σ).
    pub fn draw_h(&self, rng: &mut Rng) -> f64 {
        let g = rng.rayleigh(self.rayleigh_sigma);
        (self.path_loss).sqrt() * g
    }

    /// Expected power gain E[h²] = path_loss · 2σ².
    pub fn mean_power_gain(&self) -> f64 {
        self.path_loss * 2.0 * self.rayleigh_sigma * self.rayleigh_sigma
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dbm_conversions() {
        assert!((dbm_to_watts(30.0) - 1.0).abs() < 1e-12);
        assert!((dbm_to_watts(20.0) - 0.1).abs() < 1e-12);
        assert!((dbm_to_watts(43.0) - 19.952).abs() < 1e-2);
        // -174 dBm/Hz thermal noise density
        let n0 = dbm_per_hz_to_w_per_hz(-174.0);
        assert!((n0 - 3.98e-21).abs() / 3.98e-21 < 0.01);
    }

    #[test]
    fn rayleigh_power_gain_mean() {
        let p = ChannelParams::default();
        let mut rng = Rng::new(42);
        let n = 100_000;
        let mean_h2: f64 = (0..n)
            .map(|_| {
                let h = p.draw_h(&mut rng);
                h * h
            })
            .sum::<f64>()
            / n as f64;
        // E[h²] = path_loss for unit-power fading
        assert!(
            (mean_h2 - p.mean_power_gain()).abs() / p.mean_power_gain() < 0.02,
            "mean_h2={mean_h2}"
        );
        assert!((p.mean_power_gain() - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn h_always_positive() {
        let p = ChannelParams::default();
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            assert!(p.draw_h(&mut rng) > 0.0);
        }
    }
}
