//! Wireless substrate — paper §II-A: Rayleigh channel with flat path loss,
//! OFDMA continuous bandwidth sharing, rate equation and ρ_min computation,
//! and per-epoch bandwidth accounting.

pub mod allocator;
pub mod channel;
pub mod ofdma;

pub use allocator::{allocate, Allocation, AllocationPolicy};
pub use channel::{dbm_to_watts, ChannelParams};
pub use ofdma::{BandwidthLedger, RadioParams};
