//! Pure-Rust CPU engine (default backend): executes the tiny transformer
//! directly from the ELLM weight container, mirroring the model semantics of
//! `python/compile/model.py` layer for layer — embedding lookup, LN-free
//! decoder layers (causal attention + ReLU FFN, both with residuals), tied
//! output embeddings with the manifest's `logit_scale`.
//!
//! Each sequence is computed independently (the mathematical result of the
//! padded batched graphs is identical, because padding rows never leak into
//! valid rows), which makes batch-variant invariance hold by construction.
//! The model is ~3.4 M parameters, so naive f32 matmuls serve sub-second
//! epochs comfortably on a CPU; this backend exists so the whole serving
//! stack — scheduler, driver, epoch server — runs end-to-end with zero
//! external crates. Enable the `pjrt` feature for the XLA-compiled path.

use crate::runtime::artifact::{load_weights, Meta, Tensor};
use crate::runtime::engine::{argmax, EngineError};
use std::path::Path;

type Result<T> = std::result::Result<T, EngineError>;

/// The KV cache of one in-flight batch. `k[layer][seq]` is a
/// `[max_seq, d_model]` row-major slab; slot `t` holds the head-concatenated
/// K (resp. V) vector of position `t`.
pub struct KvCache {
    /// Number of real sequences in the batch.
    pub active: usize,
    /// Loaded batch variant this cache is shaped for.
    pub batch: usize,
    /// Per-sequence next write position (= current length).
    pub pos: Vec<i32>,
    max_seq: usize,
    d_model: usize,
    k: Vec<Vec<Vec<f32>>>,
    v: Vec<Vec<Vec<f32>>>,
}

impl KvCache {
    fn new(layers: usize, active: usize, batch: usize, max_seq: usize, d_model: usize) -> Self {
        let slab = || {
            (0..active)
                .map(|_| vec![0f32; max_seq * d_model])
                .collect::<Vec<_>>()
        };
        KvCache {
            active,
            batch,
            pos: vec![0; active],
            max_seq,
            d_model,
            k: (0..layers).map(|_| slab()).collect(),
            v: (0..layers).map(|_| slab()).collect(),
        }
    }

    /// Write one position's K/V vectors for (layer, seq, slot).
    fn write_slot(&mut self, layer: usize, seq: usize, slot: usize, k: &[f32], v: &[f32]) {
        let dm = k.len();
        self.k[layer][seq][slot * dm..(slot + 1) * dm].copy_from_slice(k);
        self.v[layer][seq][slot * dm..(slot + 1) * dm].copy_from_slice(v);
    }

    /// Append a fresh zeroed slot for one more sequence (continuous
    /// batching: mid-flight admission). Returns the new sequence index.
    /// Capacity against the engine's batch variants is the engine's job
    /// (`Engine::prefill_into`); the cache itself just grows.
    fn admit_slot(&mut self) -> usize {
        let seq = self.active;
        for layer in self.k.iter_mut() {
            layer.push(vec![0f32; self.max_seq * self.d_model]);
        }
        for layer in self.v.iter_mut() {
            layer.push(vec![0f32; self.max_seq * self.d_model]);
        }
        self.pos.push(0);
        self.active += 1;
        seq
    }

    /// Evict sequence `seq`, returning its KV slot to the pool (continuous
    /// batching: completion releases headroom). Uses swap-remove semantics:
    /// the *last* sequence moves into index `seq`, so a caller tracking a
    /// parallel per-sequence vector stays aligned by calling its own
    /// `swap_remove(seq)` in the same breath.
    pub fn release(&mut self, seq: usize) {
        assert!(seq < self.active, "release of inactive slot {seq}");
        for layer in self.k.iter_mut() {
            layer.swap_remove(seq);
        }
        for layer in self.v.iter_mut() {
            layer.swap_remove(seq);
        }
        self.pos.swap_remove(seq);
        self.active -= 1;
    }
}

/// The weight-loaded model, ready to serve (CPU, std-only).
pub struct Engine {
    pub meta: Meta,
    pub quant_label: String,
    /// Tensors in canonical parameter order: `embed`, then per layer
    /// `wq, wk, wv, wo, w1, w2`.
    params: Vec<Tensor>,
    /// Loaded batch variants (sorted ascending).
    variants: Vec<usize>,
}

impl Engine {
    /// Load the manifest and one weight variant for every declared batch
    /// variant.
    pub fn load(artifact_dir: &Path, quant_label: &str) -> Result<Engine> {
        let meta = Meta::load(artifact_dir).map_err(EngineError::Artifact)?;
        let variants = meta.batch_variants.clone();
        Self::load_with_variants(artifact_dir, quant_label, &variants)
    }

    /// Load with a subset of batch variants (API parity with the PJRT
    /// backend, where each variant costs a compilation; here the list only
    /// bounds `max_batch`).
    pub fn load_with_variants(
        artifact_dir: &Path,
        quant_label: &str,
        variants: &[usize],
    ) -> Result<Engine> {
        let meta = Meta::load(artifact_dir).map_err(EngineError::Artifact)?;
        let weights_path = meta
            .weights_path(quant_label)
            .map_err(EngineError::Artifact)?;
        let tensors = load_weights(&weights_path).map_err(EngineError::Artifact)?;
        if tensors.len() != meta.param_order.len() {
            return Err(EngineError::Artifact(format!(
                "weight container has {} tensors, meta declares {}",
                tensors.len(),
                meta.param_order.len()
            )));
        }
        // The forward pass indexes params as embed + 6 per layer; a
        // layers/param_order mismatch must fail at load, not panic on the
        // request path.
        if tensors.len() != 1 + 6 * meta.layers {
            return Err(EngineError::Artifact(format!(
                "manifest declares {} layers (expecting {} tensors) but the \
                 container holds {}",
                meta.layers,
                1 + 6 * meta.layers,
                tensors.len()
            )));
        }
        // Validate every tensor's shape against the manifest-derived layout
        // (the forward pass trusts these shapes; a mismatch must fail here,
        // not panic or mis-multiply on the request path).
        for (i, t) in tensors.iter().enumerate() {
            let expect: Vec<usize> = if i == 0 {
                vec![meta.vocab, meta.d_model]
            } else {
                match (i - 1) % 6 {
                    4 => vec![meta.d_model, meta.d_ff],  // w1
                    5 => vec![meta.d_ff, meta.d_model],  // w2
                    _ => vec![meta.d_model, meta.d_model], // wq/wk/wv/wo
                }
            };
            if t.dims != expect {
                return Err(EngineError::Artifact(format!(
                    "tensor {} (`{}`) has shape {:?}, manifest implies {:?}",
                    i, t.name, t.dims, expect
                )));
            }
        }
        let mut variants: Vec<usize> = variants.iter().copied().filter(|&b| b > 0).collect();
        variants.sort_unstable();
        variants.dedup();
        if variants.is_empty() {
            return Err(EngineError::Artifact("no batch variants requested".into()));
        }
        Ok(Engine {
            meta,
            quant_label: quant_label.to_string(),
            params: tensors,
            variants,
        })
    }

    /// Largest batch the engine can run in one call.
    pub fn max_batch(&self) -> usize {
        self.variants.last().copied().unwrap_or(0)
    }

    /// Smallest loaded variant that fits `n` sequences.
    fn variant_for(&self, n: usize) -> Result<usize> {
        self.variants
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or(EngineError::BatchTooLarge(n, self.max_batch()))
    }

    pub fn platform(&self) -> String {
        "host-cpu".to_string()
    }

    fn layer_weights(&self, l: usize) -> [&Tensor; 6] {
        let base = 1 + 6 * l;
        [
            &self.params[base],
            &self.params[base + 1],
            &self.params[base + 2],
            &self.params[base + 3],
            &self.params[base + 4],
            &self.params[base + 5],
        ]
    }

    fn embed_row(&self, token: i32) -> &[f32] {
        let dm = self.meta.d_model;
        // Out-of-range ids clamp, matching XLA gather semantics.
        let id = (token.max(0) as usize).min(self.meta.vocab - 1);
        &self.params[0].data[id * dm..(id + 1) * dm]
    }

    /// Tied-embedding logits for one hidden state: `x @ embed.T * scale`.
    fn logits_for(&self, x: &[f32]) -> Vec<f32> {
        let dm = self.meta.d_model;
        let scale = self.meta.logit_scale as f32;
        let embed = &self.params[0].data;
        (0..self.meta.vocab)
            .map(|t| dot(x, &embed[t * dm..(t + 1) * dm]) * scale)
            .collect()
    }

    /// Initial Stage over up to `max_batch` prompts. Returns per-prompt
    /// last-position logits and the batch KV cache.
    pub fn prefill(&self, prompts: &[Vec<i32>]) -> Result<(Vec<Vec<f32>>, KvCache)> {
        let n = prompts.len();
        if n == 0 {
            return Err(EngineError::Other("empty prefill batch".into()));
        }
        let b = self.variant_for(n)?;
        let s_max = self.meta.max_prompt;
        for (i, p) in prompts.iter().enumerate() {
            if p.is_empty() || p.len() > s_max {
                return Err(EngineError::Other(format!(
                    "prompt {i} length {} out of range 1..={s_max}",
                    p.len()
                )));
            }
        }
        let mut cache = KvCache::new(self.meta.layers, n, b, self.meta.max_seq, self.meta.d_model);
        let mut logits = Vec::with_capacity(n);
        for (i, p) in prompts.iter().enumerate() {
            logits.push(self.prefill_one(i, p, &mut cache));
        }
        cache.pos = prompts.iter().map(|p| p.len() as i32).collect();
        Ok((logits, cache))
    }

    fn prefill_one(&self, seq: usize, prompt: &[i32], cache: &mut KvCache) -> Vec<f32> {
        let dm = self.meta.d_model;
        let df = self.meta.d_ff;
        let s = prompt.len();
        let mut x = vec![0f32; s * dm];
        for (t, &tok) in prompt.iter().enumerate() {
            x[t * dm..(t + 1) * dm].copy_from_slice(self.embed_row(tok));
        }
        for l in 0..self.meta.layers {
            let [wq, wk, wv, wo, w1, w2] = self.layer_weights(l);
            let q = matmul(&x, s, dm, &wq.data, dm);
            let k = matmul(&x, s, dm, &wk.data, dm);
            let v = matmul(&x, s, dm, &wv.data, dm);
            let att = causal_attention(&q, &k, &v, s, self.meta.n_heads, self.meta.d_head);
            let mut x_out = matmul(&att, s, dm, &wo.data, dm);
            add_assign(&mut x_out, &x);
            let mut h = matmul(&x_out, s, dm, &w1.data, df);
            relu(&mut h);
            let mut x_next = matmul(&h, s, df, &w2.data, dm);
            add_assign(&mut x_next, &x_out);
            x = x_next;
            for t in 0..s {
                cache.write_slot(l, seq, t, &k[t * dm..(t + 1) * dm], &v[t * dm..(t + 1) * dm]);
            }
        }
        self.logits_for(&x[(s - 1) * dm..s * dm])
    }

    /// Admit one more prompt into a *running* batch (continuous batching):
    /// grows the cache by a slot, prefills the new sequence, and returns its
    /// last-position logits. The sequences already in flight are untouched —
    /// each sequence's computation is independent, so mid-flight admission
    /// is mathematically identical to having co-batched from the start.
    /// Fails with `BatchTooLarge` when the engine's largest loaded batch
    /// variant is already full.
    pub fn prefill_into(&self, prompt: &[i32], cache: &mut KvCache) -> Result<Vec<f32>> {
        if prompt.is_empty() || prompt.len() > self.meta.max_prompt {
            return Err(EngineError::Other(format!(
                "prompt length {} out of range 1..={}",
                prompt.len(),
                self.meta.max_prompt
            )));
        }
        let b = self.variant_for(cache.active + 1)?;
        let seq = cache.admit_slot();
        let logits = self.prefill_one(seq, prompt, cache);
        cache.pos[seq] = prompt.len() as i32;
        cache.batch = b;
        Ok(logits)
    }

    /// One Auto-regressive Stage step for every active sequence in `cache`.
    pub fn decode(&self, tokens: &[i32], cache: &mut KvCache) -> Result<Vec<Vec<f32>>> {
        if tokens.len() != cache.active {
            return Err(EngineError::Other(format!(
                "decode got {} tokens for {} active sequences",
                tokens.len(),
                cache.active
            )));
        }
        if cache.pos.iter().any(|&p| p as usize >= self.meta.max_seq) {
            return Err(EngineError::Other(
                "KV cache exhausted (sequence reached max_seq)".into(),
            ));
        }
        let mut logits = Vec::with_capacity(cache.active);
        for (i, &tok) in tokens.iter().enumerate() {
            logits.push(self.decode_one(i, tok, cache));
        }
        for p in cache.pos.iter_mut() {
            *p += 1;
        }
        Ok(logits)
    }

    fn decode_one(&self, seq: usize, token: i32, cache: &mut KvCache) -> Vec<f32> {
        let dm = self.meta.d_model;
        let df = self.meta.d_ff;
        let nh = self.meta.n_heads;
        let dh = self.meta.d_head;
        let pos = cache.pos[seq] as usize;
        let scale = 1.0 / (dh as f32).sqrt();
        let mut x = self.embed_row(token).to_vec();
        for l in 0..self.meta.layers {
            let [wq, wk, wv, wo, w1, w2] = self.layer_weights(l);
            let q = matmul(&x, 1, dm, &wq.data, dm);
            let k_new = matmul(&x, 1, dm, &wk.data, dm);
            let v_new = matmul(&x, 1, dm, &wv.data, dm);
            cache.write_slot(l, seq, pos, &k_new, &v_new);
            // Attend to cache slots 0..=pos, head by head.
            let kc = &cache.k[l][seq];
            let vc = &cache.v[l][seq];
            let mut att = vec![0f32; dm];
            for h in 0..nh {
                let off = h * dh;
                let qh = &q[off..off + dh];
                let mut scores = Vec::with_capacity(pos + 1);
                let mut m = f32::NEG_INFINITY;
                for j in 0..=pos {
                    let sc = dot(qh, &kc[j * dm + off..j * dm + off + dh]) * scale;
                    if sc > m {
                        m = sc;
                    }
                    scores.push(sc);
                }
                let mut denom = 0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - m).exp();
                    denom += *sc;
                }
                for (j, &w) in scores.iter().enumerate() {
                    let vr = &vc[j * dm + off..j * dm + off + dh];
                    let w = w / denom;
                    for (o, &vv) in att[off..off + dh].iter_mut().zip(vr.iter()) {
                        *o += w * vv;
                    }
                }
            }
            let mut x_out = matmul(&att, 1, dm, &wo.data, dm);
            add_assign(&mut x_out, &x);
            let mut hid = matmul(&x_out, 1, dm, &w1.data, df);
            relu(&mut hid);
            let mut x_next = matmul(&hid, 1, df, &w2.data, dm);
            add_assign(&mut x_next, &x_out);
            x = x_next;
        }
        self.logits_for(&x)
    }

    /// Greedy generation: prefill + `steps` decode iterations, stopping a
    /// sequence early when it emits `eos` (if provided).
    pub fn generate_greedy(
        &self,
        prompts: &[Vec<i32>],
        steps: usize,
        eos: Option<i32>,
    ) -> Result<Vec<Vec<i32>>> {
        let (logits, mut cache) = self.prefill(prompts)?;
        let n = prompts.len();
        let mut out: Vec<Vec<i32>> = vec![Vec::new(); n];
        let mut done = vec![false; n];
        let mut next: Vec<i32> = logits.iter().map(|row| argmax(row)).collect();
        for _ in 0..steps {
            for i in 0..n {
                if !done[i] {
                    out[i].push(next[i]);
                    if Some(next[i]) == eos {
                        done[i] = true;
                    }
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
            let logits = self.decode(&next, &mut cache)?;
            next = logits.iter().map(|row| argmax(row)).collect();
        }
        Ok(out)
    }
}

/// Row-major `[m, k] @ [k, n]` with k-ascending accumulation (the same
/// reduction order as a per-element dot product).
fn matmul(x: &[f32], m: usize, k: usize, w: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let xrow = &x[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &xv) in xrow.iter().enumerate() {
            let wrow = &w[kk * n..(kk + 1) * n];
            for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                *o += xv * wv;
            }
        }
    }
    out
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b.iter()).map(|(x, y)| x * y).sum()
}

fn add_assign(a: &mut [f32], b: &[f32]) {
    for (x, y) in a.iter_mut().zip(b.iter()) {
        *x += y;
    }
}

fn relu(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
}

/// Masked causal attention over a whole prompt (Initial Stage), matching
/// `attention_prefill_ref` in python/compile/kernels/ref.py.
fn causal_attention(q: &[f32], k: &[f32], v: &[f32], s: usize, nh: usize, dh: usize) -> Vec<f32> {
    let dm = nh * dh;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = vec![0f32; s * dm];
    for h in 0..nh {
        let off = h * dh;
        for i in 0..s {
            let qi = &q[i * dm + off..i * dm + off + dh];
            let mut scores = Vec::with_capacity(i + 1);
            let mut m = f32::NEG_INFINITY;
            for j in 0..=i {
                let sc = dot(qi, &k[j * dm + off..j * dm + off + dh]) * scale;
                if sc > m {
                    m = sc;
                }
                scores.push(sc);
            }
            let mut denom = 0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - m).exp();
                denom += *sc;
            }
            let orow = &mut out[i * dm + off..i * dm + off + dh];
            for (j, &w) in scores.iter().enumerate() {
                let vr = &v[j * dm + off..j * dm + off + dh];
                let w = w / denom;
                for (o, &vv) in orow.iter_mut().zip(vr.iter()) {
                    *o += w * vv;
                }
            }
        }
    }
    out
}

/// Build a tiny deterministic in-memory engine (no artifacts on disk) —
/// shared by this module's tests and the serving layer's continuous-mode
/// tests, so the real decode loop gets CI coverage without `make artifacts`.
#[cfg(test)]
pub(crate) fn test_engine() -> Engine {
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    let (vocab, layers, dm, nh, dh, df) = (32usize, 2usize, 16usize, 2usize, 8usize, 32usize);
    let meta = Meta {
        model_name: "tiny-test".into(),
        vocab,
        layers,
        d_model: dm,
        n_heads: nh,
        d_head: dh,
        d_ff: df,
        max_prompt: 8,
        max_seq: 16,
        logit_scale: 8.0,
        batch_variants: vec![1, 2, 4],
        param_order: Vec::new(),
        programs: Vec::new(),
        weights: BTreeMap::new(),
        dir: PathBuf::new(),
    };
    let mut rng = Rng::new(0xE2E);
    let mut tensor = |name: &str, dims: Vec<usize>, scale: f64| {
        let n: usize = dims.iter().product();
        Tensor {
            name: name.into(),
            dims,
            data: (0..n)
                .map(|_| (rng.gaussian() * scale) as f32)
                .collect(),
        }
    };
    let mut params = vec![tensor("embed", vec![vocab, dm], 0.25)];
    for l in 0..layers {
        for w in ["wq", "wk", "wv", "wo", "w1", "w2"] {
            let dims = match w {
                "w1" => vec![dm, df],
                "w2" => vec![df, dm],
                _ => vec![dm, dm],
            };
            params.push(tensor(&format!("layer{l}.{w}"), dims, 0.25));
        }
    }
    Engine {
        meta,
        quant_label: "W16A16".into(),
        params,
        variants: vec![1, 2, 4],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_engine() -> Engine {
        test_engine()
    }

    #[test]
    fn prefill_shapes_and_determinism() {
        let e = tiny_engine();
        let prompts = vec![vec![1, 2, 3], vec![4, 5, 6, 7]];
        let (l1, c1) = e.prefill(&prompts).unwrap();
        let (l2, _c2) = e.prefill(&prompts).unwrap();
        assert_eq!(l1.len(), 2);
        assert_eq!(l1[0].len(), e.meta.vocab);
        assert_eq!(l1, l2, "prefill must be deterministic");
        assert_eq!(c1.active, 2);
        assert_eq!(c1.pos, vec![3, 4]);
        assert!(l1[0].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn batch_invariance() {
        let e = tiny_engine();
        let solo = e.generate_greedy(&[vec![3, 1, 4]], 5, None).unwrap();
        let batched = e
            .generate_greedy(&[vec![3, 1, 4], vec![9, 9], vec![2; 6]], 5, None)
            .unwrap();
        assert_eq!(solo[0], batched[0], "co-batched prompts must not leak");
        assert!(batched.iter().all(|g| g.len() == 5));
        assert!(batched
            .iter()
            .all(|g| g.iter().all(|&t| (0..e.meta.vocab as i32).contains(&t))));
    }

    #[test]
    fn decode_advances_and_cache_exhausts() {
        let e = tiny_engine();
        let (logits, mut cache) = e.prefill(&[vec![1; e.meta.max_prompt]]).unwrap();
        let mut next = vec![argmax(&logits[0])];
        let budget = e.meta.max_seq - e.meta.max_prompt;
        for _ in 0..budget {
            let l = e.decode(&next, &mut cache).unwrap();
            next = vec![argmax(&l[0])];
        }
        assert!(e.decode(&next, &mut cache).is_err(), "cache must exhaust");
    }

    #[test]
    fn rejects_bad_inputs() {
        let e = tiny_engine();
        assert!(e.prefill(&[]).is_err());
        assert!(e.prefill(&[vec![]]).is_err());
        assert!(e.prefill(&[vec![1; e.meta.max_prompt + 1]]).is_err());
        let too_many: Vec<Vec<i32>> = (0..e.max_batch() + 1).map(|_| vec![1]).collect();
        assert!(matches!(
            e.prefill(&too_many),
            Err(EngineError::BatchTooLarge(5, 4))
        ));
        let (_, mut cache) = e.prefill(&[vec![1, 2]]).unwrap();
        assert!(e.decode(&[1, 2], &mut cache).is_err(), "token count mismatch");
    }

    #[test]
    fn mid_flight_admission_matches_solo_run() {
        // A prompt admitted into a running batch must generate exactly what
        // it would have generated alone — continuous batching adds
        // scheduling, not nondeterminism.
        let e = tiny_engine();
        let late_prompt = vec![4, 5];
        let want = e.generate_greedy(&[late_prompt.clone()], 4, None).unwrap()[0].clone();

        let (logits, mut cache) = e.prefill(&[vec![1, 2, 3]]).unwrap();
        let mut next0 = argmax(&logits[0]);
        // Sequence 0 decodes one step before the newcomer shows up.
        let l = e.decode(&[next0], &mut cache).unwrap();
        next0 = argmax(&l[0]);
        // Mid-flight admission.
        let l1 = e.prefill_into(&late_prompt, &mut cache).unwrap();
        assert_eq!(cache.active, 2);
        assert_eq!(cache.pos[1], late_prompt.len() as i32);
        let mut next1 = argmax(&l1);
        let mut got = vec![next1];
        while got.len() < 4 {
            let l = e.decode(&[next0, next1], &mut cache).unwrap();
            next0 = argmax(&l[0]);
            next1 = argmax(&l[1]);
            got.push(next1);
        }
        assert_eq!(got, want, "mid-flight admission must not perturb output");
    }

    #[test]
    fn release_returns_slot_and_keeps_others_running() {
        let e = tiny_engine();
        let solo = e.generate_greedy(&[vec![7, 3, 1]], 5, None).unwrap()[0].clone();
        let (logits, mut cache) = e.prefill(&[vec![2, 2], vec![7, 3, 1]]).unwrap();
        let mut next = vec![argmax(&logits[0]), argmax(&logits[1])];
        let mut got = vec![next[1]];
        // One joint step, then sequence 0 completes and is evicted.
        let l = e.decode(&next, &mut cache).unwrap();
        next = vec![argmax(&l[0]), argmax(&l[1])];
        got.push(next[1]);
        cache.release(0);
        assert_eq!(cache.active, 1);
        // Sequence 1 moved into slot 0 (swap-remove) and keeps decoding.
        let mut next1 = next[1];
        while got.len() < 5 {
            let l = e.decode(&[next1], &mut cache).unwrap();
            next1 = argmax(&l[0]);
            got.push(next1);
        }
        assert_eq!(got, solo, "eviction must not disturb surviving sequences");
    }

    #[test]
    fn prefill_into_enforces_batch_capacity() {
        let e = tiny_engine();
        let prompts: Vec<Vec<i32>> = (0..e.max_batch()).map(|i| vec![1 + i as i32]).collect();
        let (_, mut cache) = e.prefill(&prompts).unwrap();
        assert!(matches!(
            e.prefill_into(&[9], &mut cache),
            Err(EngineError::BatchTooLarge(5, 4))
        ));
        // Releasing one slot makes room again.
        cache.release(1);
        assert!(e.prefill_into(&[9], &mut cache).is_ok());
        assert_eq!(cache.active, e.max_batch());
        // Shape validation still applies mid-flight.
        assert!(e.prefill_into(&[], &mut cache).is_err());
    }

    #[test]
    fn out_of_vocab_tokens_clamp() {
        let e = tiny_engine();
        let a = e.prefill(&[vec![e.meta.vocab as i32 + 100]]).unwrap().0;
        let b = e.prefill(&[vec![e.meta.vocab as i32 - 1]]).unwrap().0;
        assert_eq!(a, b, "ids past the vocabulary clamp to the last row");
    }

    #[test]
    fn matmul_matches_manual() {
        // [2,3] @ [3,2]
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let out = matmul(&x, 2, 3, &w, 2);
        assert_eq!(out, vec![4.0, 5.0, 10.0, 11.0]);
    }

    #[test]
    fn attention_rows_are_convex_combinations() {
        // With q = 0, attention weights are uniform over visible slots, so
        // row i equals the mean of v[0..=i] per head.
        let (s, nh, dh) = (3usize, 1usize, 4usize);
        let dm = nh * dh;
        let q = vec![0f32; s * dm];
        let k: Vec<f32> = (0..s * dm).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..s * dm).map(|i| (i % 7) as f32).collect();
        let out = causal_attention(&q, &k, &v, s, nh, dh);
        for d in 0..dm {
            let mean01 = (v[d] + v[dm + d]) / 2.0;
            assert!((out[dm + d] - mean01).abs() < 1e-5);
            assert!((out[d] - v[d]).abs() < 1e-6, "first row attends to itself only");
        }
    }
}
